"""Export a Perfetto-loadable trace from a bursty heavy-mix serve run.

The cell is deliberately hostile: an MMPP burst process over the heavy
model pool at 4.4x the per-array service rate, deadline-driven preemption
armed, and an aggressive rebalance cadence — so the exported timeline
shows everything the tracer captures: per-tenant stage-in / compute /
stage-out slices on each array node's track, drain spans where a
preemption cut a segment short, and instant markers for every dispatch
choice, preemption and cross-node migration.

    PYTHONPATH=src python examples/trace_viewer.py
    # then open trace_viewer.perfetto-trace.json at https://ui.perfetto.dev

Spans derive from the scheduler's ``keep_trace=True`` records; per-job
instants derive from the job records the run builds anyway — so the run
itself pays almost nothing for the trace (see ``benchmarks/obs_bench.py``
for the gated overhead numbers).
"""

from repro.api import (RebalanceConfig, SchedulingConfig, ServeConfig,
                       Session, resolve_backend)
from repro.core.partition import Partition
from repro.obs import Observability
from repro.sim.workloads import MODEL_POOLS, MODELS

OUT = "trace_viewer.perfetto-trace.json"


def mean_service_s(pool):
    """Mean full-array sequential time of one job from ``pool`` — the
    load normaliser (arrival rate = per-array load / service time)."""
    b = resolve_backend("sim")
    time_fn, stage = b.time_fn(), b.stage_model()
    full = Partition(rows=b.array.rows, col_start=0, cols=b.array.cols)
    times = []
    for name in MODEL_POOLS[pool]:
        g = MODELS[name]()
        times.append(sum(stage.stage_in_s(ls) + time_fn(ls, full)
                         + stage.stage_out_s(ls) for ls in g.layers))
    return sum(times) / len(times)


svc = mean_service_s("heavy")
rate = 4 * 1.1 / svc  # 1.1x load across 4 arrays

cfg = ServeConfig(
    scheduling=SchedulingConfig(n_arrays=4, dispatch="jsq",
                                max_concurrent=4, queue_cap=8, seed=0,
                                preemption=True, keep_trace=True),
    rebalance=RebalanceConfig(interval=1e-3),
    obs=Observability(sample_every=1))

res = Session(policy="deadline_preempt", backend="sim").serve(
    "mmpp", config=cfg, rate=rate, horizon=240 / rate,
    pool="heavy", slo_s=3 * svc, burst_factor=6.0)

print(res.timeline.render(title="bursty heavy mix, 4 arrays"))

blob = res.timeline.write_chrome_trace(OUT)
kinds = res.timeline.tracer.counts_by_kind()
print(f"\nwrote {OUT}: {len(blob['traceEvents'])} trace events "
      f"({kinds.get('preempt', 0)} preemptions, "
      f"{kinds.get('migrate', 0)} migrations) "
      f"-- open it at https://ui.perfetto.dev")

with open("trace_viewer.timeline.csv", "w") as f:
    f.write(res.timeline.timeline_csv())
print("wrote trace_viewer.timeline.csv (per-node utilization/queue series)")
