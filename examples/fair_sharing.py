"""Fair sharing: equal vs DRF vs min-cost flow on a bursty heavy mix.

    PYTHONPATH=src python examples/fair_sharing.py [--trace] [--sharded]

Width-equal splitting looks fair but isn't: a tenant whose layers hammer
the stage-in bus gets the same columns as a compute-bound one and both
stall differently, so per-tenant slowdown (latency vs an isolated run of
the same model on the full array) spreads wide.  This example serves the
*identical* bursty MMPP stream over the paper's heavy pool under three
policies and prints the fairness view next to the SLA view:

* ``equal``         — the paper's baseline width split;
* ``drf``           — dominant-resource fairness over (columns, stage-in
  bus share, SRAM footprint): progressive filling grants columns to the
  tenant with the smallest dominant share, so bus-bound and compute-bound
  tenants equalize on the resource each actually saturates;
* ``min_cost_flow`` — tenants -> partitions as a min-cost max-flow over
  the batch cost oracle: globally cheapest assignment, fairness emergent.

``--trace`` replays a synthetic Alibaba ``batch_instance``-style CSV
(``synth_batch_instance_rows``) instead of MMPP — the production-trace
path.  ``--sharded`` reruns the winner through the sharded fleet
simulator (4 pods over 8 arrays) to show the deterministic-merge path.
"""

import argparse

from repro.api import Session

RATE = 1000.0     # jobs/s — ~0.9 rho over 2 arrays; bursts push past 1.0
HORIZON = 0.3     # s of simulated arrivals (~300 jobs)
SLO_S = 0.01      # deadline: arrival + 10 ms (tier-scaled)
POLICIES = ("equal", "drf", "min_cost_flow")


def _row(policy, res):
    m = res.metrics
    rep = res.fairness
    print(f"{policy:>14}{m.jobs_arrived:>6}{m.p99_latency_s*1e3:>9.1f}"
          f"{m.deadline_miss_rate*100:>7.1f}{rep.jain_fairness:>7.3f}"
          f"{max(rep.per_tenant_slowdown.values()):>9.1f}"
          f"{sum(rep.per_tenant_slowdown.values()) / len(rep.per_tenant_slowdown):>9.1f}")


def main() -> None:
    parser = argparse.ArgumentParser(description="fair-sharing demo")
    parser.add_argument("--trace", action="store_true",
                        help="replay a synthetic batch_instance CSV "
                             "instead of the MMPP stream")
    parser.add_argument("--sharded", action="store_true",
                        help="rerun one cell through the sharded fleet "
                             "simulator (4 pods / 8 arrays)")
    args = parser.parse_args()

    if args.trace:
        from repro.traffic import synth_batch_instance_rows
        rows = synth_batch_instance_rows(400, seed=0)
        arrivals, kwargs = "batch_instance", dict(source=rows, pool="heavy",
                                                  slo_s=SLO_S, seed=0)
        print(f"batch_instance trace replay: {len(rows) - 1} rows, "
              f"pool=heavy, SLO={SLO_S*1e3:.0f}ms\n")
    else:
        arrivals, kwargs = "mmpp", dict(rate=RATE, horizon=HORIZON, seed=0,
                                        pool="heavy", slo_s=SLO_S,
                                        tiers=(0, 1))
        print(f"MMPP bursty open-loop: mean rate={RATE:.0f} jobs/s, "
              f"horizon={HORIZON}s, SLO={SLO_S*1e3:.0f}ms, pool=heavy\n")

    print(f"{'policy':>14}{'jobs':>6}{'p99ms':>9}{'miss%':>7}{'jain':>7}"
          f"{'slo_max':>9}{'slo_mu':>9}")
    results = {}
    for policy in POLICIES:
        res = Session(policy=policy, backend="sim").serve(
            arrivals, n_arrays=2, dispatch="jsq", fairness=True, **kwargs)
        results[policy] = res
        _row(policy, res)

    best = max(POLICIES, key=lambda p: results[p].fairness.jain_fairness)
    print(f"\nhighest Jain fairness: {best} "
          f"({results[best].fairness.jain_fairness:.3f} vs "
          f"{results['equal'].fairness.jain_fairness:.3f} for equal)")
    print("per-tenant slowdown under", best, "(latency / isolated run):")
    for model, s in sorted(results[best].fairness.per_tenant_slowdown.items()):
        print(f"  {model:<18}{s:>8.1f}x")

    if args.sharded:
        from repro.traffic import serve_sharded
        print(f"\nsharded rerun of {best}: 8 arrays, 4 pods, rr dispatch "
              f"(byte-identical to the single-process simulator):")
        res = serve_sharded(arrivals, policy=best, backend="sim",
                            n_arrays=8, n_shards=4, dispatch="rr",
                            fairness=True, **kwargs)
        m = res.metrics
        print(f"  p99 {m.p99_latency_s*1e3:.1f}ms, "
              f"miss {m.deadline_miss_rate*100:.1f}%, "
              f"jain {m.jain_fairness:.3f}")


if __name__ == "__main__":
    main()
