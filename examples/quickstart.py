"""Quickstart: the paper's algorithm end-to-end in 60 seconds on CPU.

1. Reproduce Fig. 9 (heavy workload) through `repro.api.Session`: dynamic
   partitioning vs sequential, then compare partition policies.
2. Run the fused multi-tenant Pallas GEMM (interpret mode) and check it
   against the oracle.
3. Train a reduced llama3.2-3b for 30 steps and watch the loss drop.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

# -- 1. the paper's simulation, via the API front door -------------------
from repro.api import Session, list_policies
from repro.sim.runner import format_report

print("=" * 70)
print("1) Fig. 9 reproduction — heavy workload (policy='equal' = Alg. 1)")
print("=" * 70)
res = Session(policy="equal", backend="sim").run("heavy")
print(format_report(res))

print()
print("policy comparison (heavy):")
for pol in list_policies():
    r = Session(policy=pol, backend="sim").run("heavy")
    print(f"  {pol:<14} time saving {r.time_saving*100:5.1f}%  "
          f"energy saving {r.energy_saving*100:5.1f}%")

# -- 2. the kernel -------------------------------------------------------
from repro.kernels import fused_tenant_gemm

print()
print("=" * 70)
print("2) fused multi-tenant partitioned-WS GEMM (Pallas, interpret)")
print("=" * 70)
key = jax.random.key(0)
xs, ws = [], []
for i, (t, k, n) in enumerate([(100, 200, 96), (256, 128, 300)]):
    k1, k2 = jax.random.split(jax.random.fold_in(key, i))
    xs.append(jax.random.normal(k1, (t, k), jnp.float32))
    ws.append(jax.random.normal(k2, (k, n), jnp.float32))
outs = fused_tenant_gemm(xs, ws, interpret=True)
for i, (x, w, o) in enumerate(zip(xs, ws, outs)):
    err = float(jnp.abs(o - x @ w).max())
    print(f"tenant {i}: {x.shape} @ {w.shape} -> {o.shape}, "
          f"max err {err:.2e}")
    assert err < 1e-3

# -- 3. train ------------------------------------------------------------
from repro.configs import get
from repro.launch.mesh import make_host_mesh
from repro.training.data import DataConfig, make_batch
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainConfig, init_sharded, \
    make_train_step

print()
print("=" * 70)
print("3) train reduced llama3.2-3b, 30 steps")
print("=" * 70)
cfg = get("llama3.2-3b").smoke
mesh = make_host_mesh()
params, opt_state = init_sharded(cfg, mesh, seed=0)
_, jitted = make_train_step(
    cfg, mesh, TrainConfig(opt=OptConfig(lr=1e-2, warmup_steps=5,
                                         total_steps=100)))
dcfg = DataConfig(vocab=cfg.vocab, batch=8, seq=32, seed=0)
step_fn = None
first = last = None
for i in range(30):
    batch = make_batch(dcfg, i, mesh)
    if step_fn is None:
        step_fn = jitted(params, opt_state, batch)
    params, opt_state, m = step_fn(params, opt_state, batch)
    if i == 0:
        first = float(m["loss"])
    last = float(m["loss"])
    if (i + 1) % 10 == 0:
        print(f"step {i+1:3d}  loss {last:.4f}")
assert last < first, "loss did not drop"
print(f"\nloss {first:.3f} -> {last:.3f}: OK")
print("\nquickstart complete.")
