"""Partitioned training: two tenants fine-tune on disjoint mesh slices,
with gradient compression and checkpoint/restart.

The training-side version of the paper's claim: the SAME physical mesh
hosts two independent training jobs on disjoint column slices (no
cross-tenant collectives by construction), each with its own optimizer,
data stream and checkpoint lineage; when one job finishes, the other
inherits the freed columns at the next rebalance (here: demonstrated by
re-initialising the survivor's step on the wider slice).

    PYTHONPATH=src python examples/partitioned_training.py
"""

import tempfile


from repro.configs import get
from repro.distributed.tenancy import TenantMeshManager
from repro.launch.mesh import make_host_mesh
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, make_batch
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (
    TrainConfig,
    init_sharded,
    make_train_step,
)

mesh = make_host_mesh(model=1)
# demand-weighted slices via the repro.api policy registry ("equal" would
# reproduce the paper's Algorithm 1 verbatim)
mgr = TenantMeshManager(mesh, "model", policy="proportional")
mgr.admit("llama", demand=10.0)
mgr.admit("mamba", demand=5.0)
grants = mgr.rebalance()
print(f"tenancy grants: { {k: str(v) for k, v in grants.items()} }")

jobs = {}
for name, arch, steps in (("llama", "llama3.2-3b", 20),
                          ("mamba", "mamba2-780m", 10)):
    # on a 1-column host mesh only one tenant gets a spatial slice; the
    # other time-shares the whole mesh (what a real deployment does when
    # over-subscribed — Algorithm 1 queues it for the next free round)
    placed = mgr.tenant(name).partition is not None
    sub = mgr.submesh(name) if placed else mesh
    cfg = get(arch).smoke
    params, opt = init_sharded(cfg, sub, seed=hash(name) % 1000)
    _, jitted = make_train_step(
        cfg, sub, TrainConfig(opt=OptConfig(lr=5e-3, warmup_steps=2,
                                            total_steps=steps)))
    dcfg = DataConfig(vocab=cfg.vocab, batch=4, seq=32, seed=1)
    jobs[name] = dict(cfg=cfg, params=params, opt=opt, jitted=jitted,
                      dcfg=dcfg, steps=steps, step_fn=None, losses=[])

ckpt_dir = tempfile.mkdtemp(prefix="partitioned_training_")
for step in range(20):
    for name, j in list(jobs.items()):
        if step >= j["steps"]:
            continue
        batch = make_batch(j["dcfg"], step)
        if j["step_fn"] is None:
            j["step_fn"] = j["jitted"](j["params"], j["opt"], batch)
        j["params"], j["opt"], m = j["step_fn"](j["params"], j["opt"],
                                                batch)
        j["losses"].append(float(m["loss"]))
        if step == j["steps"] - 1:
            d = ckpt.save(f"{ckpt_dir}/{name}", step + 1,
                          {"params": j["params"], "opt": j["opt"]})
            print(f"[{name}] finished at step {step+1}, "
                  f"loss {j['losses'][0]:.3f} -> {j['losses'][-1]:.3f}, "
                  f"checkpointed")
            if name == "mamba":
                # tenant drains -> release + merge-accelerate survivor
                mgr.release("mamba")
                grown = mgr.grow_into_free()
                print(f"mamba released; survivor growth: "
                      f"{ {k: str(v) for k, v in grown.items()} }")

# restart demo: restore llama from its checkpoint (elastic re-shard path)
got = ckpt.restore_latest(f"{ckpt_dir}/llama",
                          {"params": jobs["llama"]["params"],
                           "opt": jobs["llama"]["opt"]})
assert got is not None
print(f"\nrestored llama checkpoint from step {got[0]} — restart-safe")
print("done.")
