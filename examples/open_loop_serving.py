"""Open-loop serving: Poisson arrivals -> per-policy p99 / miss-rate table.

    PYTHONPATH=src python examples/open_loop_serving.py [--preemption]

The closed-workload quickstart asks "how fast does a fixed batch drain?";
this example asks the serving question: jobs arrive on their own clock
(seeded Poisson stream over the paper's light RNN pool, one DNNG per job,
each with a deadline), the partition policy re-splits the array on every
arrival and completion, and we compare policies on tail latency and SLO
attainment — on the *identical* arrival stream.

Also shown: the same stream over a 4-array fleet behind a
join-shortest-queue dispatcher (`n_arrays=4`), which is how the simulator
scales past one array's saturation point.

With ``--preemption`` the single-array table runs with layer-granular
preemption armed (`PreemptionModel`; only `deadline_preempt` acts on it)
and the fleet run adds cross-node migration (`rebalance_interval`), and
the preemption/migration counters are printed per row.
"""

import argparse

from repro.api import Session, list_policies

RATE = 600.0      # jobs/s — near one array's saturation for the light pool
HORIZON = 0.1     # s of simulated arrivals (~60 jobs)
SLO_S = 0.01      # per-job deadline: arrival + 10 ms


def main() -> None:
    parser = argparse.ArgumentParser(description="open-loop serving demo")
    parser.add_argument(
        "--preemption", action="store_true",
        help="arm layer-granular preemption (+ migration on the fleet run)")
    args = parser.parse_args()

    print(f"Poisson open-loop: rate={RATE:.0f} jobs/s, horizon={HORIZON}s, "
          f"SLO={SLO_S*1e3:.0f}ms, pool=light, "
          f"preemption={'on' if args.preemption else 'off'}\n")
    print(f"{'policy':>16}{'jobs':>6}{'rej%':>7}{'p50ms':>8}{'p95ms':>8}"
          f"{'p99ms':>8}{'miss%':>7}{'goodput/s':>11}{'util%':>7}"
          f"{'npre':>6}")
    for policy in list_policies():
        res = Session(policy=policy, backend="sim").serve(
            "poisson", rate=RATE, horizon=HORIZON, seed=0, pool="light",
            slo_s=SLO_S, max_concurrent=4, queue_cap=8,
            preemption=args.preemption)
        m = res.metrics
        print(f"{policy:>16}{m.jobs_arrived:>6}{m.rejection_rate*100:>7.1f}"
              f"{m.p50_latency_s*1e3:>8.2f}{m.p95_latency_s*1e3:>8.2f}"
              f"{m.p99_latency_s*1e3:>8.2f}{m.deadline_miss_rate*100:>7.1f}"
              f"{m.goodput_jobs_per_s:>11.1f}{m.utilization*100:>7.1f}"
              f"{m.preemptions:>6}")

    fleet_policy = "deadline_preempt" if args.preemption else "equal"
    fleet_kwargs = {}
    if args.preemption:
        fleet_kwargs = dict(preemption=True, rebalance_interval=2e-3)
    print(f"\nSame stream, 4-array fleet (join-shortest-queue, "
          f"policy={fleet_policy}):")
    res = Session(policy=fleet_policy, backend="sim").serve(
        "poisson", rate=RATE, horizon=HORIZON, seed=0, pool="light",
        slo_s=SLO_S, n_arrays=4, dispatch="jsq", **fleet_kwargs)
    m = res.metrics
    print(f"  p99 {m.p99_latency_s*1e3:.2f}ms, miss {m.deadline_miss_rate*100:.1f}%, "
          f"goodput {m.goodput_jobs_per_s:.1f}/s, util {m.utilization*100:.1f}%")
    if args.preemption:
        print(f"  preemptions {m.preemptions}, migrations {m.migrations}")
    per_model = res.per("model")
    print("\nPer-model p99 (fleet run):")
    for model, mm in per_model.items():
        print(f"  {model:<18} {mm.p99_latency_s*1e3:>7.2f}ms "
              f"({mm.jobs_arrived} jobs)")


if __name__ == "__main__":
    main()
