"""Memory contention: shared-bandwidth pressure and joint partitioning.

    PYTHONPATH=src python examples/memory_contention.py [--capacity F]

A bursty (MMPP) heavy-model mix with one latency-critical tenant in
three overdrives a 4-array fleet whose shared DRAM/NoC bandwidth is
derated to ``--capacity`` of nominal (default 0.5).  Every stage-in /
stage-out books raw demand into fleet-wide accounting windows; windows
pushed past capacity stretch transfers superlinearly (MoCA-style row-
buffer/backpressure compounding), and the stretch is priced into both
latency and energy.

The same contended stream runs under:

* ``equal``       — compute-only partitioning, bandwidth-blind;
* ``moca``        — joint compute + memory partitioning: tier-first
  placement plus per-tenant bandwidth caps on batch tenants whenever a
  latency tier shares the array (tier 0 is never capped).

The run prints per-policy tier-0 p99 / deadline-miss rate, the fleet
bus-stall seconds, and the worst window overcommit — moca trades batch
bandwidth for tier-0 latency under pressure.  The serving setup is one
:class:`repro.ServeConfig` value, reused across both arms.
"""

import argparse

from repro import ServeConfig, Session
from repro.api import MemoryConfig, SchedulingConfig
from repro.core.scheduler import ContentionModel

N_ARRAYS = 4
RATE = 2700.0     # jobs/s over 4 arrays — ~1.2x what the fleet sustains
HORIZON = 0.22    # s of simulated arrivals (~600 jobs)
SLO_S = 0.007     # tight: contention stalls turn into deadline misses
WINDOW_S = 1e-4   # contention accounting window


def _run(policy: str, cfg: ServeConfig):
    return Session(policy=policy, backend="sim").serve(
        "mmpp", config=cfg, rate=RATE, horizon=HORIZON, pool="heavy",
        slo_s=SLO_S, tiers=(0, 1, 1))


def _summary(label: str, res) -> None:
    tier0 = res.per("tier")[0]
    m = res.metrics
    print(f"{label:>12}: tier0 p99 {tier0.p99_latency_s*1e3:8.2f}ms  "
          f"miss {tier0.deadline_miss_rate*100:5.1f}%  |  "
          f"bus stall {m.memory_stall_s:.3f}s, "
          f"peak pressure {m.memory_peak_pressure:.1f}x")


def main() -> None:
    parser = argparse.ArgumentParser(description="memory contention demo")
    parser.add_argument("--capacity", type=float, default=0.5,
                        help="shared bandwidth as a fraction of nominal")
    args = parser.parse_args()

    contention = ContentionModel(window_s=WINDOW_S,
                                 capacity=args.capacity)
    cfg = ServeConfig(
        scheduling=SchedulingConfig(n_arrays=N_ARRAYS, max_concurrent=4,
                                    queue_cap=8, seed=0),
        memory=MemoryConfig(contention=contention))
    print(f"shared bus derated to {args.capacity:.0%} of nominal, "
          f"{WINDOW_S*1e6:.0f}us accounting windows\n")

    results = {p: _run(p, cfg) for p in ("equal", "moca")}
    for label, res in results.items():
        _summary(label, res)

    eq = results["equal"].per("tier")[0].p99_latency_s
    mo = results["moca"].per("tier")[0].p99_latency_s
    print(f"\nmoca cuts tier-0 p99 by {(1 - mo / eq) * 100:.1f}% by "
          f"capping batch tenants' bandwidth under pressure")


if __name__ == "__main__":
    main()
