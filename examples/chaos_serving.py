"""Chaos serving: seeded faults, detection, and SLA-preserving recovery.

    PYTHONPATH=src python examples/chaos_serving.py [--seed N] [--none]

Crashes, blackouts and stragglers hit a 4-array fleet mid-run while a
Poisson stream is being served.  Failures are *detected*, not announced:
the HealthMonitor watches heartbeat staleness, failed dispatch RPCs and
service-time outliers, dispatchers route around the belief, and lost
jobs restart warm from their last completed-layer checkpoint under the
``retry_restart`` policy.  The run prints:

* the fault schedule (deterministic under ``--seed``);
* every belief transition the monitor fired, with its cause;
* the chaos accounting (lost / retried / recovered / shed) and per-tier
  availability, next to the same run with ``recovery="none"`` — the
  control arm shows what the retry path buys.

``--none`` skips the recovery arm comparison and only runs the control.
"""

import argparse

from repro.api import Session
from repro.chaos import FaultPlan

N_ARRAYS = 4
RATE = 1800.0     # jobs/s over 4 arrays — busy but with failover headroom
HORIZON = 0.4     # s of simulated arrivals (~700 jobs)
SLO_S = 0.05      # generous enough that a warm restart can still make it


def _run(plan, recovery):
    return Session(policy="equal", backend="sim").serve(
        "poisson", rate=RATE, horizon=HORIZON, pool="light", slo_s=SLO_S,
        tiers=(0, 1, 2), n_arrays=N_ARRAYS, dispatch="jsq",
        max_concurrent=4, queue_cap=16, faults=plan, recovery=recovery)


def _summary(label, res):
    c, m = res.chaos, res.metrics
    avail = ", ".join(f"tier{t}={v:.3f}"
                      for t, v in sorted(m.availability_by_tier.items()))
    print(f"{label:>14}: {m.jobs_completed}/{m.jobs_arrived} completed, "
          f"miss {m.deadline_miss_rate*100:.1f}%  |  "
          f"lost {c.jobs_lost}, retried {c.jobs_retried}, "
          f"recovered {c.jobs_recovered}, shed {c.jobs_shed}")
    print(f"{'':>16}availability: {avail}")


def main() -> None:
    parser = argparse.ArgumentParser(description="chaos serving demo")
    parser.add_argument("--seed", type=int, default=5,
                        help="fault-plan seed (same seed, same run)")
    parser.add_argument("--none", action="store_true",
                        help="run only the recovery-disabled control arm")
    args = parser.parse_args()

    plan = FaultPlan.seeded(args.seed, horizon=HORIZON, n_nodes=N_ARRAYS,
                            crashes=1, blackouts=1, stragglers=1)
    print(f"fault plan (seed {args.seed}):")
    for e in plan.events:
        extra = f" for {e.duration_s*1e3:.1f}ms" if e.duration_s else ""
        print(f"  t={e.t*1e3:7.2f}ms  {e.kind:<10} node {e.node}{extra}")
    print()

    arms = [("none", "none")] if args.none else \
           [("retry_restart", "retry_restart"), ("none", "none")]
    results = {}
    for label, recovery in arms:
        results[label] = _run(plan, recovery)

    res = results[arms[0][0]]
    print("belief transitions (detection, not announcement):")
    churn = 0
    for t, node, old, new, cause in res.chaos.transitions:
        if cause in ("service_outlier", "probe_ok"):
            churn += 1     # gray-failure probation churn; summarized below
            continue
        print(f"  t={t*1e3:7.2f}ms  node {node}: {old} -> {new}  [{cause}]")
    if churn:
        print(f"  (+ {churn} service-outlier suspect/probe cycles under "
              f"co-tenancy load)")
    print()
    for label, _ in arms:
        _summary(label, results[label])
    if len(results) == 2:
        d = (results["none"].metrics.deadline_miss_rate
             - results["retry_restart"].metrics.deadline_miss_rate)
        print(f"\nrecovery saves {d*100:.2f}pp of deadline misses "
              f"on this plan")


if __name__ == "__main__":
    main()
