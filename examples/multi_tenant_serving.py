"""Multi-tenant serving with dynamic partitioning + fault injection.

Three architectures (dense llama, SSM mamba2, hybrid recurrentgemma) share
one device mesh under Algorithm-1 tenancy, with the partition policy chosen
by name from the `repro.api` registry (``proportional`` here — MoCA-style
demand-weighted slices; the llama tenant is pinned to SLA tier 0).
Mid-run, a device column fails: the affected tenant is evicted, re-placed
by the same policy that handles arrivals, and the run completes — the
paper's merge/re-assign logic IS the fault-tolerance story.

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""

import jax

from repro.configs import get
from repro.distributed.tenancy import TenantMeshManager
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_params
from repro.serving.engine import MultiTenantEngine
from repro.serving.kv_cache import DecodeSession

TENANTS = ["llama3.2-3b", "mamba2-780m", "recurrentgemma-2b"]

mesh = make_host_mesh(model=1)
mgr = TenantMeshManager(mesh, "model")
eng = MultiTenantEngine(mgr, policy="proportional")

key = jax.random.key(0)
for i, name in enumerate(TENANTS):
    cfg = get(name).smoke
    params = init_params(cfg, jax.random.fold_in(key, i))
    sess = DecodeSession(cfg, params, batch_slots=2, max_seq=64)
    flops_tok = 2.0 * sum(x.size for x in jax.tree.leaves(params))
    eng.add_tenant(name, sess, flops_per_token=flops_tok, tier=i)
    for r in range(3):
        eng.submit(name, prompt=[1 + r, 2, 3], max_new=6 + 2 * i)
    print(f"admitted {name} (family={cfg.family}, tier={i}), 3 requests")

print("\n-- running 5 rounds --")
for _ in range(5):
    out = eng.step()
    print(f"round {eng.round}: emitted "
          f"{ {k: len(v) for k, v in out.items()} }")

print("\n-- injecting device-column failure --")
evicted = eng.fail_column(0)
print(f"column 0 failed; evicted tenants: {evicted}")
eng.heal_column(0)
print("column 0 healed; tenants re-placed by Task_Assignment")

rounds = eng.run_until_drained()
print(f"\nall tenants drained after {rounds} total rounds")
print("partition width history (round, tenant, cols):")
for rec in eng.width_history:
    print(f"  {rec}")
