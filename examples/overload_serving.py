"""Overload survival: admission control, brownout, and pod respawn.

    PYTHONPATH=src python examples/overload_serving.py [--load X]

A 4-array fleet is driven at 1.5x its service capacity with a bursty
MMPP mix (one latency-critical tier-0 stream, two batch tiers).  Three
arms serve the SAME arrival stream:

* ``static``   — admit everything; the bounded queues are the only
  backpressure (jobs die as tier-blind ``queue_full`` rejections);
* ``codel``    — CoDel-style adaptive admission: when fleet queue delay
  sits above target for a full interval, batch arrivals are shed on a
  sqrt-spaced schedule (tier 0 is never shed);
* ``brownout`` — a feedback controller walks a declared degradation
  ladder as pressure rises (shrink batch column floors -> stretch batch
  deadlines -> shed batch), and walks back up when pressure clears.
  Every transition is priced in joules and logged.

The run prints tier-0 p99 / deadline misses / goodput per arm, the
per-cause rejection split, the per-tier shed counts (tier 0 is always
absent — sheds are batch-only by construction), and the brownout
stage log.

The second half kills a pod mid-run in a sharded fleet: without
``respawn=True`` the run aborts with a ``PodFailureError`` carrying the
partial results; with it, the supervisor respawns the pod from the last
epoch boundary and re-admits the lost jobs through the retry path —
and the serial and forked supervisors produce byte-identical results.
"""

import argparse
import json

from repro.api import Session
from repro.chaos import FaultEvent
from repro.overload import BrownoutController, BrownoutStage, CoDelAdmission
from repro.traffic import PodFailureError, ShardedTrafficSimulator

N_ARRAYS = 4
SVC_S = 2.32e-3   # mean light-pool service time on one array
SLO_S = 4 * SVC_S
TIERS = (0, 1, 1)

LADDER = (
    BrownoutStage("shrink_floors", batch_demand_scale=0.5),
    BrownoutStage("stretch_deadlines", batch_demand_scale=0.35,
                  deadline_stretch=2.0),
    BrownoutStage("shed", batch_demand_scale=0.25, deadline_stretch=2.0,
                  shed_batch=True),
)


def _serve(arm, rate):
    knobs = {}
    if arm == "codel":
        # the bounded-queue fleet's delay estimate saturates around
        # 2.5x mean service time, so the setpoint must sit below that
        # ceiling (the stock 5 ms default would never fire here)
        knobs["admission"] = CoDelAdmission(target_delay_s=2e-3,
                                            interval_s=5e-3)
    elif arm == "brownout":
        knobs["brownout"] = BrownoutController(delay_target_s=2e-3,
                                               stages=LADDER)
    return Session(policy="width_aware", backend="sim").serve(
        "mmpp", rate=rate, horizon=600 / rate, pool="light", slo_s=SLO_S,
        tiers=TIERS, n_arrays=N_ARRAYS, dispatch="jsq", max_concurrent=4,
        queue_cap=8, seed=0, **knobs)


def _tier0_p99(res):
    lat = sorted(r.completed - r.arrival for r in res.records
                 if r.tier == 0 and r.completed is not None)
    return lat[min(len(lat) - 1, int(0.99 * len(lat)))]


def _goodput(res):
    horizon = max(r.arrival for r in res.records)
    ok = sum(1 for r in res.records
             if r.completed is not None and r.met_deadline)
    return ok / horizon


def overload_arms(load):
    rate = N_ARRAYS * load / SVC_S
    print(f"== admission / brownout at {load:.2f}x load "
          f"({rate:.0f} jobs/s over {N_ARRAYS} arrays) ==")
    for arm in ("static", "codel", "brownout"):
        res = _serve(arm, rate)
        m = res.metrics
        print(f"{arm:>9}: tier0 p99 {_tier0_p99(res)*1e3:6.2f} ms  "
              f"miss {m.deadline_miss_rate*100:5.1f}%  "
              f"goodput {_goodput(res):7.1f} jobs/s")
        print(f"{'':>11}rejections {dict(m.rejections_by_cause or {})}  "
              f"shed_by_tier {m.shed_by_tier or {}}")
        if res.brownout is not None:
            rep = res.brownout
            print(f"{'':>11}brownout: {rep.transitions} transitions, "
                  f"{rep.energy_overhead_j:.2f} J overhead")
            for t, frm, to in rep.log[:6]:
                print(f"{'':>13}t={t*1e3:7.2f} ms  "
                      f"{frm or 'off'} -> {to or 'off'}")
            if len(rep.log) > 6:
                print(f"{'':>13}... {len(rep.log) - 6} more")


def pod_respawn():
    print("\n== pod respawn in the sharded fleet ==")
    kill = FaultEvent(t=0.0, kind="pod_kill", node=1, epoch=1)

    def sharded(**kw):
        return ShardedTrafficSimulator(
            "poisson", n_arrays=N_ARRAYS, n_shards=2, rate=3000.0,
            horizon=0.05, pool="light", seed=0, sync_every=64,
            parallel=False, **kw)

    try:
        sharded(faults=kill).run()
    except PodFailureError as e:
        print(f"without respawn: aborts — {e}")
        print(f"  partial payload: {e.jobs_completed} jobs completed, "
              f"pod status {e.pod_status}")

    res = sharded(faults=kill, respawn=True).run()
    print(f"with respawn: completes — {len(res.records)} records, "
          f"recovery={res.recovery!r}")

    forked = ShardedTrafficSimulator(
        "poisson", n_arrays=N_ARRAYS, n_shards=2, rate=3000.0,
        horizon=0.05, pool="light", seed=0, sync_every=64,
        parallel=True, pod_timeout_s=60.0, faults=kill, respawn=True).run()
    same = json.dumps(res.as_dict()) == json.dumps(forked.as_dict())
    print(f"serial == forked supervisor: {'byte-identical' if same else 'MISMATCH'}")


def main() -> None:
    parser = argparse.ArgumentParser(description="overload survival demo")
    parser.add_argument("--load", type=float, default=1.5,
                        help="offered load as a multiple of fleet capacity")
    args = parser.parse_args()
    overload_arms(args.load)
    pod_respawn()


if __name__ == "__main__":
    main()
