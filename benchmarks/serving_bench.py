"""Serving benchmark — multi-tenant engine vs sequential tenant-at-a-time.

The mesh-level version of Fig. 9(a,b): three architectures share one device
mesh; the engine runs them concurrently under Algorithm-1 tenancy, vs a
baseline that serves each tenant to completion before admitting the next.
Metric: per-tenant completion round + total rounds (a round ≙ one decode
step of every live tenant — the time unit of the simulated accelerator).
"""

from __future__ import annotations

import jax

from repro.configs import get
from repro.distributed.tenancy import TenantMeshManager
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_params
from repro.serving.engine import MultiTenantEngine
from repro.serving.kv_cache import DecodeSession

TENANTS = ("llama3.2-3b", "mamba2-780m", "recurrentgemma-2b")


def _mk_session(arch: str, i: int) -> tuple[DecodeSession, float]:
    cfg = get(arch).smoke
    params = init_params(cfg, jax.random.fold_in(jax.random.key(0), i))
    flops_tok = 2.0 * sum(x.size for x in jax.tree.leaves(params))
    return DecodeSession(cfg, params, batch_slots=2, max_seq=64), flops_tok


def run(requests: int = 3, max_new: int = 6) -> dict:
    # concurrent: Algorithm-1 engine
    mesh = make_host_mesh(model=1)
    eng = MultiTenantEngine(TenantMeshManager(mesh, "model"))
    done_round: dict[str, int] = {}
    for i, arch in enumerate(TENANTS):
        sess, ft = _mk_session(arch, i)
        eng.add_tenant(arch, sess, flops_per_token=ft)
        for r in range(requests):
            eng.submit(arch, prompt=[1 + r, 2, 3], max_new=max_new + 2 * i)
    while eng.tenants:
        live_before = set(eng.tenants)
        eng.step()
        for name in live_before - set(eng.tenants):
            done_round[name] = eng.round
    conc_rounds = eng.round

    # sequential baseline: one tenant at a time on the whole mesh
    seq_rounds = 0
    seq_done: dict[str, int] = {}
    for i, arch in enumerate(TENANTS):
        eng2 = MultiTenantEngine(
            TenantMeshManager(make_host_mesh(model=1), "model"))
        sess, ft = _mk_session(arch, i)
        eng2.add_tenant(arch, sess, flops_per_token=ft)
        for r in range(requests):
            eng2.submit(arch, prompt=[1 + r, 2, 3], max_new=max_new + 2 * i)
        seq_rounds += eng2.run_until_drained()
        seq_done[arch] = seq_rounds

    print("== serving_bench: multi-tenant vs sequential ==")
    print(f"{'tenant':<20}{'sequential done':>16}{'concurrent done':>17}")
    for t in TENANTS:
        print(f"{t:<20}{seq_done[t]:>16}{done_round[t]:>17}")
    print(f"total rounds: sequential {seq_rounds} vs concurrent "
          f"{conc_rounds}")
    turn_seq = sum(seq_done.values())
    turn_conc = sum(done_round.values())
    print(f"turnaround sum: {turn_seq} -> {turn_conc} "
          f"({100*(1-turn_conc/turn_seq):.0f}% saving)")
    print(f"width history: {eng.width_history}")
    return {"seq_rounds": seq_rounds, "conc_rounds": conc_rounds,
            "turnaround_saving": 1 - turn_conc / turn_seq}


if __name__ == "__main__":
    run()
