"""Perf-iteration harness: one (arch × cell) under a candidate config.

Each §Perf hypothesis is one invocation: pick mesh factorization, sharding
rules, microbatches, attention chunk — re-lower, re-analyse, print the three
roofline terms.  Iterations are recorded in EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m benchmarks.perf_iter \
        --arch deepseek-coder-33b --cell train_4k --mesh-shape 32,8 \
        --microbatches 16

NOTE: must run in a fresh process per mesh-device-count (jax locks devices).
"""

import os

_SHAPE = os.environ.get("PERF_MESH_DEVICES", "256")
os.environ["XLA_FLAGS"] = \
    f"--xla_force_host_platform_device_count={_SHAPE}"

import argparse
import dataclasses
import json
import time


def main() -> int:
    import jax

    from benchmarks.roofline import (
        HBM_BW,
        ICI_BW,
        PEAK_FLOPS,
        _model_flops,
    )
    from repro.configs import get
    from repro.distributed.sharding import FSDP_TP
    from repro.launch.hlo_analysis import collective_stats, loop_aware_cost
    from repro.launch.steps import build_lowerable
    from repro.training.train_loop import TrainConfig

    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--cell", required=True)
    p.add_argument("--mesh-shape", default="16,16",
                   help="data,model factorization (product = devices)")
    p.add_argument("--microbatches", type=int, default=16)
    p.add_argument("--rules", default="fsdp_tp",
                   choices=["fsdp_tp", "embed_replicated", "tp_only",
                            "tp_experts"])
    p.add_argument("--attn-chunk", type=int, default=0,
                   help="override attention KV-chunk (0 = config default)")
    p.add_argument("--q-chunks", type=int, default=0,
                   help="Q-block count for static causal skipping")
    p.add_argument("--remat", default="on", choices=["on", "off"])
    p.add_argument("--tag", default="")
    args = p.parse_args()

    dims = tuple(int(x) for x in args.mesh_shape.split(","))
    mesh = jax.make_mesh(dims, ("data", "model"))

    spec = get(args.arch)
    cfg = spec.model
    if args.attn_chunk:
        cfg = dataclasses.replace(cfg, attn_chunk=args.attn_chunk)
    if args.q_chunks:
        cfg = dataclasses.replace(cfg, attn_q_chunks=args.q_chunks)
    if args.remat == "off":
        cfg = dataclasses.replace(cfg, remat=False)
    spec = dataclasses.replace(spec, model=cfg)

    rules = {
        "fsdp_tp": FSDP_TP,
        # kill the vocab-sharded embedding gather (its GSPMD lowering
        # replicates-then-repartitions): embed table fully replicated
        "embed_replicated": dataclasses.replace(FSDP_TP, vocab=None),
        "tp_only": dataclasses.replace(FSDP_TP, embed=None),
        # MoE: shard expert FFN dims over "model" (like a dense MLP) and
        # leave the expert axis to FSDP — dispatch stays shard-local
        "tp_experts": dataclasses.replace(FSDP_TP, expert=None),
    }[args.rules]

    t0 = time.time()
    low = build_lowerable(spec, args.cell, mesh, rules=rules,
                          train=TrainConfig(microbatches=args.microbatches))
    compiled = low.lower().compile()
    dt = time.time() - t0
    txt = compiled.as_text()
    cost = loop_aware_cost(txt)
    coll = collective_stats(txt)
    ma = compiled.memory_analysis()

    chips = mesh.devices.size
    t_comp = cost.flops / PEAK_FLOPS
    t_mem = cost.bytes_hbm / HBM_BW
    t_coll = coll.total_bytes / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mflops = _model_flops(args.arch, args.cell)
    frac = (mflops / chips / PEAK_FLOPS) / max(max(terms.values()), 1e-12)

    rec = {
        "tag": args.tag or f"{args.mesh_shape}/{args.rules}"
               f"/mb{args.microbatches}"
               + (f"/chunk{args.attn_chunk}" if args.attn_chunk else "")
               + (f"/qc{args.q_chunks}" if args.q_chunks else "")
               + (f"/remat-{args.remat}" if args.remat != "on" else ""),
        "arch": args.arch, "cell": args.cell,
        "mesh": args.mesh_shape, "rules": args.rules,
        "microbatches": args.microbatches,
        "compile_s": round(dt, 1),
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dom,
        "useful_ratio": mflops / chips / max(cost.flops, 1e-9),
        "roofline_fraction": frac,
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "collectives": coll.summary(),
    }
    print(json.dumps(rec, indent=1))
    # append to the iteration log
    log = os.path.join(os.path.dirname(__file__), "results",
                       "perf_iters.jsonl")
    os.makedirs(os.path.dirname(log), exist_ok=True)
    with open(log, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
