"""CI bench-regression gate — regenerate BENCH_*.json and diff vs committed.

    PYTHONPATH=src python benchmarks/check_regression.py [--tolerance R]

Regenerates the tracked benchmark records into a scratch directory and
compares every tracked metric against the committed copies at the repo
root:

* ``BENCH_fig9.json``    — per (policy, workload): time/turnaround/energy
  savings and utilization must not drop (higher is better);
* ``BENCH_traffic.json`` — per (process, policy, load) and per cluster
  dispatcher: p99 latency and deadline-miss rate must not rise (lower is
  better);
* ``BENCH_kernel.json``  — per compact-mode mix: blocks scheduled and
  bytes fetched must not rise, and compact mode must still schedule
  exactly the live-block count;
* ``BENCH_scale.json``   — per fleet cell: events processed, oracle
  calls/event, deadline-miss rate must not rise and jobs completed must
  not drop.  Wall-clock fields (``wall_s``, ``events_per_s``, the
  ``traffic_bench`` timing block) are machine-dependent and deliberately
  NOT gated — they are informational trajectory records (see README
  "Performance");
* ``BENCH_fairness.json`` — per policy (bursty matrix + trace replay):
  deadline-miss rate, p99 and mean slowdown must not rise, Jain fairness
  must not drop, and the sharded-simulator identity flags must stay 1.
  The 100k-job sharded cell is wall-clock-bound and re-validated by the
  scale-bench CI job instead (its deterministic fields are committed in
  the record; regeneration here skips it to keep the gate fast);
* ``BENCH_chaos.json``   — the fault-injection contract flags (unarmed
  byte purity, seeded determinism, pod_kill error surface, straggler
  detection, and the headline recovery-beats-none tier-0 flag) are
  pinned at 1; the crash cell's tier-0 miss rates and miss-inflation
  deltas must not rise and tier-0 availability under recovery must not
  drop.  ``wall_s`` is informational;
* ``BENCH_obs.json``     — the observability contract flags (observation
  purity byte-identity, deterministic Perfetto export, one track per
  node, tenant lanes, span/preempt/migrate content) are pinned at 1,
  and the freshly measured armed-tracing overhead ratios (default and
  span-source serving paths) must stay within the committed
  ``overhead_budget``.  The informational audit ratio and CPU-seconds
  fields are machine-dependent and not gated;
* ``BENCH_moca.json``    — the memory-contention contract flags (unarmed
  byte purity, armed determinism, stall observed, and the headline
  moca-beats-equal / moca-beats-width_aware tier-0 flags) are pinned at
  1; every arm's tier-0 p99 latency and deadline-miss rate must not
  rise.  ``wall_s`` is informational;
* ``BENCH_overload.json`` — the overload-control contract flags (unarmed
  byte purity incl. BENCH_traffic row replay, armed determinism, the
  headline brownout-beats-static tier-0-p99/goodput flags, tier-0 never
  shed, and the pod-respawn abort/complete/serial==forked flags) are
  pinned at 1; every arm's tier-0 p99 latency and deadline-miss rate
  must not rise and goodput must not drop.  ``wall_s`` is
  informational.

Every comparison is printed as a metric-by-metric diff table; when
``$GITHUB_STEP_SUMMARY`` is set the table is also appended there as
markdown.  Exit code 1 on any regression beyond ``--tolerance`` (relative,
default 2% — the benches are seeded and deterministic, so the slack only
absorbs cross-platform float noise).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class Gate:
    """Collect metric comparisons; render the diff table; decide pass/fail."""

    def __init__(self, tolerance: float):
        self.tolerance = tolerance
        self.rows: list[tuple[str, str, float, float, bool]] = []

    def check(
        self, key: str, metric: str, old: float, new: float, higher_is_better: bool
    ) -> None:
        if higher_is_better:
            regressed = new < old - self.tolerance * max(abs(old), 1e-12)
        else:
            regressed = new > old + self.tolerance * max(abs(old), 1e-12)
        self.rows.append((key, metric, old, new, regressed))

    @property
    def regressions(self) -> list[tuple[str, str, float, float, bool]]:
        return [r for r in self.rows if r[4]]

    def table(self, markdown: bool = False) -> str:
        lines = []
        if markdown:
            lines.append("| benchmark cell | metric | committed | fresh | status |")
            lines.append("|---|---|---|---|---|")
        else:
            lines.append(
                f"{'benchmark cell':<44}{'metric':<22}{'committed':>12}"
                f"{'fresh':>12}  status"
            )
        for key, metric, old, new, bad in self.rows:
            status = "REGRESSED" if bad else "ok"
            if markdown:
                lines.append(f"| {key} | {metric} | {old:.6g} | {new:.6g} | {status} |")
            else:
                lines.append(f"{key:<44}{metric:<22}{old:>12.6g}{new:>12.6g}  {status}")
        return "\n".join(lines)


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def check_fig9(gate: Gate, committed: dict, fresh: dict) -> None:
    old = {(r["policy"], r["workload"]): r for r in committed["results"]}
    new = {(r["policy"], r["workload"]): r for r in fresh["results"]}
    for key in sorted(old):
        if key not in new:
            gate.check(f"fig9 {key}", "row-present", 1.0, 0.0, True)
            continue
        for metric in (
            "time_saving",
            "turnaround_saving",
            "energy_saving",
            "utilization",
        ):
            gate.check(
                f"fig9 {key[0]}/{key[1]}",
                metric,
                old[key][metric],
                new[key][metric],
                higher_is_better=True,
            )


def check_traffic(gate: Gate, committed: dict, fresh: dict) -> None:
    def index(blob):
        rows = {}
        for r in blob["results"]:
            rows[(r["arrivals"], r["policy"], r["load"])] = r
        for r in blob.get("cluster_results", []):
            rows[("cluster", r["dispatch"], r["load"])] = r
        return rows

    old, new = index(committed), index(fresh)
    for key in sorted(old):
        if key not in new:
            gate.check(f"traffic {key}", "row-present", 1.0, 0.0, True)
            continue
        cell = f"traffic {key[0]}/{key[1]}@{key[2]}"
        for metric in ("p99_latency_s", "deadline_miss_rate"):
            gate.check(
                cell,
                metric,
                old[key][metric],
                new[key][metric],
                higher_is_better=False,
            )


def check_kernel(gate: Gate, committed: dict, fresh: dict) -> None:
    old = {r["mix"]: r["compact"] for r in committed["results"]}
    new = {r["mix"]: r["compact"] for r in fresh["results"]}
    for mix in sorted(old):
        if mix not in new:
            gate.check(f"kernel {mix}", "row-present", 1.0, 0.0, True)
            continue
        cell = f"kernel {mix}/compact"
        for metric in ("blocks_scheduled", "bytes_fetched"):
            gate.check(
                cell,
                metric,
                old[mix][metric],
                new[mix][metric],
                higher_is_better=False,
            )
        gate.check(
            cell,
            "scheduled-minus-live",
            0.0,
            abs(new[mix]["blocks_scheduled"] - new[mix]["blocks_live"]),
            higher_is_better=False,
        )


def check_scale(gate: Gate, committed: dict, fresh: dict) -> None:
    old = {(r["jobs_target"], r["n_arrays"]): r for r in committed["results"]}
    new = {(r["jobs_target"], r["n_arrays"]): r for r in fresh["results"]}
    for key in sorted(old):
        if key not in new:
            gate.check(f"scale {key}", "row-present", 1.0, 0.0, True)
            continue
        cell = f"scale {key[0]}jobs/{key[1]}arrays"
        for metric in (
            "events",
            "oracle_calls_per_event",
            "deadline_miss_rate",
        ):
            gate.check(
                cell, metric, old[key][metric], new[key][metric],
                higher_is_better=False,
            )
        gate.check(
            cell, "jobs_completed",
            old[key]["jobs_completed"], new[key]["jobs_completed"],
            higher_is_better=True,
        )


def check_fairness(gate: Gate, committed: dict, fresh: dict) -> None:
    for block, label in (("policy_results", "mmpp"), ("trace_results", "trace")):
        old = {r["policy"]: r for r in committed[block]}
        new = {r["policy"]: r for r in fresh[block]}
        for pol in sorted(old):
            if pol not in new:
                gate.check(f"fairness {label}/{pol}", "row-present", 1.0, 0.0, True)
                continue
            cell = f"fairness {label}/{pol}"
            for metric in ("deadline_miss_rate", "p99_latency_s", "slowdown_mean"):
                gate.check(
                    cell,
                    metric,
                    old[pol][metric],
                    new[pol][metric],
                    higher_is_better=False,
                )
            gate.check(
                cell,
                "jain_fairness",
                old[pol]["jain_fairness"],
                new[pol]["jain_fairness"],
                higher_is_better=True,
            )
    # the sharded determinism contract: identity flags are pinned at 1 —
    # any divergence is an engine-correctness regression, not drift
    for key in sorted(committed["identity"]):
        if key in ("jobs", "n_arrays"):
            continue
        gate.check(
            "fairness sharded-identity",
            key,
            1.0,
            float(fresh["identity"].get(key, 0)),
            higher_is_better=True,
        )


def check_chaos(gate: Gate, committed: dict, fresh: dict) -> None:
    # contract flags are pinned at 1: purity/determinism/recovery breakage
    # is an engine-correctness regression, not drift
    for key in sorted(committed["flags"]):
        gate.check(
            "chaos contract",
            key,
            1.0,
            float(fresh["flags"].get(key, 0)),
            higher_is_better=True,
        )
    for metric in ("tier0_miss_recovery", "tier0_miss_delta"):
        gate.check(
            "chaos crash",
            metric,
            committed["crash"][metric],
            fresh["crash"][metric],
            higher_is_better=False,
        )
    gate.check(
        "chaos crash",
        "tier0_availability_recovery",
        committed["crash"]["tier0_availability_recovery"],
        fresh["crash"]["tier0_availability_recovery"],
        higher_is_better=True,
    )
    for cell in ("degrade", "straggler"):
        gate.check(
            f"chaos {cell}",
            "tier0_miss_inflation",
            committed[cell]["tier0_miss_inflation"],
            fresh[cell]["tier0_miss_inflation"],
            higher_is_better=False,
        )


def check_obs(gate: Gate, committed: dict, fresh: dict) -> None:
    # contract flags are pinned at 1: purity/export/structure breakage is
    # an engine-correctness regression, not drift
    for key in sorted(committed["flags"]):
        gate.check(
            "obs contract",
            key,
            1.0,
            float(fresh["flags"].get(key, 0)),
            higher_is_better=True,
        )
    # the armed overhead is re-measured fresh and held to the *committed*
    # budget (not the committed ratio — that would ratchet machine noise)
    budget = committed["overhead_budget"]
    for metric in ("overhead_ratio", "overhead_ratio_spans"):
        gate.check(
            "obs overhead", metric, budget, fresh[metric], higher_is_better=False
        )


def check_moca(gate: Gate, committed: dict, fresh: dict) -> None:
    # contract flags are pinned at 1: purity/determinism/tier-0 breakage
    # is an engine-correctness regression, not drift
    for key in sorted(committed["flags"]):
        gate.check(
            "moca contract",
            key,
            1.0,
            float(fresh["flags"].get(key, 0)),
            higher_is_better=True,
        )
    for policy in sorted(committed["arms"]):
        if policy not in fresh["arms"]:
            gate.check(f"moca {policy}", "row-present", 1.0, 0.0, True)
            continue
        for metric in ("tier0_p99_latency_s", "tier0_miss_rate"):
            gate.check(
                f"moca {policy}",
                metric,
                committed["arms"][policy][metric],
                fresh["arms"][policy][metric],
                higher_is_better=False,
            )


def check_overload(gate: Gate, committed: dict, fresh: dict) -> None:
    # contract flags are pinned at 1: purity/determinism/tier-0/respawn
    # breakage is an engine-correctness regression, not drift
    for key in sorted(committed["flags"]):
        gate.check(
            "overload contract",
            key,
            1.0,
            float(fresh["flags"].get(key, 0)),
            higher_is_better=True,
        )
    for arm in sorted(committed["arms"]):
        if arm not in fresh["arms"]:
            gate.check(f"overload {arm}", "row-present", 1.0, 0.0, True)
            continue
        for metric in ("tier0_p99_latency_s", "tier0_miss_rate"):
            gate.check(
                f"overload {arm}",
                metric,
                committed["arms"][arm][metric],
                fresh["arms"][arm][metric],
                higher_is_better=False,
            )
        gate.check(
            f"overload {arm}",
            "goodput_jobs_per_s",
            committed["arms"][arm]["goodput_jobs_per_s"],
            fresh["arms"][arm]["goodput_jobs_per_s"],
            higher_is_better=True,
        )


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--tolerance", type=float, default=0.02)
    args = parser.parse_args(argv)

    sys.path.insert(0, os.path.join(ROOT, "src"))
    sys.path.insert(0, ROOT)
    from benchmarks import (
        chaos_bench,
        fairness_bench,
        kernel_bench,
        moca_bench,
        obs_bench,
        overload_bench,
        scale_bench,
        traffic_bench,
    )
    from benchmarks.run import emit_bench_json

    gate = Gate(args.tolerance)
    with tempfile.TemporaryDirectory() as tmp:
        print("# regenerating BENCH_fig9.json ...")
        fresh_fig9 = emit_bench_json(os.path.join(tmp, "fig9.json"))
        print("# regenerating BENCH_traffic.json ...")
        fresh_traffic = traffic_bench.run(path=os.path.join(tmp, "traffic.json"))
        print("# regenerating BENCH_scale.json ...")
        fresh_scale = scale_bench.run(
            path=os.path.join(tmp, "scale.json"), check_budget=False,
            time_traffic=False,  # wall fields are not gated; skip re-timing
            repeats=1,  # best-of-N walls are informational; one pass here
        )
        print("# regenerating BENCH_kernel.json ...")
        fresh_kernel = kernel_bench.run(path=os.path.join(tmp, "kernel.json"))
        print("# regenerating BENCH_fairness.json (fast rows) ...")
        fresh_fairness = fairness_bench.run(
            path=os.path.join(tmp, "fairness.json"),
            include_scale=False,  # wall-bound cell lives in scale-bench CI
        )
        print("# regenerating BENCH_chaos.json ...")
        chaos_path = os.path.join(tmp, "chaos.json")
        try:
            fresh_chaos = chaos_bench.run(path=chaos_path)
        except SystemExit:
            # the bench's own flag gate tripped; fold its record into
            # the diff table anyway so the failure is itemized
            fresh_chaos = _load(chaos_path)
        print("# regenerating BENCH_obs.json ...")
        obs_path = os.path.join(tmp, "obs.json")
        try:
            fresh_obs = obs_bench.run(path=obs_path)
        except SystemExit:
            # the bench's own gate tripped; fold its record into the
            # diff table anyway so the failure is itemized
            fresh_obs = _load(obs_path)
        print("# regenerating BENCH_moca.json ...")
        moca_path = os.path.join(tmp, "moca.json")
        try:
            fresh_moca = moca_bench.run(path=moca_path)
        except SystemExit:
            # the bench's own flag gate tripped; fold its record into
            # the diff table anyway so the failure is itemized
            fresh_moca = _load(moca_path)
        print("# regenerating BENCH_overload.json ...")
        overload_path = os.path.join(tmp, "overload.json")
        try:
            fresh_overload = overload_bench.run(path=overload_path)
        except SystemExit:
            # the bench's own flag gate tripped; fold its record into
            # the diff table anyway so the failure is itemized
            fresh_overload = _load(overload_path)

    check_fig9(gate, _load(os.path.join(ROOT, "BENCH_fig9.json")), fresh_fig9)
    check_traffic(gate, _load(os.path.join(ROOT, "BENCH_traffic.json")), fresh_traffic)
    check_scale(gate, _load(os.path.join(ROOT, "BENCH_scale.json")), fresh_scale)
    check_kernel(gate, _load(os.path.join(ROOT, "BENCH_kernel.json")), fresh_kernel)
    check_fairness(
        gate, _load(os.path.join(ROOT, "BENCH_fairness.json")), fresh_fairness
    )
    check_chaos(gate, _load(os.path.join(ROOT, "BENCH_chaos.json")), fresh_chaos)
    check_obs(gate, _load(os.path.join(ROOT, "BENCH_obs.json")), fresh_obs)
    check_moca(gate, _load(os.path.join(ROOT, "BENCH_moca.json")), fresh_moca)
    check_overload(
        gate, _load(os.path.join(ROOT, "BENCH_overload.json")), fresh_overload
    )

    print()
    print(gate.table())
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("## Bench-regression gate\n\n")
            f.write(gate.table(markdown=True))
            f.write("\n")
    bad = gate.regressions
    if bad:
        print(
            f"\nFAIL: {len(bad)} tracked metric(s) regressed beyond "
            f"{args.tolerance:.1%} tolerance",
            file=sys.stderr,
        )
        return 1
    print(f"\nOK: {len(gate.rows)} tracked metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
