"""Open-loop serving benchmark — BENCH_traffic.json.

    PYTHONPATH=src python benchmarks/traffic_bench.py

The serving-side complement of BENCH_fig9.json: instead of draining a fixed
batch, each cell drives a seeded arrival process (`repro.traffic`) through
one partition policy and records SLA metrics — p50/p95/p99 latency,
deadline-miss rate, goodput, rejection rate, utilization.

Matrix: arrival process × policy × offered load.  *Offered load* ρ is the
arrival rate normalised by the pool's mean sequential service time (ρ=1 ≈
one array's worth of work arriving per unit time), so the load levels mean
the same thing regardless of model-mix calibration.  All cells at the same
(process, load) share the identical arrival stream — policies are compared
on the same jobs.  A second small block compares cluster dispatchers (jsq
vs p2c) on a 4-array fleet.

Everything is seeded; two runs of this script are byte-identical.

The run also reports end-to-end wall time and the host cost-cache hit
rates via the `repro.obs` registry renderer (stdout only — the JSON stays
byte-stable): the scheduler re-prices the same (layer, partition) pairs on
every arrival/completion rebalance, and the memoized cost path serves the
vast majority of those oracle calls from cache.
"""

from __future__ import annotations

import json
import os
import sys
import time

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_traffic.json")

PROCESSES = ("poisson", "mmpp", "diurnal")
POLICIES = ("equal", "proportional", "best_fit", "width_aware")
LOADS = (0.4, 0.9, 1.5)   # ρ: fraction of one array's service capacity
JOBS_PER_CELL = 40
SEED = 0


def mean_service_s(pool: str) -> float:
    """Mean full-array sequential time of one job from ``pool`` (the load
    normaliser: rate = ρ / mean_service_s)."""
    from repro.api import resolve_backend
    from repro.core.partition import Partition
    from repro.sim.workloads import MODEL_POOLS, MODELS

    b = resolve_backend("sim")
    time_fn, stage = b.time_fn(), b.stage_model()
    full = Partition(rows=b.array.rows, col_start=0, cols=b.array.cols)
    times = []
    for name in MODEL_POOLS[pool]:
        g = MODELS[name]()
        times.append(sum(stage.stage_in_s(ls) + time_fn(ls, full)
                         + stage.stage_out_s(ls) for ls in g.layers))
    return sum(times) / len(times)


def run(pool: str = "light", path: str = BENCH_JSON, obs=None,
        keep_trace: bool = False) -> dict:
    """``obs=`` (None / True / an ``Observability``) arms tracing on every
    cell — used by ``obs_bench.py`` to price the armed overhead.  The JSON
    stays byte-identical either way: the timeline is detached before a
    row serializes, so armed rows never even compute the gated ``obs``
    digest (the bench measures instrumentation, not digest rendering).
    ``keep_trace=True`` retains per-layer schedules on every node (the
    obs span source) — obs_bench prices that path as a separate paired
    ratio; ``as_dict`` never serializes schedules, so the JSON is
    byte-identical either way."""
    import dataclasses

    from repro.traffic import TrafficSimulator, get_arrival_process

    t_start = time.perf_counter()
    svc = mean_service_s(pool)
    slo = 4.0 * svc
    rows = []
    print(f"pool={pool}  mean_service={svc*1e3:.3f} ms  slo={slo*1e3:.3f} ms")
    print(f"{'process':>8}{'policy':>14}{'load':>6}{'jobs':>6}{'rej%':>6}"
          f"{'p50ms':>8}{'p95ms':>8}{'p99ms':>8}{'miss%':>7}{'goodput':>9}"
          f"{'util%':>7}")
    for proc in PROCESSES:
        for load in LOADS:
            rate = load / svc
            horizon = JOBS_PER_CELL / rate
            # one process instance per (process, load): every policy cell
            # replays the identical materialized stream (frozen Jobs) —
            # same comparison as before, minus 3 redundant regenerations
            arr = get_arrival_process(
                proc, rate=rate, horizon=horizon, seed=SEED,
                pool=pool, slo_s=slo)
            for pol in POLICIES:
                res = TrafficSimulator(
                    arr, policy=pol, backend="sim",
                    max_concurrent=4, queue_cap=8, seed=SEED,
                    obs=obs, keep_trace=keep_trace).run()
                m = res.metrics
                if res.timeline is not None:   # profiling aid, not an artifact
                    res = dataclasses.replace(res, timeline=None)
                row = {"load": load, "rate_jobs_per_s": rate,
                       "slo_s": slo, **res.as_dict()}
                rows.append(row)
                print(f"{proc:>8}{pol:>14}{load:>6.1f}{m.jobs_arrived:>6}"
                      f"{m.rejection_rate*100:>6.1f}"
                      f"{m.p50_latency_s*1e3:>8.2f}"
                      f"{m.p95_latency_s*1e3:>8.2f}"
                      f"{m.p99_latency_s*1e3:>8.2f}"
                      f"{m.deadline_miss_rate*100:>7.1f}"
                      f"{m.goodput_jobs_per_s:>9.1f}"
                      f"{m.utilization*100:>7.1f}")

    # cluster block: 4 arrays, offered load 4×ρ=0.9, jsq vs p2c dispatch
    cluster_rows = []
    n_arrays = 4
    rate = n_arrays * 0.9 / svc
    horizon = n_arrays * JOBS_PER_CELL / rate
    arr = get_arrival_process("poisson", rate=rate, horizon=horizon,
                              seed=SEED, pool=pool, slo_s=slo)
    for dispatch in ("jsq", "p2c"):
        res = TrafficSimulator(arr, policy="equal", backend="sim",
                               n_arrays=n_arrays, dispatch=dispatch,
                               max_concurrent=4, queue_cap=8,
                               seed=SEED, obs=obs,
                               keep_trace=keep_trace).run()
        m = res.metrics
        if res.timeline is not None:
            res = dataclasses.replace(res, timeline=None)
        row = {"load": 0.9, "rate_jobs_per_s": rate,
               "slo_s": slo, **res.as_dict()}
        cluster_rows.append(row)
        print(f"{'poisson':>8}{'equal/' + dispatch:>14}{0.9:>6.1f}"
              f"{m.jobs_arrived:>6}{m.rejection_rate*100:>6.1f}"
              f"{m.p50_latency_s*1e3:>8.2f}{m.p95_latency_s*1e3:>8.2f}"
              f"{m.p99_latency_s*1e3:>8.2f}"
              f"{m.deadline_miss_rate*100:>7.1f}"
              f"{m.goodput_jobs_per_s:>9.1f}{m.utilization*100:>7.1f}"
              f"  [{n_arrays} arrays]")

    blob = {"benchmark": "traffic", "backend": "sim", "pool": pool,
            "seed": SEED, "mean_service_s": svc, "slo_s": slo,
            "results": rows, "cluster_results": cluster_rows}
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    from repro.obs.render import render_summary, snapshot_host_caches
    print(f"end-to-end {time.perf_counter() - t_start:.2f}s")
    print(render_summary(snapshot_host_caches(),
                         title="cost-path caches (cumulative)"))
    print(f"wrote {path}")
    return blob


if __name__ == "__main__":
    run()
    sys.exit(0)
