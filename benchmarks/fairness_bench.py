"""Fairness benchmark — BENCH_fairness.json.

    PYTHONPATH=src python benchmarks/fairness_bench.py

Three questions, one record:

1. **Who suffers under contention?**  The policy matrix drives one bursty
   heavy-mix MMPP stream (the preempt bench's stress shape) through every
   general partition policy — the five incumbents plus `repro.fairness`'s
   ``drf`` and ``min_cost_flow`` — with per-tenant accounting armed, and
   records Jain fairness over per-model slowdowns next to the usual SLA
   numbers.  All policies see the identical arrival stream.
2. **Does it hold on production arrivals?**  A trace-replay block runs an
   Alibaba ``batch_instance``-style stream (synthesized in memory by
   ``synth_batch_instance_rows`` — deterministic, nothing multi-MB
   committed) through the fairness-relevant policies.
3. **Does the sharded engine tell the truth?**  Identity cells assert the
   `repro.traffic.sharded` determinism contract on a common cell —
   sharded == single-process under ``rr`` dispatch, and shard-count /
   parallel-vs-serial invariance under ``jsq`` — recorded as 0/1 fields
   the regression gate pins at 1.  A 100k-job, 256-array sharded cell
   then exercises fleet scale under the same ``TIME_BUDGET_S`` contract
   as the scale bench (``--no-scale`` / ``include_scale=False`` skips it;
   the bench-gate job does, the scale-bench CI job does not).

Deterministic fields are byte-stable across runs/platforms and gated by
``benchmarks/check_regression.py``; ``wall_s`` is machine-dependent and
informational only (README "Performance").
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(ROOT, "BENCH_fairness.json")

if __package__ in (None, ""):  # run as a script: make `benchmarks.*`
    sys.path.insert(0, ROOT)   # (mean_service_s reuse) importable

# the five incumbent general policies + the two repro.fairness plugins
# (deadline_preempt is excluded as in BENCH_fig9: it is the preempt
# bench's subject and degenerates to `equal` without armed preemption)
POLICIES = ("equal", "proportional", "best_fit", "priority",
            "width_aware", "drf", "min_cost_flow")
TRACE_POLICIES = ("equal", "drf", "min_cost_flow")
SEED = 0
LOAD = 0.9                   # ρ per array for the policy matrix
MATRIX_JOBS = 400
MATRIX_ARRAYS = 4
TRACE_JOBS = 2000
TRACE_ARRAYS = 8
SCALE_JOBS = 100_000
SCALE_ARRAYS = 256
SCALE_SHARDS = 8
SCALE_LOAD = 0.85            # matches scale_bench's steady-state ρ
TIME_BUDGET_S = 120.0        # CI gate for the sharded scale cell


def _fairness_fields(res) -> dict:
    """The gated per-tenant fairness slice of one ServeResult."""
    m = res.metrics
    slow = m.per_tenant_slowdown or {}
    return {
        "jain_fairness": m.jain_fairness,
        "slowdown_mean": (sum(slow.values()) / len(slow)
                          if slow else float("nan")),
        "slowdown_max": max(slow.values()) if slow else float("nan"),
        "per_tenant_slowdown": dict(sorted(slow.items())),
        "jain_dominant_share": m.jain_dominant_share,
    }


def policy_matrix() -> list[dict]:
    """Every policy on the identical bursty heavy-mix MMPP stream."""
    from benchmarks.traffic_bench import mean_service_s
    from repro.traffic import TrafficSimulator, get_arrival_process

    svc = mean_service_s("heavy")
    rate = MATRIX_ARRAYS * LOAD / svc
    arr = get_arrival_process(
        "mmpp", rate=rate, horizon=MATRIX_JOBS / rate, seed=SEED,
        pool="heavy", slo_s=6.0 * svc, tiers=(0, 1))
    rows = []
    for pol in POLICIES:
        res = TrafficSimulator(arr, policy=pol, backend="sim",
                               n_arrays=MATRIX_ARRAYS, dispatch="jsq",
                               max_concurrent=4, queue_cap=16, seed=SEED,
                               fairness=True).run()
        m = res.metrics
        rows.append({
            "policy": pol,
            "arrivals": "mmpp",
            "load": LOAD,
            "jobs_arrived": m.jobs_arrived,
            "jobs_completed": m.jobs_completed,
            "deadline_miss_rate": m.deadline_miss_rate,
            "p99_latency_s": m.p99_latency_s,
            "mean_latency_s": m.mean_latency_s,
            **_fairness_fields(res),
        })
    return rows


def trace_replay() -> list[dict]:
    """Fairness-relevant policies on a production-shaped trace replay."""
    from repro.traffic import (
        TrafficSimulator,
        resolve_arrivals,
        synth_batch_instance_rows,
    )

    csv_rows = synth_batch_instance_rows(TRACE_JOBS, seed=SEED)
    rows = []
    for pol in TRACE_POLICIES:
        arr = resolve_arrivals("batch_instance", source=csv_rows,
                               seed=SEED, pool="heavy", slo_s=0.05)
        res = TrafficSimulator(arr, policy=pol, backend="sim",
                               n_arrays=TRACE_ARRAYS, dispatch="jsq",
                               max_concurrent=4, queue_cap=16, seed=SEED,
                               fairness=True).run()
        m = res.metrics
        rows.append({
            "policy": pol,
            "arrivals": "batch_instance",
            "trace_rows": TRACE_JOBS,
            "jobs_arrived": m.jobs_arrived,
            "jobs_completed": m.jobs_completed,
            "deadline_miss_rate": m.deadline_miss_rate,
            "p99_latency_s": m.p99_latency_s,
            **_fairness_fields(res),
        })
    return rows


def identity_cells() -> dict:
    """The sharded determinism contract on a common cell, as 0/1 fields.

    The gate pins each at 1: any divergence between the sharded engine
    and the single-process truth is a correctness regression, not noise.
    """
    from repro.traffic import ShardedTrafficSimulator, TrafficSimulator

    kw = dict(rate=4000.0, horizon=0.25, pool="light", slo_s=0.02)

    def run_sharded(dispatch, n_shards, parallel):
        return ShardedTrafficSimulator(
            "poisson", policy="drf", backend="sim", n_arrays=8,
            n_shards=n_shards, dispatch=dispatch, seed=SEED,
            sync_every=64, parallel=parallel, **kw).run()

    plain = TrafficSimulator("poisson", policy="drf", backend="sim",
                             n_arrays=8, dispatch="rr", seed=SEED,
                             **kw).run()
    rr4 = run_sharded("rr", 4, True)
    rr_serial = run_sharded("rr", 4, False)
    jsq2 = run_sharded("jsq", 2, True)
    jsq8 = run_sharded("jsq", 8, False)

    def same(a, b) -> int:
        return int(a.records == b.records and a.metrics == b.metrics)

    return {
        "jobs": plain.metrics.jobs_arrived,
        "n_arrays": 8,
        "rr_sharded_equals_single_process": same(rr4, plain),
        "rr_parallel_equals_serial": same(rr4, rr_serial),
        "jsq_invariant_to_shards_and_mode": same(jsq2, jsq8),
    }


def sharded_scale(svc: float) -> dict:
    """100k jobs over 256 arrays through the pod-sharded engine."""
    from repro.traffic import ShardedTrafficSimulator

    rate = SCALE_ARRAYS * SCALE_LOAD / svc
    t0 = time.perf_counter()
    res = ShardedTrafficSimulator(
        "poisson", policy="drf", backend="sim", n_arrays=SCALE_ARRAYS,
        n_shards=SCALE_SHARDS, dispatch="rr", max_concurrent=4,
        queue_cap=8, seed=SEED, sync_every=256, fairness=True,
        rate=rate, horizon=SCALE_JOBS / rate, pool="light",
        slo_s=4.0 * svc).run()
    wall = time.perf_counter() - t0
    m = res.metrics
    return {
        "jobs_target": SCALE_JOBS,
        "n_arrays": SCALE_ARRAYS,
        "n_shards": SCALE_SHARDS,
        "dispatch": "rr",
        "load": SCALE_LOAD,
        "jobs_arrived": m.jobs_arrived,
        "jobs_completed": m.jobs_completed,
        "deadline_miss_rate": m.deadline_miss_rate,
        "rejection_rate": m.rejection_rate,
        "utilization": m.utilization,
        "jain_fairness": m.jain_fairness,
        # -- informational (machine-dependent, not gated) --
        "wall_s": wall,
        "jobs_per_s": m.jobs_arrived / wall if wall > 0 else 0.0,
    }


def run(path: str = BENCH_JSON, include_scale: bool = True,
        check_budget: bool = True) -> dict:
    from benchmarks.traffic_bench import mean_service_s

    print(f"{'policy':>14}{'jobs':>6}{'miss%':>7}{'p99_ms':>8}"
          f"{'jain':>7}{'slow_mu':>9}{'slow_max':>9}")
    matrix = policy_matrix()
    for r in matrix:
        print(f"{r['policy']:>14}{r['jobs_arrived']:>6}"
              f"{r['deadline_miss_rate'] * 100:>7.1f}"
              f"{r['p99_latency_s'] * 1e3:>8.2f}{r['jain_fairness']:>7.3f}"
              f"{r['slowdown_mean']:>9.2f}{r['slowdown_max']:>9.2f}")
    print("# batch_instance trace replay")
    trace = trace_replay()
    for r in trace:
        print(f"{r['policy']:>14}{r['jobs_arrived']:>6}"
              f"{r['deadline_miss_rate'] * 100:>7.1f}"
              f"{r['p99_latency_s'] * 1e3:>8.2f}{r['jain_fairness']:>7.3f}"
              f"{r['slowdown_mean']:>9.2f}{r['slowdown_max']:>9.2f}")
    identity = identity_cells()
    print(f"# sharded identity: rr==single {identity['rr_sharded_equals_single_process']}, "
          f"parallel==serial {identity['rr_parallel_equals_serial']}, "
          f"jsq shard-invariant {identity['jsq_invariant_to_shards_and_mode']}")
    blob = {"benchmark": "fairness", "backend": "sim", "seed": SEED,
            "time_budget_s": TIME_BUDGET_S,
            "policy_results": matrix,
            "trace_results": trace,
            "identity": identity}
    if include_scale:
        scale = sharded_scale(mean_service_s("light"))
        print(f"# sharded scale: {scale['jobs_arrived']} jobs / "
              f"{scale['n_arrays']} arrays / {scale['n_shards']} shards in "
              f"{scale['wall_s']:.1f}s "
              f"({scale['jobs_per_s']:,.0f} jobs/s), "
              f"miss {scale['deadline_miss_rate'] * 100:.1f}%, "
              f"jain {scale['jain_fairness']:.3f}")
        blob["sharded_scale"] = scale
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    from repro.obs.render import render_summary, snapshot_host_caches
    print(render_summary(snapshot_host_caches(),
                         title="cost-path caches (cumulative)"))
    print(f"wrote {path}")
    bad = [k for k, v in identity.items()
           if k not in ("jobs", "n_arrays") and v != 1]
    if bad:
        print(f"FAIL: sharded identity broken: {bad}", file=sys.stderr)
        raise SystemExit(1)
    if include_scale and check_budget:
        if blob["sharded_scale"]["wall_s"] > TIME_BUDGET_S:
            print(f"FAIL: sharded scale cell took "
                  f"{blob['sharded_scale']['wall_s']:.1f}s > "
                  f"{TIME_BUDGET_S:.0f}s budget", file=sys.stderr)
            raise SystemExit(1)
        print(f"OK: scale cell {blob['sharded_scale']['wall_s']:.1f}s "
              f"within {TIME_BUDGET_S:.0f}s budget")
    return blob


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--no-scale", action="store_true",
                        help="skip the 100k-job sharded cell (the "
                             "bench-gate job gates the fast rows only)")
    parser.add_argument("--out", default=BENCH_JSON)
    args = parser.parse_args()
    run(path=args.out, include_scale=not args.no_scale)
    sys.exit(0)
