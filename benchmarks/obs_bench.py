"""Observability benchmark + gate — BENCH_obs.json.

    PYTHONPATH=src python benchmarks/obs_bench.py

Two contracts, one record:

1. **Zero cost disabled, bounded cost armed.**  The traffic bench runs
   obs-off and obs-armed (tracer + registry, the default bundle) on
   identical streams; the armed overhead is gated at
   ``OVERHEAD_BUDGET`` on both serving paths — the default
   (``keep_trace=False``) and the span-source path (``keep_trace=True``,
   where stage-in/compute/stage-out spans derive lazily from the
   schedulers' per-layer records, so arming adds no per-layer work to
   either side of the pair).  The estimator is deliberately
   noise-hardened for shared CI runners: CPU time (``process_time``,
   not wall — the instrumented code is single-threaded pure Python, so
   CPU time bounds the added work without charging scheduler jitter),
   samples alternated off/armed so slow machine phases hit both sides,
   the gated ratio built from the *minimum* per side (timing noise
   only ever adds, so min-of-``REPEATS`` is the standard timeit-style
   floor estimate; the median of per-pair ratios is recorded alongside
   as the informational central estimate), and the sample pool grown —
   up to ``MAX_TRIES`` rounds — until the floor ratio clears the
   budget: more samples only sharpen the floor estimate toward the true
   overhead, while a genuine regression keeps the armed floor high no
   matter how many samples land.  The obs-off JSON must be
   byte-identical to the committed ``BENCH_traffic.json``, and the
   armed JSON byte-identical to the obs-off one — observation purity,
   down to serialization.  ``Observability(audit=True)`` (per-round
   policy decision audits) is priced as the informational
   ``overhead_ratio_audit`` — deliberately outside the budget, which is
   why audits are opt-in.
2. **The exported trace is real and deterministic.**  A bursty heavy-mix
   fleet cell with preemption + migration armed exports a Chrome
   trace-event / Perfetto JSON (written to
   ``benchmarks/results/sample.perfetto-trace.json`` — load it at
   ui.perfetto.dev); the bench asserts one process track per array node,
   per-tenant thread lanes, stage-in/compute/stage-out/drain spans,
   preempt/migrate instant markers, and that two independent runs of the
   same cell export byte-identical traces.

``flags`` fields are 0/1 and pinned at 1 by ``check_regression.py``;
the fresh overhead ratios are gated against the committed
``overhead_budget``; CPU-seconds fields are machine-dependent and
informational only.
"""

from __future__ import annotations

import contextlib
import io
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(ROOT, "BENCH_obs.json")
TRAFFIC_JSON = os.path.join(ROOT, "BENCH_traffic.json")
SAMPLE_TRACE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)),
    "results",
    "sample.perfetto-trace.json",
)

if __package__ in (None, ""):  # run as a script: make `benchmarks.*`
    sys.path.insert(0, ROOT)   # (traffic_bench reuse) importable

SEED = 0
REPEATS = 7
MAX_TRIES = 4
OVERHEAD_BUDGET = 1.05
TRACE_ARRAYS = 4
TRACE_LOAD = 1.1
TRACE_JOBS_PER_ARRAY = 60
REBALANCE_INTERVAL_S = 1e-3


def _timed_traffic(tmp: str, obs, keep_trace: bool) -> tuple[float, bytes]:
    """One traffic-bench pass (stdout swallowed); CPU time + JSON bytes."""
    import gc

    from benchmarks import traffic_bench

    path = os.path.join(tmp, "traffic.json")
    gc.collect()  # collections triggered by a prior sample stay there
    c0 = time.process_time()
    with contextlib.redirect_stdout(io.StringIO()):
        traffic_bench.run(path=path, obs=obs, keep_trace=keep_trace)
    cpu = time.process_time() - c0
    with open(path, "rb") as f:
        return cpu, f.read()


class _Pool:
    """Accumulating off/armed CPU-sample pool for one configuration.

    ``ratio`` is ``min(armed) / min(off)`` over every sample so far:
    timing noise only ever adds, so each side's min converges on its
    true floor as the pool grows, and the ratio on the true overhead —
    while a genuine regression keeps the armed floor high no matter how
    many samples land.  ``median`` (of per-pair ratios) is the
    informational central estimate."""

    def __init__(self, mk_obs, keep_trace: bool = False):
        self.mk_obs = mk_obs
        self.keep_trace = keep_trace
        self.offs: list[float] = []
        self.obss: list[float] = []
        self.bytes_off = self.bytes_obs = b""

    def extend(self, tmp: str, pairs: int) -> None:
        for i in range(pairs):
            if i % 2 == 0:  # alternate order: slow machine phases hit
                first, second = None, self.mk_obs()  # both sides
            else:
                first, second = self.mk_obs(), None
            for obs in (first, second):
                c, blob = _timed_traffic(tmp, obs, self.keep_trace)
                if obs is None:
                    self.offs.append(c)
                    self.bytes_off = blob
                else:
                    self.obss.append(c)
                    self.bytes_obs = blob

    @property
    def ratio(self) -> float:
        off = min(self.offs)
        return min(self.obss) / off if off > 0 else float("inf")

    @property
    def median(self) -> float:
        import statistics

        return statistics.median(
            b / a for a, b in zip(self.offs, self.obss)
        )


def measure_overhead(tmp: str) -> dict:
    """The three paired ratios: default path, span-source path, audits.

    The two gated pools keep growing (up to ``MAX_TRIES`` rounds of
    ``REPEATS`` pairs) until their min-floor ratios clear the budget —
    more samples only sharpen the floor estimate, they never hide a
    real regression."""
    from repro.obs import Observability

    with open(TRAFFIC_JSON, "rb") as f:
        committed = f.read()
    pool = _Pool(Observability)
    pool_spans = _Pool(Observability, keep_trace=True)
    pool_audit = _Pool(lambda: Observability(audit=True))
    rounds = 0
    for attempt in range(MAX_TRIES):
        pool.extend(tmp, REPEATS)
        pool_spans.extend(tmp, REPEATS)
        if attempt == 0:  # informational only: one round is enough
            pool_audit.extend(tmp, REPEATS)
        rounds = attempt + 1
        if max(pool.ratio, pool_spans.ratio) <= OVERHEAD_BUDGET:
            break
        print(
            f"round {rounds}/{MAX_TRIES}: floor ratio "
            f"{max(pool.ratio, pool_spans.ratio):.4f} over budget "
            "(machine noise?) — growing the sample pool"
        )
    cpu_off, cpu_obs = min(pool.offs), min(pool.obss)
    print(
        f"traffic bench min-of-{len(pool.offs)} cpu: off {cpu_off:.3f}s, "
        f"armed {cpu_obs:.3f}s -> ratio {pool.ratio:.4f} "
        f"(median {pool.median:.4f}), spans {pool_spans.ratio:.4f} "
        f"(median {pool_spans.median:.4f}, budget {OVERHEAD_BUDGET:.2f}), "
        f"audit {pool_audit.ratio:.4f} (informational)"
    )
    return {
        "disabled_matches_committed": int(pool.bytes_off == committed),
        "armed_matches_disabled": int(
            pool.bytes_obs == pool.bytes_off
            and pool_spans.bytes_obs == pool_spans.bytes_off
        ),
        "measure_rounds": rounds,
        "cpu_off_s": cpu_off,
        "cpu_obs_s": cpu_obs,
        "overhead_ratio": pool.ratio,
        "overhead_ratio_median": pool.median,
        "overhead_ratio_spans": pool_spans.ratio,
        "overhead_ratio_spans_median": pool_spans.median,
        "overhead_ratio_audit": pool_audit.ratio,
    }


def _trace_cell() -> dict:
    """The sample fleet cell: bursty heavy mix, preemption + migration,
    per-layer schedules retained (the span source)."""
    from benchmarks.traffic_bench import mean_service_s
    from repro.traffic import TrafficSimulator, get_arrival_process

    svc = mean_service_s("heavy")
    slo = 3.0 * svc
    rate = TRACE_ARRAYS * TRACE_LOAD / svc
    arr = get_arrival_process(
        "mmpp",
        rate=rate,
        horizon=TRACE_ARRAYS * TRACE_JOBS_PER_ARRAY / rate,
        seed=SEED,
        pool="heavy",
        slo_s=slo,
        burst_factor=6.0,
    )
    res = TrafficSimulator(
        arr,
        policy="deadline_preempt",
        backend="sim",
        n_arrays=TRACE_ARRAYS,
        dispatch="jsq",
        max_concurrent=4,
        queue_cap=8,
        seed=SEED,
        preemption=True,
        rebalance_interval=REBALANCE_INTERVAL_S,
        keep_trace=True,
        obs=True,
    ).run()
    return res.timeline.chrome_trace()


def export_sample() -> dict:
    """Run the trace cell twice, assert export determinism + structure,
    write the sample Perfetto trace, return the record fields."""
    trace_a = _trace_cell()
    trace_b = _trace_cell()
    dump_a = json.dumps(trace_a, sort_keys=True)
    deterministic = int(dump_a == json.dumps(trace_b, sort_keys=True))
    events = trace_a["traceEvents"]
    pids = {e["pid"] for e in events if e["ph"] != "M"}
    kinds: dict[str, int] = {}
    spans = 0
    for e in events:
        if e["ph"] in ("X", "i"):
            kinds[e["cat"]] = kinds.get(e["cat"], 0) + 1
            spans += e["ph"] == "X"
    lanes = {
        (e["pid"], e["tid"])
        for e in events
        if e["ph"] != "M" and e["tid"] != 0
    }
    os.makedirs(os.path.dirname(SAMPLE_TRACE), exist_ok=True)
    with open(SAMPLE_TRACE, "w") as f:
        json.dump(trace_a, f, indent=1)
        f.write("\n")
    print(
        f"sample trace: {len(events)} events ({spans} spans) over "
        f"{len(pids)} node tracks, {len(lanes)} tenant lanes, "
        f"{kinds.get('preempt', 0)} preempt + "
        f"{kinds.get('migrate', 0)} migrate markers -> {SAMPLE_TRACE}"
    )
    return {
        "export_deterministic": deterministic,
        "one_track_per_node": int(pids == set(range(TRACE_ARRAYS))),
        "has_spans": int(spans > 0),
        "has_tenant_lanes": int(len(lanes) > 0),
        "has_preempt_markers": int(kinds.get("preempt", 0) > 0),
        "has_migrate_markers": int(kinds.get("migrate", 0) > 0),
        "trace_events": len(events),
        "trace_spans": spans,
        "preempt_markers": kinds.get("preempt", 0),
        "migrate_markers": kinds.get("migrate", 0),
    }


def run(path: str = BENCH_JSON) -> dict:
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        overhead = measure_overhead(tmp)
    sample = export_sample()
    flags = {
        "disabled_matches_committed": overhead["disabled_matches_committed"],
        "armed_matches_disabled": overhead["armed_matches_disabled"],
        "export_deterministic": sample["export_deterministic"],
        "one_track_per_node": sample["one_track_per_node"],
        "has_spans": sample["has_spans"],
        "has_tenant_lanes": sample["has_tenant_lanes"],
        "has_preempt_markers": sample["has_preempt_markers"],
        "has_migrate_markers": sample["has_migrate_markers"],
    }
    blob = {
        "benchmark": "obs",
        "backend": "sim",
        "seed": SEED,
        "overhead_budget": OVERHEAD_BUDGET,
        "cpu_repeats": REPEATS,
        "measure_rounds": overhead["measure_rounds"],
        "flags": flags,
        "trace": {
            "n_arrays": TRACE_ARRAYS,
            "events": sample["trace_events"],
            "spans": sample["trace_spans"],
            "preempt_markers": sample["preempt_markers"],
            "migrate_markers": sample["migrate_markers"],
        },
        # -- informational (machine-dependent, not gated on bytes) --
        "cpu_off_s": overhead["cpu_off_s"],
        "cpu_obs_s": overhead["cpu_obs_s"],
        "overhead_ratio": overhead["overhead_ratio"],
        "overhead_ratio_median": overhead["overhead_ratio_median"],
        "overhead_ratio_spans": overhead["overhead_ratio_spans"],
        "overhead_ratio_spans_median": overhead[
            "overhead_ratio_spans_median"
        ],
        "overhead_ratio_audit": overhead["overhead_ratio_audit"],
    }
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
    bad = [k for k, v in flags.items() if v != 1]
    if bad:
        print(f"FAIL: obs contract flags not 1: {bad}", file=sys.stderr)
        raise SystemExit(1)
    worst = max(blob["overhead_ratio"], blob["overhead_ratio_spans"])
    if worst > OVERHEAD_BUDGET:
        print(
            f"FAIL: armed tracing overhead {worst:.4f}x exceeds the "
            f"{OVERHEAD_BUDGET:.2f}x budget",
            file=sys.stderr,
        )
        raise SystemExit(1)
    print(
        f"OK: overhead {blob['overhead_ratio']:.4f}x "
        f"(spans {blob['overhead_ratio_spans']:.4f}x) within "
        f"{OVERHEAD_BUDGET:.2f}x, all contract flags 1"
    )
    return blob


if __name__ == "__main__":
    run()
    sys.exit(0)
