"""Fig. 9(c,d) — partition-size assignment traces of the dynamic run."""

from __future__ import annotations

from repro.sim.runner import run_experiment


def run() -> dict:
    out = {}
    for wl in ("heavy", "light"):
        res = run_experiment(wl)
        out[wl] = res
        print(f"== Fig 9({'c' if wl == 'heavy' else 'd'}) {wl}: "
              f"partition widths per layer ==")
        print(f"partition-size histogram: {res.partition_histogram()}")
        # per-tenant width trajectory (the coloured bars of the figure)
        for name in sorted(res.partitioned.completion):
            evs = res.partitioned.tenant_trace(name)
            widths = [e.partition.cols for e in
                      sorted(evs, key=lambda e: e.start)]
            print(f"  {name:<18} {widths}")
        print()
    return out


if __name__ == "__main__":
    run()
