"""Run the full benchmark suite (one entry per paper table/figure).

    PYTHONPATH=src python -m benchmarks.run

Order: the Fig. 9 reproduction (time / partitions / energy), the kernel
bench, the serving bench, then the roofline table (which needs
``benchmarks/results/dryrun.json`` from ``repro.launch.dryrun`` — skipped
with a notice when absent, since the dry-run takes ~30 min of compiles).
"""

from __future__ import annotations

import os
import sys
import time


def main() -> int:
    t0 = time.time()
    from benchmarks import (
        fig9_energy,
        fig9_partitions,
        fig9_time,
        kernel_bench,
        serving_bench,
    )

    print("#" * 72)
    print("# Fig 9(a,b) — computation time")
    print("#" * 72)
    fig9_time.run(policies=("paper", "width_aware"))

    print("#" * 72)
    print("# Fig 9(c,d) — partition assignment")
    print("#" * 72)
    fig9_partitions.run()

    print("#" * 72)
    print("# Fig 9(e,f) — energy")
    print("#" * 72)
    fig9_energy.run()

    print("#" * 72)
    print("# Fig 9 sensitivity ablation (unpublished workload knobs)")
    print("#" * 72)
    from benchmarks import fig9_ablation
    fig9_ablation.run()

    print("#" * 72)
    print("# kernel bench — partitioned-WS fused GEMM")
    print("#" * 72)
    kernel_bench.run()

    print("#" * 72)
    print("# serving bench — multi-tenant engine")
    print("#" * 72)
    serving_bench.run()

    print("#" * 72)
    print("# roofline (from dry-run artifacts)")
    print("#" * 72)
    dry = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")
    if os.path.exists(dry):
        from benchmarks import roofline
        roofline.run()
    else:
        print(f"SKIPPED: {dry} not found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")

    print(f"\nbenchmark suite done in {time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
