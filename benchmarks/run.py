"""Run the full benchmark suite (one entry per paper table/figure).

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --profile   # hot-spot survey

Order: the policy × workload matrix (written to ``BENCH_fig9.json`` at the
repo root so the perf trajectory is machine-trackable across PRs), the
Fig. 9 reproduction (time / partitions / energy), the sensitivity ablation,
the kernel bench (dense-vs-compact grid accounting, written alongside the
matrix as ``BENCH_kernel.json`` — the kernel-level perf trajectory), the
serving bench, the fairness bench (per-tenant DRF/min-cost-flow accounting
plus the sharded 100k-job fleet cell — ``BENCH_fairness.json``), then the
roofline table (which needs
``benchmarks/results/dryrun.json`` from ``repro.launch.dryrun`` — skipped
with a notice when absent, since the dry-run takes ~30 min of compiles).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fig9.json")


def emit_bench_json(path: str = BENCH_JSON) -> dict:
    """Fig. 9 matrix over every registered policy, machine-readable.

    One row per workload × policy with time/turnaround/energy savings,
    utilization and the partition-width histogram — the cross-PR perf
    trajectory record.

    The sequential baseline is policy-independent, so it is computed once
    per workload (``Session.run_baseline``) and shared across every
    policy's run — same numbers, ~2× fewer schedules simulated.
    """
    from repro.api import Session, list_policies

    baselines = {wl: Session(backend="sim").run_baseline(wl)
                 for wl in ("heavy", "light")}
    rows = []
    for pol in list_policies():
        if pol == "deadline_preempt":
            # deadline-driven serving policy: closed workloads carry no
            # deadlines, so it degenerates to `equal` here — its numbers
            # live in BENCH_preempt.json (benchmarks/preempt_bench.py)
            continue
        for wl in ("heavy", "light"):
            rows.append(Session(policy=pol, backend="sim")
                        .run(wl, baseline=baselines[wl]).as_dict())
    blob = {"benchmark": "fig9", "backend": "sim", "results": rows}
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    print(f"{'policy':>14}{'workload':>9}{'time%':>8}{'turnar%':>9}"
          f"{'energy%':>9}{'util%':>7}")
    for r in rows:
        print(f"{r['policy']:>14}{r['workload']:>9}"
              f"{r['time_saving']*100:>8.1f}{r['turnaround_saving']*100:>9.1f}"
              f"{r['energy_saving']*100:>9.1f}{r['utilization']*100:>7.1f}")
    print(f"wrote {path}")
    return blob


def _profile_one(label: str, fn, top: int, sort: str) -> "object":
    """cProfile one bench entry point, print the hot-spot table and the
    host cost-cache summary (obs registry renderer), return the Stats."""
    import cProfile
    import pstats

    prof = cProfile.Profile()
    prof.enable()
    fn()
    prof.disable()
    stats = pstats.Stats(prof).sort_stats(sort)
    print(f"\n# top {top} {sort} hot spots of {label}")
    stats.print_stats(top)
    from repro.obs.render import render_summary, snapshot_host_caches
    print(render_summary(snapshot_host_caches(),
                         title=f"host caches after {label} (cumulative)"))
    return stats


def profile_traffic(top: int = 20, sort: str = "cumulative") -> "object":
    """cProfile the open-loop traffic bench and print the ``top`` hot spots.

    Perf PRs should start from this table, not from guesses — PR 5's
    event-engine overhaul came out of exactly this view (the ready-set
    rescan and per-event policy rounds dominated).  Writes the bench JSON
    to a scratch file so the committed BENCH_traffic.json is untouched.
    Returns the ``pstats.Stats`` for programmatic use (tests).
    """
    import tempfile

    from benchmarks import traffic_bench

    with tempfile.TemporaryDirectory() as tmp:
        return _profile_one(
            "benchmarks/traffic_bench.py",
            lambda: traffic_bench.run(path=os.path.join(tmp,
                                                        "traffic.json")),
            top, sort)


def profile_suite(top: int = 20, sort: str = "cumulative") -> None:
    """Hot-spot survey across the serving-side benches: traffic, fairness
    (fast rows — the 100k sharded cell is the scale bench's job) and the
    scale sweep (single repeat, no budget enforcement — profiling wall
    times are not comparable to the committed ones).  Each table is
    followed by the cumulative host cost-cache counters so cache-behavior
    regressions show up next to the hot spots that caused them."""
    import tempfile

    from benchmarks import fairness_bench, scale_bench, traffic_bench

    with tempfile.TemporaryDirectory() as tmp:
        _profile_one(
            "benchmarks/traffic_bench.py",
            lambda: traffic_bench.run(path=os.path.join(tmp,
                                                        "traffic.json")),
            top, sort)
        _profile_one(
            "benchmarks/fairness_bench.py (fast rows)",
            lambda: fairness_bench.run(
                path=os.path.join(tmp, "fairness.json"),
                include_scale=False),
            top, sort)
        _profile_one(
            "benchmarks/scale_bench.py (1 repeat)",
            lambda: scale_bench.run(
                path=os.path.join(tmp, "scale.json"),
                check_budget=False, time_traffic=False, repeats=1),
            top, sort)


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument(
        "--profile", action="store_true",
        help="cProfile the traffic, fairness and scale benches and print "
             "the top-20 cumulative hot spots of each (plus the host "
             "cost-cache counters) instead of running the full suite")
    args = parser.parse_args()
    if args.profile:
        profile_suite()
        return 0
    t0 = time.time()
    from benchmarks import (
        fig9_energy,
        fig9_partitions,
        fig9_time,
        kernel_bench,
        serving_bench,
    )

    print("#" * 72)
    print("# Fig 9 policy x workload matrix -> BENCH_fig9.json")
    print("#" * 72)
    emit_bench_json()

    print("#" * 72)
    print("# Fig 9(a,b) — computation time")
    print("#" * 72)
    fig9_time.run(policies=("equal", "width_aware"))

    print("#" * 72)
    print("# Fig 9(c,d) — partition assignment")
    print("#" * 72)
    fig9_partitions.run()

    print("#" * 72)
    print("# Fig 9(e,f) — energy")
    print("#" * 72)
    fig9_energy.run()

    print("#" * 72)
    print("# Fig 9 sensitivity ablation (unpublished workload knobs)")
    print("#" * 72)
    from benchmarks import fig9_ablation
    fig9_ablation.run(policy_matrix=False)  # matrix already in BENCH_fig9

    print("#" * 72)
    print("# kernel bench — dense vs compact grids -> BENCH_kernel.json")
    print("#" * 72)
    kernel_bench.run()

    print("#" * 72)
    print("# serving bench — multi-tenant engine")
    print("#" * 72)
    serving_bench.run()

    print("#" * 72)
    print("# fairness bench — DRF / min-cost flow + sharded fleet "
          "-> BENCH_fairness.json")
    print("#" * 72)
    from benchmarks import fairness_bench
    fairness_bench.run()

    print("#" * 72)
    print("# roofline (from dry-run artifacts)")
    print("#" * 72)
    dry = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")
    if os.path.exists(dry):
        from benchmarks import roofline
        roofline.run()
    else:
        print(f"SKIPPED: {dry} not found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")

    print(f"\nbenchmark suite done in {time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
