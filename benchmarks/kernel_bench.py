"""Kernel benchmark — fused partitioned-WS GEMM vs per-tenant execution.

CPU has no MXU, so the comparison is structural (the same accounting the
paper's Fig. 9 uses, at kernel granularity):

* correctness: fused kernel ≡ per-tenant oracle on a realistic multi-tenant
  mix (the heavy workload's first-layer GEMMs);
* grid accounting: MXU-blocks scheduled, blocks skipped by the ``Mul_En``
  ``pl.when`` (ragged-T work skipping), and the dead-lane waste a
  sequential per-tenant launch pays from padding each GEMM to the MXU tile
  — the kernel-level mirror of baseline column idling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import GEMM
from repro.kernels.ops import _round_up, build_owner_map, fused_tenant_gemm
from repro.sim.workloads import heavy_workload


def _tenant_gemms(n_tenants: int = 4) -> list[GEMM]:
    """First-layer GEMMs of the heavy workload's first n tenants."""
    out = []
    for g in heavy_workload()[:n_tenants]:
        layer = g.layers[0]
        out.append(GEMM(T=min(layer.gemm_m, 512), K=min(layer.gemm_k, 512),
                        N=min(layer.gemm_n, 512)))
    return out


def run(block: int = 128) -> dict:
    gemms = _tenant_gemms()
    key = jax.random.key(0)
    xs, ws = [], []
    for i, g in enumerate(gemms):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        xs.append(jax.random.normal(k1, (g.T, g.K), jnp.float32))
        ws.append(jax.random.normal(k2, (g.K, g.N), jnp.float32))

    # correctness
    outs = fused_tenant_gemm(xs, ws, block_t=block, block_k=block,
                             block_n=block, interpret=True)
    max_rel = 0.0
    for x, w, o in zip(xs, ws, outs):
        ref = x @ w
        max_rel = max(max_rel, float(
            jnp.max(jnp.abs(o - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9)))
    assert max_rel < 1e-4, max_rel

    # grid accounting
    T_pad = _round_up(max(g.T for g in gemms), block)
    K_pad = _round_up(max(g.K for g in gemms), block)
    owner = build_owner_map([g.N for g in gemms], block)
    n_blocks_n = int(owner.shape[0])
    t_blocks = T_pad // block
    k_blocks = K_pad // block
    total_blocks = n_blocks_n * t_blocks * k_blocks
    # Mul_En skipping: (n,t,k) runs iff t·block < valid_t AND k·block <
    # valid_k of the owning tenant
    skipped = 0
    for nb in range(n_blocks_n):
        g = gemms[int(owner[nb])]
        for tb in range(t_blocks):
            for kb in range(k_blocks):
                if tb * block >= g.T or kb * block >= g.K:
                    skipped += 1
    fused_run = total_blocks - skipped

    # sequential per-tenant launches: each GEMM padded to its own grid
    seq_blocks = sum(
        (_round_up(g.T, block) // block) * (_round_up(g.K, block) // block)
        * (_round_up(g.N, block) // block) for g in gemms)

    useful_macs = sum(g.macs for g in gemms)
    blk_macs = block ** 3
    fused_util = useful_macs / (fused_run * blk_macs)
    seq_util = useful_macs / (seq_blocks * blk_macs)

    print("== kernel_bench: fused partitioned-WS GEMM ==")
    print(f"tenants: {[f'{g.T}x{g.K}x{g.N}' for g in gemms]}")
    print(f"max rel err vs oracle:        {max_rel:.2e}")
    print(f"fused grid blocks:            {total_blocks} "
          f"({skipped} skipped by Mul_En -> {fused_run} run)")
    print(f"sequential launches blocks:   {seq_blocks}")
    print(f"MXU-block utilization:        fused {fused_util*100:.1f}%  "
          f"vs sequential {seq_util*100:.1f}%")
    return {"max_rel": max_rel, "fused_blocks": fused_run,
            "seq_blocks": seq_blocks, "fused_util": fused_util,
            "seq_util": seq_util}


if __name__ == "__main__":
    run()
