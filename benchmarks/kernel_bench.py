"""Kernel benchmark — dense vs compact grids on ragged tenant mixes.

    PYTHONPATH=src python benchmarks/kernel_bench.py   # -> BENCH_kernel.json

CPU has no MXU, so wall-clock numbers here are interpret-mode figures
(useful as a grid-step proxy, not silicon truth); the *accounting* is
exact and hardware-independent — grid steps scheduled, MXU-live blocks,
``Mul_En``-gated dead steps, and the HBM→VMEM bytes each mode fetches:

* ``dense``   schedules the full (n, t, k) iteration space and gates dead
  blocks with ``pl.when`` — every dead block still pays a grid step and
  its block fetches;
* ``compact`` schedules exactly the live blocks via scalar-prefetch index
  tables — the true zero-cost ``Mul_En`` (gated → not-scheduled →
  not-fetched).

Each mix is checked against the per-tenant oracle in both modes, and the
bench **asserts** that compact mode schedules exactly the live-block count
(CI fails on any regression).  Results land in ``BENCH_kernel.json`` at
the repo root — the kernel-level perf trajectory across PRs, next to
``BENCH_fig9.json`` and ``BENCH_traffic.json``.
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.core.dataflow import GEMM
from repro.kernels.ops import (
    _round_up,
    autotune_blocks,
    build_owner_map,
    fused_tenant_gemm,
)
from repro.kernels.partitioned_matmul import live_block_tables
from repro.sim.workloads import heavy_workload

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernel.json")


def _heavy_gemms(n_tenants: int, cap: int = 512) -> list[GEMM]:
    """First-layer GEMMs of the heavy workload's first ``n_tenants``."""
    out = []
    for g in heavy_workload()[:n_tenants]:
        layer = g.layers[0]
        out.append(GEMM(T=min(layer.gemm_m, cap), K=min(layer.gemm_k, cap),
                        N=min(layer.gemm_n, cap)))
    return out


def _mixes() -> dict[str, list[GEMM]]:
    return {
        # no raggedness: every tenant fills the shared grid exactly —
        # compact has nothing to delete (sanity anchor, auto picks dense)
        "uniform": [GEMM(T=256, K=256, N=256) for _ in range(4)],
        # the seed bench's 4-tenant heavy mix
        "ragged": _heavy_gemms(4),
        # all 8 heavy tenants — the arrival-driven serving norm: widely
        # ragged T and K, most of the dense grid is padding
        "ragged_heavy": _heavy_gemms(8),
    }


def _operands(gemms: list[GEMM]) -> tuple[list, list]:
    key = jax.random.key(0)
    xs, ws = [], []
    for i, g in enumerate(gemms):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        xs.append(jax.random.normal(k1, (g.T, g.K), jnp.float32))
        ws.append(jax.random.normal(k2, (g.K, g.N), jnp.float32))
    return xs, ws


def _run_mode(xs, ws, mode: str, block: int) -> tuple[dict, float, float]:
    """One fused call: (accounting dict, max rel err vs oracle, wall s)."""
    t0 = time.perf_counter()
    outs, stats = fused_tenant_gemm(
        xs, ws, block_t=block, block_k=block, block_n=block,
        grid_mode=mode, interpret=True, return_stats=True)
    jax.block_until_ready(outs)
    wall = time.perf_counter() - t0
    max_rel = 0.0
    for x, w, o in zip(xs, ws, outs):
        ref = x @ w
        max_rel = max(max_rel, float(
            jnp.max(jnp.abs(o - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9)))
    assert max_rel < 1e-4, (mode, max_rel)
    return stats.accounting.as_dict(), max_rel, wall


def run(block: int = 128, path: str = BENCH_JSON) -> dict:
    print("== kernel_bench: dense vs compact partitioned-WS grids ==")
    rows = []
    for mix, gemms in _mixes().items():
        xs, ws = _operands(gemms)
        dense, err_d, wall_d = _run_mode(xs, ws, "dense", block)
        compact, err_c, wall_c = _run_mode(xs, ws, "compact", block)

        # the tentpole invariant: the compact grid IS the live-block set.
        # `realized` is the ACTUAL pallas grid length (the same table
        # _compact_call schedules); `brute` re-counts liveness with a
        # naive triple loop sharing no code with the kernel's helpers —
        # a regression that schedules dead triples fails here, not just
        # in the cost model's own books.
        T_pad = _round_up(max(g.T for g in gemms), block)
        K_pad = _round_up(max(g.K for g in gemms), block)
        owner = build_owner_map([g.N for g in gemms], block)
        realized = live_block_tables(
            owner, [g.T for g in gemms], [g.K for g in gemms],
            T=T_pad, K=K_pad, block_t=block, block_k=block)[0].size
        brute = sum(
            1
            for e in (int(o) for o in owner)
            for tb in range(T_pad // block)
            for kb in range(K_pad // block)
            if tb * block < gemms[e].T and kb * block < gemms[e].K)
        assert realized == brute == compact["blocks_scheduled"] \
            == compact["blocks_live"] == dense["blocks_live"], \
            (mix, realized, brute, compact, dense)
        assert compact["blocks_skipped"] == 0, (mix, compact)

        step_saving = 1.0 - (compact["blocks_scheduled"]
                             / dense["blocks_scheduled"])
        fetch_saving = 1.0 - (compact["bytes_fetched"]
                              / dense["bytes_fetched"])
        shapes = tuple((g.T, g.K, g.N) for g in gemms)
        tuned = autotune_blocks(shapes)
        rows.append({
            "mix": mix,
            "tenants": [f"{g.T}x{g.K}x{g.N}" for g in gemms],
            "block": block,
            "dense": dense,
            "compact": compact,
            "grid_step_saving": step_saving,
            "fetch_byte_saving": fetch_saving,
            "wall_s_dense_interpret": wall_d,
            "wall_s_compact_interpret": wall_c,
            "max_rel_err": max(err_d, err_c),
            "autotuned_blocks": list(tuned),
        })
        print(f"{mix:>14}: dense {dense['blocks_scheduled']:>4} steps "
              f"({dense['blocks_skipped']} gated dead) -> compact "
              f"{compact['blocks_scheduled']:>4} steps "
              f"({step_saving * 100:.1f}% fewer, "
              f"{fetch_saving * 100:.1f}% fewer fetched bytes); "
              f"interpret wall {wall_d:.2f}s -> {wall_c:.2f}s; "
              f"autotune {tuned}")

    heavy = next(r for r in rows if r["mix"] == "ragged_heavy")
    assert heavy["grid_step_saving"] >= 0.25, heavy["grid_step_saving"]

    blob = {"benchmark": "kernel", "block": block, "interpret": True,
            "results": rows}
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
    return blob


if __name__ == "__main__":
    run()
    sys.exit(0)
