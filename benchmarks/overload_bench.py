"""Overload-control benchmark — BENCH_overload.json.

    PYTHONPATH=src python benchmarks/overload_bench.py

Three questions, one record:

1. **Is the overload layer invisible when unarmed?**  Purity flags: two
   unarmed runs of the overdriven cell must serialize byte-identically
   and carry none of the gated overload keys — and an unarmed run of a
   committed BENCH_traffic.json cell must reproduce that row byte for
   byte (the overload wiring changed nothing it did not arm).
2. **Does graceful degradation pay under overdrive?**  A bursty (MMPP)
   mix at 1.5x offered load with one latency-critical tenant in three
   runs under ``static`` admission (the pre-overload behavior: the
   bounded node queue does all shedding), tier-aware ``codel``
   admission, and the ``brownout`` stage ladder on identical streams.
   The declared ladder here walks shrink-floors -> stretch-deadlines ->
   shed: the bandwidth-cap rung of the default ladder is deliberately
   absent because this cell runs without the shared-DRAM contention
   model — caps write through the PR-9 ``set_caps`` surface, which only
   *relieves* anything when the bus is the bottleneck (that composition
   is pinned by the unit tests; the cap-free rungs are what pay in a
   slot-limited fleet).  Brownout must beat static on tier-0 p99
   latency (strictly) and on fleet goodput (strictly) —
   degrade-before-drop, priced in energy.  Armed arms must be
   run-to-run deterministic, and neither codel nor brownout may ever
   shed tier 0.
3. **Does pod respawn turn an abort into a completed run?**  A sharded
   cell with a mid-run ``pod_kill``: without ``respawn`` the run must
   abort with a :class:`~repro.traffic.sharded.PodFailureError` carrying
   the partial-result payload; with ``respawn=True`` the same cell must
   complete, serial and forked byte-identical.

Deterministic fields are byte-stable across runs/platforms and gated by
``benchmarks/check_regression.py`` (``check_overload``); ``wall_s`` is
machine-dependent and informational only.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(ROOT, "BENCH_overload.json")
TRAFFIC_JSON = os.path.join(ROOT, "BENCH_traffic.json")

if __package__ in (None, ""):  # run as a script: make `benchmarks.*`
    sys.path.insert(0, ROOT)   # (mean_service_s reuse) importable

SEED = 0
N_ARRAYS = 4
LOAD = 1.5                   # rho per array; the fleet is overdriven
JOBS = 600
SLO_FACTOR = 4.0
TIERS = (0, 1, 1)            # one latency tenant : two batch tenants
POLICY = "width_aware"       # demand-aware: brownout floor-shrink pays
# queue-delay setpoint: the bounded node queues saturate the fleet
# wait-estimate around ~2.5x the pool's mean service time, so the
# controller setpoint must sit below that ceiling to see overload
DELAY_TARGET_S = 2e-3
CODEL_INTERVAL_S = 5e-3
ARMS = ("static", "codel", "brownout")

GATED_KEYS = {"rejections_by_cause", "shed_by_tier",
              "brownout_transitions", "brownout_energy_j"}


def _cell_kwargs(svc: float) -> tuple[dict, dict]:
    rate = N_ARRAYS * LOAD / svc
    horizon = JOBS / rate
    sim_kw = dict(n_arrays=N_ARRAYS, dispatch="jsq", max_concurrent=4,
                  queue_cap=8, seed=SEED)
    arr_kw = dict(rate=rate, horizon=horizon, pool="light",
                  slo_s=SLO_FACTOR * svc, tiers=TIERS)
    return sim_kw, arr_kw


def _bench_ladder():
    """The declared degradation ladder for this (uncontended) cell:
    shrink batch column floors, stretch batch deadlines, shed batch."""
    from repro.overload import BrownoutStage

    return (
        BrownoutStage("shrink_floors", batch_demand_scale=0.5),
        BrownoutStage("stretch_deadlines", batch_demand_scale=0.35,
                      deadline_stretch=2.0),
        BrownoutStage("shed", batch_demand_scale=0.25,
                      deadline_stretch=2.0, shed_batch=True),
    )


def _serve(arm: str | None, sim_kw: dict, arr_kw: dict):
    from repro.api import OverloadConfig, SchedulingConfig, ServeConfig
    from repro.overload import BrownoutController, CoDelAdmission
    from repro.traffic import TrafficSimulator

    admission, brownout = None, None
    if arm == "static":
        admission = "static"
    elif arm == "codel":
        admission = CoDelAdmission(target_delay_s=DELAY_TARGET_S,
                                   interval_s=CODEL_INTERVAL_S)
    elif arm == "brownout":
        brownout = BrownoutController(delay_target_s=DELAY_TARGET_S,
                                      stages=_bench_ladder())
    cfg = ServeConfig(
        scheduling=SchedulingConfig(**sim_kw),
        overload=OverloadConfig(admission=admission, brownout=brownout))
    return TrafficSimulator("mmpp", policy=POLICY, backend="sim",
                            config=cfg, **arr_kw).run()


def _tier0(res) -> dict:
    rows = [r for r in res.records if r.tier == 0]
    miss = [r for r in rows
            if r.completed is None or r.completed > r.deadline]
    per = res.per("tier")[0]
    return {"p99": per.p99_latency_s,
            "miss": len(miss) / len(rows) if rows else 0.0}


def purity_flags(sim_kw: dict, arr_kw: dict) -> dict:
    """Unarmed runs: byte-stable, no gated keys, and byte-faithful to
    the committed BENCH_traffic.json cell they share parameters with."""
    from repro.traffic import TrafficSimulator, get_arrival_process
    from benchmarks.traffic_bench import mean_service_s

    a = _serve(None, sim_kw, arr_kw).as_dict()
    b = _serve(None, sim_kw, arr_kw).as_dict()
    flags = {
        "unarmed_byte_stable": int(
            json.dumps(a, indent=1) == json.dumps(b, indent=1)),
        "unarmed_has_no_overload_keys": int(not GATED_KEYS & set(a)),
    }
    # replay one committed BENCH_traffic.json cell (poisson / equal /
    # load 1.5, single array) through the post-overload build
    svc = mean_service_s("light")
    slo = 4.0 * svc
    rate = 1.5 / svc
    arr = get_arrival_process("poisson", rate=rate, horizon=40 / rate,
                              seed=SEED, pool="light", slo_s=slo)
    res = TrafficSimulator(arr, policy="equal", backend="sim",
                           max_concurrent=4, queue_cap=8, seed=SEED).run()
    row = {"load": 1.5, "rate_jobs_per_s": rate, "slo_s": slo,
           **res.as_dict()}
    match = 0
    if os.path.exists(TRAFFIC_JSON):
        with open(TRAFFIC_JSON) as f:
            committed = json.load(f)["results"]
        want = [r for r in committed
                if r["load"] == 1.5 and r["policy"] == "equal"
                and r["arrivals"] == "poisson"]
        match = int(bool(want) and
                    json.dumps(row, indent=1) ==
                    json.dumps(want[0], indent=1))
    flags["unarmed_matches_traffic_bench"] = match
    return flags


def overload_cell(sim_kw: dict, arr_kw: dict) -> tuple[dict, dict]:
    """static / codel / brownout on one overdriven bursty stream."""
    arms = {}
    for arm in ARMS:
        res = _serve(arm, sim_kw, arr_kw)
        t0 = _tier0(res)
        m = res.metrics
        arms[arm] = {
            "overload": res.overload,
            "tier0_p99_latency_s": t0["p99"],
            "tier0_miss_rate": t0["miss"],
            "goodput_jobs_per_s": m.goodput_jobs_per_s,
            "fleet_miss_rate": m.deadline_miss_rate,
            "rejections_by_cause": dict(m.rejections_by_cause or {}),
            "shed_by_tier": {str(k): v for k, v in
                             sorted((m.shed_by_tier or {}).items())},
            "brownout_transitions": m.brownout_transitions,
            "brownout_energy_j": m.brownout_energy_j,
        }
    a2 = _serve("brownout", sim_kw, arr_kw)
    again = json.dumps(a2.as_dict(), indent=1)
    brown, static = arms["brownout"], arms["static"]
    flags = {
        "armed_deterministic": int(
            again == json.dumps(_serve("brownout", sim_kw,
                                       arr_kw).as_dict(), indent=1)),
        "brownout_stages_walked": int(
            brown["brownout_transitions"] > 0
            and brown["brownout_energy_j"] > 0.0),
        "brownout_beats_static_tier0_p99": int(
            brown["tier0_p99_latency_s"] < static["tier0_p99_latency_s"]),
        "brownout_beats_static_goodput": int(
            brown["goodput_jobs_per_s"] > static["goodput_jobs_per_s"]),
        "tier0_never_shed": int(all(
            "0" not in a["shed_by_tier"] for a in arms.values())),
    }
    return arms, flags


def respawn_cell() -> tuple[dict, dict]:
    """Sharded 1.5x cell with a mid-run pod_kill: abort without respawn,
    deterministic completion (serial == forked) with it."""
    from repro.chaos import FaultEvent
    from repro.traffic import PodFailureError, ShardedTrafficSimulator
    from benchmarks.traffic_bench import mean_service_s

    svc = mean_service_s("light")
    rate = N_ARRAYS * LOAD / svc

    def sim(**kw):
        return ShardedTrafficSimulator(
            "poisson", n_arrays=N_ARRAYS, n_shards=2, dispatch="rr",
            max_concurrent=4, queue_cap=8, seed=SEED, sync_every=64,
            rate=rate, horizon=JOBS / (2 * rate), pool="light",
            slo_s=SLO_FACTOR * svc, tiers=TIERS, **kw)

    kill = FaultEvent(t=0.0, kind="pod_kill", node=1, epoch=1)
    aborted, payload = 0, {}
    try:
        sim(parallel=False, faults=kill).run()
    except PodFailureError as e:
        aborted = int("pod 1" in str(e) and "epoch 1" in str(e))
        payload = {"jobs_completed": e.jobs_completed,
                   "partial_records": len(e.partial_records),
                   "pod_status": {str(k): v
                                  for k, v in sorted(e.pod_status.items())}}
    serial = sim(parallel=False, faults=kill, respawn=True).run()
    forked = sim(parallel=True, faults=kill, respawn=True,
                 pod_timeout_s=60.0).run()
    ds = json.dumps(serial.as_dict(), indent=1)
    cell = {
        "pod_kill": {"pod": 1, "epoch": 1},
        "abort_payload": payload,
        "respawn": {"faults": serial.faults, "recovery": serial.recovery,
                    "n_records": len(serial.records),
                    "tier0_miss_rate": _tier0(serial)["miss"],
                    "goodput_jobs_per_s":
                        serial.metrics.goodput_jobs_per_s},
    }
    flags = {
        "unrespawned_aborts": aborted,
        "respawn_completes": int(serial.recovery == "pod_respawn"),
        "respawn_serial_forked_identical": int(
            ds == json.dumps(forked.as_dict(), indent=1)),
    }
    return cell, flags


def run(path: str = BENCH_JSON) -> dict:
    from benchmarks.traffic_bench import mean_service_s

    t0 = time.perf_counter()
    svc = mean_service_s("light")
    sim_kw, arr_kw = _cell_kwargs(svc)

    flags = purity_flags(sim_kw, arr_kw)
    arms, cell_flags = overload_cell(sim_kw, arr_kw)
    flags.update(cell_flags)
    respawn, respawn_flags = respawn_cell()
    flags.update(respawn_flags)

    for k, v in flags.items():
        print(f"# flag {k}: {v}")
    for arm in ARMS:
        a = arms[arm]
        print(f"# {arm:>9}: tier0 p99 {a['tier0_p99_latency_s']:.4f}s "
              f"miss {a['tier0_miss_rate']:.4f} "
              f"goodput {a['goodput_jobs_per_s']:.1f}/s "
              f"shed {a['shed_by_tier']} "
              f"transitions {a['brownout_transitions']}")

    blob = {
        "benchmark": "overload", "backend": "sim", "seed": SEED,
        "n_arrays": N_ARRAYS, "load": LOAD, "jobs": JOBS,
        "slo_factor": SLO_FACTOR, "tiers": list(TIERS),
        "flags": flags,
        "arms": arms,
        "respawn_cell": respawn,
        # -- informational (machine-dependent, not gated) --
        "wall_s": time.perf_counter() - t0,
    }
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
    bad = [k for k, v in flags.items() if v != 1]
    if bad:
        print(f"FAIL: overload contract flags broken: {bad}",
              file=sys.stderr)
        raise SystemExit(1)
    return blob


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--out", default=BENCH_JSON)
    args = parser.parse_args()
    run(path=args.out)
    sys.exit(0)
