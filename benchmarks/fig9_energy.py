"""Fig. 9(e,f) — energy, baseline PE vs Mul_En PE + dynamic partitioning."""

from __future__ import annotations

from repro.sim.runner import run_experiment


def run() -> dict:
    out = {}
    for wl, paper in (("heavy", 0.35), ("light", 0.62)):
        res = run_experiment(wl)
        out[wl] = res
        print(f"== Fig 9({'e' if wl == 'heavy' else 'f'}) {wl} ==")
        print(f"{'component':<12}{'baseline mJ':>14}{'partitioned mJ':>16}")
        b = res.baseline_energy.as_dict()
        p = res.partitioned_energy.as_dict()
        for k in b:
            print(f"{k:<12}{b[k]*1e3:14.3f}{p[k]*1e3:16.3f}")
        print(f"energy saving: {res.energy_saving*100:6.1f}% "
              f"(paper reports {paper*100:.0f}%)")
        print()
    return out


if __name__ == "__main__":
    run()
