"""Fig. 9 sensitivity ablation — the calibration study behind EXPERIMENTS
§Fig9's "magnitudes are sensitive to unpublished workload parameters".

Two sweeps over the paper's unpublished knobs:

* heavy × inference batch (CNN batching typical of INFaaS front-ends);
* light × RNN sequence scale (request chunk length).

Each cell reports makespan / turnaround / energy savings of verbatim
Algorithm 1 (``policy="equal"`` through `repro.api.Session`) vs the
sequential baseline, bracketing the paper's reported 56 %/44 % time and
35 %/62 % energy numbers.  A third sweep holds the workloads fixed and
ablates across every registered partition policy.
"""

from __future__ import annotations

import dataclasses

from repro.api import Session, list_policies
from repro.core.dnng import DNNG
from repro.sim import workloads as W


def _scale_batch(g: DNNG, factor: int) -> DNNG:
    new = [dataclasses.replace(ls, N=ls.N * factor) for ls in g.layers]
    return dataclasses.replace(g, layers=tuple(new))


def _scale_light(steps_factor: float):
    """Rebuild the light workload with scaled sequence lengths."""
    import repro.core.dnng as dn

    def lstm(name, input_size, hidden, steps, batch=1):
        return dn.LayerShape.lstm_cell(
            name, input_size=input_size, hidden=hidden,
            steps=max(int(steps * steps_factor), 1), batch=batch)

    def fc(name, i, o, batch=1):
        return dn.LayerShape.fc(name, i, o,
                                batch=max(int(batch * steps_factor), 1))

    melody = dn.chain("MelodyLSTM", [
        lstm("lstm1", 513, 512, 100), lstm("lstm2", 512, 512, 100),
        lstm("lstm3", 512, 512, 100), fc("out", 512, 722, batch=100)])
    gt_layers = [lstm("enc_bi_fwd", 1024, 1024, 20),
                 lstm("enc_bi_bwd", 1024, 1024, 20)]
    gt_layers += [lstm(f"enc{i+2}", 1024, 1024, 20) for i in range(6)]
    gt_layers += [fc("attention", 1024, 1024, batch=20)]
    gt_layers += [lstm(f"dec{i}", 1024 if i else 2048, 1024, 20)
                  for i in range(8)]
    gt = dn.chain("GoogleTranslate", gt_layers)
    dv = dn.chain("DeepVoice", [
        lstm("g2p_enc", 256, 256, 40), lstm("g2p_dec", 256, 256, 40),
        lstm("duration", 256, 256, 40), lstm("f0_rnn", 256, 256, 80),
        lstm("vocoder_rnn", 512, 512, 1600),
        fc("vocoder_proj", 512, 513, batch=1600)])
    hw = dn.chain("HandwritingLSTM", [
        lstm("lstm1", 32, 128, 200), lstm("lstm2", 128, 128, 200),
        lstm("lstm3", 128, 128, 200), fc("ctc_out", 128, 100, batch=200)])
    return W._stagger([melody, gt, dv, hw], 2e-6)


def run(policy_matrix: bool = True) -> dict:
    """``policy_matrix=False`` skips the workload × policy sweep — the
    suite driver (benchmarks.run) already computes that exact matrix for
    BENCH_fig9.json and passes False to avoid simulating it twice."""
    out = {}
    sess = Session(policy="equal", backend="sim")
    orig_heavy, orig_light = W.heavy_workload, W.light_workload
    try:
        print("== heavy × inference batch ==")
        print(f"{'batch':>6}{'makespan%':>11}{'turnaround%':>13}"
              f"{'energy%':>9}")
        for batch in (1, 2, 4, 8):
            W.WORKLOADS["heavy"] = \
                lambda b=batch: [_scale_batch(g, b) for g in orig_heavy()]
            r = sess.run("heavy")
            out[f"heavy_b{batch}"] = r
            print(f"{batch:>6}{r.time_saving*100:>11.1f}"
                  f"{r.turnaround_saving*100:>13.1f}"
                  f"{r.energy_saving*100:>9.1f}")

        print("\n== light × sequence scale ==")
        print(f"{'scale':>6}{'makespan%':>11}{'turnaround%':>13}"
              f"{'energy%':>9}")
        for scale in (0.25, 0.5, 1.0, 4.0):
            W.WORKLOADS["light"] = lambda s=scale: _scale_light(s)
            r = sess.run("light")
            out[f"light_s{scale}"] = r
            print(f"{scale:>6}{r.time_saving*100:>11.1f}"
                  f"{r.turnaround_saving*100:>13.1f}"
                  f"{r.energy_saving*100:>9.1f}")
    finally:
        W.WORKLOADS["heavy"] = orig_heavy
        W.WORKLOADS["light"] = orig_light

    if policy_matrix:
        print("\n== workload × partition policy ==")
        print(f"{'policy':>14}{'workload':>9}{'makespan%':>11}"
              f"{'turnaround%':>13}{'energy%':>9}")
        for pol in list_policies():
            for wl in ("heavy", "light"):
                r = Session(policy=pol, backend="sim").run(wl)
                out[f"{wl}_{pol}"] = r
                print(f"{pol:>14}{wl:>9}{r.time_saving*100:>11.1f}"
                      f"{r.turnaround_saving*100:>13.1f}"
                      f"{r.energy_saving*100:>9.1f}")
    return out


if __name__ == "__main__":
    run()
