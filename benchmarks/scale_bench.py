"""Fleet-scale serving benchmark — BENCH_scale.json.

    PYTHONPATH=src python benchmarks/scale_bench.py

The wall-clock trajectory of the *serving engine itself*: where
BENCH_traffic.json tracks SLA quality (p99, miss rate) of the policies,
this bench tracks how fast the host-side stack can simulate fleet-scale
open-loop load — the capability the ROADMAP's "millions of users" north
star depends on.  Three cells drive 1k/5k/10k jobs over 16/32/64 arrays
behind a jsq dispatcher and record:

* ``events``            — scheduler events processed (deterministic, gated);
* ``oracle_calls``      — cost-oracle invocations: scalar ``layer_cost``
  calls + vectorized batch pairs (deterministic, gated);
* ``oracle_calls_per_event`` — the rebalance-efficiency headline the
  PR-5 engine overhaul targets (deterministic, gated);
* ``jobs_completed`` / ``deadline_miss_rate`` — sanity that speed did not
  change scheduling decisions (deterministic, gated);
* ``wall_s`` / ``events_per_s`` — end-to-end wall clock, best-of-N over
  ``repeats`` identical seeded runs (informational: machine dependent,
  NOT gated — see README "Performance");
* ``wall_engine_s`` / ``events_per_s_engine`` — the same wall with the
  arrival-stream generation excluded (the stream is materialized before
  the clock that feeds this field): the serving *engine*'s own cost,
  comparable against the sharded engine in BENCH_fairness.json.

A fourth block re-times ``benchmarks/traffic_bench.py`` end-to-end in
this process and records the speedup against the committed pre-PR-5
baseline wall time (informational).

The 10k-job cell must finish under ``TIME_BUDGET_S`` — the separate CI
job fails otherwise, so engine regressions show up as time, not just as
metric drift.

Deterministic fields are byte-stable across runs/platforms; wall-clock
fields are re-measured every run and excluded from the regression gate
(`benchmarks/check_regression.py` gates the rest).
"""

from __future__ import annotations

import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(ROOT, "BENCH_scale.json")

if __package__ in (None, ""):  # run as a script: make `benchmarks.*`
    sys.path.insert(0, ROOT)   # (traffic_bench reuse) importable

# (target jobs, arrays): offered load is per-array-normalised, so bigger
# fleets see proportionally more arrivals over a shorter horizon
CELLS = ((1000, 16), (5000, 32), (10000, 64))
LOAD = 0.85          # aggregate ρ per array (sub-saturation steady state)
POOL = "light"
SEED = 0
TIME_BUDGET_S = 120.0          # CI gate for the 10k-job cell
# committed pre-PR-5 traffic_bench end-to-end (cold, this repo's reference
# machine) — the denominator of the recorded speedup; informational
TRAFFIC_BASELINE_WALL_S = 2.03


def _oracle_calls() -> int:
    """Total cost-oracle work so far: scalar layer_cost invocations (LRU
    hits included — each is one oracle query) + vectorized batch pairs."""
    from repro.core.dataflow import ws_cost_batch_stats
    from repro.sim.systolic import layer_cost
    info = layer_cost.cache_info()
    return info.hits + info.misses + ws_cost_batch_stats()["pairs"]


def run_cell(jobs: int, n_arrays: int, svc: float, slo: float,
             repeats: int = 1) -> dict:
    """One fleet cell, timed ``repeats`` times (identical seeded work —
    the recorded walls are best-of-N, the standard noise-robust estimator;
    deterministic fields are byte-identical across repeats)."""
    from repro.traffic import TrafficSimulator, get_arrival_process

    rate = n_arrays * LOAD / svc
    horizon = jobs / rate
    best_wall = best_engine = float("inf")
    for _ in range(max(1, repeats)):
        arr = get_arrival_process("poisson", rate=rate, horizon=horizon,
                                  seed=SEED, pool=POOL, slo_s=slo)
        sim = TrafficSimulator(arr, policy="equal", backend="sim",
                               n_arrays=n_arrays, dispatch="jsq",
                               max_concurrent=4, queue_cap=8, seed=SEED)
        calls0 = _oracle_calls()
        t0 = time.perf_counter()
        # materializing the stream first splits the wall into arrival
        # generation vs the serving engine proper (the process caches its
        # jobs, so sim.run() below iterates the cache)
        list(arr)
        t1 = time.perf_counter()
        res = sim.run()
        t2 = time.perf_counter()
        best_wall = min(best_wall, t2 - t0)
        best_engine = min(best_engine, t2 - t1)
        events = sum(n.scheduler.n_events for n in sim.nodes)
        calls = _oracle_calls() - calls0
    m = res.metrics
    return {
        "jobs_target": jobs,
        "n_arrays": n_arrays,
        "load": LOAD,
        "rate_jobs_per_s": rate,
        "jobs_arrived": m.jobs_arrived,
        "jobs_completed": m.jobs_completed,
        "deadline_miss_rate": m.deadline_miss_rate,
        "rejection_rate": m.rejection_rate,
        "events": events,
        "oracle_calls": calls,
        "oracle_calls_per_event": calls / events if events else 0.0,
        # -- informational (machine-dependent, not gated) --
        "wall_s": best_wall,
        "events_per_s": events / best_wall if best_wall > 0 else 0.0,
        "wall_engine_s": best_engine,
        "events_per_s_engine": (events / best_engine
                                if best_engine > 0 else 0.0),
    }


def time_traffic_bench(repeats: int = 5) -> dict:
    """Re-time the serving-quality bench end-to-end (scratch output).

    Best-of-``repeats``: the minimum is the standard noise-robust
    estimator of a deterministic workload's true cost."""
    import tempfile

    from benchmarks import traffic_bench
    walls = []
    with tempfile.TemporaryDirectory() as tmp:
        for _ in range(repeats):
            t0 = time.perf_counter()
            traffic_bench.run(path=os.path.join(tmp, "traffic.json"))
            walls.append(time.perf_counter() - t0)
    wall = min(walls)
    return {
        "wall_s": wall,
        "baseline_wall_s": TRAFFIC_BASELINE_WALL_S,
        "speedup_vs_baseline": TRAFFIC_BASELINE_WALL_S / wall,
    }


def run(path: str = BENCH_JSON, cells=CELLS,
        check_budget: bool = True, time_traffic: bool = True,
        repeats: int = 2) -> dict:
    rows = []
    print(f"{'jobs':>7}{'arrays':>8}{'events':>9}{'oracle':>9}"
          f"{'orc/evt':>9}{'miss%':>7}{'wall_s':>8}{'engine_s':>9}"
          f"{'evt/s':>10}")
    from benchmarks.traffic_bench import mean_service_s
    svc = mean_service_s(POOL)
    slo = 4.0 * svc
    for jobs, n_arrays in cells:
        r = run_cell(jobs, n_arrays, svc, slo, repeats=repeats)
        rows.append(r)
        print(f"{r['jobs_arrived']:>7}{r['n_arrays']:>8}{r['events']:>9}"
              f"{r['oracle_calls']:>9}{r['oracle_calls_per_event']:>9.3f}"
              f"{r['deadline_miss_rate'] * 100:>7.1f}{r['wall_s']:>8.2f}"
              f"{r['wall_engine_s']:>9.2f}{r['events_per_s']:>10.0f}")
    blob = {"benchmark": "scale", "backend": "sim", "pool": POOL,
            "seed": SEED, "load": LOAD,
            "time_budget_s": TIME_BUDGET_S,
            "wall_repeats": max(1, repeats),
            "results": rows}
    if time_traffic:
        traffic = time_traffic_bench()
        print(f"traffic_bench end-to-end {traffic['wall_s']:.2f}s "
              f"({traffic['speedup_vs_baseline']:.1f}x vs committed "
              f"{traffic['baseline_wall_s']:.2f}s pre-PR-5 baseline)")
        blob["traffic_bench"] = traffic
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
    if check_budget:
        worst = max(r["wall_s"] for r in rows)
        if worst > TIME_BUDGET_S:
            print(f"FAIL: slowest scale cell took {worst:.1f}s > "
                  f"{TIME_BUDGET_S:.0f}s budget", file=sys.stderr)
            raise SystemExit(1)
        print(f"OK: slowest cell {worst:.1f}s within "
              f"{TIME_BUDGET_S:.0f}s budget")
    return blob


if __name__ == "__main__":
    run()
    sys.exit(0)
