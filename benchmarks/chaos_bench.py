"""Chaos benchmark — BENCH_chaos.json.

    PYTHONPATH=src python benchmarks/chaos_bench.py

Four questions, one record:

1. **Is the chaos subsystem invisible when unarmed?**  A purity flag:
   two ``faults=None`` runs of a traffic cell must serialize
   byte-identically and carry none of the gated chaos keys (the
   committed BENCH_traffic.json byte contract is pinned separately by
   ``tests/test_record_stability.py``).
2. **Is fault injection deterministic?**  Identical seeds and plans must
   produce identical serialized records, identical ChaosReports and an
   identical belief-transition trace — recorded as 0/1 flags the
   regression gate pins at 1.
3. **Does recovery preserve the SLA?**  The crash cell drives the same
   seeded Poisson stream through ``retry_restart`` and the ``none``
   control arm.  Tier-0 jobs must miss *strictly less* with recovery
   (lost jobs count as misses; the SLO is generous enough that a warm
   restart completes in time) — the headline flag plus the raw per-arm
   miss rates and availability, all gated.
4. **Is degradation graceful?**  Degrade (dead columns) and straggler
   (slow node) cells record tier-0 miss inflation over the fault-free
   baseline; the sharded pod_kill cell asserts the failure surface is a
   named RuntimeError, not a hang.

Deterministic fields are byte-stable across runs/platforms and gated by
``benchmarks/check_regression.py`` (``check_chaos``); ``wall_s`` is
machine-dependent and informational only.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(ROOT, "BENCH_chaos.json")

if __package__ in (None, ""):  # run as a script: make `benchmarks.*`
    sys.path.insert(0, ROOT)   # (mean_service_s reuse) importable

SEED = 0
N_ARRAYS = 4
LOAD = 0.65                  # ρ per array; 3 survivors stay under water
JOBS = 800
SLO_FACTOR = 10.0            # generous: a warm restart can still make it
TIERS = (0, 1, 2)


def _cell_kwargs(svc: float) -> tuple[dict, dict, float]:
    rate = N_ARRAYS * LOAD / svc
    horizon = JOBS / rate
    sim_kw = dict(policy="equal", backend="sim", n_arrays=N_ARRAYS,
                  dispatch="jsq", max_concurrent=4, queue_cap=16, seed=SEED)
    arr_kw = dict(rate=rate, horizon=horizon, pool="light",
                  slo_s=SLO_FACTOR * svc, tiers=TIERS)
    return sim_kw, arr_kw, horizon


def _tier_miss(res, tier: int) -> float:
    rows = [r for r in res.records if r.tier == tier]
    miss = [r for r in rows
            if r.completed is None or r.completed > r.deadline]
    return len(miss) / len(rows) if rows else 0.0


def _serve(sim_kw: dict, arr_kw: dict, **extra):
    from repro.traffic import TrafficSimulator

    return TrafficSimulator("poisson", **extra, **sim_kw, **arr_kw).run()


def purity_flags(sim_kw: dict, arr_kw: dict) -> dict:
    """Unarmed runs must be byte-stable and free of gated chaos keys."""
    a = _serve(sim_kw, arr_kw).as_dict()
    b = _serve(sim_kw, arr_kw).as_dict()
    gated = {"faults", "recovery", "faults_injected", "jobs_lost",
             "jobs_retried", "jobs_recovered", "retries_exhausted",
             "jobs_shed", "availability_by_tier"}
    return {
        "unarmed_byte_stable": int(
            json.dumps(a, indent=1) == json.dumps(b, indent=1)),
        "unarmed_has_no_chaos_keys": int(not gated & set(a)),
    }


def determinism_flags(sim_kw: dict, arr_kw: dict, horizon: float) -> dict:
    """Identical seed + plan => identical records, report and trace."""
    from repro.chaos import FaultPlan

    plan = FaultPlan.seeded(SEED, horizon=horizon, n_nodes=N_ARRAYS,
                            crashes=1, blackouts=1, stragglers=1)
    a = _serve(sim_kw, arr_kw, faults=plan)
    b = _serve(sim_kw, arr_kw, faults=plan)
    return {
        "same_seed_same_records": int(
            json.dumps(a.as_dict()) == json.dumps(b.as_dict())),
        "same_seed_same_report": int(a.chaos.as_dict() == b.chaos.as_dict()),
        "same_seed_same_transitions": int(
            a.chaos.transitions == b.chaos.transitions),
    }


def crash_cell(sim_kw: dict, arr_kw: dict, horizon: float) -> dict:
    """retry_restart vs the none control arm on one mid-run crash."""
    from repro.chaos import FaultPlan

    plan = FaultPlan.single("crash", t=horizon * 0.3, node=1)
    rec = _serve(sim_kw, arr_kw, faults=plan)
    non = _serve(sim_kw, arr_kw, faults=plan, recovery="none")
    rec_miss, non_miss = _tier_miss(rec, 0), _tier_miss(non, 0)
    rec_av = rec.metrics.availability_by_tier[0]
    non_av = non.metrics.availability_by_tier[0]
    return {
        "fault": "crash",
        "jobs_lost": rec.chaos.jobs_lost,
        "jobs_recovered": rec.chaos.jobs_recovered,
        "tier0_miss_recovery": rec_miss,
        "tier0_miss_none": non_miss,
        "tier0_miss_delta": rec_miss - non_miss,
        "tier0_availability_recovery": rec_av,
        "tier0_availability_none": non_av,
        "recovery_beats_none_tier0": int(
            rec_miss < non_miss and rec_av >= non_av),
    }


def degrade_cell(sim_kw: dict, arr_kw: dict, horizon: float,
                 base_miss: float) -> dict:
    """Half the columns of one node die; service continues on the rest."""
    from repro.chaos import FaultPlan

    plan = FaultPlan.single("degrade", t=horizon * 0.3, node=1,
                            dead_cols=64)
    res = _serve(sim_kw, arr_kw, faults=plan)
    miss = _tier_miss(res, 0)
    return {
        "fault": "degrade",
        "dead_cols": 64,
        "jobs_completed": res.metrics.jobs_completed,
        "tier0_miss": miss,
        "tier0_miss_inflation": miss - base_miss,
        "still_serving": int(res.metrics.jobs_completed > 0),
    }


def straggler_cell(sim_kw: dict, arr_kw: dict, horizon: float,
                   base_miss: float) -> dict:
    """One node runs 4x slow for a window; the monitor must notice."""
    from repro.chaos import FaultPlan

    plan = FaultPlan.single("straggler", t=horizon * 0.3, node=2,
                            factor=4.0, duration_s=horizon * 0.3)
    res = _serve(sim_kw, arr_kw, faults=plan)
    causes = [tr[4] for tr in res.chaos.transitions]
    miss = _tier_miss(res, 0)
    return {
        "fault": "straggler",
        "factor": 4.0,
        "tier0_miss": miss,
        "tier0_miss_inflation": miss - base_miss,
        "straggler_detected": int("service_outlier" in causes),
    }


def pod_kill_flag() -> dict:
    """A dead pod must surface as a named RuntimeError, not a hang."""
    from repro.chaos import FaultEvent
    from repro.traffic import ShardedTrafficSimulator

    sim = ShardedTrafficSimulator(
        "poisson", policy="equal", backend="sim", n_arrays=4, n_shards=2,
        seed=SEED, sync_every=16, parallel=False,
        faults=FaultEvent(t=0.0, kind="pod_kill", node=1, epoch=1),
        rate=3000.0, horizon=0.05, pool="light", slo_s=0.05)
    try:
        sim.run()
    except RuntimeError as exc:
        return {"pod_kill_raises_named_error": int(
            "pod 1" in str(exc) and "epoch 1" in str(exc))}
    return {"pod_kill_raises_named_error": 0}


def run(path: str = BENCH_JSON) -> dict:
    from benchmarks.traffic_bench import mean_service_s

    t0 = time.perf_counter()
    svc = mean_service_s("light")
    sim_kw, arr_kw, horizon = _cell_kwargs(svc)

    flags = purity_flags(sim_kw, arr_kw)
    flags.update(determinism_flags(sim_kw, arr_kw, horizon))
    flags.update(pod_kill_flag())

    base_miss = _tier_miss(_serve(sim_kw, arr_kw), 0)
    crash = crash_cell(sim_kw, arr_kw, horizon)
    flags["recovery_beats_none_tier0"] = crash.pop(
        "recovery_beats_none_tier0")
    degrade = degrade_cell(sim_kw, arr_kw, horizon, base_miss)
    flags["degrade_still_serving"] = degrade.pop("still_serving")
    straggler = straggler_cell(sim_kw, arr_kw, horizon, base_miss)
    flags["straggler_detected"] = straggler.pop("straggler_detected")

    for k, v in flags.items():
        print(f"# flag {k}: {v}")
    print(f"# crash: tier0 miss {crash['tier0_miss_recovery']:.4f} "
          f"(retry_restart) vs {crash['tier0_miss_none']:.4f} (none), "
          f"{crash['jobs_recovered']}/{crash['jobs_lost']} recovered")
    print(f"# degrade: tier0 miss {degrade['tier0_miss']:.4f} "
          f"(+{degrade['tier0_miss_inflation']:.4f} over fault-free)")
    print(f"# straggler: tier0 miss {straggler['tier0_miss']:.4f} "
          f"(+{straggler['tier0_miss_inflation']:.4f} over fault-free)")

    blob = {
        "benchmark": "chaos", "backend": "sim", "seed": SEED,
        "n_arrays": N_ARRAYS, "load": LOAD, "jobs": JOBS,
        "slo_factor": SLO_FACTOR,
        "flags": flags,
        "tier0_miss_fault_free": base_miss,
        "crash": crash,
        "degrade": degrade,
        "straggler": straggler,
        # -- informational (machine-dependent, not gated) --
        "wall_s": time.perf_counter() - t0,
    }
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
    bad = [k for k, v in flags.items() if v != 1]
    if bad:
        print(f"FAIL: chaos contract flags broken: {bad}", file=sys.stderr)
        raise SystemExit(1)
    return blob


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--out", default=BENCH_JSON)
    args = parser.parse_args()
    run(path=args.out)
    sys.exit(0)
