"""MoCA benchmark — BENCH_moca.json.

    PYTHONPATH=src python benchmarks/moca_bench.py

Three questions, one record:

1. **Is the memory subsystem invisible when unarmed?**  A purity flag:
   two ``memory=None`` runs of the contention cell must serialize
   byte-identically and carry none of the gated memory keys (the
   committed BENCH_traffic.json byte contract is pinned separately by
   ``tests/test_record_stability.py``).
2. **Is the armed contention model deterministic?**  Two identical runs
   with the fleet-shared bandwidth ledger armed must produce identical
   serialized records — the window-indexed demand booking has no hidden
   iteration-order dependence.
3. **Does joint compute+memory partitioning pay?**  A bursty (MMPP)
   heavy-model mix with one latency-critical tenant in three, overdriven
   past the shared DRAM capacity, runs under ``equal``, ``width_aware``
   and ``moca`` on identical streams.  ``moca`` — the only policy that
   also caps batch tenants' bandwidth shares — must beat *both* compute-
   only baselines on tier-0 p99 latency (strictly) and tier-0 deadline
   miss rate (no worse), while every armed arm observes non-zero bus
   stall.

Deterministic fields are byte-stable across runs/platforms and gated by
``benchmarks/check_regression.py`` (``check_moca``); ``wall_s`` is
machine-dependent and informational only.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(ROOT, "BENCH_moca.json")

if __package__ in (None, ""):  # run as a script: make `benchmarks.*`
    sys.path.insert(0, ROOT)   # (mean_service_s reuse) importable

SEED = 0
N_ARRAYS = 4
LOAD = 1.2                   # ρ per array; the fleet is overcommitted
JOBS = 600
SLO_FACTOR = 4.0             # tight: contention stalls turn into misses
TIERS = (0, 1, 1)            # one latency tenant : two batch tenants
WINDOW_S = 1e-4              # contention accounting window
CAPACITY = 0.5               # shared DRAM derated to half nominal
POLICIES = ("equal", "width_aware", "moca")


def _cell_kwargs(svc: float) -> tuple[dict, dict]:
    rate = N_ARRAYS * LOAD / svc
    horizon = JOBS / rate
    sim_kw = dict(n_arrays=N_ARRAYS, dispatch="jsq", max_concurrent=4,
                  queue_cap=8, seed=SEED)
    arr_kw = dict(rate=rate, horizon=horizon, pool="heavy",
                  slo_s=SLO_FACTOR * svc, tiers=TIERS)
    return sim_kw, arr_kw


def _tier_miss(res, tier: int) -> float:
    rows = [r for r in res.records if r.tier == tier]
    miss = [r for r in rows
            if r.completed is None or r.completed > r.deadline]
    return len(miss) / len(rows) if rows else 0.0


def _serve(policy: str, sim_kw: dict, arr_kw: dict, armed: bool):
    from repro.api import MemoryConfig, SchedulingConfig, ServeConfig
    from repro.core.scheduler import ContentionModel
    from repro.traffic import TrafficSimulator

    contention = (ContentionModel(window_s=WINDOW_S, capacity=CAPACITY)
                  if armed else None)
    cfg = ServeConfig(scheduling=SchedulingConfig(**sim_kw),
                      memory=MemoryConfig(contention=contention))
    return TrafficSimulator("mmpp", policy=policy, backend="sim",
                            config=cfg, **arr_kw).run()


def purity_flags(sim_kw: dict, arr_kw: dict) -> dict:
    """Unarmed runs must be byte-stable and free of gated memory keys."""
    a = _serve("equal", sim_kw, arr_kw, armed=False).as_dict()
    b = _serve("equal", sim_kw, arr_kw, armed=False).as_dict()
    gated = {"memory", "memory_stall_s", "memory_stall_by_node",
             "memory_peak_pressure"}
    return {
        "unarmed_byte_stable": int(
            json.dumps(a, indent=1) == json.dumps(b, indent=1)),
        "unarmed_has_no_memory_keys": int(not gated & set(a)),
    }


def determinism_flag(sim_kw: dict, arr_kw: dict) -> dict:
    """Identical seed + contention model => identical records."""
    a = _serve("moca", sim_kw, arr_kw, armed=True).as_dict()
    b = _serve("moca", sim_kw, arr_kw, armed=True).as_dict()
    return {"armed_deterministic": int(
        json.dumps(a, indent=1) == json.dumps(b, indent=1))}


def contention_cell(sim_kw: dict, arr_kw: dict) -> tuple[dict, dict]:
    """equal / width_aware / moca on one overdriven contended stream."""
    arms = {}
    for policy in POLICIES:
        res = _serve(policy, sim_kw, arr_kw, armed=True)
        tier0 = res.per("tier")[0]
        arms[policy] = {
            "tier0_p99_latency_s": tier0.p99_latency_s,
            "tier0_miss_rate": _tier_miss(res, 0),
            "fleet_miss_rate": res.metrics.deadline_miss_rate,
            "memory_stall_s": res.metrics.memory_stall_s,
            "memory_peak_pressure": res.metrics.memory_peak_pressure,
        }
    moca, equal, width = (arms[p] for p in ("moca", "equal", "width_aware"))

    def beats(base: dict) -> int:
        return int(moca["tier0_p99_latency_s"] < base["tier0_p99_latency_s"]
                   and moca["tier0_miss_rate"] <= base["tier0_miss_rate"])

    flags = {
        "contention_stall_observed": int(
            all(a["memory_stall_s"] > 0.0 for a in arms.values())),
        "moca_beats_equal_tier0": beats(equal),
        "moca_beats_width_aware_tier0": beats(width),
    }
    return arms, flags


def run(path: str = BENCH_JSON) -> dict:
    from benchmarks.traffic_bench import mean_service_s

    t0 = time.perf_counter()
    svc = mean_service_s("heavy")
    sim_kw, arr_kw = _cell_kwargs(svc)

    flags = purity_flags(sim_kw, arr_kw)
    flags.update(determinism_flag(sim_kw, arr_kw))
    arms, cell_flags = contention_cell(sim_kw, arr_kw)
    flags.update(cell_flags)

    for k, v in flags.items():
        print(f"# flag {k}: {v}")
    for policy in POLICIES:
        a = arms[policy]
        print(f"# {policy:>12}: tier0 p99 {a['tier0_p99_latency_s']:.4f}s "
              f"miss {a['tier0_miss_rate']:.4f} "
              f"stall {a['memory_stall_s']:.4f}s "
              f"peak {a['memory_peak_pressure']:.1f}")

    blob = {
        "benchmark": "moca", "backend": "sim", "seed": SEED,
        "n_arrays": N_ARRAYS, "load": LOAD, "jobs": JOBS,
        "slo_factor": SLO_FACTOR, "tiers": list(TIERS),
        "window_s": WINDOW_S, "capacity": CAPACITY,
        "flags": flags,
        "arms": arms,
        # -- informational (machine-dependent, not gated) --
        "wall_s": time.perf_counter() - t0,
    }
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
    bad = [k for k, v in flags.items() if v != 1]
    if bad:
        print(f"FAIL: moca contract flags broken: {bad}", file=sys.stderr)
        raise SystemExit(1)
    return blob


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--out", default=BENCH_JSON)
    args = parser.parse_args()
    run(path=args.out)
    sys.exit(0)
