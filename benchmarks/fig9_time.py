"""Fig. 9(a,b) — per-DNN computation time, baseline vs dynamic partitioning.

Runs through `repro.api.Session` so any registered policy can be compared;
the paper's numbers correspond to ``policy="equal"``.
"""

from __future__ import annotations

from repro.api import Session


def run(policies=("equal",)) -> dict:
    out = {}
    for wl, paper_time in (("heavy", 0.56), ("light", 0.44)):
        for pol in policies:
            res = Session(policy=pol, backend="sim").run(wl)
            tag = wl if pol in ("equal", "paper") else f"{wl}[{pol}]"
            out[tag] = res
            print(f"== Fig 9({'a' if wl == 'heavy' else 'b'}) {tag} ==")
            print(f"{'DNN':<18}{'baseline ms':>14}{'partitioned ms':>16}")
            for name in sorted(res.baseline.completion):
                b = res.baseline.completion[name] * 1e3
                p = res.partitioned.completion[name] * 1e3
                print(f"{name:<18}{b:14.3f}{p:16.3f}")
            print(f"makespan saving:   {res.time_saving*100:6.1f}% "
                  f"(paper reports {paper_time*100:.0f}%)")
            print(f"turnaround saving: {res.turnaround_saving*100:6.1f}%")
            print()
    return out


if __name__ == "__main__":
    run(policies=("equal", "width_aware"))
