"""Preemption + migration benchmark — BENCH_preempt.json.

    PYTHONPATH=src python benchmarks/preempt_bench.py

The runtime-adaptation companion to BENCH_traffic.json: bursty (MMPP) and
diurnal heavy-pool mixes are served with and without layer-granular
preemption (``deadline_preempt`` + ``PreemptionModel``) and cross-node
migration (``migrate_on_pressure``), on the *identical* arrival streams.

Two blocks:

* **single** — one saturated 128x128 array, high co-residency: preemption
  off vs on, per (process, load) cell, with exact energy accounting
  (``keep_trace=True`` + the sim backend's Accelergy-style model) so the
  drain/re-stage overhead is priced, not just counted;
* **fleet** — four arrays behind jsq dispatch: off vs migration-only vs
  preemption+migration.

The script asserts the headline acceptance criteria (bursty heavy mix:
preemption strictly improves p99 latency and deadline-miss rate; the
adaptation counters actually fire), so CI fails on a behavioural
regression, then writes the machine-readable record.

Everything is seeded; two runs of this script are byte-identical.
"""

from __future__ import annotations

import json
import os
import sys
import time

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_preempt.json",
)

PROCESSES = ("mmpp", "diurnal")
SINGLE_LOADS = (1.0, 1.3)
FLEET_LOAD = 1.1
N_ARRAYS = 4
SLO_MULT = 3.0
JOBS_PER_CELL = 60
SEED = 0
REBALANCE_INTERVAL_S = 1e-3


def mean_service_s(pool: str) -> float:
    """Mean full-array sequential time of one job from ``pool`` — the one
    load normaliser shared with BENCH_traffic (same oracle, so the two
    benches' load factors stay comparable)."""
    try:
        from benchmarks.traffic_bench import mean_service_s as _svc
    except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
        from traffic_bench import mean_service_s as _svc
    return _svc(pool)


def _arrivals(proc: str, rate: float, horizon: float, slo: float):
    from repro.traffic import get_arrival_process

    kwargs = {"burst_factor": 6.0} if proc == "mmpp" else {}
    return get_arrival_process(
        proc,
        rate=rate,
        horizon=horizon,
        seed=SEED,
        pool="heavy",
        slo_s=slo,
        **kwargs,
    )


def _energy_j(res) -> float:
    """Total fleet energy of a ``keep_trace=True`` serve run (layer shapes
    rebuilt from the traced tenants' model names)."""
    from repro.api import resolve_backend

    backend = resolve_backend("sim")
    return sum(
        backend.energy(sched, _layers_of(sched), baseline_pe=False).total
        for sched in res.schedules
    )


def _layers_of(sched) -> dict:
    from repro.sim.workloads import MODELS

    out = {}
    for ev in sched.trace:
        key = (ev.tenant, ev.layer_index)
        if key not in out:
            model = ev.tenant.split("#", 1)[0]
            out[key] = MODELS[model]().layers[ev.layer_index]
    return out


def _row(block: str, proc: str, load: float, mode: str, res, **extra) -> dict:
    return {
        "block": block,
        "process": proc,
        "load": load,
        "mode": mode,
        **res.as_dict(),
        **extra,
    }


def run(path: str = BENCH_JSON) -> dict:
    from repro.traffic import TrafficSimulator

    t_start = time.perf_counter()
    svc = mean_service_s("heavy")
    slo = SLO_MULT * svc
    rows = []
    print(f"pool=heavy  mean_service={svc * 1e3:.3f} ms  slo={slo * 1e3:.3f} ms")
    hdr = (
        f"{'block':>7}{'process':>9}{'load':>6}{'mode':>13}{'jobs':>6}"
        f"{'p99ms':>9}{'miss%':>7}{'npre':>6}{'nmig':>6}{'energy_x':>9}"
    )
    print(hdr)

    def show(row):
        print(
            f"{row['block']:>7}{row['process']:>9}{row['load']:>6.1f}"
            f"{row['mode']:>13}{row['jobs_arrived']:>6}"
            f"{row['p99_latency_s'] * 1e3:>9.2f}"
            f"{row['deadline_miss_rate'] * 100:>7.1f}"
            f"{row.get('preemptions', 0):>6}{row.get('migrations', 0):>6}"
            f"{row.get('energy_overhead', float('nan')):>9.4f}"
        )

    # -- single-array block: preemption off vs on, exact energy ------------
    for proc in PROCESSES:
        for load in SINGLE_LOADS:
            rate = load / svc
            horizon = JOBS_PER_CELL / rate
            arr = _arrivals(proc, rate, horizon, slo)
            base = TrafficSimulator(
                arr,
                policy="equal",
                max_concurrent=8,
                queue_cap=8,
                seed=SEED,
                keep_trace=True,
            ).run()
            pre = TrafficSimulator(
                arr,
                policy="deadline_preempt",
                max_concurrent=8,
                queue_cap=8,
                seed=SEED,
                keep_trace=True,
                preemption=True,
            ).run()
            e_base, e_pre = _energy_j(base), _energy_j(pre)
            rows.append(_row("single", proc, load, "off", base, energy_j=e_base))
            show(rows[-1])
            rows.append(
                _row(
                    "single",
                    proc,
                    load,
                    "preempt",
                    pre,
                    energy_j=e_pre,
                    energy_overhead=e_pre / e_base - 1.0,
                )
            )
            show(rows[-1])

    # -- fleet block: off vs migrate vs preempt+migrate --------------------
    rate = N_ARRAYS * FLEET_LOAD / svc
    horizon = N_ARRAYS * JOBS_PER_CELL / rate
    fleet_modes = {
        "off": dict(policy="equal"),
        "migrate": dict(policy="equal", rebalance_interval=REBALANCE_INTERVAL_S),
        "pre+migrate": dict(
            policy="deadline_preempt",
            preemption=True,
            rebalance_interval=REBALANCE_INTERVAL_S,
        ),
    }
    for proc in PROCESSES:
        arr = _arrivals(proc, rate, horizon, slo)
        for mode, kwargs in fleet_modes.items():
            res = TrafficSimulator(
                arr,
                n_arrays=N_ARRAYS,
                max_concurrent=4,
                queue_cap=8,
                seed=SEED,
                **kwargs,
            ).run()
            rows.append(_row("fleet", proc, FLEET_LOAD, mode, res))
            show(rows[-1])

    # -- acceptance assertions (CI fails on behavioural regression) --------
    def cell(block, proc, load, mode):
        for r in rows:
            if (r["block"], r["process"], r["load"], r["mode"]) == (
                block,
                proc,
                load,
                mode,
            ):
                return r
        raise KeyError((block, proc, load, mode))

    for load in SINGLE_LOADS:
        off = cell("single", "mmpp", load, "off")
        on = cell("single", "mmpp", load, "preempt")
        assert on["p99_latency_s"] < off["p99_latency_s"], (
            f"preemption must cut p99 on the bursty heavy mix (load {load}): "
            f"{on['p99_latency_s']} vs {off['p99_latency_s']}"
        )
        assert on["deadline_miss_rate"] <= off["deadline_miss_rate"], (
            f"preemption must not raise the miss rate (load {load})"
        )
    f_off = cell("fleet", "mmpp", FLEET_LOAD, "off")
    f_on = cell("fleet", "mmpp", FLEET_LOAD, "pre+migrate")
    assert f_on["p99_latency_s"] < f_off["p99_latency_s"], (
        "preemption+migration must cut fleet p99 on the bursty heavy mix"
    )
    assert f_on["deadline_miss_rate"] < f_off["deadline_miss_rate"], (
        "preemption+migration must cut the fleet deadline-miss rate"
    )
    assert any(r.get("preemptions", 0) > 0 for r in rows), (
        "no cell ever preempted — the preemption path is dead"
    )
    assert any(r.get("migrations", 0) > 0 for r in rows), (
        "no cell ever migrated — the migration path is dead"
    )

    blob = {
        "benchmark": "preempt",
        "backend": "sim",
        "pool": "heavy",
        "seed": SEED,
        "mean_service_s": svc,
        "slo_s": slo,
        "rebalance_interval_s": REBALANCE_INTERVAL_S,
        "results": rows,
    }
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    print(f"end-to-end {time.perf_counter() - t_start:.2f}s")
    print(f"wrote {path}")
    return blob


if __name__ == "__main__":
    run()
    sys.exit(0)
