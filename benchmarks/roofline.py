"""Roofline analysis per (arch × shape × mesh) from the dry-run artifacts.

Three terms, all in seconds-per-step on TPU v5e hardware constants:

    compute    = HLO_FLOPs_per_device  / 197 TFLOP/s (bf16, per chip)
    memory     = HLO_bytes_per_device  / 819 GB/s HBM
    collective = collective_bytes_per_device / 50 GB/s ICI link

plus the model-FLOPs accounting that catches remat/redundancy waste:

    MODEL_FLOPS (train)   = 6·N·D   (N params — active for MoE; D tokens)
    MODEL_FLOPS (prefill) = 2·N·D
    MODEL_FLOPS (decode)  = 2·N·B   (one token per live row)

    useful_ratio = MODEL_FLOPS/chips / HLO_FLOPs-per-device
    roofline_fraction = (MODEL_FLOPS/chips / PEAK) / max(term)
       — "of the time the dominant wall imposes, how much is useful math"
       — THE §Perf score.

Reads benchmarks/results/dryrun.json (produced by repro.launch.dryrun);
writes benchmarks/results/roofline.{json,md}.
"""

from __future__ import annotations

import json
import os

import jax

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _param_counts(arch_id: str) -> tuple[int, int]:
    """(total, active) parameter counts; active discounts unrouted experts."""
    from repro.configs import get, params_spec
    spec = get(arch_id)
    cfg = spec.model
    tree = params_spec(cfg)
    total = moe = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        names = [str(getattr(k, "key", k)) for k in path]
        total += leaf.size
        if "moe" in names and names[-1] in ("gate", "up", "down"):
            moe += leaf.size
    active = total
    if cfg.n_experts:
        active = total - moe + moe * cfg.top_k / cfg.n_experts
    return int(total), int(active)


def _model_flops(arch_id: str, cell_name: str) -> float:
    from repro.configs import get
    spec = get(arch_id)
    cell = spec.cell(cell_name)
    _, active = _param_counts(arch_id)
    if cell.kind == "train":
        return 6.0 * active * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * active * cell.global_batch * cell.seq_len
    return 2.0 * active * cell.global_batch  # decode: 1 token per row


def _advice(dom: str, kind: str, rec: dict) -> str:
    if dom == "collective":
        kinds = rec.get("collectives", {})
        big = max(kinds, key=lambda k: kinds[k][1]) if kinds else "?"
        return (f"dominated by {big}: reshard to keep the operand local "
                f"(layer-scan weights resident / cache partial-softmax) or "
                f"overlap with compute")
    if dom == "memory":
        if kind == "decode":
            return ("decode is weight+cache streaming: raise live batch, "
                    "quantize KV cache, or fuse layers to reuse resident "
                    "weights")
        return ("HBM-bound: increase arithmetic intensity — bigger matmul "
                "tiles, fewer remat passes, bf16 end-to-end")
    return ("compute-bound (the good wall): recover the useful_ratio gap — "
            "cut remat recompute and attention-mask waste")


def analyse(dryrun_path: str | None = None) -> dict:
    path = dryrun_path or os.path.join(RESULTS, "dryrun.json")
    with open(path) as f:
        dry = json.load(f)

    out: dict[str, dict] = {}
    for key, rec in sorted(dry.items()):
        if not rec.get("ok"):
            continue
        arch, cell, mesh = rec["arch"], rec["cell"], rec["mesh"]
        chips = rec["chips"]
        kind = ("train" if cell.startswith("train")
                else "prefill" if cell.startswith("prefill") else "decode")

        t_comp = rec["flops_per_device"] / PEAK_FLOPS
        t_mem = rec["bytes_per_device"] / HBM_BW
        t_coll = rec["collective_bytes"] / ICI_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)

        mflops = _model_flops(arch, cell)
        useful = mflops / chips / max(rec["flops_per_device"], 1e-9)
        frac = (mflops / chips / PEAK_FLOPS) / max(max(terms.values()),
                                                   1e-12)
        out[key] = {
            "arch": arch, "cell": cell, "mesh": mesh, "chips": chips,
            "kind": kind,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": dom,
            "model_flops": mflops,
            "useful_ratio": useful,
            "roofline_fraction": frac,
            "advice": _advice(dom, kind, rec),
        }
    return out


def to_markdown(rows: dict, mesh: str = "16x16") -> str:
    lines = [
        f"### Roofline — {mesh} mesh "
        f"(v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | cell | compute s | memory s | collective s | dominant | "
        "useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(rows):
        r = rows[key]
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} |")
    return "\n".join(lines)


def compare_tuned(base_path: str | None = None,
                  tuned_path: str | None = None) -> str:
    """Baseline (16×16 generic) vs tuned (per-arch mesh + Q-chunking)
    roofline fractions for every runnable cell — the fleet-wide §Perf
    table.  Requires dryrun.json + dryrun_tuned.json."""
    base = analyse(base_path)
    tuned = analyse(tuned_path or os.path.join(RESULTS,
                                               "dryrun_tuned.json"))
    by_cell_b = {(r["arch"], r["cell"]): r for r in base.values()
                 if r["mesh"] == "16x16"}
    by_cell_t = {(r["arch"], r["cell"]): r for r in tuned.values()
                 if not r["mesh"].startswith("2x")}
    lines = ["### Fleet-wide baseline vs tuned (single-pod)",
             "",
             "| arch | cell | rf base | rf tuned | gain | dominant "
             "base→tuned |",
             "|---|---|---|---|---|---|"]
    gains = []
    for key in sorted(by_cell_b):
        if key not in by_cell_t:
            continue
        b, t = by_cell_b[key], by_cell_t[key]
        gain = t["roofline_fraction"] / max(b["roofline_fraction"], 1e-12)
        gains.append(gain)
        lines.append(
            f"| {key[0]} | {key[1]} | {b['roofline_fraction']:.2e} | "
            f"{t['roofline_fraction']:.2e} | {gain:.2f}× | "
            f"{b['dominant']}→{t['dominant']} |")
    if gains:
        import math
        geo = math.exp(sum(math.log(g) for g in gains) / len(gains))
        lines.append("")
        lines.append(f"geometric-mean gain over {len(gains)} cells: "
                     f"**{geo:.2f}×**")
    return "\n".join(lines)


def run() -> dict:
    rows = analyse()
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1, sort_keys=True)
    md = to_markdown(rows, "16x16") + "\n\n" + to_markdown(rows, "2x16x16")
    with open(os.path.join(RESULTS, "roofline.md"), "w") as f:
        f.write(md + "\n")
    print(md)
    # summary: worst cells per criterion (the hillclimb candidates)
    single = {k: r for k, r in rows.items() if r["mesh"] == "16x16"}
    if single:
        worst = min(single.values(), key=lambda r: r["roofline_fraction"])
        collb = max(single.values(), key=lambda r: r["t_collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']}|{worst['cell']} "
              f"({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound:   {collb['arch']}|{collb['cell']} "
              f"({collb['t_collective_s']:.3e}s)")
    tuned_path = os.path.join(RESULTS, "dryrun_tuned.json")
    if os.path.exists(tuned_path):
        cmp_md = compare_tuned()
        with open(os.path.join(RESULTS, "roofline_tuned.md"), "w") as f:
            f.write(cmp_md + "\n")
        print("\n" + cmp_md)
    return rows


if __name__ == "__main__":
    run()
