"""Tests for `repro.fairness` (DRF, min-cost flow, accounting), the
``batch_instance`` production-trace loader, and the sharded fleet
simulator's determinism contract.

The sharded contract is the load-bearing part: `repro.traffic.sharded`
claims (1) invariance to shard count and serial/parallel mode for every
dispatcher, and (2) byte-identity with the single-process simulator under
``rr`` dispatch.  Both are asserted here on real runs — the same flags
BENCH_fairness.json pins via check_regression.
"""

import itertools
import json
import math
import random

import pytest

from repro.core.dnng import LayerShape
from repro.fairness import (
    DRFPolicy,
    FairnessAccounting,
    MinCostFlowPolicy,
    ResourceModel,
    jain_index,
    min_cost_assignment,
)
from repro.api.policy import TenantDemand, get_policy, list_policies


def _layer(M=64, C=32, R=1, S=1, N=1, H=8, W=8, P=8, Q=8):
    return LayerShape(M=M, N=N, C=C, R=R, S=S, H=H, W=W, P=P, Q=Q)


# ---------------------------------------------------------------------------
# jain index
# ---------------------------------------------------------------------------

class TestJainIndex:
    def test_equal_is_one(self):
        assert jain_index([3.0, 3.0, 3.0]) == pytest.approx(1.0)

    def test_one_dominates_is_one_over_n(self):
        assert jain_index([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)

    def test_empty_is_nan(self):
        assert math.isnan(jain_index([]))

    def test_all_zero_is_one(self):
        assert jain_index([0.0, 0.0]) == 1.0

    def test_bounded(self):
        rng = random.Random(0)
        for _ in range(50):
            xs = [rng.random() for _ in range(rng.randint(1, 9))]
            j = jain_index(xs)
            assert 1.0 / len(xs) - 1e-12 <= j <= 1.0 + 1e-12


# ---------------------------------------------------------------------------
# DRF
# ---------------------------------------------------------------------------

class TestResourceModel:
    def test_per_col_vector_positive(self):
        vec = ResourceModel().per_col_vector(_layer(), 128)
        assert len(vec) == 3 and all(v > 0 for v in vec)

    def test_dominant_is_max(self):
        res = ResourceModel()
        layer = _layer(M=8, C=512)  # few columns, heavy per-column traffic
        assert res.dominant_per_col(layer, 128) == \
            max(res.per_col_vector(layer, 128))


class TestDRFPolicy:
    def test_registered_and_lazy_loaded(self):
        assert "drf" in list_policies()
        pol = get_policy("drf")
        assert isinstance(pol, DRFPolicy) and pol.name == "drf"

    def test_columns_only_fallback_is_equal_split(self):
        # no layer on the demand -> single-resource DRF == max-min columns
        ws = DRFPolicy().widths(128, [TenantDemand("a", demand=100.0),
                                      TenantDemand("b", demand=1.0)])
        assert ws == {"a": 64, "b": 64}

    def test_widths_partition_exactly(self):
        ts = [TenantDemand(f"t{i}", demand=float(i + 1),
                           layer=_layer(M=32 * (i + 1), C=16 * (i + 1)))
              for i in range(4)]
        ws = DRFPolicy().widths(128, ts)
        assert sum(ws.values()) == 128
        assert all(w >= 1 for w in ws.values())

    def test_floors_respected(self):
        ts = [TenantDemand("a", demand=1.0, min_cols=48, layer=_layer()),
              TenantDemand("b", demand=1.0, layer=_layer(M=512, C=1024))]
        ws = DRFPolicy().widths(64, ts)
        assert ws["a"] >= 48

    def test_width_demand_saturates(self):
        ts = [TenantDemand("a", demand=1.0, width_demand=8, layer=_layer()),
              TenantDemand("b", demand=1.0, layer=_layer())]
        ws = DRFPolicy().widths(128, ts)
        assert ws["a"] == 8
        assert ws["b"] == 120  # leftover keeps filling the unsaturated one

    def test_dominant_shares_equalized(self):
        # bus-heavy vs compute-light: DRF should grant FEWER columns to the
        # tenant whose per-column dominant increment is larger, ending with
        # near-equal dominant shares (within one grant's increment)
        pol = DRFPolicy()
        # huge stage traffic (K·(N+M_gemm)) over few columns: bus-bound
        heavy = _layer(M=16, C=4096, P=32, Q=32)
        light = _layer(M=512, C=8)
        ts = [TenantDemand("heavy", demand=1.0, layer=heavy),
              TenantDemand("light", demand=1.0, layer=light)]
        ws = pol.widths(128, ts)
        assert ws["heavy"] < ws["light"]
        s_h = pol.dominant_share(heavy, ws["heavy"], 128)
        s_l = pol.dominant_share(light, ws["light"], 128)
        step = max(pol.resources.dominant_per_col(heavy, 128),
                   pol.resources.dominant_per_col(light, 128))
        assert abs(s_h - s_l) <= step + 1e-12

    def test_strategy_proof_against_opr_inflation(self):
        # demand (Opr) is not a DRF input: inflating it must not move widths
        layer = _layer()
        base = [TenantDemand("a", demand=1.0, layer=layer),
                TenantDemand("b", demand=1.0, layer=_layer(M=16, C=256))]
        puffed = [TenantDemand("a", demand=1e9, layer=layer), base[1]]
        assert DRFPolicy().widths(64, base) == DRFPolicy().widths(64, puffed)

    def test_deterministic(self):
        ts = [TenantDemand(f"t{i}", demand=1.0,
                           layer=_layer(M=17 * (i + 1), C=5 * (i + 2)))
              for i in range(5)]
        ws = [DRFPolicy().widths(96, ts) for _ in range(3)]
        assert ws[0] == ws[1] == ws[2]


# ---------------------------------------------------------------------------
# min-cost flow
# ---------------------------------------------------------------------------

def _brute_min_cost(costs):
    """Exhaustive max-cardinality min-cost matching total (finite costs)."""
    n, m = len(costs), len(costs[0])
    best = None
    k = min(n, m)
    for rows in itertools.combinations(range(n), k):
        for cols in itertools.permutations(range(m), k):
            total = sum(costs[i][j] for i, j in zip(rows, cols))
            best = total if best is None else min(best, total)
    return best


class TestMinCostAssignment:
    def test_matches_brute_force(self):
        rng = random.Random(4)
        for _ in range(25):
            n, m = rng.randint(1, 4), rng.randint(1, 4)
            costs = [[rng.uniform(0.0, 10.0) for _ in range(m)]
                     for _ in range(n)]
            pairs = min_cost_assignment(costs)
            assert len(pairs) == min(n, m)
            assert len({i for i, _ in pairs}) == len(pairs)
            assert len({j for _, j in pairs}) == len(pairs)
            total = sum(costs[i][j] for i, j in pairs)
            assert total == pytest.approx(_brute_min_cost(costs))

    def test_max_cardinality_beats_cost(self):
        # matching both (cost 2+1=3) beats matching only the cheap one
        inf = math.inf
        assert min_cost_assignment([[2.0, inf], [1.0, 1.0]]) == \
            [(0, 0), (1, 1)]

    def test_inf_edges_forbidden(self):
        inf = math.inf
        assert min_cost_assignment([[inf, 2.0], [inf, 1.0]]) == [(1, 1)]
        assert min_cost_assignment([[inf, inf]]) == []

    def test_empty(self):
        assert min_cost_assignment([]) == []

    def test_deterministic_under_ties(self):
        costs = [[1.0, 1.0], [1.0, 1.0]]
        assert [min_cost_assignment(costs) for _ in range(3)] == \
            [[(0, 0), (1, 1)]] * 3


class TestMinCostFlowPolicy:
    def test_registered(self):
        assert "min_cost_flow" in list_policies()
        pol = get_policy("min_cost_flow")
        assert isinstance(pol, MinCostFlowPolicy)
        assert pol.name == "min_cost_flow"

    def test_bad_width_factor_rejected(self):
        with pytest.raises(ValueError):
            MinCostFlowPolicy(max_width_factor=0.5)
        # a known name with bad kwargs must surface the constructor error,
        # not an unknown-policy error (lazy-load guard in get_policy)
        with pytest.raises(ValueError):
            get_policy("min_cost_flow", max_width_factor=0.5)

    def test_schedules_end_to_end(self):
        from repro.api.backend import resolve_backend
        from repro.core.scheduler import schedule_dynamic
        from repro.sim.workloads import MODELS

        b = resolve_backend("sim")
        dnngs = [MODELS[n]() for n in ("MelodyLSTM", "DeepVoice", "NCF")]
        res = schedule_dynamic(dnngs, b.array, b.time_fn(),
                               stage=b.stage_model(),
                               policy="min_cost_flow")
        assert set(res.completion) == {g.name for g in dnngs}
        assert res.makespan > 0


# ---------------------------------------------------------------------------
# fairness accounting
# ---------------------------------------------------------------------------

class TestFairnessAccounting:
    def _serve(self, policy, **kwargs):
        from repro.traffic import PoissonArrivals, TrafficSimulator
        arr = PoissonArrivals(rate=2500.0, horizon=0.02, seed=7,
                              pool="light", slo_s=0.02)
        return TrafficSimulator(arr, policy=policy, backend="sim",
                                n_arrays=2, seed=7, fairness=True,
                                **kwargs).run()

    def test_report_attached_and_gated_fields_set(self):
        res = self._serve("drf")
        rep = res.fairness
        assert rep is not None
        assert 0.0 < rep.jain_fairness <= 1.0 + 1e-12
        assert rep.per_tenant_slowdown
        assert all(s > 0 for s in rep.per_tenant_slowdown.values())
        assert res.metrics.jain_fairness == rep.jain_fairness
        assert rep.dominant_share_series  # sampled at every arrival

    def test_dominant_share_gate(self):
        res = self._serve("equal")
        d = res.as_dict()
        assert 0.0 < d["jain_dominant_share"] <= 1.0 + 1e-12
        assert all(v >= 0 for v in d["dominant_share_mean"].values())

    def test_baseline_memoized_per_model(self):
        from repro.api.backend import resolve_backend
        from repro.traffic import PoissonArrivals
        b = resolve_backend("sim")
        acct = FairnessAccounting(b.array, b.time_fn(),
                                  stage=b.stage_model())
        jobs = list(PoissonArrivals(rate=2000.0, horizon=0.01, seed=1,
                                    pool="light"))
        for job in jobs:
            acct.observe(job)
        models = {j.model for j in jobs}
        assert all(acct.baseline(m) is acct.baseline(m) for m in models)
        assert all(acct.isolated_s(m) > 0 for m in models)
        assert acct.baseline("NoSuchModel") is None

    def test_slowdown_is_latency_over_isolated(self):
        res = self._serve("equal")
        # recompute one model's slowdown from raw records + baselines
        from repro.api.backend import resolve_backend
        b = resolve_backend("sim")
        acct = FairnessAccounting(b.array, b.time_fn(),
                                  stage=b.stage_model())
        by_model = {}
        for r in res.records:
            if r.latency is not None:
                by_model.setdefault(r.model, []).append(r.latency)
        model, lats = sorted(by_model.items())[0]
        template = next(rec for rec in res.records if rec.model == model)
        # rebuild the template DNNG the simulator observed
        from repro.sim.workloads import MODELS
        acct.observe(type("J", (), {
            "model": model, "dnng": MODELS[model]().clone(arrival_time=0.0),
        })())
        want = sum(lats) / len(lats) / acct.isolated_s(model)
        assert res.fairness.per_tenant_slowdown[model] == \
            pytest.approx(want)
        assert template is not None


# ---------------------------------------------------------------------------
# batch_instance trace loader
# ---------------------------------------------------------------------------

class TestBatchInstanceArrivals:
    def _rows(self, n=200, seed=0):
        from repro.traffic import synth_batch_instance_rows
        return synth_batch_instance_rows(n, seed=seed)

    def test_registry_and_shape(self):
        from repro.traffic import resolve_arrivals
        arr = resolve_arrivals("batch_instance", source=self._rows(),
                               pool="heavy", seed=1)
        jobs = list(arr)
        assert jobs and arr.name == "batch_instance"
        assert all(jobs[i].arrival <= jobs[i + 1].arrival
                   for i in range(len(jobs) - 1))
        assert jobs[0].job_id == 0
        assert all(0.0 <= j.arrival < arr.horizon for j in jobs)

    def test_non_terminated_rows_dropped(self):
        from repro.traffic import BatchInstanceArrivals
        rows = self._rows(400)
        kept = BatchInstanceArrivals(rows, pool="light")
        dropped = sum(1 for r in rows[1:] if ",Terminated," not in r)
        assert dropped > 0   # the synth helper plants non-Terminated rows
        assert len(list(kept)) == len(rows) - 1 - dropped
        everything = BatchInstanceArrivals(
            rows, pool="light",
            keep_status=("Terminated", "Failed", "Running"))
        assert len(list(everything)) == len(rows) - 1

    def test_malformed_rows_skipped(self):
        from repro.traffic import BatchInstanceArrivals
        rows = self._rows(50) + ["bad,row", "i,j,1,Terminated,zzz,5,100,1"]
        a = BatchInstanceArrivals(rows, pool="light")
        b = BatchInstanceArrivals(self._rows(50), pool="light")
        assert len(list(a)) == len(list(b))

    def test_deterministic_and_seed_sensitive(self):
        from repro.traffic import BatchInstanceArrivals
        rows = self._rows()
        def sig(a):
            return [(j.arrival, j.model, j.tier) for j in a]

        assert sig(BatchInstanceArrivals(rows, seed=3, pool="heavy")) == \
            sig(BatchInstanceArrivals(rows, seed=3, pool="heavy"))
        assert sig(BatchInstanceArrivals(rows, seed=3, pool="heavy")) != \
            sig(BatchInstanceArrivals(rows, seed=4, pool="heavy"))

    def test_tiers_follow_plan_cpu(self):
        from repro.traffic import BatchInstanceArrivals
        jobs = list(BatchInstanceArrivals(self._rows(300), pool="light",
                                          slo_s=0.05, cpu_hi=100.0))
        tiers = {j.tier for j in jobs}
        assert tiers == {0, 1}   # synth mixes sub- and super-100 plan_cpu
        for j in jobs:
            slack = 0.05 * (1 + j.tier)
            assert j.deadline - j.arrival == pytest.approx(slack)

    def test_work_rank_maps_onto_pool(self):
        from repro.traffic import BatchInstanceArrivals
        from repro.sim.workloads import MODEL_POOLS
        jobs = list(BatchInstanceArrivals(self._rows(300), pool="heavy"))
        assert {j.model for j in jobs} <= set(MODEL_POOLS["heavy"])
        assert len({j.model for j in jobs}) > 1

    def test_file_source(self, tmp_path):
        from repro.traffic import BatchInstanceArrivals
        p = tmp_path / "trace.csv"
        p.write_text("\n".join(self._rows(60)) + "\n")
        assert [j.model for j in BatchInstanceArrivals(str(p),
                                                       pool="light")] == \
            [j.model for j in BatchInstanceArrivals(self._rows(60),
                                                    pool="light")]

    def test_unusable_input_rejected(self):
        from repro.traffic import BatchInstanceArrivals
        with pytest.raises(ValueError):
            BatchInstanceArrivals(self._rows(20), time_scale=0.0)
        with pytest.raises(ValueError):   # everything filtered out
            BatchInstanceArrivals(self._rows(20), keep_status=("Nope",))

    def test_serves_end_to_end(self):
        from repro.traffic import TrafficSimulator, resolve_arrivals
        arr = resolve_arrivals("batch_instance", source=self._rows(150),
                               pool="light", seed=0)
        res = TrafficSimulator(arr, policy="drf", backend="sim",
                               n_arrays=2, seed=0).run()
        assert res.metrics.jobs_arrived == len(list(arr))
        assert res.metrics.jobs_completed > 0


# ---------------------------------------------------------------------------
# sharded fleet simulator
# ---------------------------------------------------------------------------

KW = dict(rate=3000.0, horizon=0.04, pool="light", slo_s=0.02)


def _sharded(dispatch, n_shards, parallel, policy="drf", **extra):
    from repro.traffic import ShardedTrafficSimulator
    return ShardedTrafficSimulator(
        "poisson", policy=policy, backend="sim", n_arrays=8,
        n_shards=n_shards, dispatch=dispatch, seed=3, sync_every=16,
        parallel=parallel, **KW, **extra).run()


class TestShardedSimulator:
    def test_rr_byte_identical_to_single_process(self):
        from repro.traffic import TrafficSimulator
        plain = TrafficSimulator("poisson", policy="drf", backend="sim",
                                 n_arrays=8, dispatch="rr", seed=3,
                                 **KW).run()
        for n_shards, parallel in ((1, False), (2, False), (4, True),
                                   (8, True)):
            sh = _sharded("rr", n_shards, parallel)
            assert sh.records == plain.records
            assert sh.metrics == plain.metrics
            assert json.dumps(sh.as_dict()) == json.dumps(plain.as_dict())

    @pytest.mark.parametrize("dispatch", ["jsq", "p2c"])
    def test_invariant_to_shards_and_mode(self, dispatch):
        ref = _sharded(dispatch, 1, False)
        for n_shards, parallel in ((2, False), (4, False), (4, True)):
            sh = _sharded(dispatch, n_shards, parallel)
            assert sh.records == ref.records
            assert sh.metrics == ref.metrics

    def test_depth_samples_sum_exactly(self):
        # queue_depth_mean is derived from the per-arrival element-wise sum
        # of pod-local samples; rr identity already pins it, this pins the
        # jsq path (no single-process twin exists for stale-load routing)
        a = _sharded("jsq", 2, False)
        b = _sharded("jsq", 8, False)
        assert a.metrics.queue_depth_mean == b.metrics.queue_depth_mean
        assert a.metrics.queue_depth_max == b.metrics.queue_depth_max

    def test_fairness_slowdowns_match_single_process(self):
        # merged-record slowdowns must equal the single-loop computation
        from repro.traffic import TrafficSimulator
        plain = TrafficSimulator("poisson", policy="equal", backend="sim",
                                 n_arrays=8, dispatch="rr", seed=3,
                                 fairness=True, **KW).run()
        sh = _sharded("rr", 4, False, policy="equal", fairness=True)
        assert sh.metrics.jain_fairness == plain.metrics.jain_fairness
        assert sh.metrics.per_tenant_slowdown == \
            plain.metrics.per_tenant_slowdown
        assert sh.metrics.jain_dominant_share is None

    def test_validation(self):
        from repro.traffic import ShardedTrafficSimulator
        from repro.api.policy import resolve_policy
        with pytest.raises(ValueError):
            ShardedTrafficSimulator("poisson", n_arrays=2, n_shards=4,
                                    **KW)
        with pytest.raises(ValueError):
            ShardedTrafficSimulator("poisson", n_arrays=4, n_shards=2,
                                    sync_every=0, **KW)
        with pytest.raises(ValueError):   # instances cannot be replicated
            ShardedTrafficSimulator("poisson",
                                    policy=resolve_policy("equal"),
                                    n_arrays=4, n_shards=2, **KW)

    def test_preemption_plumbs_through(self):
        sh = _sharded("rr", 2, False, policy="deadline_preempt",
                      preemption=True)
        assert sh.preemption == "PreemptionModel"
        assert sh.metrics.jobs_completed > 0
