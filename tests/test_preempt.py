"""Preemption + migration tests (scheduler hook, rebalancer, dispatchers).

Runs without hypothesis — plain parametrised cases — so this module is part
of the hypothesis-optional tier-1 path.
"""

import dataclasses
import json
import os
import random

import pytest

from repro.api import Session
from repro.core.dnng import LayerShape, chain
from repro.core.partition import ArrayShape, Partition
from repro.core.scheduler import (
    DynamicScheduler,
    PreemptionModel,
    StageModel,
    TraceEvent,
    schedule_dynamic,
)
from repro.sim.systolic import SystolicConfig, layer_time_fn
from repro.traffic import (
    Job,
    JoinShortestQueue,
    MigrationModel,
    PowerOfTwoChoices,
    TrafficSimulator,
    list_rebalancers,
    resolve_rebalancer,
)
from repro.traffic.cluster import ArrayNode

DATA = os.path.join(os.path.dirname(__file__), "data")
FC = LayerShape.fc
ARRAY = ArrayShape(128, 128)
TIME_FN = layer_time_fn(SystolicConfig())


def _dnng(name, n_layers, size=256, arrival=0.0):
    return chain(
        name,
        [FC(f"l{i}", size, size, batch=size) for i in range(n_layers)],
        arrival_time=arrival,
    )


def _job(jid, arrival, n_layers=2, size=256, slo=1.0):
    g = _dnng(f"J#{jid}", n_layers, size=size, arrival=arrival)
    return Job(job_id=jid, arrival=arrival, dnng=g, deadline=arrival + slo)


# ---------------------------------------------------------------------------
# preemption-free invariant: armed model + hook-less policy change NOTHING
# ---------------------------------------------------------------------------


class TestPreemptionFreeInvariant:
    @pytest.mark.parametrize("workload", ["heavy", "light"])
    def test_byte_identical_to_seed_trace_with_model_armed(self, workload):
        """A PreemptionModel-armed run under `equal` (no preempt hook) must
        reproduce the pre-preemption golden trace bit for bit."""
        with open(os.path.join(DATA, f"seed_trace_{workload}.json")) as f:
            golden = json.load(f)
        from repro.sim import workloads as w

        dnngs = list(w.WORKLOADS[workload]())
        backend = Session(policy="equal").backend
        res = schedule_dynamic(
            dnngs,
            backend.array,
            backend.time_fn(),
            stage=backend.stage_model(),
            policy="equal",
            preemption=PreemptionModel(),
        )
        assert res.preemptions == 0
        assert res.makespan.hex() == golden["makespan"]
        completion_hex = {k: v.hex() for k, v in res.completion.items()}
        assert completion_hex == golden["completion"]
        assert len(res.trace) == len(golden["trace"])
        for e, g in zip(res.trace, golden["trace"]):
            got = (
                e.tenant,
                e.layer_index,
                e.partition.rows,
                e.partition.col_start,
                e.partition.cols,
                e.start.hex(),
                e.end.hex(),
                e.compute_start.hex(),
                e.compute_end.hex(),
            )
            want = (
                g["tenant"],
                g["layer_index"],
                g["rows"],
                g["col_start"],
                g["cols"],
                g["start"],
                g["end"],
                g["compute_start"],
                g["compute_end"],
            )
            assert got == want
            assert e.fraction == 1.0
            assert not e.preempted and not e.resumed

    def test_simulator_records_identical_with_hookless_policy(self):
        jobs = [_job(i, arrival=i * 1e-5, n_layers=2) for i in range(8)]
        plain = TrafficSimulator(jobs, policy="equal").run()
        armed = TrafficSimulator(jobs, policy="equal", preemption=True).run()
        assert armed.metrics.preemptions == 0
        assert armed.records == plain.records
        # the preemption knob is reported even when it never fired
        assert "preemptions" in armed.as_dict()
        assert "preemptions" not in plain.as_dict()


# ---------------------------------------------------------------------------
# the preemption mechanism itself
# ---------------------------------------------------------------------------


def _preempt_run(stage=None, arrival=1e-4, deadline=3e-4):
    big = chain("big", [FC("l0", 4096, 4096, batch=4096)])
    small = chain("small", [FC("s0", 64, 64, batch=32)], arrival_time=arrival)
    sched = DynamicScheduler(
        ARRAY,
        TIME_FN,
        stage=stage,
        policy="deadline_preempt",
        preemption=PreemptionModel(),
    )
    sched.submit(big)
    sched.submit(small, deadline=deadline)
    sched.run()
    return sched.result()


class TestPreemption:
    def test_urgent_job_preempts_long_layer(self):
        res = _preempt_run()
        assert res.preemptions == 1
        assert res.completion["small"] <= 3e-4
        # without preemption the small job waits out the whole big layer
        big = chain("big", [FC("l0", 4096, 4096, batch=4096)])
        small = chain("small", [FC("s0", 64, 64, batch=32)], arrival_time=1e-4)
        base = schedule_dynamic([big, small], ARRAY, TIME_FN, policy="equal")
        assert res.completion["small"] < base.completion["small"] / 10

    def test_segment_fractions_sum_to_one(self):
        res = _preempt_run()
        segs = [e for e in res.trace if e.tenant == "big"]
        assert len(segs) == 2
        assert segs[0].preempted and not segs[0].resumed
        assert segs[1].resumed and not segs[1].preempted
        assert sum(e.fraction for e in segs) == pytest.approx(1.0, abs=1e-12)

    def test_busy_pe_seconds_match_trace(self):
        res = _preempt_run()
        derived = sum(e.compute_duration * e.partition.n_pes for e in res.trace)
        assert res.pe_seconds_busy == pytest.approx(derived)

    def test_stage_in_eviction_pays_fixed_overhead_only(self):
        """A victim caught before compute starts has no psums to drain: the
        partition frees after just the fixed quiesce overhead."""
        res = _preempt_run(stage=StageModel(), arrival=1e-6, deadline=1e-4)
        seg = next(e for e in res.trace if e.tenant == "big" and e.preempted)
        assert seg.fraction == 0.0
        assert seg.compute_duration == 0.0
        assert seg.end - seg.compute_end == pytest.approx(
            PreemptionModel().fixed_overhead_s
        )

    def test_drain_cost_scales_with_partition(self):
        model = PreemptionModel()
        narrow = Partition(rows=128, col_start=0, cols=8)
        wide = Partition(rows=128, col_start=0, cols=128)
        assert model.drain_s(wide) > model.drain_s(narrow) > 0.0

    def test_bus_abort_only_reclaims_tail_reservations(self):
        from repro.core.scheduler import _Bus

        bus = _Bus()
        bus.acquire(0.0, 10.0)  # tenant A: [0, 10)
        bus.acquire(0.0, 4.0)  # tenant B stage-in queued behind: [10, 14)
        bus.abort_reservation(2.0, 10.0, 14.0)  # B preempted at t=2
        assert bus.free_at == 10.0  # A's committed window is untouched
        assert bus.busy_s == pytest.approx(10.0)
        # a reservation that is NOT the bus tail is sunk cost: no reclaim
        bus2 = _Bus()
        bus2.acquire(0.0, 10.0)
        bus2.acquire(0.0, 4.0)
        bus2.acquire(0.0, 3.0)  # tenant C behind B: [14, 17)
        bus2.abort_reservation(2.0, 10.0, 14.0)
        assert bus2.free_at == 17.0
        assert bus2.busy_s == pytest.approx(17.0)

    def test_withdraw_only_pristine_tenants(self):
        sched = DynamicScheduler(ARRAY, TIME_FN, policy="equal")
        sched.submit(_dnng("a", 2))
        sched.submit(_dnng("b", 2, arrival=1e-3))
        sched.run_until(1e-6)  # a launched; b still pending arrival
        assert not sched.withdraw("a")  # in flight: has array state
        assert sched.withdraw("b")
        assert not sched.withdraw("b")  # already gone
        sched.run()
        assert set(sched.completion) == {"a"}


class TestPreemptionEnergy:
    def test_segmented_trace_energy_adds_only_overhead(self):
        """Two segments covering fractions f and 1-f must cost exactly the
        whole layer plus the drain + re-stage DRAM overhead."""
        from repro.sim.energy import EnergyModel, schedule_energy_with_layers
        from repro.core.scheduler import ScheduleResult

        layer = FC("l0", 512, 512, batch=512)
        part = Partition(rows=128, col_start=0, cols=64)
        whole = TraceEvent(
            tenant="t",
            layer_index=0,
            layer_name="l0",
            partition=part,
            start=0.0,
            end=1.0,
            compute_start=0.0,
            compute_end=1.0,
        )
        seg_a = dataclasses.replace(
            whole, end=0.25, compute_end=0.25, fraction=0.25, preempted=True
        )
        seg_b = dataclasses.replace(
            whole, start=0.5, compute_start=0.5, fraction=0.75, resumed=True
        )
        cfg = SystolicConfig()
        model = EnergyModel()
        layers = {("t", 0): layer}

        def energy(trace):
            res = ScheduleResult(
                trace=trace, completion={"t": 1.0}, makespan=1.0, array=ARRAY
            )
            return schedule_energy_with_layers(
                res, layers, cfg, model, baseline_pe=False
            )

        one = energy((whole,))
        two = energy((seg_a, seg_b))
        pj = 1e-12
        overhead = (
            model.e_dram_pj * 2 * part.n_pes * pj  # psum drain (fp32)
            + model.e_dram_pj * layer.gemm_k * layer.gemm_n * pj  # re-stage
        )
        assert two.mac_j == pytest.approx(one.mac_j, rel=1e-12)
        assert two.sram_j == pytest.approx(one.sram_j, rel=1e-12)
        assert two.dram_j == pytest.approx(one.dram_j + overhead, rel=1e-12)


# ---------------------------------------------------------------------------
# dispatchers: edge cases (satellite)
# ---------------------------------------------------------------------------


class TestDispatcherEdgeCases:
    def test_single_node_fleet_always_routes_to_zero(self):
        rng = random.Random(0)
        for load in (0, 3, 17):
            assert JoinShortestQueue().choose([load], rng) == 0
            assert PowerOfTwoChoices().choose([load], rng) == 0

    def test_jsq_all_equal_loads_is_lowest_index(self):
        rng = random.Random(0)
        assert JoinShortestQueue().choose([2, 2, 2, 2], rng) == 0

    def test_p2c_all_equal_loads_deterministic_under_seed(self):
        picks_a = [
            PowerOfTwoChoices().choose([1, 1, 1, 1], random.Random(7))
            for _ in range(5)
        ]
        picks_b = [
            PowerOfTwoChoices().choose([1, 1, 1, 1], random.Random(7))
            for _ in range(5)
        ]
        assert picks_a == picks_b
        # equal loads: the lower-indexed of the two sampled nodes wins
        rng = random.Random(7)
        i, j = random.Random(7).sample(range(4), 2)
        assert PowerOfTwoChoices().choose([1, 1, 1, 1], rng) == min(i, j)

    def test_p2c_prefers_less_loaded_sample(self):
        rng = random.Random(3)
        pick = PowerOfTwoChoices().choose([0, 100, 100, 100], rng)
        sampled = random.Random(3).sample(range(4), 2)
        expected = min(sampled, key=lambda k: ([0, 100, 100, 100][k], k))
        assert pick == expected


# ---------------------------------------------------------------------------
# migration
# ---------------------------------------------------------------------------


def _node(index, on_complete=lambda n, t, s: None, **kwargs):
    kwargs.setdefault("max_concurrent", 1)
    kwargs.setdefault("queue_cap", 4)
    kwargs.setdefault("keep_trace", True)
    return ArrayNode(
        index,
        ARRAY,
        TIME_FN,
        None,
        "equal",
        on_complete=on_complete,
        **kwargs,
    )


class TestMigration:
    def test_migration_model_checkpoint_bytes(self):
        g = _dnng("a", 3, size=64)
        light = MigrationModel()
        heavy = MigrationModel(include_weights=True)
        assert heavy.checkpoint_bytes(g) > light.checkpoint_bytes(g) > 0
        assert heavy.migrate_s(g) > light.migrate_s(g) > 0.0

    def test_registry(self):
        assert "migrate_on_pressure" in list_rebalancers()
        with pytest.raises(ValueError):
            resolve_rebalancer("bogus")

    def test_take_queued_job_and_admit_on_peer(self):
        src, dst = _node(0), _node(1)
        j_run = _job(0, arrival=0.0, n_layers=4)
        j_wait = _job(1, arrival=0.0, slo=10.0)
        assert src.offer(j_run) == "run"
        assert src.offer(j_wait) == "queued"
        taken = src.take_for_migration("J#1")
        assert taken is j_wait
        assert src.queue == [] and "J#1" not in src.jobs
        delay = 5e-4
        assert dst.admit_migrated(taken, now=0.0, ready_at=delay) == "run"
        dst.scheduler.run()
        # the job could not start before its checkpoint arrived
        assert dst.scheduler.completion["J#1"] >= delay

    def test_admit_migrated_queues_until_checkpoint_arrives(self):
        dst = _node(1)
        dst.offer(_job(0, arrival=0.0, n_layers=4))  # saturates the slot
        delay = 5e-4
        status = dst.admit_migrated(_job(9, arrival=0.0), now=0.0, ready_at=delay)
        assert status == "queued" and len(dst.queue) == 1
        dst.scheduler.run()  # J#0 completes -> J#9 promoted, transit honored
        assert dst.scheduler.completion["J#9"] >= delay

    def test_migration_kwarg_rejected_with_rebalancer_instance(self):
        with pytest.raises(ValueError, match="registry name"):
            TrafficSimulator(
                [],
                rebalance_interval=1e-3,
                rebalancer=resolve_rebalancer("migrate_on_pressure"),
                migration=MigrationModel(),
            )

    def test_take_unknown_or_started_returns_none(self):
        src = _node(0)
        j = _job(0, arrival=0.0)
        assert src.offer(j) == "run"
        src.scheduler.run_until(1e-6)  # first layer launched
        assert src.take_for_migration("J#0") is None
        assert src.take_for_migration("nope") is None

    def test_rebalancer_moves_pressured_job_to_idle_node(self):
        reb = resolve_rebalancer("migrate_on_pressure")
        src, dst = _node(0), _node(1)
        big = _job(0, arrival=0.0, n_layers=6, size=1024)
        # deadline chosen so waiting behind `big` predicts a miss but the
        # migration transit does not: slack ~ 40% of big's service time
        slo = 0.4 * src.service_estimate(big.dnng)
        src.offer(big)
        src.offer(_job(1, arrival=0.0, slo=slo))
        assert len(src.queue) == 1
        moved = reb.rebalance([src, dst], now=1e-6)
        assert moved == 1 and reb.n_migrations == 1
        assert src.queue == [] and dst.in_system == 1

    def test_rebalancer_noop_on_single_node(self):
        reb = resolve_rebalancer("migrate_on_pressure")
        src = _node(0)
        src.offer(_job(0, arrival=0.0))
        src.offer(_job(1, arrival=0.0, slo=1e-6))
        assert reb.rebalance([src], now=0.0) == 0

    def test_simulator_migration_end_to_end_deterministic(self):
        # jsq alternates: node 0 gets the big jobs (and a queue), node 1
        # gets tiny ones and drains — the periodic tick must then move
        # queued work across
        jobs = []
        for i in range(8):
            if i % 2 == 0:
                jobs.append(_job(i, arrival=i * 1e-6, n_layers=6, size=2048, slo=0.5))
            else:
                jobs.append(_job(i, arrival=i * 1e-6, n_layers=1, size=32, slo=0.5))
        runs = [
            TrafficSimulator(
                list(jobs),
                policy="equal",
                n_arrays=2,
                max_concurrent=1,
                queue_cap=8,
                rebalance_interval=1e-3,
            ).run()
            for _ in range(2)
        ]
        assert runs[0].records == runs[1].records
        assert runs[0].metrics.migrations == runs[1].metrics.migrations
        assert runs[0].metrics.migrations > 0
        d = runs[0].as_dict()
        assert d["rebalance"] == "migrate_on_pressure"
        assert d["migrations"] == runs[0].metrics.migrations
        # a migrated job's record points at the node that actually served it
        served = {r.array for r in runs[0].records if r.array is not None}
        assert served == {0, 1}

    def test_per_class_p99_delta(self):
        jobs = [_job(i, arrival=i * 1e-5) for i in range(6)]
        a = TrafficSimulator(list(jobs), policy="equal").run()
        b = TrafficSimulator(list(jobs), policy="equal").run()
        delta = a.per_class_p99_delta(b)
        assert set(delta) == {0}
        assert delta[0] == pytest.approx(0.0, abs=1e-15)

    def test_rebalance_interval_validation(self):
        with pytest.raises(ValueError, match="rebalance_interval"):
            TrafficSimulator([], rebalance_interval=0.0)
