"""`repro.api` tests: registries, policy invariants, cross-policy ablation.

Runs without hypothesis — plain parametrised cases — so this module is part
of the hypothesis-optional tier-1 path.
"""

import json
import os

import pytest

from repro.api import (
    EqualPolicy,
    Session,
    TenantDemand,
    get_backend,
    get_policy,
    list_backends,
    list_policies,
    register_policy,
    resolve_policy,
)
from repro.api.policy import _POLICIES
from repro.core.dnng import LayerShape, chain
from repro.core.partition import ArrayShape, PartitionSet
from repro.core.scheduler import schedule_dynamic
from repro.sim.systolic import SystolicConfig, layer_time_fn

DATA = os.path.join(os.path.dirname(__file__), "data")
ALL_POLICIES = ("equal", "proportional", "best_fit", "priority",
                "width_aware")

TENANT_SETS = [
    [TenantDemand("a", demand=100.0)],
    [TenantDemand("a", demand=100.0), TenantDemand("b", demand=1.0)],
    [TenantDemand("a", demand=5.0, min_cols=16),
     TenantDemand("b", demand=50.0, width_demand=8),
     TenantDemand("c", demand=5.0, tier=1),
     TenantDemand("d", demand=0.0)],
    [TenantDemand(f"t{i}", demand=float(i + 1)) for i in range(9)],
    # over-subscribed: more tenants than columns
    [TenantDemand(f"t{i}", demand=1.0) for i in range(40)],
]


class TestRegistry:
    def test_four_required_policies_registered(self):
        for name in ("equal", "proportional", "best_fit", "priority"):
            assert name in list_policies()

    def test_round_trip(self):
        for name in list_policies():
            pol = get_policy(name)
            assert pol.name == name
            assert resolve_policy(name) is not pol  # fresh instance
            assert resolve_policy(pol) is pol       # passthrough

    def test_paper_alias(self):
        assert isinstance(get_policy("paper"), EqualPolicy)

    def test_unknown_rejected(self):
        with pytest.raises(ValueError):
            get_policy("bogus")
        with pytest.raises(ValueError):
            get_backend("bogus")
        with pytest.raises(ValueError):
            resolve_policy(object())

    def test_backends_registered(self):
        assert {"sim", "mesh"} <= set(list_backends())

    def test_register_plugin_policy(self):
        @register_policy("test_only_plugin")
        class Plugin(EqualPolicy):
            pass

        try:
            assert "test_only_plugin" in list_policies()
            assert isinstance(get_policy("test_only_plugin"), Plugin)
            with pytest.raises(ValueError):  # duplicate names rejected
                register_policy("test_only_plugin")(Plugin)
        finally:
            del _POLICIES["test_only_plugin"]


@pytest.mark.parametrize("name", ALL_POLICIES)
@pytest.mark.parametrize("cols", [1, 7, 64, 128])
@pytest.mark.parametrize("tenants", TENANT_SETS,
                         ids=lambda ts: f"n{len(ts)}")
class TestSplitInvariants:
    def test_split_tiles_and_checks(self, name, cols, tenants):
        """Split slices tile [0, cols) with no overlap: allocating each into
        a fresh PartitionSet leaves exactly zero free columns and passes
        the interval invariant after every allocation."""
        array = ArrayShape(rows=16, cols=cols)
        parts = get_policy(name).split(array, tenants)
        if not parts:
            return  # nothing placeable (e.g. floors exceed columns)
        assert sum(p.cols for p in parts) == cols
        ps = PartitionSet(array)
        for i, p in enumerate(sorted(parts, key=lambda p: p.col_start)):
            ps.allocate_exact(f"p{i}", p)
            ps.check()
        assert ps.utilization == 1.0

    def test_widths_respect_floors(self, name, cols, tenants):
        pol = get_policy(name)
        ws = pol.widths(cols, tenants)
        assert sum(ws.values()) <= cols
        floors = {t.name: t.min_cols for t in tenants}
        for tname, w in ws.items():
            assert w >= 1
            if name in ("proportional", "priority", "best_fit"):
                assert w >= floors[tname], (tname, w)


class TestPolicyBehaviour:
    def test_proportional_weights_by_demand(self):
        pol = get_policy("proportional")
        ws = pol.widths(100, [TenantDemand("big", demand=90.0),
                              TenantDemand("small", demand=10.0)])
        assert ws["big"] == 90 and ws["small"] == 10

    def test_priority_floor_and_tier(self):
        pol = get_policy("priority", tiers={"premium": 0, "batch": 2},
                         floors={"premium": 24})
        ws = pol.widths(32, [TenantDemand("batch", demand=1000.0),
                             TenantDemand("premium", demand=1.0)])
        assert ws["premium"] >= 24
        order = pol.order([TenantDemand("batch", demand=1000.0),
                           TenantDemand("premium", demand=1.0)])
        assert order[0].name == "premium"  # tier beats demand

    def test_best_fit_trims_to_gemm_n(self):
        """A narrow FC (gemm_n=16) must never occupy more than 16 columns."""
        gs = [chain("narrow", [LayerShape.fc("l0", 64, 16, batch=64),
                               LayerShape.fc("l1", 16, 16, batch=64)]),
              chain("wide", [LayerShape.fc("l0", 512, 4096, batch=512),
                             LayerShape.fc("l1", 4096, 4096, batch=512)],
                    arrival_time=1e-9)]
        array = ArrayShape(128, 128)
        res = schedule_dynamic(gs, array, layer_time_fn(SystolicConfig()),
                               policy="best_fit")
        for e in res.tenant_trace("narrow"):
            assert e.partition.cols <= 16

    def test_place_matches_priority_order(self):
        pol = get_policy("equal")
        grants = pol.place(ArrayShape(8, 8),
                           [TenantDemand("light", demand=1.0),
                            TenantDemand("heavy", demand=9.0)])
        assert set(grants) == {"light", "heavy"}
        # heaviest takes the widest (here: the remainder-padded first slice)
        assert grants["heavy"].n_pes >= grants["light"].n_pes


class TestAssignContextCostCache:
    def test_repeated_probes_hit_the_shared_cache(self):
        from repro.api import AssignContext
        from repro.core.partition import Partition
        calls = []

        def time_fn(layer, part):
            calls.append((layer, part))
            return 1.0

        layer = LayerShape.fc("l", 64, 64, batch=8)
        part = Partition(rows=128, col_start=0, cols=32)
        cache: dict = {}
        ctx = AssignContext(array=ArrayShape(128, 128), time_fn=time_fn,
                            cost_cache=cache)
        assert ctx.time(layer, part) == 1.0
        assert ctx.time(layer, part) == 1.0
        assert len(calls) == 1          # second probe served from the dict
        # a second context of the same round shares the same memo
        ctx2 = AssignContext(array=ArrayShape(128, 128), time_fn=time_fn,
                             cost_cache=cache)
        assert ctx2.time(layer, part) == 1.0
        assert len(calls) == 1

    def test_no_cache_falls_through(self):
        from repro.api import AssignContext
        from repro.core.partition import Partition
        calls = []
        ctx = AssignContext(array=ArrayShape(128, 128),
                            time_fn=lambda la, pa: calls.append(1) or 2.0)
        layer = LayerShape.fc("l", 64, 64, batch=8)
        part = Partition(rows=128, col_start=0, cols=32)
        assert ctx.time(layer, part) == 2.0
        assert ctx.time(layer, part) == 2.0
        assert len(calls) == 2

    def test_missing_time_fn_raises(self):
        from repro.api import AssignContext
        from repro.core.partition import Partition
        ctx = AssignContext(array=ArrayShape(128, 128))
        with pytest.raises(ValueError, match="time_fn"):
            ctx.time(LayerShape.fc("l", 64, 64, batch=8),
                     Partition(rows=128, col_start=0, cols=32))


@pytest.mark.parametrize("workload", ["heavy", "light"])
class TestSessionAcceptance:
    def test_all_policies_run_all_workloads(self, workload):
        for pol in ALL_POLICIES:
            res = Session(policy=pol, backend="sim").run(workload)
            assert res.policy == pol
            assert res.partitioned.makespan > 0
            assert set(res.partitioned.completion) == \
                set(res.baseline.completion)
            # every policy must still beat sequential on mean turnaround
            assert res.turnaround_saving > 0 or res.time_saving > 0

    def test_equal_reproduces_seed_trace_byte_for_byte(self, workload):
        """Cross-policy ablation anchor: `equal` IS the seed scheduler.

        The golden file was captured from the pre-API scheduler (hex floats
        — exact bit patterns, not approximations).
        """
        with open(os.path.join(DATA, f"seed_trace_{workload}.json")) as f:
            golden = json.load(f)
        res = Session(policy="equal", backend="sim").run(workload)
        dyn = res.partitioned
        assert dyn.makespan.hex() == golden["makespan"]
        assert {k: v.hex() for k, v in dyn.completion.items()} == \
            golden["completion"]
        assert len(dyn.trace) == len(golden["trace"])
        for e, g in zip(dyn.trace, golden["trace"]):
            got = (e.tenant, e.layer_index, e.partition.rows,
                   e.partition.col_start, e.partition.cols,
                   e.start.hex(), e.end.hex(),
                   e.compute_start.hex(), e.compute_end.hex())
            want = (g["tenant"], g["layer_index"], g["rows"], g["col_start"],
                    g["cols"], g["start"], g["end"], g["compute_start"],
                    g["compute_end"])
            assert got == want


class TestSessionMisc:
    def test_mesh_backend_runs(self):
        res = Session(policy="proportional", backend="mesh",
                      n_cols=8).run("light")
        assert res.backend == "mesh"
        assert res.partitioned.makespan > 0
        assert res.energy_saving == 0.0  # mesh backend has no energy model
        assert max(e.partition.col_end for e in res.partitioned.trace) <= 8

    def test_explicit_dnng_workload(self):
        gs = [chain("a", [LayerShape.fc("l", 64, 64, batch=8)])]
        res = Session(policy="equal", backend="sim").run(gs)
        assert res.workload == "custom"
        assert len(res.partitioned.trace) == 1

    def test_unknown_workload_rejected(self):
        with pytest.raises(ValueError):
            Session().run("nonesuch")

    def test_as_dict_is_json_serialisable(self):
        d = Session(policy="equal").run("light").as_dict()
        blob = json.loads(json.dumps(d))
        assert blob["policy"] == "equal"
        assert 0 <= blob["utilization"] <= 1
