"""Serving-engine tests: continuous batching, Algorithm-1 tenancy, faults."""

import jax
import numpy as np
import pytest

from repro.configs import get
from repro.distributed.tenancy import TenantMeshManager
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_params
from repro.serving.engine import MultiTenantEngine
from repro.serving.kv_cache import DecodeSession, Request

CFG = get("llama3.2-3b").smoke
PARAMS = init_params(CFG, jax.random.key(0))


def _session(slots=2, max_seq=32):
    return DecodeSession(CFG, PARAMS, batch_slots=slots, max_seq=max_seq)


class TestDecodeSession:
    def test_admit_and_drain(self):
        s = _session()
        r = Request(rid=0, prompt=[1, 2, 3], max_new=4)
        s.admit(r)
        assert s.occupancy == 0.5
        steps = 0
        while s.live and steps < 20:
            s.step()
            steps += 1
        assert r.done and len(r.out) == 4
        assert s.occupancy == 0.0

    def test_slot_isolation(self):
        """Two requests with identical prompts must produce identical
        outputs regardless of which slot they occupy."""
        s1 = _session(slots=2)
        a = Request(rid=0, prompt=[5, 6], max_new=3)
        s1.admit(a)
        while s1.live:
            s1.step()

        s2 = _session(slots=2)
        filler = Request(rid=1, prompt=[9, 9, 9], max_new=6)
        b = Request(rid=2, prompt=[5, 6], max_new=3)
        s2.admit(filler)
        s2.admit(b)  # lands in the other slot, decodes alongside filler
        while s2.live:
            s2.step()
        assert a.out == b.out, (a.out, b.out)

    def test_slot_reuse_after_release(self):
        s = _session(slots=1)
        r1 = Request(rid=0, prompt=[1], max_new=2)
        s.admit(r1)
        while s.live:
            s.step()
        assert s.can_admit()
        r2 = Request(rid=1, prompt=[2], max_new=2)
        s.admit(r2)
        while s.live:
            s.step()
        assert r2.done

    def test_overfull_rejected(self):
        s = _session(slots=1)
        s.admit(Request(rid=0, prompt=[1], max_new=8))
        with pytest.raises(RuntimeError):
            s.admit(Request(rid=1, prompt=[2], max_new=8))


class TestEngine:
    def _engine(self):
        mesh = make_host_mesh(model=1)
        return MultiTenantEngine(TenantMeshManager(mesh, "model"))

    def test_multi_tenant_drain_and_history(self):
        eng = self._engine()
        for i, arch in enumerate(["llama3.2-3b", "mamba2-780m"]):
            cfg = get(arch).smoke
            params = init_params(cfg, jax.random.key(i))
            eng.add_tenant(arch, DecodeSession(cfg, params, 2, 32),
                           flops_per_token=float(i + 1))
            for r in range(2):
                eng.submit(arch, prompt=[1, 2], max_new=3)
        rounds = eng.run_until_drained(max_rounds=100)
        assert rounds > 0
        assert not eng.tenants
        assert eng.width_history  # Fig. 9(c,d) analogue recorded

    def test_served_counts(self):
        eng = self._engine()
        eng.add_tenant("llama3.2-3b", _session(), flops_per_token=1.0)
        eng.submit("llama3.2-3b", prompt=[1], max_new=5)
        eng.run_until_drained(max_rounds=50)
        # tenant retired after drain; emissions were recorded on the way
        assert not eng.tenants

    def test_column_failure_evicts_and_replaces(self):
        eng = self._engine()
        eng.add_tenant("llama3.2-3b", _session(), flops_per_token=1.0)
        eng.submit("llama3.2-3b", prompt=[1], max_new=3)
        evicted = eng.fail_column(0)
        assert evicted == ["llama3.2-3b"]
        # single-column mesh: no healthy columns left -> tenant unplaced
        assert eng.tenants["llama3.2-3b"].width in (0, 1)
        eng.heal_column(0)
        eng.run_until_drained(max_rounds=50)

    def test_unknown_tenant_submit_raises(self):
        eng = self._engine()
        with pytest.raises(KeyError):
            eng.submit("ghost", prompt=[1], max_new=1)


class _FakeMesh:
    """Multi-column stand-in: the engine's tenancy/fault path never builds a
    submesh on the CPU rig, so a bare (axis_names, devices) object lets the
    eviction machinery be tested across 4 columns with one real device."""

    def __init__(self, model_cols: int):
        self.axis_names = ("data", "model")
        self.devices = np.empty((1, model_cols), dtype=object)


class TestEngineFaultPath:
    """fail_column/heal_column: eviction, re-placement, width_history."""

    def _engine(self, cols=4, policy="equal"):
        eng = MultiTenantEngine(TenantMeshManager(_FakeMesh(cols), "model"),
                                policy=policy)
        for i, name in enumerate(["A", "B"]):
            eng.add_tenant(name, _session(), flops_per_token=float(i + 1))
        return eng

    @staticmethod
    def _placements(eng):
        return {t.name: t.partition for t in eng.manager.tenants()}

    def test_fail_column_evicts_only_overlapping_tenant(self):
        eng = self._engine()
        parts = self._placements(eng)
        victim = next(n for n, p in parts.items()
                      if p.col_start <= 0 < p.col_end)
        other = ({"A", "B"} - {victim}).pop()
        evicted = eng.fail_column(0)
        assert victim in evicted and other not in evicted

    def test_failed_tenant_is_replaced_off_the_dead_column(self):
        eng = self._engine()
        eng.fail_column(0)
        parts = self._placements(eng)
        # both tenants re-placed, neither touching the fenced column
        for name, p in parts.items():
            assert p is not None, f"{name} left unplaced"
            assert not (p.col_start <= 0 < p.col_end)
        assert sum(p.cols for p in parts.values()) <= 3
        eng.manager._pset.check()  # free+busy still tile the array

    def test_heal_column_restores_full_width(self):
        eng = self._engine()
        eng.fail_column(2)
        width_degraded = sum(p.cols for p in self._placements(eng).values())
        eng.heal_column(2)
        width_healed = sum(p.cols for p in self._placements(eng).values())
        assert width_degraded <= 3 and width_healed == 4
        eng.manager._pset.check()

    def test_width_history_tracks_fault_and_heal(self):
        eng = self._engine()
        n0 = len(eng.width_history)
        eng.fail_column(0)
        n1 = len(eng.width_history)
        eng.heal_column(0)
        n2 = len(eng.width_history)
        assert n0 < n1 < n2  # both transitions re-recorded every grant
        # history entries are well-formed and the tail matches live widths
        for rnd, name, w in eng.width_history:
            assert name in ("A", "B") and w >= 1 and rnd >= 0
        last = {}
        for _, name, w in eng.width_history:
            last[name] = w
        for name, svc in eng.tenants.items():
            assert svc.width == last[name]

    def test_engine_drains_after_fail_heal_cycle(self):
        eng = self._engine()
        eng.submit("A", prompt=[1, 2], max_new=3)
        eng.submit("B", prompt=[3], max_new=2)
        eng.fail_column(1)
        eng.heal_column(1)
        eng.run_until_drained(max_rounds=100)
        assert not eng.tenants


class TestRebalanceOnSubmit:
    """submit() changes outstanding demand → widths must follow (the engine
    marks itself dirty and rebalances at the next step() start)."""

    def _engine(self, policy="proportional"):
        eng = MultiTenantEngine(TenantMeshManager(_FakeMesh(4), "model"),
                                policy=policy)
        eng.add_tenant("A", _session(slots=2), flops_per_token=1.0)
        eng.add_tenant("B", _session(slots=2), flops_per_token=1.0)
        return eng

    def test_submit_marks_dirty_step_rebalances(self):
        eng = self._engine()
        n0 = len(eng.width_history)
        eng.submit("A", prompt=[1, 2, 3], max_new=8)
        assert eng._dirty and len(eng.width_history) == n0  # deferred
        eng.step()
        assert not eng._dirty
        assert len(eng.width_history) > n0  # rebalanced at step start

    def test_demand_shift_widens_loaded_tenant(self):
        eng = self._engine()
        for _ in range(4):
            eng.submit("A", prompt=[1, 2, 3, 4], max_new=16)
        eng.submit("B", prompt=[1], max_new=2)  # keep B live through step()
        eng.step()
        widths = {n: s.width for n, s in eng.tenants.items()}
        # proportional split: nearly all outstanding work is A's, so the
        # step-start rebalance hands A everything above B's floor
        assert widths["A"] == 3 and widths["B"] == 1
