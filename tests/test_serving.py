"""Serving-engine tests: continuous batching, Algorithm-1 tenancy, faults."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.distributed.tenancy import TenantMeshManager
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_params
from repro.serving.engine import MultiTenantEngine
from repro.serving.kv_cache import DecodeSession, Request

CFG = get("llama3.2-3b").smoke
PARAMS = init_params(CFG, jax.random.key(0))


def _session(slots=2, max_seq=32):
    return DecodeSession(CFG, PARAMS, batch_slots=slots, max_seq=max_seq)


class TestDecodeSession:
    def test_admit_and_drain(self):
        s = _session()
        r = Request(rid=0, prompt=[1, 2, 3], max_new=4)
        s.admit(r)
        assert s.occupancy == 0.5
        steps = 0
        while s.live and steps < 20:
            s.step()
            steps += 1
        assert r.done and len(r.out) == 4
        assert s.occupancy == 0.0

    def test_slot_isolation(self):
        """Two requests with identical prompts must produce identical
        outputs regardless of which slot they occupy."""
        s1 = _session(slots=2)
        a = Request(rid=0, prompt=[5, 6], max_new=3)
        s1.admit(a)
        while s1.live:
            s1.step()

        s2 = _session(slots=2)
        filler = Request(rid=1, prompt=[9, 9, 9], max_new=6)
        b = Request(rid=2, prompt=[5, 6], max_new=3)
        s2.admit(filler)
        s2.admit(b)  # lands in the other slot, decodes alongside filler
        while s2.live:
            s2.step()
        assert a.out == b.out, (a.out, b.out)

    def test_slot_reuse_after_release(self):
        s = _session(slots=1)
        r1 = Request(rid=0, prompt=[1], max_new=2)
        s.admit(r1)
        while s.live:
            s.step()
        assert s.can_admit()
        r2 = Request(rid=1, prompt=[2], max_new=2)
        s.admit(r2)
        while s.live:
            s.step()
        assert r2.done

    def test_overfull_rejected(self):
        s = _session(slots=1)
        s.admit(Request(rid=0, prompt=[1], max_new=8))
        with pytest.raises(RuntimeError):
            s.admit(Request(rid=1, prompt=[2], max_new=8))


class TestEngine:
    def _engine(self):
        mesh = make_host_mesh(model=1)
        return MultiTenantEngine(TenantMeshManager(mesh, "model"))

    def test_multi_tenant_drain_and_history(self):
        eng = self._engine()
        for i, arch in enumerate(["llama3.2-3b", "mamba2-780m"]):
            cfg = get(arch).smoke
            params = init_params(cfg, jax.random.key(i))
            eng.add_tenant(arch, DecodeSession(cfg, params, 2, 32),
                           flops_per_token=float(i + 1))
            for r in range(2):
                eng.submit(arch, prompt=[1, 2], max_new=3)
        rounds = eng.run_until_drained(max_rounds=100)
        assert rounds > 0
        assert not eng.tenants
        assert eng.width_history  # Fig. 9(c,d) analogue recorded

    def test_served_counts(self):
        eng = self._engine()
        eng.add_tenant("llama3.2-3b", _session(), flops_per_token=1.0)
        eng.submit("llama3.2-3b", prompt=[1], max_new=5)
        eng.run_until_drained(max_rounds=50)
        # tenant retired after drain; emissions were recorded on the way
        assert not eng.tenants

    def test_column_failure_evicts_and_replaces(self):
        eng = self._engine()
        eng.add_tenant("llama3.2-3b", _session(), flops_per_token=1.0)
        eng.submit("llama3.2-3b", prompt=[1], max_new=3)
        evicted = eng.fail_column(0)
        assert evicted == ["llama3.2-3b"]
        # single-column mesh: no healthy columns left -> tenant unplaced
        assert eng.tenants["llama3.2-3b"].width in (0, 1)
        eng.heal_column(0)
        eng.run_until_drained(max_rounds=50)

    def test_unknown_tenant_submit_raises(self):
        eng = self._engine()
        with pytest.raises(KeyError):
            eng.submit("ghost", prompt=[1], max_new=1)
