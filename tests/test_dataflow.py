"""Partitioned-WS dataflow model tests (core/dataflow.py)."""

# only the property tests need hypothesis; deterministic tests always run
from _hypothesis_compat import given, settings, st

from repro.core.dataflow import (
    GEMM,
    partitioned_ws_loopnest,
    utilization,
    ws_cost,
    ws_cost_cache_clear,
    ws_cost_cache_stats,
)
from repro.core.dnng import LayerShape
from repro.core.partition import Partition


class TestWsCost:
    def test_single_fold(self):
        g = GEMM(T=100, K=128, N=128)
        c = ws_cost(g, Partition(128, 0, 128))
        assert c.folds_k == 1 and c.folds_n == 1
        # 2R + C + T - 2
        assert c.cycles == 2 * 128 + 128 + 100 - 2

    def test_fold_counts(self):
        g = GEMM(T=10, K=300, N=500)
        c = ws_cost(g, Partition(128, 0, 64))
        assert c.folds_k == 3 and c.folds_n == 8

    def test_col_offset_penalty(self):
        g = GEMM(T=64, K=128, N=64)
        c0 = ws_cost(g, Partition(128, 0, 64))
        c1 = ws_cost(g, Partition(128, 64, 64))
        assert c1.cycles == c0.cycles + 64  # pass-through fill offset

    def test_mul_en_accounting(self):
        g = GEMM(T=50, K=128, N=128)
        part = Partition(128, 0, 128)
        c = ws_cost(g, part)
        # feed-phase multiplier firings = T per PE per fold
        assert c.feed_pe_cycles == 50 * part.n_pes
        # load-phase latch cycles = R per PE per fold
        assert c.load_pe_cycles == 128 * part.n_pes
        assert c.active_pe_cycles == g.macs

    @given(t=st.integers(1, 4096), k=st.integers(1, 4096),
           n=st.integers(1, 4096), cols=st.sampled_from([16, 32, 64, 128]),
           start=st.sampled_from([0, 16, 64]))
    @settings(max_examples=200, deadline=None)
    def test_properties(self, t, k, n, cols, start):
        g = GEMM(T=t, K=k, N=n)
        part = Partition(128, start, cols)
        c = ws_cost(g, part)
        assert c.cycles > 0
        assert c.macs == t * k * n
        # a PE cannot do more useful MACs than it has cycles
        assert c.active_pe_cycles <= c.pe_cycles
        # feed firings cover at least every useful MAC
        assert c.feed_pe_cycles >= c.active_pe_cycles
        assert 0 < utilization(g, part) <= 1.0

    def test_utilization_improves_on_fitting_partition(self):
        """Small-N layers waste columns on wide partitions."""
        g = GEMM(T=64, K=128, N=16)
        wide = utilization(g, Partition(128, 0, 128))
        snug = utilization(g, Partition(128, 0, 16))
        assert snug > wide


class TestWsCostCache:
    def test_identical_queries_hit_the_lru(self):
        ws_cost_cache_clear()
        g, p = GEMM(T=77, K=256, N=333), Partition(128, 16, 64)
        first = ws_cost(g, p)
        # equal-by-value (not identical) arguments must hit
        again = ws_cost(GEMM(T=77, K=256, N=333), Partition(128, 16, 64))
        assert again is first  # the cache returns the memoized object
        stats = ws_cost_cache_stats()
        assert stats["hits"] >= 1 and stats["misses"] >= 1
        assert stats["currsize"] >= 1

    def test_clear_resets_counters(self):
        ws_cost(GEMM(T=5, K=5, N=5), Partition(128, 0, 8))
        ws_cost_cache_clear()
        stats = ws_cost_cache_stats()
        assert stats["hits"] == 0 and stats["currsize"] == 0

    def test_layer_cost_is_memoized_too(self):
        from repro.sim.systolic import layer_cost
        layer = LayerShape.fc("l", 128, 128, batch=8)
        part = Partition(128, 0, 32)
        assert layer_cost(layer, part) is layer_cost(layer, part)


class TestLoopNest:
    def test_three_phases(self):
        layer = LayerShape.fc("l", 256, 512, batch=64)
        g = GEMM.of_layer(layer)
        nest = partitioned_ws_loopnest(g, Partition(128, 0, 32))
        assert [k for k, _, _ in nest.load] == ["parallel", "parallel"]
        assert [k for k, _, _ in nest.feed] == ["parallel", "temporal"]
        assert [k for k, _, _ in nest.drain] == ["parallel", "temporal"]
        # spatial extents never exceed the partition geometry
        assert nest.load[0][2] <= 128 and nest.load[1][2] <= 32
