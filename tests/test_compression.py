"""Gradient-compression tests (distributed/compression.py)."""

import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.distributed.compression import (
    CompressionConfig,
    dequantize_int8,
    quantize_int8,
)


class TestInt8Quant:
    @given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128, 256]))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_error_bounded(self, seed, block):
        g = jax.random.normal(jax.random.key(seed), (777,), jnp.float32)
        q, s = quantize_int8(g, block)
        back = dequantize_int8(q, s, g.shape, g.size)
        # symmetric int8: error <= scale/2 = max|block| / 254
        err = jnp.abs(back - g)
        assert float(err.max()) <= float(jnp.abs(g).max()) / 127.0 + 1e-7

    def test_zero_tensor(self):
        g = jnp.zeros((100,), jnp.float32)
        q, s = quantize_int8(g, 64)
        back = dequantize_int8(q, s, g.shape, g.size)
        np.testing.assert_array_equal(np.asarray(back), 0.0)

    def test_wire_bytes_are_4x_smaller(self):
        g = jnp.ones((1024,), jnp.float32)
        q, s = quantize_int8(g, 256)
        wire = q.size * 1 + s.size * 4
        assert wire < g.size * 4 / 3  # >3x reduction incl. scales


MULTIDEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import (int8_psum_mean, topk_psum_mean,
                                           CompressionConfig,
                                           compressed_mean,
                                           init_error_state)

mesh = jax.make_mesh((8,), ("data",))
g = jax.random.normal(jax.random.key(1), (8, 512), jnp.float32)
ref = jnp.mean(g, axis=0)

f = jax.shard_map(lambda gg: int8_psum_mean(gg[0], "data")[None], mesh=mesh,
                  in_specs=P("data"), out_specs=P("data"), check_vma=False)
err = float(jnp.abs(f(g)[0] - ref).max() / (jnp.abs(ref).max() + 1e-9))
assert err < 0.05, f"int8 err {err}"

# error feedback: compressed SGD with EF tracks the true mean over steps
cfg = CompressionConfig(kind="int8", block=64)
def step(gg, ee):
    red, e2 = compressed_mean({"g": gg[0]}, {"g": ee[0]}, "data", cfg)
    return red["g"][None], e2["g"][None]
fstep = jax.shard_map(step, mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data")), check_vma=False)
e = jnp.zeros_like(g)
acc_c = jnp.zeros_like(ref); acc_t = jnp.zeros_like(ref)
for s in range(8):
    gs = jax.random.normal(jax.random.key(100 + s), g.shape, jnp.float32)
    red, e = fstep(gs, e)
    acc_c = acc_c + red[0]
    acc_t = acc_t + jnp.mean(gs, axis=0)
drift = float(jnp.abs(acc_c - acc_t).max() / (jnp.abs(acc_t).max() + 1e-9))
assert drift < 0.08, f"EF drift {drift}"
print("COMPRESS_OK")
"""


def test_compressed_allreduce_multidev():
    r = subprocess.run([sys.executable, "-c", MULTIDEV],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "COMPRESS_OK" in r.stdout, r.stderr[-2000:]
