"""Mesh-level tenancy manager tests (distributed/tenancy.py)."""

import jax
import pytest
pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.distributed.tenancy import TenantMeshManager
from repro.launch.mesh import make_host_mesh


class TestTenancySingleDevice:
    def test_single_column_mesh(self):
        mgr = TenantMeshManager(make_host_mesh(model=1), "model")
        mgr.admit("a", demand=1.0)
        grants = mgr.rebalance()
        assert grants["a"].cols == 1
        sm = mgr.submesh("a")
        assert sm.devices.size == len(jax.devices())
        mgr.release("a")
        assert mgr.utilization() == 0.0

    def test_admit_twice_rejected(self):
        mgr = TenantMeshManager(make_host_mesh(model=1), "model")
        mgr.admit("a", demand=1.0)
        with pytest.raises(ValueError):
            mgr.admit("a", demand=2.0)

    def test_min_cols_too_large(self):
        mgr = TenantMeshManager(make_host_mesh(model=1), "model")
        with pytest.raises(ValueError):
            mgr.admit("a", demand=1.0, min_cols=99)


MULTIDEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.distributed.tenancy import TenantMeshManager

mesh = jax.make_mesh((1, 8), ("data", "model"))
mgr = TenantMeshManager(mesh, "model")

# Algorithm 1: equal split, heaviest -> widest
mgr.admit("heavy", demand=100.0)
mgr.admit("light", demand=1.0)
g = mgr.rebalance()
assert g["heavy"].cols == 4 and g["light"].cols == 4
assert mgr.submesh("heavy").devices.shape == (1, 4)

# release + grow_into_free = the paper's merge-accelerate
mgr.release("light")
grown = mgr.grow_into_free()
assert grown["heavy"].cols == 8, grown

# fault: failing a column inside the tenant evicts it...
ev = mgr.mark_unhealthy(3)
assert ev == ["heavy"]
# ...and rebalance re-places it around the dead column
g2 = mgr.rebalance()
assert g2["heavy"].cols >= 1
s, e = g2["heavy"].col_start, g2["heavy"].col_end
assert not (s <= 3 < e)

# heal and regrow
mgr.mark_healthy(3)
g3 = mgr.rebalance()
assert g3["heavy"].cols == 8
print("MULTIDEV_OK")
"""


def test_tenancy_multidev_subprocess():
    """Full Algorithm-1 behaviour on 8 fake devices (own process: the
    device count must be set before jax initialises)."""
    import subprocess
    import sys
    r = subprocess.run([sys.executable, "-c", MULTIDEV],
                       capture_output=True, text=True, timeout=300,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert "MULTIDEV_OK" in r.stdout, r.stderr[-2000:]


@given(st.lists(st.tuples(st.sampled_from(["admit", "release", "fail",
                                           "heal"]),
                          st.integers(0, 5)),
                min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_manager_invariants_random_ops(ops):
    """The PartitionSet invariant holds under any admit/release/fail/heal
    sequence + rebalance (single-column mesh keeps this CPU-fast)."""
    mgr = TenantMeshManager(make_host_mesh(model=1), "model")
    live = set()
    for kind, tid in ops:
        name = f"t{tid}"
        if kind == "admit" and name not in live:
            mgr.admit(name, demand=float(tid + 1))
            live.add(name)
        elif kind == "release" and name in live:
            mgr.release(name)
            live.remove(name)
        elif kind == "fail":
            mgr.mark_unhealthy(0)
        elif kind == "heal":
            mgr.mark_healthy(0)
        mgr.rebalance()
        mgr._pset.check()
