"""Event-driven scheduler tests (core/scheduler.py)."""

import pytest
pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.dnng import DNNG, LayerShape, chain
from repro.core.partition import ArrayShape
from repro.core.scheduler import (
    StageModel,
    schedule_dynamic,
    schedule_sequential,
)
from repro.sim.systolic import SystolicConfig, layer_time_fn

FC = LayerShape.fc
ARRAY = ArrayShape(128, 128)
TIME_FN = layer_time_fn(SystolicConfig())


def _dnng(name, n_layers, size=256, arrival=0.0):
    return chain(name, [FC(f"l{i}", size, size, batch=size)
                        for i in range(n_layers)], arrival_time=arrival)


class TestSequentialBaseline:
    def test_order_and_makespan(self):
        gs = [_dnng("a", 2), _dnng("b", 3)]
        res = schedule_sequential(gs, ARRAY, TIME_FN)
        assert res.completion["a"] < res.completion["b"]
        assert res.makespan == res.completion["b"]
        assert len(res.trace) == 5
        # every layer on the full array
        assert all(e.partition.cols == 128 for e in res.trace)

    def test_stage_serialisation(self):
        gs = [_dnng("a", 2)]
        plain = schedule_sequential(gs, ARRAY, TIME_FN)
        staged = schedule_sequential(gs, ARRAY, TIME_FN, stage=StageModel())
        assert staged.makespan > plain.makespan


class TestDynamicScheduler:
    def test_single_dnng_uses_full_array(self):
        res = schedule_dynamic([_dnng("a", 3)], ARRAY, TIME_FN)
        assert all(e.partition.cols == 128 for e in res.trace)

    def test_all_complete(self):
        gs = [_dnng(f"t{i}", 3 + i) for i in range(5)]
        res = schedule_dynamic(gs, ARRAY, TIME_FN)
        assert set(res.completion) == {g.name for g in gs}

    def test_concurrent_beats_sequential_turnaround(self):
        """Mixed sizes: small tenants no longer queue behind big ones, so
        mean turnaround drops (the Fig. 9(a,b) effect).  With identical
        tenants concurrency cannot beat work-conservation — mixture is the
        paper's setting (Table 1 spans AlexNet..NCF)."""
        gs = [_dnng("big", 8, size=2048)] + \
            [_dnng(f"s{i}", 2, size=64, arrival=1e-9) for i in range(3)]
        stage = StageModel()
        seq = schedule_sequential(gs, ARRAY, TIME_FN, stage=stage)
        dyn = schedule_dynamic(gs, ARRAY, TIME_FN, stage=stage)
        assert sum(dyn.completion.values()) < sum(seq.completion.values())

    def test_first_layer_whole_array(self):
        """Fig. 5 line 5: first DNNG's first layer gets every PE when it is
        alone (others arrive later, per Fig. 4)."""
        gs = [_dnng("first", 2, arrival=0.0),
              _dnng("late", 2, arrival=1e-9)]
        res = schedule_dynamic(gs, ARRAY, TIME_FN)
        first_ev = min(res.trace, key=lambda e: e.start)
        assert first_ev.tenant == "first"
        assert first_ev.partition.cols == 128

    def test_merge_gives_wider_partitions_later(self):
        """Paper §3.3: survivors inherit wider slices after merges."""
        gs = [_dnng("big", 8)] + [_dnng(f"s{i}", 1, arrival=1e-9)
                                  for i in range(3)]
        res = schedule_dynamic(gs, ARRAY, TIME_FN)
        big = res.tenant_trace("big")
        assert big[-1].partition.cols > big[1].partition.cols

    def test_partitions_never_overlap_in_time(self):
        gs = [_dnng(f"t{i}", 3) for i in range(6)]
        res = schedule_dynamic(gs, ARRAY, TIME_FN, stage=StageModel())
        evs = sorted(res.trace, key=lambda e: e.start)
        for i, a in enumerate(evs):
            for b in evs[i + 1:]:
                if b.start >= a.end:
                    continue
                overlap_cols = not (
                    a.partition.col_end <= b.partition.col_start
                    or b.partition.col_end <= a.partition.col_start)
                same_tenant = a.tenant == b.tenant
                assert not (overlap_cols and not same_tenant), (a, b)

    def test_width_aware_policy_never_overallocates(self):
        gs = [_dnng("tiny", 2, size=16),
              _dnng("huge", 2, size=4096, arrival=1e-9)]
        res = schedule_dynamic(gs, ARRAY, TIME_FN, policy="width_aware")
        for e in res.tenant_trace("tiny"):
            assert e.partition.cols <= 16

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            schedule_dynamic([_dnng("a", 1)], ARRAY, TIME_FN, policy="bogus")

    @given(n_dnngs=st.integers(1, 6), layers=st.integers(1, 5),
           seed=st.integers(0, 99))
    @settings(max_examples=25, deadline=None)
    def test_property_all_layers_executed_once(self, n_dnngs, layers, seed):
        import random
        rng = random.Random(seed)
        gs = []
        for i in range(n_dnngs):
            ls = [FC(f"l{j}", rng.choice([32, 128, 512]),
                     rng.choice([32, 128, 512]),
                     batch=rng.choice([1, 64])) for j in range(layers)]
            gs.append(chain(f"t{i}", ls, arrival_time=rng.random() * 1e-4))
        res = schedule_dynamic(gs, ARRAY, TIME_FN, stage=StageModel())
        assert len(res.trace) == n_dnngs * layers
        seen = {(e.tenant, e.layer_index) for e in res.trace}
        assert len(seen) == n_dnngs * layers
        # layer order per tenant respects the chain DAG
        for g in gs:
            evs = res.tenant_trace(g.name)
            idxs = [e.layer_index for e in
                    sorted(evs, key=lambda e: e.start)]
            assert idxs == sorted(idxs)
