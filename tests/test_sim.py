"""Fig. 9 reproduction tests (sim/)."""

import pytest

from repro.sim.energy import EnergyModel
from repro.sim.runner import run_experiment
from repro.sim.systolic import SystolicConfig
from repro.sim.workloads import heavy_workload, light_workload


class TestWorkloads:
    def test_table1_composition(self):
        heavy = heavy_workload()
        light = light_workload()
        assert {g.name for g in heavy} == {
            "AlexNet", "ResNet50", "GoogleNet", "SA_CNN", "SA_LSTM", "NCF",
            "AlphaGoZero", "Transformer"}
        assert {g.name for g in light} == {
            "MelodyLSTM", "GoogleTranslate", "DeepVoice", "HandwritingLSTM"}

    def test_arrivals_staggered_fig4(self):
        heavy = heavy_workload()
        ats = [g.arrival_time for g in heavy]
        assert ats[0] == 0.0
        assert all(b > a for a, b in zip(ats, ats[1:]))

    def test_known_layer_dims(self):
        alex = next(g for g in heavy_workload() if g.name == "AlexNet")
        fc6 = next(ls for ls in alex.layers if ls.name == "fc6")
        assert fc6.gemm_k == 9216 and fc6.gemm_n == 4096


@pytest.mark.parametrize("workload", ["heavy", "light"])
class TestFig9:
    def test_partitioned_beats_baseline(self, workload):
        res = run_experiment(workload)
        # the paper's headline: concurrent multi-tenancy saves BOTH energy
        # and time (makespan AND mean turnaround) vs sequential baseline
        assert res.energy_saving > 0.15, res.energy_saving
        assert res.time_saving > 0.0
        assert res.turnaround_saving > 0.15

    def test_partition_histogram_is_paperlike(self, workload):
        """Fig. 9(c,d): the dynamic run uses the paper's partition widths
        (128×16/32/64/128 families) and the full array at least once."""
        res = run_experiment(workload)
        hist = res.partition_histogram()
        assert any(k.startswith("128x") for k in hist)
        assert "128x128" in hist

    def test_energy_breakdown_consistent(self, workload):
        res = run_experiment(workload)
        for br in (res.baseline_energy, res.partitioned_energy):
            assert br.total > 0
            assert abs(br.total - sum(
                [br.mac_j, br.forward_j, br.sram_j, br.dram_j, br.clock_j,
                 br.leakage_j])) < 1e-12
        # baseline PE has no Mul_En → no forwarding energy
        assert res.baseline_energy.forward_j == 0.0
        # Mul_En eliminates idle multiplier toggling → partitioned MAC < base
        assert res.partitioned_energy.mac_j < res.baseline_energy.mac_j

    def test_light_saves_more_energy_than_heavy(self, workload):
        if workload == "light":
            rh = run_experiment("heavy")
            rl = run_experiment("light")
            # the paper's crossover: light (RNN) saves more energy (62 vs
            # 35 %) because small-T layers waste most baseline MAC toggles
            assert rl.energy_saving > rh.energy_saving


class TestEnergyModel:
    def test_leakage_scales_with_makespan(self):
        res = run_experiment("light")
        m = EnergyModel()
        cfg = SystolicConfig()
        assert res.partitioned_energy.leakage_j == pytest.approx(
            m.leak_power(cfg.array) * res.partitioned.makespan)
