"""Batch cost oracle: vectorized == scalar, bit for bit (PR 5 tentpole).

The whole engine overhaul rests on one contract: ``ws_cost_batch`` /
``layer_cost_batch`` / ``time_fn.batch`` return EXACTLY what the scalar
oracles return — not approximately, byte for byte — so policies can
consume the batched table with zero behavioral drift.  Property tests
sweep hypothesis-generated shape/width grids; the deterministic cases run
on the no-extras CI leg too.
"""

# only the property tests need hypothesis; deterministic tests always run
from _hypothesis_compat import given, settings, st

import pytest

from repro.core.dataflow import (
    GEMM,
    pack_gemms,
    pack_partitions,
    ws_cost,
    ws_cost_batch,
    ws_cost_batch_stats,
    ws_cost_batch_stats_clear,
)
from repro.core.dnng import LayerShape
from repro.core.partition import Partition
from repro.sim.systolic import (
    SystolicConfig,
    layer_cost,
    layer_cost_batch,
    layer_time_fn,
)
from repro.sim.workloads import MODELS

ARRAY_ROWS = 128


def _grid_pairs():
    """Every Table-1 layer × a spread of partition widths/offsets."""
    layers, parts = [], []
    widths = (1, 3, 16, 64, 128)
    offsets = (0, 16, 96)
    i = 0
    for build in MODELS.values():
        for layer in build().layers:
            w = widths[i % len(widths)]
            c0 = offsets[i % len(offsets)]
            layers.append(layer)
            parts.append(Partition(rows=ARRAY_ROWS, col_start=c0, cols=w))
            i += 1
    return layers, parts


class TestWsCostBatch:
    def test_matches_scalar_on_table1_grid(self):
        layers, parts = _grid_pairs()
        gemms = [GEMM.of_layer(layer) for layer in layers]
        table = ws_cost_batch(gemms, parts)
        assert len(table) == len(gemms)
        for i, (g, p) in enumerate(zip(gemms, parts)):
            assert table.row(i) == ws_cost(g, p)

    def test_accepts_prepacked_arrays(self):
        gemms = [GEMM(T=10, K=300, N=500), GEMM(T=7, K=64, N=9)]
        parts = [Partition(128, 0, 64), Partition(128, 32, 3)]
        packed = ws_cost_batch(pack_gemms(gemms), pack_partitions(parts))
        direct = ws_cost_batch(gemms, parts)
        for i in range(2):
            assert packed.row(i) == direct.row(i) == ws_cost(gemms[i],
                                                             parts[i])

    def test_empty_batch(self):
        assert len(ws_cost_batch([], [])) == 0

    def test_mismatched_lengths_raise(self):
        with pytest.raises(ValueError, match="matching shapes"):
            ws_cost_batch([GEMM(T=1, K=1, N=1)],
                          [Partition(1, 0, 1), Partition(1, 1, 1)])

    def test_batch_stats_count_calls_and_pairs(self):
        ws_cost_batch_stats_clear()
        gemms = [GEMM(T=5, K=10, N=20)] * 3
        parts = [Partition(8, 0, 4)] * 3
        ws_cost_batch(gemms, parts)
        ws_cost_batch(gemms[:1], parts[:1])
        stats = ws_cost_batch_stats()
        assert stats == {"calls": 2, "pairs": 4}
        ws_cost_batch_stats_clear()
        assert ws_cost_batch_stats() == {"calls": 0, "pairs": 0}

    @settings(max_examples=200, deadline=None)
    @given(
        T=st.integers(1, 5000), K=st.integers(1, 4096),
        N=st.integers(1, 4096), rows=st.integers(1, 256),
        col_start=st.integers(0, 128), cols=st.integers(1, 256),
    )
    def test_property_bit_identical(self, T, K, N, rows, col_start, cols):
        g = GEMM(T=T, K=K, N=N)
        p = Partition(rows=rows, col_start=col_start, cols=cols)
        assert ws_cost_batch([g], [p]).row(0) == ws_cost(g, p)


class TestLayerCostBatch:
    def test_matches_scalar_on_table1_grid(self):
        layers, parts = _grid_pairs()
        table = layer_cost_batch(layers, parts)
        for i, (layer, p) in enumerate(zip(layers, parts)):
            assert table.row(i) == layer_cost(layer, p)

    @settings(max_examples=100, deadline=None)
    @given(
        M=st.integers(1, 2048), N=st.integers(1, 64),
        C=st.integers(1, 1024), R=st.integers(1, 7), S=st.integers(1, 7),
        HW=st.integers(1, 64), cols=st.integers(1, 128),
    )
    def test_property_bit_identical(self, M, N, C, R, S, HW, cols):
        layer = LayerShape(M=M, N=N, C=C, R=R, S=S, H=HW, W=HW, P=HW, Q=HW)
        p = Partition(rows=ARRAY_ROWS, col_start=0, cols=cols)
        assert layer_cost_batch([layer], [p]).row(0) == layer_cost(layer, p)


class TestBatchTimeOracle:
    def test_seconds_bit_identical_both_paths(self):
        # small batch -> scalar-LRU path; large batch -> NumPy path: both
        # must equal the scalar oracle exactly
        layers, parts = _grid_pairs()
        pairs = list(zip(layers, parts))
        assert len(pairs) >= 64
        for chunk in (pairs[:4], pairs):  # under / over VECTOR_THRESHOLD
            fn = layer_time_fn(SystolicConfig())
            fn.batch._memo.clear()
            got = fn.batch(chunk)
            assert got == [fn(layer, p) for layer, p in chunk]

    def test_memo_hits_and_stats(self):
        fn = layer_time_fn(SystolicConfig())
        fn.batch._memo.clear()
        layers, parts = _grid_pairs()
        pairs = list(zip(layers[:6], parts[:6]))
        fn.batch(pairs)
        misses0 = fn.batch.misses
        assert misses0 == len(dict.fromkeys(pairs))
        fn.batch(pairs)  # pure replay: all hits
        stats = fn.batch.stats()
        assert stats["misses"] == misses0
        assert stats["hits"] >= len(pairs)
        assert stats["currsize"] >= misses0

    def test_shared_memo_across_instances(self):
        cfg = SystolicConfig()
        a, b = layer_time_fn(cfg), layer_time_fn(cfg)
        assert a.batch._memo is b.batch._memo

    def test_mesh_style_time_fn_without_batch_attr(self):
        # AssignContext.time_batch must fall back to the scalar oracle for
        # backends that expose no vectorized surface
        from repro.api.policy import AssignContext
        from repro.core.partition import ArrayShape

        calls = []

        def scalar_fn(layer, part):
            calls.append((layer, part))
            return 1.5

        layer = LayerShape.fc("l", 8, 8)
        part = Partition(4, 0, 4)
        ctx = AssignContext(array=ArrayShape(4, 4), time_fn=scalar_fn,
                            cost_cache={})
        assert ctx.time_batch([(layer, part), (layer, part)]) == [1.5, 1.5]
        assert len(calls) == 1  # deduped through the shared cost cache
        assert ctx.time(layer, part) == 1.5
        assert len(calls) == 1  # scalar probe now hits the primed cache


class TestContextTimeBatch:
    def test_primes_shared_cost_cache(self):
        from repro.api.policy import AssignContext
        from repro.core.partition import ArrayShape

        cfg = SystolicConfig()
        fn = layer_time_fn(cfg)
        layers, parts = _grid_pairs()
        pairs = list(zip(layers[:5], parts[:5]))
        cache: dict = {}
        ctx = AssignContext(array=ArrayShape(cfg.rows, cfg.cols),
                            time_fn=fn, cost_cache=cache)
        got = ctx.time_batch(pairs)
        assert got == [fn(layer, p) for layer, p in pairs]
        assert set(cache) == set(pairs)

    def test_preempt_context_time_batch(self):
        from repro.api.policy import PreemptContext
        from repro.core.partition import ArrayShape

        cfg = SystolicConfig()
        fn = layer_time_fn(cfg)
        layer = LayerShape.fc("l", 64, 64)
        part = Partition(cfg.rows, 0, 16)
        ctx = PreemptContext(
            array=ArrayShape(cfg.rows, cfg.cols), now=0.0, ready=(),
            free=(), inflight={}, deadlines={}, time_fn=fn,
            drain_s=lambda p: 0.0, stage_in_s=lambda la: 0.0,
            cost_cache={})
        assert ctx.time_batch([(layer, part)]) == [fn(layer, part)]
        assert ctx.time(layer, part) == fn(layer, part)
