"""Sharding-rule tests (distributed/sharding.py)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get, params_spec
from repro.distributed.sharding import (
    FSDP_TP,
    REPLICATED,
    TP_ONLY,
    batch_shardings,
    cache_shardings,
    logical_axes_of,
    params_shardings,
)
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_cache


MESH = make_host_mesh()  # (n_dev, 1) — axis names data/model


def _leaves_with_specs(tree, mesh, rules):
    sh = params_shardings(tree, mesh, rules)
    return list(zip(jax.tree_util.tree_leaves_with_path(tree),
                    jax.tree.leaves(sh)))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "dbrx-132b", "mamba2-780m",
                                  "recurrentgemma-2b", "whisper-small"])
@pytest.mark.parametrize("rules", [FSDP_TP, TP_ONLY, REPLICATED])
def test_every_leaf_gets_valid_spec(arch, rules):
    tree = params_spec(get(arch).smoke)
    for (path, leaf), sh in _leaves_with_specs(tree, MESH, rules):
        spec = sh.spec
        assert len(spec) <= leaf.ndim
        # sharded dims must divide (the divisibility fallback guarantee)
        sizes = dict(zip(MESH.axis_names, MESH.devices.shape))
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= sizes[a]
            assert dim % total == 0, (path, leaf.shape, spec)


def test_logical_axes_stacked_blocks():
    tree = params_spec(get("llama3.2-3b").smoke)
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        names = [str(getattr(k, "key", k)) for k in path]
        axes = logical_axes_of(path, leaf)
        if names[0] == "blocks":
            assert axes[0] == "layers"
        if names[-1] == "wq":
            assert axes[-2:] == ("embed", "heads")


def test_moe_expert_axis():
    tree = params_spec(get("dbrx-132b").smoke)
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        names = [str(getattr(k, "key", k)) for k in path]
        if "moe" in names and names[-1] in ("gate", "up", "down"):
            axes = logical_axes_of(path, leaf)
            assert "expert" in axes
            assert leaf.ndim == 4  # (layers, E, in, out)


def test_batch_shardings_dim0():
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "odd": jax.ShapeDtypeStruct((7, 3), jnp.float32)}
    sh = batch_shardings(batch, MESH)
    n = MESH.devices.size
    if n > 1:
        assert sh["tokens"].spec == P(("data",))
        assert sh["odd"].spec == P()  # 7 not divisible -> replicate
    else:
        assert sh["tokens"].spec in (P(("data",)), P())


def test_cache_shardings_kv_seq_dim():
    cfg = get("llama3.2-3b").smoke
    cache = jax.eval_shape(lambda: init_cache(cfg, 8, 32))
    sh = cache_shardings(cache, MESH)
    specs = jax.tree.leaves(sh)
    assert all(hasattr(s, "spec") for s in specs)


def test_tuned_profiles_are_valid():
    """Every measured tuned profile factorizes 256 chips and respects the
    framework's own divisibility constraints for its arch."""
    from repro.configs import ARCHS, TUNED_PROFILES
    assert set(TUNED_PROFILES) == set(ARCHS)
    for arch, prof in TUNED_PROFILES.items():
        data, model = prof["mesh"]
        assert data * model == 256, (arch, prof)
        assert prof["q_chunks"] >= 1
        assert prof["attn_chunk"] in (512, 1024, 2048)
        cfg = ARCHS[arch].model
        # mesh-override archs: TP divides q-heads, EXCEPT whisper where
        # the measured optimum trades head divisibility for exact
        # batch=data fit (EXPERIMENTS.md §Perf H17 — its heads are small
        # enough that contraction-sharded attention is cheap)
        if model != 16 and cfg.n_heads and arch != "whisper-small":
            assert cfg.n_heads % model == 0, (arch, model, cfg.n_heads)
