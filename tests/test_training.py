"""Training-substrate tests: optimizer, microbatching, data, checkpoints."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.launch.mesh import make_host_mesh
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, make_batch
from repro.training.optimizer import (
    OptConfig,
    adamw_update,
    cosine_lr,
    init_opt_state,
)
from repro.training.train_loop import (
    TrainConfig,
    init_sharded,
    loss_and_grads,
    make_train_step,
)

MESH = make_host_mesh()
CFG = get("llama3.2-3b").smoke


class TestOptimizer:
    def test_cosine_schedule_shape(self):
        oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                       min_lr_frac=0.1)
        lrs = [float(cosine_lr(oc, jnp.asarray(s))) for s in
               [0, 5, 10, 50, 100]]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(5e-4)
        assert lrs[2] == pytest.approx(1e-3)
        assert lrs[3] < lrs[2]
        assert lrs[4] == pytest.approx(1e-4, rel=1e-3)

    def test_master_weights_are_f32(self):
        params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        st = init_opt_state(params)
        assert st["master"]["w"].dtype == jnp.float32

    def test_update_moves_params_and_keeps_dtype(self):
        params = {"w": jnp.ones((4, 4), jnp.bfloat16),
                  "scale": jnp.ones((4,), jnp.float32)}
        st = init_opt_state(params)
        grads = {"w": jnp.ones((4, 4), jnp.float32),
                 "scale": jnp.ones((4,), jnp.float32)}
        new, st2 = adamw_update(OptConfig(lr=1e-2, warmup_steps=0),
                                params, grads, st)
        assert new["w"].dtype == jnp.bfloat16
        assert float(st2["step"]) == 1
        assert not np.allclose(np.asarray(new["w"], np.float32), 1.0)

    def test_no_decay_on_norm_scales(self):
        params = {"w": jnp.ones((4, 4), jnp.bfloat16),
                  "scale": jnp.ones((4,), jnp.float32)}
        st = init_opt_state(params)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        new, _ = adamw_update(OptConfig(lr=1e-2, warmup_steps=0,
                                        weight_decay=0.5),
                              params, zeros, st)
        # zero grad + decay: 'w' shrinks, 'scale' must not
        assert float(np.asarray(new["w"], np.float32).max()) < 1.0
        np.testing.assert_allclose(np.asarray(new["scale"]), 1.0)


class TestMicrobatching:
    def test_grads_match_unbatched(self):
        key = jax.random.key(0)
        from repro.models.model import init_params
        params = init_params(CFG, key)
        dcfg = DataConfig(vocab=CFG.vocab, batch=8, seq=16, seed=1)
        batch = make_batch(dcfg, 0)
        l1, g1 = loss_and_grads(CFG, params, batch, microbatches=1)
        l2, g2 = loss_and_grads(CFG, params, batch, microbatches=4)
        assert float(l1) == pytest.approx(float(l2), rel=2e-2)
        n1 = np.sqrt(sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
                         for x in jax.tree.leaves(g1)))
        n2 = np.sqrt(sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
                         for x in jax.tree.leaves(g2)))
        assert n1 == pytest.approx(n2, rel=5e-2)

    def test_indivisible_batch_rejected(self):
        from repro.models.model import init_params
        params = init_params(CFG, jax.random.key(0))
        batch = make_batch(DataConfig(vocab=CFG.vocab, batch=6, seq=8), 0)
        with pytest.raises(ValueError, match="divisible"):
            loss_and_grads(CFG, params, batch, microbatches=4)


class TestData:
    def test_deterministic_per_step(self):
        dcfg = DataConfig(vocab=100, batch=4, seq=16, seed=7)
        a = make_batch(dcfg, 3)
        b = make_batch(dcfg, 3)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        c = make_batch(dcfg, 4)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c["tokens"]))

    def test_labels_are_next_tokens(self):
        dcfg = DataConfig(vocab=100, batch=2, seq=16, seed=0)
        b = make_batch(dcfg, 0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)
        assert int(b["tokens"].max()) < 100

    def test_loss_decreases_end_to_end(self):
        params, opt_state = init_sharded(CFG, MESH, seed=0)
        _, jitted = make_train_step(
            CFG, MESH, TrainConfig(opt=OptConfig(lr=1e-2, warmup_steps=5,
                                                 total_steps=100)))
        dcfg = DataConfig(vocab=CFG.vocab, batch=8, seq=32, seed=0)
        step_fn, losses = None, []
        for i in range(30):
            batch = make_batch(dcfg, i, MESH)
            if step_fn is None:
                step_fn = jitted(params, opt_state, batch)
            params, opt_state, m = step_fn(params, opt_state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.4, losses[::6]


class TestCheckpoint:
    def test_roundtrip_bf16(self):
        tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.float32),
                      "d": jnp.zeros((), jnp.int32)}}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 5, tree)
            assert ckpt.latest_step(d) == 5
            out = ckpt.restore(d, 5, tree)
            for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
                assert x.dtype == y.dtype
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_latest_ignores_tmp(self):
        with tempfile.TemporaryDirectory() as d:
            tree = {"a": jnp.ones((2,))}
            ckpt.save(d, 1, tree)
            os.makedirs(os.path.join(d, "step_00000009.tmp"))
            assert ckpt.latest_step(d) == 1

    def test_structure_mismatch_detected(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"a": jnp.ones((2,))})
            with pytest.raises(ValueError, match="mismatch"):
                ckpt.restore(d, 1, {"a": jnp.ones((3,))})

    def test_atomic_commit_overwrites(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"a": jnp.ones((2,))})
            ckpt.save(d, 1, {"a": jnp.zeros((2,))})  # re-commit same step
            out = ckpt.restore(d, 1, {"a": jnp.ones((2,))})
            np.testing.assert_array_equal(np.asarray(out["a"]), 0.0)

    def test_elastic_restore_with_shardings(self):
        from repro.distributed.sharding import params_shardings
        from repro.models.model import init_params
        params = init_params(CFG, jax.random.key(0))
        sh = params_shardings(params, MESH)
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 2, params)
            out = ckpt.restore(d, 2, params, shardings=sh)
            for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


ELASTIC = r"""
import os, sys, tempfile
ckpt_dir = sys.argv[1]
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get
from repro.distributed.sharding import params_shardings
from repro.models.model import init_params
from repro.training import checkpoint as ckpt
import numpy as np

cfg = get("llama3.2-3b").smoke
template = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
# restore a 1-device checkpoint onto a (2, 4) mesh — the elastic path
mesh = jax.make_mesh((2, 4), ("data", "model"))
sh = params_shardings(template, mesh)
got = ckpt.restore_latest(ckpt_dir, template, shardings=sh)
assert got is not None
step, params = got
leaf = jax.tree.leaves(params)[0]
assert len(leaf.sharding.device_set) >= 1
total = sum(x.size for x in jax.tree.leaves(params))
print("ELASTIC_OK", step, total)
"""


def test_elastic_reshard_across_meshes(tmp_path):
    """Checkpoint written on THIS process's 1-device mesh restores onto an
    8-device (2,4) mesh in a subprocess — the paper's merge/rebalance as
    an elastic-scaling event."""
    import subprocess
    import sys

    from repro.models.model import init_params
    params = init_params(CFG, jax.random.key(0))
    ckpt.save(str(tmp_path), 7, params)
    r = subprocess.run([sys.executable, "-c", ELASTIC, str(tmp_path)],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "ELASTIC_OK 7" in r.stdout, r.stderr[-2000:]
