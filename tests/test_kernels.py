"""Pallas partitioned-WS GEMM vs the pure-jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.kernels import (
    build_owner_map,
    fused_tenant_gemm,
    partitioned_matmul,
    partitioned_matmul_ref,
)


def _mk(key, E, T, K, N, n_blocks, dtype, seed_valid=None):
    k1, k2, k3 = jax.random.split(key, 3)
    xs = jax.random.normal(k1, (E, T, K), jnp.float32)
    valid_t = (jnp.full((E,), T, jnp.int32) if seed_valid is None
               else seed_valid)
    rows = jnp.arange(T)[None, :, None]
    xs = jnp.where(rows < valid_t[:, None, None], xs, 0.0).astype(dtype)
    w = jax.random.normal(k2, (K, N), jnp.float32).astype(dtype)
    owner = jax.random.randint(k3, (n_blocks,), 0, E)
    return xs, w, owner, valid_t


TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


class TestPartitionedMatmul:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [
        (1, 128, 128, 128),    # single tenant, single block
        (2, 128, 256, 512),    # multi-block N
        (3, 256, 128, 384),    # 3 tenants
        (4, 128, 384, 1024),   # K folds
    ])
    def test_allclose_vs_oracle(self, dtype, shape):
        E, T, K, N = shape
        bn = 128
        xs, w, owner, valid_t = _mk(jax.random.key(0), E, T, K, N,
                                    N // bn, dtype)
        out = partitioned_matmul(xs, w, owner, valid_t, interpret=True)
        ref = partitioned_matmul_ref(xs, w, owner, valid_t, bn)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   **TOL[dtype])

    def test_ragged_valid_t_masks_rows(self):
        E, T, K, N = 2, 256, 128, 256
        valid = jnp.array([100, 256], jnp.int32)
        xs, w, owner, valid_t = _mk(jax.random.key(1), E, T, K, N, 2,
                                    jnp.float32, seed_valid=valid)
        owner = jnp.array([0, 1], jnp.int32)
        out = partitioned_matmul(xs, w, owner, valid_t, interpret=True)
        # tenant 0 owns cols [0,128): rows >= 100 are zero (skipped blocks)
        np.testing.assert_array_equal(np.asarray(out[128:, :128]), 0.0)
        # tenant 1 rows all live
        assert np.abs(np.asarray(out[200:, 128:])).sum() > 0

    def test_block_shape_sweep(self):
        E, T, K, N = 2, 256, 256, 256
        xs, w, owner, valid_t = _mk(jax.random.key(2), E, T, K, N, 2,
                                    jnp.float32)
        ref = partitioned_matmul_ref(xs, w, owner, valid_t, 128)
        for bt, bk in [(128, 128), (64, 128), (128, 64), (256, 256)]:
            out = partitioned_matmul(xs, w, owner, valid_t, block_t=bt,
                                     block_k=bk, block_n=128,
                                     interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)

    def test_indivisible_shapes_rejected(self):
        xs = jnp.zeros((1, 100, 128))
        w = jnp.zeros((128, 128))
        with pytest.raises(ValueError, match="not divisible"):
            partitioned_matmul(xs, w, jnp.zeros((1,), jnp.int32),
                               jnp.array([100]), interpret=True)

    def test_owner_shape_checked(self):
        xs = jnp.zeros((1, 128, 128))
        w = jnp.zeros((128, 256))
        with pytest.raises(ValueError, match="owner"):
            partitioned_matmul(xs, w, jnp.zeros((5,), jnp.int32),
                               jnp.array([128]), interpret=True)


class TestFusedTenantGemm:
    @given(st.lists(
        st.tuples(st.integers(1, 150), st.integers(1, 150),
                  st.integers(1, 150)),
        min_size=1, max_size=4), st.integers(0, 2**31 - 1))
    @settings(max_examples=12, deadline=None)
    def test_ragged_matches_per_tenant_matmul(self, shapes, seed):
        key = jax.random.key(seed)
        xs, ws = [], []
        for i, (t, k, n) in enumerate(shapes):
            k1, k2 = jax.random.split(jax.random.fold_in(key, i))
            xs.append(jax.random.normal(k1, (t, k), jnp.float32))
            ws.append(jax.random.normal(k2, (k, n), jnp.float32))
        outs = fused_tenant_gemm(xs, ws, block_t=64, block_k=64, block_n=64,
                                 interpret=True)
        for x, w, o in zip(xs, ws, outs):
            assert o.shape == (x.shape[0], w.shape[1])
            np.testing.assert_allclose(np.asarray(o), np.asarray(x @ w),
                                       rtol=1e-4, atol=1e-4)

    def test_owner_map_is_vertical_partitioning(self):
        owner = build_owner_map([100, 300, 128], 128)
        # ceil(100/128)=1, ceil(300/128)=3, ceil(128/128)=1 blocks
        assert owner.tolist() == [0, 1, 1, 1, 2]
        # contiguous runs — the paper's vertical slices
        runs = [owner[0]]
        for o in owner[1:]:
            if o != runs[-1]:
                runs.append(o)
        assert runs == sorted(runs)

    def test_mismatched_pairs_rejected(self):
        with pytest.raises(ValueError):
            fused_tenant_gemm([jnp.zeros((4, 8))], [], interpret=True)
        with pytest.raises(ValueError):
            fused_tenant_gemm([jnp.zeros((4, 8))], [jnp.zeros((9, 4))],
                              interpret=True)


class TestKernelAlgorithmIntegration:
    """The fused kernel driven by Algorithm 1's partition state — the
    kernel-level realisation of the paper's dynamic partitioning."""

    def test_partition_calculation_drives_owner_map(self):
        from repro.core.partition import ArrayShape, partition_calculation
        # 4 tenants on a 512-lane "array" with 128-lane blocks: Algorithm 1
        # gives each tenant 128 lanes -> owner blocks [0,1,2,3]
        parts = partition_calculation(ArrayShape(rows=128, cols=512), 4)
        owner = []
        for i, p in enumerate(sorted(parts, key=lambda p: p.col_start)):
            assert p.cols % 128 == 0
            owner += [i] * (p.cols // 128)
        assert owner == [0, 1, 2, 3]
        # and the fused kernel computes exactly those tenants' GEMMs
        key = jax.random.key(9)
        xs = jax.random.normal(key, (4, 128, 128), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (128, 512),
                              jnp.float32)
        out = partitioned_matmul(xs, w, jnp.asarray(owner, jnp.int32),
                                 jnp.full((4,), 128, jnp.int32),
                                 interpret=True)
        ref = partitioned_matmul_ref(xs, w, jnp.asarray(owner, jnp.int32),
                                     jnp.full((4,), 128, jnp.int32), 128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    @given(n_tenants=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_merge_then_regrant_still_exact(self, n_tenants, seed):
        """Merging partitions (tenant drains) and re-granting produces a
        new owner map; the SAME kernel stays exact for any layout."""
        from repro.core.partition import ArrayShape, PartitionSet
        key = jax.random.key(seed)
        pset = PartitionSet(ArrayShape(rows=128, cols=128 * 4))
        widths = [128] * n_tenants
        for i, wd in enumerate(widths):
            pset.allocate(f"t{i}", wd)
        if n_tenants > 1:
            pset.free("t0")  # drain one -> merge
        busy = sorted(pset.busy_partitions.items(),
                      key=lambda kv: kv[1].col_start)
        if not busy:
            return
        owner = np.zeros(4, np.int32)
        live = {}
        for rank, (name, part) in enumerate(busy):
            live[rank] = name
            for b in range(part.col_start // 128, part.col_end // 128):
                owner[b] = rank
        E = len(busy)
        xs = jax.random.normal(key, (E, 128, 128), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (128, 512),
                              jnp.float32)
        vt = jnp.full((E,), 128, jnp.int32)
        out = partitioned_matmul(xs, w, jnp.asarray(owner), vt,
                                 interpret=True)
        ref = partitioned_matmul_ref(xs, w, jnp.asarray(owner), vt, 128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
