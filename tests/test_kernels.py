"""Pallas partitioned-WS GEMM vs the pure-jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# only the property tests need hypothesis — the deterministic compact-grid
# / dtype / VMEM / autotune coverage always runs
from _hypothesis_compat import given, settings, st

from repro.kernels import (
    autotune_blocks,
    block_vmem_bytes,
    build_owner_map,
    fused_tenant_gemm,
    grid_accounting,
    live_block_tables,
    partitioned_matmul,
    partitioned_matmul_ref,
)


def _mk(key, E, T, K, N, n_blocks, dtype, seed_valid=None):
    k1, k2, k3 = jax.random.split(key, 3)
    xs = jax.random.normal(k1, (E, T, K), jnp.float32)
    valid_t = (jnp.full((E,), T, jnp.int32) if seed_valid is None
               else seed_valid)
    rows = jnp.arange(T)[None, :, None]
    xs = jnp.where(rows < valid_t[:, None, None], xs, 0.0).astype(dtype)
    w = jax.random.normal(k2, (K, N), jnp.float32).astype(dtype)
    owner = jax.random.randint(k3, (n_blocks,), 0, E)
    return xs, w, owner, valid_t


TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


class TestPartitionedMatmul:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [
        (1, 128, 128, 128),    # single tenant, single block
        (2, 128, 256, 512),    # multi-block N
        (3, 256, 128, 384),    # 3 tenants
        (4, 128, 384, 1024),   # K folds
    ])
    def test_allclose_vs_oracle(self, dtype, shape):
        E, T, K, N = shape
        bn = 128
        xs, w, owner, valid_t = _mk(jax.random.key(0), E, T, K, N,
                                    N // bn, dtype)
        out = partitioned_matmul(xs, w, owner, valid_t, interpret=True)
        ref = partitioned_matmul_ref(xs, w, owner, valid_t, bn)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   **TOL[dtype])

    def test_ragged_valid_t_masks_rows(self):
        E, T, K, N = 2, 256, 128, 256
        valid = jnp.array([100, 256], jnp.int32)
        xs, w, owner, valid_t = _mk(jax.random.key(1), E, T, K, N, 2,
                                    jnp.float32, seed_valid=valid)
        owner = jnp.array([0, 1], jnp.int32)
        out = partitioned_matmul(xs, w, owner, valid_t, interpret=True)
        # tenant 0 owns cols [0,128): rows >= 100 are zero (skipped blocks)
        np.testing.assert_array_equal(np.asarray(out[128:, :128]), 0.0)
        # tenant 1 rows all live
        assert np.abs(np.asarray(out[200:, 128:])).sum() > 0

    def test_block_shape_sweep(self):
        E, T, K, N = 2, 256, 256, 256
        xs, w, owner, valid_t = _mk(jax.random.key(2), E, T, K, N, 2,
                                    jnp.float32)
        ref = partitioned_matmul_ref(xs, w, owner, valid_t, 128)
        for bt, bk in [(128, 128), (64, 128), (128, 64), (256, 256)]:
            out = partitioned_matmul(xs, w, owner, valid_t, block_t=bt,
                                     block_k=bk, block_n=128,
                                     interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)

    def test_indivisible_shapes_rejected(self):
        xs = jnp.zeros((1, 100, 128))
        w = jnp.zeros((128, 128))
        with pytest.raises(ValueError, match="not divisible"):
            partitioned_matmul(xs, w, jnp.zeros((1,), jnp.int32),
                               jnp.array([100]), interpret=True)

    def test_owner_shape_checked(self):
        xs = jnp.zeros((1, 128, 128))
        w = jnp.zeros((128, 256))
        with pytest.raises(ValueError, match="owner"):
            partitioned_matmul(xs, w, jnp.zeros((5,), jnp.int32),
                               jnp.array([128]), interpret=True)


def _mk_int(seed, E, T, K, N, n_blocks, valid_t, valid_k):
    """Integer-valued f32 operands honouring the zero-padding contract.

    Small-integer entries keep every product and partial sum exactly
    representable in f32, so dense, compact and the oracle must agree
    BIT-exactly regardless of accumulation grouping.
    """
    rng = np.random.default_rng(seed)
    xs = rng.integers(-4, 5, (E, T, K)).astype(np.float32)
    for e in range(E):
        xs[e, valid_t[e]:, :] = 0.0
        xs[e, :, valid_k[e]:] = 0.0
    w = rng.integers(-4, 5, (K, N)).astype(np.float32)
    owner = rng.integers(0, E, n_blocks).astype(np.int32)
    return (jnp.asarray(xs), jnp.asarray(w), jnp.asarray(owner),
            jnp.asarray(valid_t, jnp.int32), jnp.asarray(valid_k, jnp.int32))


class TestCompactGrid:
    """grid_mode='compact' — live blocks only, same numerics as dense."""

    @given(seed=st.integers(0, 2**31 - 1),
           dims=st.tuples(st.integers(1, 3),      # E
                          st.integers(1, 3),      # t blocks
                          st.integers(1, 3),      # k blocks
                          st.integers(1, 4)),     # n blocks
           data=st.data())
    @settings(max_examples=10, deadline=None)
    def test_matches_dense_and_oracle_bit_exactly(self, seed, dims, data):
        E, tb, kb, nb = dims
        B = 64
        T, K, N = tb * B, kb * B, nb * B
        valid_t = data.draw(st.lists(st.integers(0, T), min_size=E,
                                     max_size=E))
        valid_k = data.draw(st.lists(st.integers(0, K), min_size=E,
                                     max_size=E))
        xs, w, owner, vt, vk = _mk_int(seed, E, T, K, N, nb,
                                       valid_t, valid_k)
        kw = dict(block_t=B, block_k=B, block_n=B, interpret=True)
        dense = partitioned_matmul(xs, w, owner, vt, vk,
                                   grid_mode="dense", **kw)
        compact = partitioned_matmul(xs, w, owner, vt, vk,
                                     grid_mode="compact", **kw)
        np.testing.assert_array_equal(np.asarray(compact), np.asarray(dense))
        # the oracle masks by valid_t only; valid_k exactness comes from
        # the zero-padded K columns contributing exact zeros
        ref = partitioned_matmul_ref(xs, w, owner, vt, B)
        np.testing.assert_array_equal(np.asarray(compact), np.asarray(ref))

    def test_compact_schedules_exactly_the_live_blocks(self):
        owner = np.array([0, 1, 1, 2], np.int32)
        vt, vk = np.array([100, 256, 7]), np.array([384, 130, 40])
        nidx, tidx, kidx, last = live_block_tables(
            owner, vt, vk, T=256, K=384, block_t=128, block_k=128)
        acc = grid_accounting(T=256, K=384, N=512, owner=owner, valid_t=vt,
                              valid_k=vk, grid_mode="compact")
        assert acc.blocks_scheduled == nidx.size == acc.blocks_live
        assert acc.blocks_skipped == 0
        # tenant0: 1x3 blocks; tenant1 (2 cols): 2*(2x2); tenant2: 1x1
        assert acc.blocks_live == 3 + 2 * 4 + 1
        # K-runs contiguous, drain flagged on the run's last step
        runs = np.flatnonzero(kidx == 0)
        for s, e in zip(runs, list(runs[1:]) + [nidx.size]):
            assert (nidx[s:e] == nidx[s]).all() and (tidx[s:e] == tidx[s]).all()
            assert list(kidx[s:e]) == list(range(e - s))
            assert last[e - 1] == 1 and not last[s:e - 1].any()

    def test_dense_accounting_counts_gated_steps(self):
        owner = np.array([0, 1], np.int32)
        acc = grid_accounting(T=256, K=256, N=256, owner=owner,
                              valid_t=np.array([128, 256]),
                              valid_k=np.array([256, 128]),
                              grid_mode="dense")
        assert acc.blocks_total == acc.blocks_scheduled == 2 * 2 * 2
        assert acc.blocks_live == 2 + 2          # t0: 1x2, t1: 2x1
        assert acc.blocks_skipped == 4
        # fetch model: every scheduled step pulls one x and one w tile
        assert acc.x_bytes_fetched == 8 * 128 * 128 * 4
        assert acc.w_bytes_fetched == 8 * 128 * 128 * 4
        assert acc.schedule_efficiency == 0.5

    def test_zero_live_blocks_returns_zeros(self):
        xs = jnp.ones((1, 128, 128), jnp.float32)
        out = partitioned_matmul(xs, jnp.ones((128, 128), jnp.float32),
                                 jnp.zeros((1,), jnp.int32),
                                 jnp.array([0], jnp.int32),
                                 grid_mode="compact", interpret=True)
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_compact_rejects_traced_partition_state(self):
        xs = jnp.zeros((1, 128, 128), jnp.float32)
        w = jnp.zeros((128, 128), jnp.float32)

        @jax.jit
        def f(owner, vt):
            return partitioned_matmul(xs, w, owner, vt,
                                      grid_mode="compact", interpret=True)

        with pytest.raises(ValueError, match="concrete"):
            f(jnp.zeros((1,), jnp.int32), jnp.array([128], jnp.int32))

    def test_bad_grid_mode_rejected(self):
        xs = jnp.zeros((1, 128, 128), jnp.float32)
        with pytest.raises(ValueError, match="grid_mode"):
            partitioned_matmul(xs, jnp.zeros((128, 128)),
                               jnp.zeros((1,), jnp.int32),
                               jnp.array([128]), grid_mode="sparse",
                               interpret=True)


class TestOperandContract:
    """Explicit dtype validation/promotion + the VMEM block budget."""

    def test_int_operands_rejected(self):
        xs = jnp.zeros((1, 128, 128), jnp.int32)
        with pytest.raises(TypeError, match="bfloat16 or float32"):
            partitioned_matmul(xs, jnp.zeros((128, 128), jnp.float32),
                               jnp.zeros((1,), jnp.int32),
                               jnp.array([128]), interpret=True)

    def test_f16_weights_rejected(self):
        xs = jnp.zeros((1, 128, 128), jnp.float32)
        with pytest.raises(TypeError, match="bfloat16 or float32"):
            partitioned_matmul(xs, jnp.zeros((128, 128), jnp.float16),
                               jnp.zeros((1,), jnp.int32),
                               jnp.array([128]), interpret=True)

    def test_mixed_bf16_f32_promotes(self):
        key = jax.random.key(3)
        x = jax.random.normal(key, (64, 64), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 64),
                              jnp.float32)
        out = fused_tenant_gemm([x.astype(jnp.bfloat16)], [w],
                                block_t=64, block_k=64, block_n=64,
                                interpret=True)[0]
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(x.astype(jnp.bfloat16).astype(jnp.float32) @ w),
            rtol=1e-5, atol=1e-5)

    def test_vmem_budget_enforced(self):
        xs = jnp.zeros((1, 1024, 1024), jnp.float32)
        w = jnp.zeros((1024, 1024), jnp.float32)
        with pytest.raises(ValueError, match="VMEM"):
            partitioned_matmul(xs, w, jnp.zeros((1,), jnp.int32),
                               jnp.array([1024]), block_t=1024,
                               block_k=1024, block_n=1024, interpret=True)

    def test_mixed_dtype_autotune_budgets_for_the_promoted_type(self):
        # regression: the autotuner must budget/account for the PROMOTED
        # operand dtypes (bf16 × f32 → f32), exactly like the kernel does
        key = jax.random.key(11)
        x = jax.random.normal(key, (64, 64), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 64),
                              jnp.float32)
        budget = block_vmem_bytes(128, 128, 128, "float32", "float32")
        _, stats = fused_tenant_gemm(
            [x.astype(jnp.bfloat16)], [w], vmem_budget_bytes=budget,
            interpret=True, return_stats=True)
        assert (stats.block_t, stats.block_k, stats.block_n) == \
            (128, 128, 128)
        # byte accounting reflects the f32 fetch, not the bf16 source
        acc = stats.accounting
        assert acc.x_bytes_fetched == acc.blocks_scheduled * 128 * 128 * 4

    def test_vmem_budget_is_dtype_aware(self):
        f32 = block_vmem_bytes(256, 256, 256, jnp.float32, jnp.float32)
        bf16 = block_vmem_bytes(256, 256, 256, jnp.bfloat16, jnp.bfloat16)
        assert bf16 < f32  # narrower operands buy headroom


class TestAutotune:
    def test_fits_budget_and_caches(self):
        shapes = ((512, 363, 96), (512, 147, 64), (54, 512, 100))
        before = autotune_blocks.cache_info().hits
        bt, bk, bn = autotune_blocks(shapes)
        assert autotune_blocks(shapes) == (bt, bk, bn)
        assert autotune_blocks.cache_info().hits == before + 1
        assert block_vmem_bytes(bt, bk, bn, "float32", "float32") <= \
            16 * 2 ** 20

    def test_prefers_fewer_fetched_bytes(self):
        # tiny tenants: any block over 128 only adds padding fetch traffic
        assert autotune_blocks(((64, 64, 64), (32, 48, 64))) == \
            (128, 128, 128)

    def test_respects_tight_budget(self):
        budget = block_vmem_bytes(128, 128, 128, "float32", "float32")
        bt, bk, bn = autotune_blocks(((512, 512, 512),),
                                     vmem_budget_bytes=budget)
        assert (bt, bk, bn) == (128, 128, 128)
        with pytest.raises(ValueError, match="fits the VMEM budget"):
            autotune_blocks(((512, 512, 512),),
                            vmem_budget_bytes=budget - 1)

    def test_auto_mode_picks_compact_iff_ragged(self):
        key = jax.random.key(7)
        def mk(t, k, n, s):
            return (jax.random.normal(jax.random.fold_in(key, s), (t, k)),
                    jax.random.normal(jax.random.fold_in(key, s + 100),
                                      (k, n)))
        # tenant 1 is >1 block smaller on T and K: its padding tiles are
        # dead blocks in the shared dense grid
        ragged = [mk(256, 256, 128, 0), mk(40, 60, 128, 1)]
        _, stats = fused_tenant_gemm(
            [x for x, _ in ragged], [w for _, w in ragged],
            block_t=128, block_k=128, block_n=128, interpret=True,
            return_stats=True)
        assert stats.grid_mode == "compact"
        assert stats.accounting.blocks_skipped == 0
        uniform = [mk(128, 128, 128, 2), mk(128, 128, 128, 3)]
        _, stats = fused_tenant_gemm(
            [x for x, _ in uniform], [w for _, w in uniform],
            block_t=128, block_k=128, block_n=128, interpret=True,
            return_stats=True)
        assert stats.grid_mode == "dense"
        assert stats.accounting.schedule_efficiency == 1.0


class TestFusedTenantGemm:
    @given(st.lists(
        st.tuples(st.integers(1, 150), st.integers(1, 150),
                  st.integers(1, 150)),
        min_size=1, max_size=4), st.integers(0, 2**31 - 1))
    @settings(max_examples=12, deadline=None)
    def test_ragged_matches_per_tenant_matmul(self, shapes, seed):
        key = jax.random.key(seed)
        xs, ws = [], []
        for i, (t, k, n) in enumerate(shapes):
            k1, k2 = jax.random.split(jax.random.fold_in(key, i))
            xs.append(jax.random.normal(k1, (t, k), jnp.float32))
            ws.append(jax.random.normal(k2, (k, n), jnp.float32))
        outs = fused_tenant_gemm(xs, ws, block_t=64, block_k=64, block_n=64,
                                 interpret=True)
        for x, w, o in zip(xs, ws, outs):
            assert o.shape == (x.shape[0], w.shape[1])
            np.testing.assert_allclose(np.asarray(o), np.asarray(x @ w),
                                       rtol=1e-4, atol=1e-4)

    def test_owner_map_is_vertical_partitioning(self):
        owner = build_owner_map([100, 300, 128], 128)
        # ceil(100/128)=1, ceil(300/128)=3, ceil(128/128)=1 blocks
        assert owner.tolist() == [0, 1, 1, 1, 2]
        # contiguous runs — the paper's vertical slices
        runs = [owner[0]]
        for o in owner[1:]:
            if o != runs[-1]:
                runs.append(o)
        assert runs == sorted(runs)

    def test_mismatched_pairs_rejected(self):
        with pytest.raises(ValueError):
            fused_tenant_gemm([jnp.zeros((4, 8))], [], interpret=True)
        with pytest.raises(ValueError):
            fused_tenant_gemm([jnp.zeros((4, 8))], [jnp.zeros((9, 4))],
                              interpret=True)


class TestKernelAlgorithmIntegration:
    """The fused kernel driven by Algorithm 1's partition state — the
    kernel-level realisation of the paper's dynamic partitioning."""

    def test_partition_calculation_drives_owner_map(self):
        from repro.core.partition import ArrayShape, partition_calculation
        # 4 tenants on a 512-lane "array" with 128-lane blocks: Algorithm 1
        # gives each tenant 128 lanes -> owner blocks [0,1,2,3]
        parts = partition_calculation(ArrayShape(rows=128, cols=512), 4)
        owner = []
        for i, p in enumerate(sorted(parts, key=lambda p: p.col_start)):
            assert p.cols % 128 == 0
            owner += [i] * (p.cols // 128)
        assert owner == [0, 1, 2, 3]
        # and the fused kernel computes exactly those tenants' GEMMs
        key = jax.random.key(9)
        xs = jax.random.normal(key, (4, 128, 128), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (128, 512),
                              jnp.float32)
        out = partitioned_matmul(xs, w, jnp.asarray(owner, jnp.int32),
                                 jnp.full((4,), 128, jnp.int32),
                                 interpret=True)
        ref = partitioned_matmul_ref(xs, w, jnp.asarray(owner, jnp.int32),
                                     jnp.full((4,), 128, jnp.int32), 128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    @given(n_tenants=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_merge_then_regrant_still_exact(self, n_tenants, seed):
        """Merging partitions (tenant drains) and re-granting produces a
        new owner map; the SAME kernel stays exact for any layout."""
        from repro.core.partition import ArrayShape, PartitionSet
        key = jax.random.key(seed)
        pset = PartitionSet(ArrayShape(rows=128, cols=128 * 4))
        widths = [128] * n_tenants
        for i, wd in enumerate(widths):
            pset.allocate(f"t{i}", wd)
        if n_tenants > 1:
            pset.free("t0")  # drain one -> merge
        busy = sorted(pset.busy_partitions.items(),
                      key=lambda kv: kv[1].col_start)
        if not busy:
            return
        owner = np.zeros(4, np.int32)
        live = {}
        for rank, (name, part) in enumerate(busy):
            live[rank] = name
            for b in range(part.col_start // 128, part.col_end // 128):
                owner[b] = rank
        E = len(busy)
        xs = jax.random.normal(key, (E, 128, 128), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (128, 512),
                              jnp.float32)
        vt = jnp.full((E,), 128, jnp.int32)
        out = partitioned_matmul(xs, w, jnp.asarray(owner), vt,
                                 interpret=True)
        ref = partitioned_matmul_ref(xs, w, jnp.asarray(owner), vt, 128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
