"""repro.obs: tracer ring + lazy derivation, metrics registry, exporters,
and — the load-bearing contract — observation purity: arming obs never
changes a serialized byte of any run."""

import json

import pytest

from repro.obs import Observability, Timeline, resolve_obs
from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import SPAN_KINDS, Tracer, _trace_spans
from repro.traffic import TrafficSimulator
from repro.traffic.arrivals import PoissonArrivals


def _small_run(obs=None, **kwargs):
    arr = PoissonArrivals(rate=2000.0, horizon=0.01, seed=3, pool="light",
                          slo_s=0.01)
    return TrafficSimulator(arr, policy="equal", backend="sim",
                            max_concurrent=2, queue_cap=4, seed=3,
                            obs=obs, **kwargs).run()


class TestTracerRing:
    def test_ring_bounds_memory_and_counts_drops(self):
        tr = Tracer(max_events=8)
        for i in range(20):
            tr.instant("dispatch", float(i))
        assert len(tr) == 8
        assert tr.n_recorded == 20
        assert tr.n_dropped == 12
        # newest events win: the oldest 12 fell out
        assert [r[1] for r in tr.raw()] == [float(i) for i in range(12, 20)]

    def test_max_events_validated(self):
        with pytest.raises(ValueError):
            Tracer(max_events=0)

    def test_counts_by_kind_sorted(self):
        tr = Tracer()
        tr.instant("migrate", 1.0)
        tr.instant("dispatch", 0.0)
        tr.span("compute", 0.0, 1.0)
        tr.instant("dispatch", 2.0)
        assert tr.counts_by_kind() == {
            "compute": 1, "dispatch": 2, "migrate": 1}
        assert list(tr.counts_by_kind()) == ["compute", "dispatch",
                                             "migrate"]

    def test_state_absorb_round_trip(self):
        a, b = Tracer(), Tracer()
        a.instant("dispatch", 0.5, 0, "t0")
        b.instant("dispatch", 0.25, 1, "t1")
        b.absorb(a.state())
        assert b.n_recorded == 2
        # merged stream interleaves by start time
        assert [r[4] for r in b.raw()] == ["t1", "t0"]


class TestSpanDerivation:
    class _Ev:
        # the scheduler TraceEvent surface _trace_spans reads
        def __init__(self, start, compute_start, compute_end, end,
                     preempted=False):
            self.tenant = "t"
            self.layer_name = "conv1"
            self.fraction = 1.0
            self.resumed = False
            self.preempted = preempted
            self.start = start
            self.compute_start = compute_start
            self.compute_end = compute_end
            self.end = end
            self.partition = type("P", (), {"cols": 4, "col_start": 0})()

    def test_record_fans_out_to_three_spans(self):
        spans = _trace_spans(2, [self._Ev(0.0, 1.0, 3.0, 3.5)])
        assert [s[0] for s in spans] == ["stage_in", "compute", "stage_out"]
        assert [(s[1], s[2]) for s in spans] == [
            (0.0, 1.0), (1.0, 3.0), (3.0, 3.5)]
        assert all(s[3] == 2 and s[4] == "t" for s in spans)
        assert dict(spans[1][5])["cols"] == 4

    def test_preempted_tail_is_drain(self):
        spans = _trace_spans(0, [self._Ev(0.0, 1.0, 2.0, 2.5,
                                          preempted=True)])
        assert [s[0] for s in spans] == ["stage_in", "compute", "drain"]
        assert dict(spans[1][5])["preempted"] is True

    def test_zero_width_phases_are_skipped(self):
        spans = _trace_spans(0, [self._Ev(1.0, 1.0, 2.0, 2.0)])
        assert [s[0] for s in spans] == ["compute"]

    def test_attach_is_lazy_and_cached(self):
        tr = Tracer()
        trace = [self._Ev(0.0, 1.0, 2.0, 2.5)]
        tr.attach(0, trace)
        assert tr._attached[0][1] is None  # nothing converted yet
        assert tr.n_recorded == 3
        cached = tr._attached[0][1]
        assert cached is not None
        assert tr._attached[0][1] is cached  # second read reuses it

    def test_attach_source_derives_arbitrary_records(self):
        tr = Tracer()
        tr.attach_source(lambda: [("dispatch", 0.0, 0.0, 1, "j0", ())])
        assert tr.counts_by_kind() == {"dispatch": 1}
        assert tr.n_dropped == 0  # derived records never drop


class TestDerivedJobInstants:
    def test_instants_match_job_records(self):
        res = _small_run(obs=True)
        tr = res.timeline.tracer
        counts = tr.counts_by_kind()
        m = res.metrics
        assert counts["dispatch"] == m.jobs_arrived
        assert counts["arrive"] == m.jobs_arrived - m.jobs_rejected
        assert counts["complete"] == m.jobs_completed
        by_kind = {}
        for e in tr.events():
            by_kind.setdefault(e.kind, []).append(e)
        statuses = {dict(e.args)["status"] for e in by_kind["dispatch"]}
        assert statuses <= {"run", "queued", "rejected"}
        got = sorted((e.t0, e.node) for e in by_kind["complete"])
        want = sorted((r.completed, r.array) for r in res.records
                      if r.completed is not None)
        assert got == want

    def test_instants_survive_keep_trace_false(self):
        res = _small_run(obs=True)  # keep_trace defaults off in serving
        counts = res.timeline.tracer.counts_by_kind()
        assert "dispatch" in counts and "complete" in counts
        assert not set(counts) & set(SPAN_KINDS)

    def test_spans_ride_keep_trace(self):
        res = _small_run(obs=True, keep_trace=True)
        counts = res.timeline.tracer.counts_by_kind()
        assert counts["compute"] > 0 and counts["stage_in"] > 0


class TestObservationPurity:
    @pytest.mark.parametrize("keep_trace", [False, True])
    def test_armed_run_serializes_byte_identically(self, keep_trace):
        plain = _small_run(keep_trace=keep_trace)
        armed = _small_run(obs=True, keep_trace=keep_trace)
        assert armed.timeline is not None
        import dataclasses
        detached = dataclasses.replace(armed, timeline=None)
        assert json.dumps(detached.as_dict()) == json.dumps(plain.as_dict())

    def test_obs_key_appends_last(self):
        plain = _small_run()
        armed = _small_run(obs=True)
        keys = list(armed.as_dict())
        assert keys[-1] == "obs"
        assert keys[:-1] == list(plain.as_dict())

    def test_resolve_obs_front_door(self):
        assert resolve_obs(None) is None
        assert resolve_obs(False) is None
        assert isinstance(resolve_obs(True), Observability)
        o = Observability()
        assert resolve_obs(o) is o
        with pytest.raises(ValueError):
            resolve_obs("yes")

    def test_sample_every_validated(self):
        with pytest.raises(ValueError):
            Observability(sample_every=0)


class TestMetricsRegistry:
    def test_series_decimation_keeps_running_mean(self):
        reg = MetricsRegistry(max_samples=8)
        s = reg.series("x")
        for i in range(100):
            s.sample(float(i), float(i))
        assert len(s.samples) < 8
        assert s.stride > 1
        assert s.n_offered == 100
        assert s.mean == pytest.approx(
            sum(v for _, v in s.samples) / len(s.samples))

    def test_merge_folds_counters_gauges_series(self):
        a, b = MetricsRegistry(max_samples=8), MetricsRegistry(max_samples=8)
        a.counter("c").inc(3)
        b.counter("c").inc(4)
        a.gauge("g").set(2.0)
        b.gauge("g").set(5.0)
        a.histogram("h").observe(1.0)
        b.histogram("h").observe(9.0)
        a.series("s").sample(0.0, 1.0)
        b.series("s").sample(0.5, 3.0)
        a.merge(b.state())
        assert a.counter("c").value == 7
        assert a.gauge("g").value == 5.0
        h = a.histogram("h")
        assert (h.count, h.min, h.max) == (2, 1.0, 9.0)
        assert a.series("s").samples == [(0.0, 1.0), (0.5, 3.0)]

    def test_registry_records_serving_series(self):
        res = _small_run(obs=Observability(sample_every=1))
        reg = res.timeline.registry
        m = res.metrics
        assert reg.counter("serve.arrivals").value == m.jobs_arrived
        assert (reg.counter("serve.dispatch.rejected").value
                == m.jobs_rejected)
        assert reg.series("node0.queue_depth").n_offered > 0
        assert reg.series("fleet.in_system").n_offered > 0


class TestExport:
    def _trace_run(self):
        return _small_run(obs=True, keep_trace=True, n_arrays=2,
                          dispatch="jsq")

    def test_chrome_trace_structure(self):
        trace = self._trace_run().timeline.chrome_trace()
        ev = trace["traceEvents"]
        body = [e for e in ev if e["ph"] != "M"]
        assert {e["pid"] for e in body} <= {0, 1}
        assert any(e["ph"] == "X" for e in body)     # tenant spans
        assert any(e["ph"] == "i" for e in body)     # instants
        assert any(e["tid"] > 0 for e in body)       # tenant lanes
        names = [e["args"]["name"] for e in ev
                 if e["ph"] == "M" and e["name"] == "process_name"]
        assert names == sorted(f"array-node-{p}"
                               for p in {e["pid"] for e in body})

    def test_export_deterministic(self):
        a = self._trace_run().timeline.chrome_trace()
        b = self._trace_run().timeline.chrome_trace()
        assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)

    def test_preempt_and_migrate_markers_export_live(self):
        tr = Tracer()
        tr.instant("preempt", 1.0, 0, "t0", (("layer_index", 2),))
        tr.instant("migrate", 2.0, 1, "t0", (("src", 0), ("dst", 1)))
        from repro.obs.export import chrome_trace
        cats = {e["cat"] for e in chrome_trace(tr)["traceEvents"]
                if e.get("ph") == "i"}
        assert cats == {"preempt", "migrate"}

    def test_timeline_csv(self):
        res = _small_run(obs=Observability(sample_every=1))
        csv = res.timeline.timeline_csv()
        lines = csv.strip().splitlines()
        assert lines[0] == "series,t,value"
        assert any(line.startswith("node0.queue_depth,")
                   for line in lines[1:])

    def test_render_summary_smoke(self):
        res = _small_run(obs=True)
        out = res.timeline.render(title="serve obs")
        assert "# serve obs" in out
        assert "serve.arrivals" in out

    def test_disarmed_surfaces_raise(self):
        t = Timeline(Observability(tracer=False))
        with pytest.raises(ValueError):
            t.chrome_trace()
        t = Timeline(Observability(metrics=False))
        with pytest.raises(ValueError):
            t.timeline_csv()


class TestShardedObs:
    def _run(self, parallel):
        from repro.traffic import ShardedTrafficSimulator
        return ShardedTrafficSimulator(
            "poisson", policy="equal", backend="sim", n_arrays=2,
            n_shards=2, dispatch="rr", max_concurrent=2, queue_cap=4,
            seed=3, parallel=parallel, obs=True,
            rate=2000.0, horizon=0.01, pool="light", slo_s=0.01).run()

    def test_pod_states_merge_into_one_timeline(self):
        res = self._run(parallel=False)
        assert res.timeline is not None
        reg = res.timeline.registry
        assert reg.counter("serve.arrivals").value == res.metrics.jobs_arrived
        assert res.timeline.tracer.n_recorded > 0

    def test_parallel_merge_matches_serial(self):
        serial = self._run(parallel=False)
        parallel = self._run(parallel=True)
        assert (serial.timeline.summary()
                == parallel.timeline.summary())


class TestSessionFrontDoor:
    def test_serve_obs_threads_through(self):
        from repro.api import Session
        res = Session(policy="equal", backend="sim").serve(
            "poisson", rate=2000.0, horizon=0.01, pool="light",
            slo_s=0.01, max_concurrent=2, queue_cap=4, seed=3, obs=True)
        assert res.timeline is not None
        assert list(res.as_dict())[-1] == "obs"
        assert res.timeline.summary()["events_recorded"] > 0


class TestFairnessReservoir:
    def test_accounting_sample_cap_bounds_memory(self):
        from repro.api.backend import resolve_backend
        from repro.fairness.accounting import FairnessAccounting

        b = resolve_backend("sim")
        acct = FairnessAccounting(b.array, b.time_fn(),
                                  stage=b.stage_model(), max_samples=16)
        for i in range(200):
            acct.sample(float(i), [])
        assert len(acct._samples) < 16
        assert acct._stride > 1
        assert acct._n_offered == 200

    def test_max_samples_validated(self):
        from repro.api.backend import resolve_backend
        from repro.fairness.accounting import FairnessAccounting

        b = resolve_backend("sim")
        with pytest.raises(ValueError):
            FairnessAccounting(b.array, b.time_fn(), max_samples=1)
