"""`repro.overload` — admission policies, brownout ladder, pod respawn.

Covers the unit surfaces (the admission registry, CoDel drop scheduling,
token-bucket rate bounds — including the Hypothesis property the bench
contract names — brownout hysteresis and stage knobs, the scheduler's
batch demand scale) and the end-to-end contracts BENCH_overload.json
gates: gated-key purity, tier-0 exemption, the PodFailureError partial
payload, and deterministic serial==forked pod respawn.
"""

import json
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.chaos import FaultEvent, respawn_backoffs
from repro.overload import (
    DEFAULT_STAGES,
    BrownoutController,
    BrownoutStage,
    CoDelAdmission,
    StaticAdmission,
    TokenBucketAdmission,
    list_admissions,
    resolve_admission,
)
from repro.traffic import (
    PodFailureError,
    ShardedTrafficSimulator,
    TrafficSimulator,
)
from repro.traffic.arrivals import PoissonArrivals


def _arrivals(**kw):
    kw.setdefault("rate", 2000.0)
    kw.setdefault("horizon", 0.02)
    kw.setdefault("seed", 3)
    kw.setdefault("pool", "light")
    kw.setdefault("slo_s", 0.01)
    return PoissonArrivals(**kw)


def _serve(**kwargs):
    return TrafficSimulator(_arrivals(), policy="equal", backend="sim",
                            max_concurrent=2, queue_cap=4, seed=3,
                            **kwargs).run()


# ---------------------------------------------------------------------------
# admission registry
# ---------------------------------------------------------------------------


class TestAdmissionRegistry:
    def test_builtin_names(self):
        assert {"static", "codel", "token_bucket"} <= set(list_admissions())

    def test_resolve_by_name_and_instance(self):
        assert isinstance(resolve_admission("static"), StaticAdmission)
        inst = CoDelAdmission(target_delay_s=1e-3)
        assert resolve_admission(inst) is inst

    def test_unknown_name_rejected(self):
        with pytest.raises(ValueError):
            resolve_admission("open-the-floodgates")

    def test_policies_carry_registry_name(self):
        for name in ("static", "codel", "token_bucket"):
            assert resolve_admission(name).name == name


class TestStaticAdmission:
    def test_admits_everything(self):
        pol = StaticAdmission()
        assert all(pol.admit(tier, t * 1e-3, 1.0)
                   for tier in (0, 1, 2) for t in range(50))


class TestCoDelAdmission:
    def test_below_target_always_admits(self):
        pol = CoDelAdmission(target_delay_s=5e-3, interval_s=10e-3)
        assert all(pol.admit(1, t * 1e-3, 1e-3) for t in range(100))

    def test_tier0_rides_through_drop_windows(self):
        pol = CoDelAdmission(target_delay_s=1e-3, interval_s=2e-3)
        # drive the controller deep into the dropping state with batch…
        decisions = [pol.admit(1, t * 1e-3, 5e-3) for t in range(40)]
        assert False in decisions
        # …and tier 0 is still never shed
        assert all(pol.admit(0, 0.040 + t * 1e-3, 5e-3) for t in range(20))

    def test_first_drop_after_one_full_interval(self):
        pol = CoDelAdmission(target_delay_s=1e-3, interval_s=10e-3)
        assert pol.admit(1, 0.000, 5e-3)     # arms first_above
        assert pol.admit(1, 0.005, 5e-3)     # still inside the interval
        assert not pol.admit(1, 0.010, 5e-3)  # interval elapsed: drop

    def test_drop_spacing_shrinks_sqrt(self):
        pol = CoDelAdmission(target_delay_s=1e-3, interval_s=8e-3)
        t = 0.0
        pol.admit(1, t, 5e-3)
        t += pol.interval_s
        assert not pol.admit(1, t, 5e-3)          # drop #1
        # next drop is a full interval later, the one after interval/sqrt(2)
        gap1 = pol._drop_next - t
        t = pol._drop_next
        assert not pol.admit(1, t, 5e-3)          # drop #2
        gap2 = pol._drop_next - t
        assert gap1 == pytest.approx(pol.interval_s)
        assert gap2 == pytest.approx(pol.interval_s / math.sqrt(2))

    def test_dip_below_target_resets_state(self):
        pol = CoDelAdmission(target_delay_s=1e-3, interval_s=2e-3)
        [pol.admit(1, t * 1e-3, 5e-3) for t in range(10)]
        assert pol._dropping
        assert pol.admit(1, 0.011, 1e-4)      # back under target
        assert not pol._dropping and pol._first_above is None

    def test_validation(self):
        with pytest.raises(ValueError):
            CoDelAdmission(target_delay_s=0.0)
        with pytest.raises(ValueError):
            CoDelAdmission(interval_s=-1.0)


class TestTokenBucketAdmission:
    def test_burst_then_shed(self):
        pol = TokenBucketAdmission(rate=1.0, burst=3.0)
        got = [pol.admit(1, 0.0, 0.0) for _ in range(5)]
        assert got == [True, True, True, False, False]

    def test_refills_with_simulated_time(self):
        pol = TokenBucketAdmission(rate=10.0, burst=1.0)
        assert pol.admit(1, 0.0, 0.0)
        assert not pol.admit(1, 0.0, 0.0)
        assert pol.admit(1, 0.2, 0.0)     # 0.2s * 10/s = 2 tokens, capped 1

    def test_tier0_bypasses_buckets(self):
        pol = TokenBucketAdmission(rate=1.0, burst=1.0)
        assert pol.admit(1, 0.0, 0.0)
        assert not pol.admit(1, 0.0, 0.0)
        assert all(pol.admit(0, 0.0, 0.0) for _ in range(100))

    def test_buckets_are_per_tier(self):
        pol = TokenBucketAdmission(rate=1.0, burst=1.0)
        assert pol.admit(1, 0.0, 0.0)
        assert not pol.admit(1, 0.0, 0.0)   # tier 1 bucket empty…
        assert pol.admit(2, 0.0, 0.0)       # …tier 2 bucket untouched

    def test_validation(self):
        with pytest.raises(ValueError):
            TokenBucketAdmission(rate=0.0)
        with pytest.raises(ValueError):
            TokenBucketAdmission(burst=0.5)

    # the property the bench contract names: over any arrival sequence a
    # batch tier's admits never exceed burst + rate x elapsed, and tier-0
    # admits are a superset of static's (i.e. every tier-0 arrival)
    @settings(max_examples=60, deadline=None)
    @given(st.lists(
        st.tuples(st.integers(min_value=0, max_value=2),
                  st.floats(min_value=0.0, max_value=0.05,
                            allow_nan=False, allow_infinity=False)),
        min_size=1, max_size=80))
    def test_rate_bound_and_tier0_superset(self, events):
        static = StaticAdmission()
        pol = TokenBucketAdmission(rate=100.0, burst=5.0)
        now = 0.0
        admits: dict[int, int] = {}
        first_seen: dict[int, float] = {}
        last_seen: dict[int, float] = {}
        for tier, dt in events:
            now += dt
            first_seen.setdefault(tier, now)
            last_seen[tier] = now
            ok = pol.admit(tier, now, 0.0)
            if tier == 0:
                # superset of static: static admits every arrival, so
                # tier 0 must too
                assert ok == static.admit(tier, now, 0.0) is True
            if ok:
                admits[tier] = admits.get(tier, 0) + 1
        for tier, n in admits.items():
            if tier == 0:
                continue
            elapsed = last_seen[tier] - first_seen[tier]
            assert n <= pol.burst + pol.rate * elapsed + 1e-9


# ---------------------------------------------------------------------------
# brownout controller
# ---------------------------------------------------------------------------


class TestBrownoutStage:
    def test_default_ladder_shape(self):
        assert [s.name for s in DEFAULT_STAGES] == [
            "cap_bandwidth", "shrink_floors", "stretch_deadlines", "shed"]
        assert DEFAULT_STAGES[-1].shed_batch

    def test_validation(self):
        with pytest.raises(ValueError):
            BrownoutStage("x", batch_bw_cap=0.0)
        with pytest.raises(ValueError):
            BrownoutStage("x", batch_demand_scale=1.5)
        with pytest.raises(ValueError):
            BrownoutStage("x", deadline_stretch=0.5)


class TestBrownoutController:
    def test_enter_hysteresis(self):
        c = BrownoutController(enter_after=3, exit_after=2)
        assert not c.observe(0.0, 1.0)
        assert not c.observe(0.1, 1.0)
        assert c.observe(0.2, 1.0)           # 3rd consecutive over-target
        assert c.stage.name == "cap_bandwidth"

    def test_under_target_sample_resets_entry_count(self):
        c = BrownoutController(enter_after=3, exit_after=50)
        c.observe(0.0, 1.0)
        c.observe(0.1, 1.0)
        c.observe(0.2, 0.0)                  # pressure cleared
        assert not c.observe(0.3, 1.0)
        assert not c.observe(0.4, 1.0)
        assert c.observe(0.5, 1.0)

    def test_exit_hysteresis_walks_back_up(self):
        c = BrownoutController(enter_after=1, exit_after=3)
        c.observe(0.0, 1.0)
        assert c.stage is not None
        assert not c.observe(0.1, 0.0)
        assert not c.observe(0.2, 0.0)
        assert c.observe(0.3, 0.0)
        assert c.stage is None               # back off the ladder

    def test_ladder_saturates_at_last_stage(self):
        c = BrownoutController(enter_after=1)
        for i in range(10):
            c.observe(i * 0.1, 1.0)
        assert c.stage.name == "shed"
        assert c.stage_idx == len(c.stages) - 1

    def test_capacity_floor_is_overload_too(self):
        c = BrownoutController(enter_after=1, capacity_floor=0.75)
        assert c.observe(0.0, 0.0, healthy_frac=0.5)
        assert c.stage is not None

    def test_shed_only_batch_and_only_in_shed_stage(self):
        c = BrownoutController(enter_after=1)
        c.observe(0.0, 1.0)                  # cap_bandwidth stage
        assert not c.shed(1)
        for i in range(1, 4):
            c.observe(i * 0.1, 1.0)          # ... -> shed stage
        assert c.shed(1) and c.shed(2)
        assert not c.shed(0)

    def test_stretch_deadline_math(self):
        c = BrownoutController(enter_after=1)
        for i in range(3):
            c.observe(i * 0.1, 1.0)          # stretch_deadlines stage
        assert c.stage.deadline_stretch == 2.0
        assert c.stretch_deadline(1, 1.0, 1.5) == pytest.approx(2.0)
        assert c.stretch_deadline(0, 1.0, 1.5) == 1.5   # tier 0 untouched

    def test_transitions_priced_and_logged(self):
        c = BrownoutController(enter_after=1, exit_after=1,
                               transition_energy_j=0.25)
        c.observe(0.0, 1.0)
        c.observe(0.1, 0.0)
        rep = c.report()
        assert rep.transitions == 2
        assert rep.energy_overhead_j == pytest.approx(0.5)
        assert rep.log == ((0.0, None, "cap_bandwidth"),
                           (0.1, "cap_bandwidth", None))
        assert rep.final_stage is None

    def test_validation(self):
        with pytest.raises(ValueError):
            BrownoutController(stages=())
        with pytest.raises(ValueError):
            BrownoutController(delay_target_s=0.0)
        with pytest.raises(ValueError):
            BrownoutController(enter_after=0)
        with pytest.raises(ValueError):
            BrownoutController(capacity_floor=1.5)
        with pytest.raises(ValueError):
            BrownoutController(transition_energy_j=-1.0)


class TestBatchDemandScale:
    def _sched(self):
        from repro.core.dnng import LayerShape, chain
        from repro.core.partition import ArrayShape
        from repro.core.scheduler import DynamicScheduler
        from repro.sim.systolic import SystolicConfig, layer_time_fn

        sched = DynamicScheduler(ArrayShape(128, 128),
                                 layer_time_fn(SystolicConfig()),
                                 policy="equal")
        for name, tier in (("rt", 0), ("batch", 1)):
            g = chain(name, [LayerShape.fc("l0", 256, 256, batch=256)])
            sched.submit(g, tier=tier)
            sched._mark_ready(name, 0.0)
        return sched

    def _snapshot(self, sched):
        return {d.name: (d.demand, d.width_demand)
                for d in sched._demands(sched._ready_tenants(0.0))}

    def test_scale_validation(self):
        sched = self._sched()
        with pytest.raises(ValueError):
            sched.set_batch_demand_scale(0.0)
        with pytest.raises(ValueError):
            sched.set_batch_demand_scale(1.5)

    def test_scale_shrinks_batch_demand_only(self):
        sched = self._sched()
        base = self._snapshot(sched)
        sched.set_batch_demand_scale(0.5)
        scaled = self._snapshot(sched)
        assert scaled["rt"] == base["rt"]                # tier 0 untouched
        assert scaled["batch"][0] == pytest.approx(base["batch"][0] * 0.5)
        assert scaled["batch"][1] <= base["batch"][1]
        sched.set_batch_demand_scale(1.0)                # cache invalidated
        assert self._snapshot(sched) == base


# ---------------------------------------------------------------------------
# simulator integration
# ---------------------------------------------------------------------------


class TestSimulatorGating:
    def test_unarmed_run_has_no_overload_surface(self):
        res = _serve()
        assert res.overload is None and res.brownout is None
        assert res.metrics.rejections_by_cause is None

    def test_static_descriptor_and_causes(self):
        res = _serve(admission="static")
        assert res.overload == "admission=static"
        causes = res.metrics.rejections_by_cause
        assert list(causes) == ["queue_full", "admission_shed",
                                "recovery_shed"]
        assert causes["queue_full"] == res.metrics.jobs_rejected
        assert causes["admission_shed"] == 0

    def test_brownout_descriptor_and_report(self):
        res = _serve(brownout=True)
        assert res.overload == "brownout"
        assert res.brownout is not None
        assert res.brownout.stages == tuple(
            s.name for s in DEFAULT_STAGES)

    def test_combined_descriptor(self):
        res = _serve(admission="codel", brownout=True)
        assert res.overload == "admission=codel+brownout"

    def test_config_and_kwargs_spellings_byte_identical(self):
        from repro.api import OverloadConfig, SchedulingConfig, ServeConfig
        kw = _serve(admission="static").as_dict()
        cfg = ServeConfig(
            scheduling=SchedulingConfig(max_concurrent=2, queue_cap=4,
                                        seed=3),
            overload=OverloadConfig(admission="static"))
        via_cfg = TrafficSimulator(_arrivals(), policy="equal",
                                   backend="sim", config=cfg).run()
        assert json.dumps(via_cfg.as_dict(), indent=1) == \
            json.dumps(kw, indent=1)

    def test_admission_shed_hits_batch_only(self):
        # an aggressive bucket on an overdriven stream: batch tiers shed,
        # tier 0 never does
        res = TrafficSimulator(
            _arrivals(rate=6000.0, horizon=0.05,
                      tiers=(0, 1, 1)), policy="equal", backend="sim",
            max_concurrent=2, queue_cap=4, seed=3,
            admission=TokenBucketAdmission(rate=50.0, burst=2.0)).run()
        m = res.metrics
        assert m.rejections_by_cause["admission_shed"] > 0
        assert 0 not in m.shed_by_tier
        assert all(t > 0 for t in m.shed_by_tier)
        # shed jobs carry no array and no completion
        shed_records = [r for r in res.records
                        if r.array is None and r.tier > 0]
        assert len(shed_records) >= m.rejections_by_cause["admission_shed"]

    def test_armed_runs_deterministic(self):
        a = _serve(admission="codel", brownout=True).as_dict()
        b = _serve(admission="codel", brownout=True).as_dict()
        assert json.dumps(a, indent=1) == json.dumps(b, indent=1)

    def test_brownout_instants_in_timeline(self):
        res = TrafficSimulator(
            _arrivals(rate=8000.0, horizon=0.05, tiers=(0, 1, 1)),
            policy="equal", backend="sim", max_concurrent=2, queue_cap=4,
            seed=3, obs=True,
            brownout=BrownoutController(delay_target_s=1e-4,
                                        enter_after=1)).run()
        assert res.brownout.transitions > 0
        kinds = {e.kind for e in res.timeline.tracer.events()}
        assert "brownout" in kinds

    def test_brownout_kind_registered_with_tracer(self):
        from repro.obs.tracer import BROWNOUT, INSTANT_KINDS
        assert BROWNOUT in INSTANT_KINDS

    def test_brownout_caps_defer_to_bandwidth_hook_policies(self):
        # moca overrides the bandwidth hook: brownout must leave its caps
        # alone (the policy re-asserts them every rebalance)
        res = TrafficSimulator(
            _arrivals(rate=8000.0, horizon=0.04, tiers=(0, 1, 1)),
            policy="moca", backend="sim", max_concurrent=2, queue_cap=4,
            seed=3, memory=True,
            brownout=BrownoutController(delay_target_s=1e-4,
                                        enter_after=1)).run()
        # the run completes and the controller walked the ladder; the
        # moca caps stayed policy-owned (no crash, no double accounting)
        assert res.brownout.transitions > 0


# ---------------------------------------------------------------------------
# pod respawn
# ---------------------------------------------------------------------------


def _sharded(**kwargs):
    kwargs.setdefault("rate", 3000.0)
    kwargs.setdefault("horizon", 0.05)
    kwargs.setdefault("pool", "light")
    kwargs.setdefault("seed", 0)
    kwargs.setdefault("sync_every", 64)
    return ShardedTrafficSimulator("poisson", n_arrays=4, n_shards=2,
                                   **kwargs)


KILL = FaultEvent(t=0.0, kind="pod_kill", node=1, epoch=1)


class TestRespawnBackoffs:
    def test_seed_key_determinism(self):
        a = respawn_backoffs(5, "respawn:0:1:1")
        b = respawn_backoffs(5, "respawn:0:1:1")
        c = respawn_backoffs(5, "respawn:0:1:2")
        assert a == b
        assert a != c
        assert all(d > 0 for d in a)


class TestPodFailurePayload:
    def test_serial_abort_carries_partial_results(self):
        sim = _sharded(parallel=False, faults=KILL)
        with pytest.raises(PodFailureError,
                           match=r"pod 1.*epoch 1") as exc_info:
            sim.run()
        e = exc_info.value
        assert isinstance(e, RuntimeError)   # historical failure surface
        assert (e.pod, e.epoch) == (1, 1)
        assert e.jobs_completed > 0
        assert len(e.partial_records) >= e.jobs_completed
        assert e.pod_status[1]["state"] == "dead"
        assert e.pod_status[0]["state"] == "ok"
        assert e.pod_status[1]["epochs_done"] == 1

    def test_records_are_arrival_ordered(self):
        sim = _sharded(parallel=False, faults=KILL)
        with pytest.raises(PodFailureError) as exc_info:
            sim.run()
        arr = [r.arrival for r in exc_info.value.partial_records]
        assert arr == sorted(arr)


class TestPodRespawn:
    def test_respawn_requires_faults(self):
        with pytest.raises(ValueError, match="faults="):
            _sharded(respawn=True)

    def test_respawn_completes_where_abort_was(self):
        res = _sharded(parallel=False, faults=KILL, respawn=True).run()
        assert res.faults == "pod_kill"
        assert res.recovery == "pod_respawn"
        base = _sharded(parallel=False).run()
        # every job is accounted exactly once (carry + retry + fresh)
        assert len(res.records) == len(base.records)

    def test_serial_forked_byte_identical(self):
        a = _sharded(parallel=False, faults=KILL, respawn=True).run()
        b = _sharded(parallel=True, faults=KILL, respawn=True,
                     pod_timeout_s=60.0).run()
        assert json.dumps(a.as_dict(), indent=1) == \
            json.dumps(b.as_dict(), indent=1)

    def test_seed_stable(self):
        a = _sharded(parallel=False, faults=KILL, respawn=True).run()
        b = _sharded(parallel=False, faults=KILL, respawn=True).run()
        assert json.dumps(a.as_dict(), indent=1) == \
            json.dumps(b.as_dict(), indent=1)

    def test_armed_unfired_respawn_is_pure(self):
        # a plan that never fires leaves the result byte-identical to a
        # fault-free run, respawn armed or not — and no recovery is
        # reported
        plain = _sharded(parallel=False).run()
        armed = _sharded(parallel=False, respawn=True,
                         faults=FaultEvent(t=0.0, kind="pod_kill", node=0,
                                           epoch=10**6)).run()
        assert json.dumps(plain.as_dict()) == json.dumps(armed.as_dict())
        assert armed.faults is None and armed.recovery is None

    def test_recovered_jobs_pay_for_the_downtime(self):
        # the lost in-flight jobs keep their ORIGINAL arrival in the
        # record, so their latency includes the outage + backoff: the
        # recovery never shaves the tail below the fault-free run's
        res = _sharded(parallel=False, faults=KILL, respawn=True).run()
        base = _sharded(parallel=False).run()

        def latencies(r):
            return [x.completed - x.arrival for x in r.records
                    if x.completed is not None]

        assert max(latencies(res)) >= max(latencies(base))
