"""`repro.chaos` — fault injection, detection, and recovery.

Covers the unit surfaces (FaultPlan schedules, RetryPolicy backoff,
truncate_dnng warm-restart graphs, ArrayNode fail/degrade/repair,
HealthMonitor classification, FleetLoads exclusion) and the end-to-end
contracts the chaos bench gates: seeded determinism, fault-free byte
purity, recovery strictly beating no-recovery on availability, and the
sharded pod_kill failure surface (no pipe hang — a RuntimeError names the
dead pod).
"""

import dataclasses
import json
import random

import pytest
from _hypothesis_compat import given, settings, st

from repro.chaos import (
    FAULT_KINDS,
    FaultEvent,
    FaultPlan,
    HealthMonitor,
    NoRecovery,
    RetryPolicy,
    RetryRestart,
    list_recoveries,
    resolve_faults,
    resolve_recovery,
    truncate_dnng,
)
from repro.core.dnng import DNNG, LayerShape
from repro.traffic.arrivals import PoissonArrivals
from repro.traffic.cluster import FleetLoads, JoinShortestQueue
from repro.traffic.simulator import TrafficSimulator, serve


def _small_serve(**kwargs):
    kwargs.setdefault("rate", 3000.0)
    kwargs.setdefault("horizon", 0.05)
    kwargs.setdefault("n_arrays", 4)
    kwargs.setdefault("seed", 7)
    kwargs.setdefault("pool", "light")
    kwargs.setdefault("slo_s", 0.05)
    return serve("poisson", **kwargs)


def _layer(i):
    return LayerShape(M=8, N=8, C=8, R=1, S=1, H=8, W=8, P=8, Q=8, name=f"L{i}")


def _dnng(n_layers=4, edges=None, name="g"):
    return DNNG(name=name, layers=tuple(_layer(i) for i in range(n_layers)),
                edges=edges)


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_event_validation(self):
        with pytest.raises(ValueError):
            FaultEvent(t=0.0, kind="meltdown")
        with pytest.raises(ValueError):
            FaultEvent(t=-1.0, kind="crash")
        with pytest.raises(ValueError):
            FaultEvent(t=0.0, kind="blackout")  # needs duration_s > 0
        with pytest.raises(ValueError):
            FaultEvent(t=0.0, kind="degrade")  # needs dead_cols >= 1
        with pytest.raises(ValueError):
            FaultEvent(t=0.0, kind="straggler", factor=1.0)  # needs > 1
        with pytest.raises(ValueError):
            FaultEvent(t=0.0, kind="bus_stall", factor=0.5)

    def test_plan_sorts_events_by_time(self):
        e1 = FaultEvent(t=0.5, kind="crash", node=1)
        e2 = FaultEvent(t=0.1, kind="crash", node=2)
        plan = FaultPlan((e1, e2))
        assert [e.t for e in plan.events] == [0.1, 0.5]
        assert len(plan) == 2
        assert plan.kinds() == {"crash": 2}

    def test_seeded_plan_is_deterministic(self):
        kw = dict(horizon=1.0, n_nodes=8, crashes=2, blackouts=1,
                  degrades=1, bus_stalls=1, stragglers=1)
        a = FaultPlan.seeded(42, **kw)
        b = FaultPlan.seeded(42, **kw)
        assert a == b
        assert FaultPlan.seeded(43, **kw) != a
        assert len(a) == 6
        assert all(0.25 <= e.t <= 0.75 for e in a.events)
        assert all(e.node < 8 for e in a.events)

    def test_resolve_faults_coercions(self):
        e = FaultEvent(t=0.1, kind="crash")
        assert resolve_faults(e).events == (e,)
        assert resolve_faults([e, e]).events == (e, e)
        plan = FaultPlan((e,), name="p")
        assert resolve_faults(plan) is plan
        with pytest.raises(ValueError):
            resolve_faults("crash-everything")

    def test_fault_kinds_inventory(self):
        assert set(FAULT_KINDS) == {"crash", "blackout", "degrade",
                                    "bus_stall", "straggler", "pod_kill"}


# ---------------------------------------------------------------------------
# retry policy + warm restart
# ---------------------------------------------------------------------------


class TestRetryPolicy:
    def test_budget_clamps_to_last_tier(self):
        p = RetryPolicy(budgets=(3, 2, 1))
        assert [p.budget(t) for t in (0, 1, 2, 3, 9)] == [3, 2, 1, 1, 1]

    def test_backoff_grows_and_caps(self):
        p = RetryPolicy(base_backoff_s=1e-3, backoff_factor=2.0,
                        max_backoff_s=3e-3, jitter_frac=0.0)
        rng = random.Random(0)
        delays = [p.delay_s(a, rng) for a in range(4)]
        assert delays == [1e-3, 2e-3, 3e-3, 3e-3]

    def test_jitter_stays_within_fraction(self):
        p = RetryPolicy(base_backoff_s=1e-3, jitter_frac=0.25)
        rng = random.Random(1)
        for _ in range(100):
            d = p.delay_s(0, rng)
            assert 0.75e-3 <= d <= 1.25e-3

    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(base_backoff_s=0.0)
        with pytest.raises(ValueError):
            RetryPolicy(backoff_factor=0.5)
        with pytest.raises(ValueError):
            RetryPolicy(jitter_frac=1.5)
        with pytest.raises(ValueError):
            RetryPolicy(budgets=())


class TestTruncateDnng:
    def test_chain_drops_completed_prefix(self):
        g = _dnng(4)
        r = truncate_dnng(g, 2, arrival_time=1.5)
        assert r.name == g.name
        assert r.layers == g.layers[2:]
        assert r.arrival_time == 1.5
        assert r.edges is None

    def test_zero_completed_is_a_clone(self):
        g = _dnng(3)
        r = truncate_dnng(g, 0, arrival_time=2.0)
        assert r.layers == g.layers
        assert r.arrival_time == 2.0

    def test_dag_edges_remap_and_drop(self):
        g = _dnng(4, edges=((0, 1), (0, 2), (1, 3), (2, 3)))
        r = truncate_dnng(g, 2, arrival_time=0.0)
        # edges out of the completed prefix are satisfied by checkpoints;
        # only (2, 3) survives, shifted to the new index origin
        assert r.edges == ((0, 1),)

    def test_fully_completed_raises(self):
        g = _dnng(2)
        with pytest.raises(ValueError):
            truncate_dnng(g, 2, arrival_time=0.0)


class TestRecoveryPolicies:
    def test_registry_lists_and_resolves(self):
        names = list_recoveries()
        assert "retry_restart" in names and "none" in names
        assert isinstance(resolve_recovery("none"), NoRecovery)

    def test_unknown_recovery_lists_registered(self):
        with pytest.raises(ValueError, match="retry_restart"):
            resolve_recovery("warm_fuzzies")

    def test_checkpoint_granularity_floors(self):
        r = RetryRestart(checkpoint_every=4)
        assert [r.checkpoint_layers(k) for k in (0, 3, 4, 7, 8)] == [
            0, 0, 4, 4, 8]

    def test_tier0_never_shed(self):
        with pytest.raises(ValueError):
            RetryRestart(shed_below={0: 0.9})
        r = RetryRestart(shed_below={1: 0.5, 2: 0.75})
        assert not r.should_shed(0, 0.1)
        assert r.should_shed(1, 0.4) and not r.should_shed(1, 0.6)
        # a tier-2 arrival sheds below EITHER watermark at or under it
        assert r.should_shed(2, 0.7) and r.should_shed(2, 0.4)
        assert not r.should_shed(2, 0.8)

    def test_restore_cost_uses_migration_model(self):
        r = RetryRestart()
        g = _dnng(3)
        assert r.restore_s(g) == r.migration.migrate_s(g)

    def test_no_recovery_has_zero_budget(self):
        n = NoRecovery()
        assert n.retry_budget(0) == 0
        assert n.backoff_s(0, random.Random(0)) == 0.0


# ---------------------------------------------------------------------------
# node fault surface
# ---------------------------------------------------------------------------


def _node(index=0, max_concurrent=2, queue_cap=4):
    from repro.api.backend import resolve_backend
    from repro.api.policy import resolve_policy
    from repro.traffic.cluster import ArrayNode

    bk = resolve_backend("sim")
    return ArrayNode(index, bk.array, bk.time_fn(), bk.stage_model(),
                     resolve_policy("equal"), max_concurrent=max_concurrent,
                     queue_cap=queue_cap,
                     on_complete=lambda node, tenant, t: None)


def _jobs(n=4, horizon=0.01):
    return list(PoissonArrivals(rate=n / horizon * 2, horizon=horizon,
                                seed=11, pool="light", slo_s=1.0))[:n]


class TestNodeFaultSurface:
    def test_fail_returns_resident_jobs_with_progress(self):
        node = _node()
        jobs = _jobs(4)
        for j in jobs:
            node.offer(j)
        lost = node.fail(jobs[-1].arrival + 1e-4)
        assert {j.dnng.name for j, _done in lost} == {
            j.dnng.name for j in jobs}
        assert all(done >= 0 for _j, done in lost)
        assert not node.alive and node.in_system == 0
        assert node.scheduler.n_active == 0

    def test_fail_banks_pe_seconds(self):
        node = _node()
        for j in _jobs(2):
            node.offer(j)
        node.scheduler.run()
        busy = node.pe_seconds_busy
        assert busy > 0.0
        node.fail(node.scheduler.now)
        assert node.pe_seconds_busy == busy  # carried across the reset

    def test_repair_restores_service(self):
        node = _node()
        node.fail(0.0)
        node.repair(1.0)
        assert node.alive and node.down_since == 0.0
        job = _jobs(1)[0]
        job = dataclasses.replace(
            job, arrival=1.0, dnng=job.dnng.clone(arrival_time=1.0))
        assert node.offer(job) == "run"

    def test_degrade_shrinks_and_refits(self):
        node = _node()
        jobs = _jobs(3)
        for j in jobs:
            node.offer(j)
        cols0 = node.array.cols
        overflow = node.degrade(jobs[-1].arrival + 1e-4, dead_cols=cols0 // 2)
        assert node.array.cols == cols0 - cols0 // 2
        assert node.alive
        # everything re-fit (2 run slots + 4 queue slots >= 3 jobs)
        assert overflow == []
        assert node.in_system == len(jobs)
        node.scheduler.run()
        assert node.in_system == 0

    def test_degrade_full_width_raises(self):
        node = _node()
        with pytest.raises(ValueError):
            node.degrade(0.0, dead_cols=node.array.cols)

    def test_scale_knobs_survive_scheduler_swap(self):
        node = _node()
        node.set_compute_scale(3.0)
        node.set_bus_scale(2.0)
        node.fail(0.0)  # installs a fresh scheduler
        assert node.scheduler.time_scale == 3.0
        assert node.scheduler.bus_scale == 2.0

    def test_straggler_scale_slows_service(self):
        fast, slow = _node(), _node()
        slow.set_compute_scale(4.0)
        job = _jobs(1)[0]
        fast.offer(job)
        slow.offer(job)
        fast.scheduler.run()
        slow.scheduler.run()
        assert slow.scheduler.now > fast.scheduler.now


# ---------------------------------------------------------------------------
# health monitor
# ---------------------------------------------------------------------------


class _FakeNode:
    def __init__(self, index):
        self.index = index
        self.alive = True
        self.health = "healthy"
        self.down_since = 0.0


class _FakeFleet:
    def __init__(self):
        self.excluded = set()

    def exclude(self, i):
        self.excluded.add(i)

    def readmit(self, i):
        self.excluded.discard(i)


class TestHealthMonitor:
    def test_staleness_thresholds(self):
        mon = HealthMonitor(suspect_after_s=1e-3, dead_after_s=3e-3)
        nodes = [_FakeNode(0), _FakeNode(1)]
        fleet = _FakeFleet()
        nodes[0].alive = False
        nodes[0].down_since = 0.0
        mon.refresh(0.5e-3, nodes, fleet)
        assert nodes[0].health == "healthy"  # undetectable window
        mon.refresh(2e-3, nodes, fleet)
        assert nodes[0].health == "suspect" and 0 in fleet.excluded
        mon.refresh(5e-3, nodes, fleet)
        assert nodes[0].health == "dead"
        assert nodes[1].health == "healthy" and 1 not in fleet.excluded

    def test_dispatch_failure_is_definitive_and_sticky(self):
        mon = HealthMonitor(suspect_after_s=1e-3, dead_after_s=3e-3)
        node, fleet = _FakeNode(0), _FakeFleet()
        node.alive = False
        node.down_since = 1.0
        mon.note_dispatch_failure(node, fleet, 1.0001)
        assert node.health == "dead" and 0 in fleet.excluded
        # the heartbeat gap still looks fresh, but the belief must hold
        mon.refresh(1.0002, [node], fleet)
        assert node.health == "dead" and 0 in fleet.excluded

    def test_repair_readmits(self):
        mon = HealthMonitor(suspect_after_s=1e-3, dead_after_s=3e-3)
        node, fleet = _FakeNode(0), _FakeFleet()
        node.alive = False
        node.down_since = 0.0
        mon.refresh(5e-3, [node], fleet)
        assert node.health == "dead"
        node.alive = True
        node.down_since = 0.0
        mon.refresh(6e-3, [node], fleet)
        assert node.health == "healthy" and 0 not in fleet.excluded
        assert mon.transitions[-1][4] == "heartbeat_back"

    def test_service_outlier_probation_cycle(self):
        mon = HealthMonitor(outlier_factor=2.0, min_observations=3,
                            probe_after_s=10e-3)
        nodes = [_FakeNode(i) for i in range(3)]
        fleet = _FakeFleet()
        for t in range(3):
            mon.observe(0, 1.0, t * 1e-3)
            mon.observe(1, 1.0, t * 1e-3)
            mon.observe(2, 10.0, t * 1e-3)  # the straggler
        mon.refresh(4e-3, nodes, fleet)
        assert nodes[2].health == "suspect" and 2 in fleet.excluded
        assert mon.transitions[-1][4] == "service_outlier"
        # probation expires: stats reset, node readmitted for re-judging
        mon.refresh(15e-3, nodes, fleet)
        assert nodes[2].health == "healthy" and 2 not in fleet.excluded
        assert mon.transitions[-1][4] == "probe_ok"

    def test_validation(self):
        with pytest.raises(ValueError):
            HealthMonitor(suspect_after_s=5e-3, dead_after_s=1e-3)
        with pytest.raises(ValueError):
            HealthMonitor(outlier_factor=0.9)
        with pytest.raises(ValueError):
            HealthMonitor(ewma_alpha=0.0)


# ---------------------------------------------------------------------------
# fleet exclusion
# ---------------------------------------------------------------------------


class _LoadNode:
    def __init__(self, index):
        self.index = index
        self.load = 0
        self.queue = ()

    @property
    def in_system(self):
        return self.load


class TestFleetExclusion:
    def test_routing_loads_is_the_live_list_when_clear(self):
        fleet = FleetLoads([_LoadNode(i) for i in range(4)])
        assert fleet.routing_loads is fleet.loads
        fleet.exclude(2)
        view = fleet.routing_loads
        assert view is not fleet.loads
        assert view[2] == float("inf") and view[0] == 0
        fleet.readmit(2)
        assert fleet.routing_loads is fleet.loads

    def test_min_index_skips_excluded(self):
        nodes = [_LoadNode(i) for i in range(4)]
        fleet = FleetLoads(nodes)
        fleet.exclude(0)
        assert fleet.min_index() == 1
        fleet.readmit(0)
        assert fleet.min_index() == 0

    def test_all_excluded_falls_back_to_argmin(self):
        nodes = [_LoadNode(i) for i in range(3)]
        nodes[1].load = -1  # force a distinct argmin
        fleet = FleetLoads(nodes)
        fleet.update(nodes[1])
        for i in range(3):
            fleet.exclude(i)
        assert fleet.min_index() == 1
        for i in range(3):
            fleet.readmit(i)
        assert fleet.min_index() == 1

    def test_exclusion_churn_matches_linear_scan_seeded(self):
        # deterministic fallback for the hypothesis property below, so
        # the invariant is exercised even where hypothesis is absent
        rng = random.Random(123)
        for case in range(20):
            n = rng.randint(2, 8)
            ops = [rng.randint(0, 11) for _ in range(rng.randint(1, 200))]
            self._churn(ops, n)

    @settings(deadline=None, max_examples=60)
    @given(st.lists(st.integers(min_value=0, max_value=11), min_size=1,
                    max_size=300),
           st.integers(min_value=2, max_value=8))
    def test_exclusion_churn_matches_linear_scan(self, ops, n):
        self._churn(ops, n)

    def _churn(self, ops, n):
        # property: under arbitrary interleavings of load updates,
        # exclusions and readmissions, min_index() equals the linear
        # argmin over non-excluded nodes (with the lowest-index
        # tie-break), falling back to the global argmin when everything
        # is excluded — and jsq routes identically on routing_loads
        nodes = [_LoadNode(i) for i in range(n)]
        fleet = FleetLoads(nodes)
        excluded = set()
        rng = random.Random(7)
        jsq = JoinShortestQueue()
        for op in ops:
            i = op % n
            mode = op % 3
            if mode == 0:
                nodes[i].load = max(0, nodes[i].load + rng.choice((-1, 1)))
                fleet.update(nodes[i])
            elif mode == 1:
                fleet.exclude(i)
                excluded.add(i)
            else:
                fleet.readmit(i)
                excluded.discard(i)
            live = [j for j in range(n) if j not in excluded] or range(n)
            want = min(live, key=lambda j: (nodes[j].load, j))
            assert fleet.min_index() == want
            assert jsq.choose_tracked(fleet, rng) == want
            view = fleet.routing_loads
            for j in range(n):
                if j in excluded:
                    assert view[j] == float("inf")
                else:
                    assert view[j] == nodes[j].load


# ---------------------------------------------------------------------------
# end-to-end serving under faults
# ---------------------------------------------------------------------------


class TestServeUnderFaults:
    def test_crash_recovery_beats_none_on_availability(self):
        # underloaded on purpose: with headroom, every recovered job is a
        # net completion (a saturated fleet would let retries crowd out
        # fresh arrivals and wash the signal out)
        plan = FaultPlan.single("crash", t=0.02, node=1)
        rec = _small_serve(faults=plan, rate=2000.0)
        none = _small_serve(faults=plan, rate=2000.0, recovery="none")
        assert rec.chaos.jobs_recovered > 0
        assert none.chaos.jobs_recovered == 0
        assert rec.metrics.jobs_completed > none.metrics.jobs_completed
        assert (rec.metrics.availability_by_tier[0]
                > none.metrics.availability_by_tier[0])

    def test_identical_seeds_identical_traces(self):
        plan = FaultPlan.seeded(5, horizon=0.05, n_nodes=4, crashes=1,
                                stragglers=1)
        a = _small_serve(faults=plan)
        b = _small_serve(faults=plan)
        assert json.dumps(a.as_dict()) == json.dumps(b.as_dict())
        assert a.chaos.as_dict() == b.chaos.as_dict()
        assert a.chaos.transitions == b.chaos.transitions

    def test_blackout_repairs_and_readmits(self):
        plan = FaultPlan.single("blackout", t=0.02, node=0, duration_s=0.01)
        res = _small_serve(faults=plan)
        causes = [tr[4] for tr in res.chaos.transitions]
        assert "heartbeat_back" in causes or "heartbeat_lost" in causes
        assert res.chaos.faults_injected == 1

    def test_degrade_keeps_serving_on_surviving_columns(self):
        plan = FaultPlan.single("degrade", t=0.02, node=2, dead_cols=64)
        res = _small_serve(faults=plan)
        base = _small_serve()
        assert res.metrics.jobs_completed > 0
        # bounded inflation: the fleet lost < 1/8 of its columns
        assert res.metrics.jobs_completed >= base.metrics.jobs_completed // 2

    def test_shedding_spares_tier0(self):
        plan = FaultPlan(
            (FaultEvent(t=0.015, kind="crash", node=0),
             FaultEvent(t=0.016, kind="crash", node=1)))
        rec = RetryRestart(shed_below={1: 0.75})
        res = _small_serve(faults=plan, recovery=rec, tiers=(0, 1))
        assert res.chaos.jobs_shed > 0
        # tier-0 arrivals are never shed (shed_below rejects a tier-0
        # watermark at construction), so tier-0 availability must beat
        # the shed tier's
        av = res.metrics.availability_by_tier
        assert av[0] > av[1]

    def test_retry_budget_exhaustion(self):
        # two crashes on the same node: jobs retried onto it can be lost
        # again; tier budgets of 0 burn immediately under "none"
        plan = FaultPlan.single("crash", t=0.02, node=1)
        res = _small_serve(
            faults=plan,
            recovery=RetryRestart(retry=RetryPolicy(budgets=(1,))))
        assert res.chaos.jobs_lost == res.chaos.jobs_retried + \
            res.chaos.retries_exhausted

    def test_chaos_report_round_trip(self):
        res = _small_serve(faults=FaultPlan.single("crash", t=0.02, node=0))
        d = res.as_dict()
        assert d["faults"] == "crash"
        assert d["recovery"] == "retry_restart"
        assert d["jobs_lost"] == res.chaos.jobs_lost
        assert d["availability_by_tier"] is not None

    def test_pod_kill_rejected_by_single_process_sim(self):
        with pytest.raises(ValueError, match="pod_kill"):
            _small_serve(
                faults=FaultEvent(t=0.0, kind="pod_kill", node=0, epoch=0))

    def test_out_of_range_node_rejected(self):
        with pytest.raises(ValueError, match="node 9"):
            _small_serve(faults=FaultPlan.single("crash", t=0.01, node=9))

    def test_recovery_knobs_require_faults(self):
        with pytest.raises(ValueError, match="faults="):
            _small_serve(recovery="none")
        with pytest.raises(ValueError, match="faults="):
            _small_serve(monitor=HealthMonitor())


class TestFaultFreePurity:
    def test_unarmed_serve_is_byte_stable(self):
        # the regression the purity contract pins: with faults=None the
        # whole chaos subsystem must be invisible — every as_dict record
        # identical, byte for byte, to a build without repro.chaos
        a = _small_serve()
        b = _small_serve()
        assert json.dumps(a.as_dict(), indent=1) == json.dumps(
            b.as_dict(), indent=1)
        assert a.chaos is None
        gated = {"faults", "recovery", "faults_injected", "jobs_lost",
                 "jobs_retried", "jobs_recovered", "retries_exhausted",
                 "jobs_shed", "availability_by_tier"}
        assert not gated & set(a.as_dict())

    def test_armed_run_keeps_metric_key_prefix(self):
        plan = FaultPlan.single("crash", t=0.02, node=0)
        plain = list(_small_serve().as_dict())
        armed = list(_small_serve(faults=plan).as_dict())
        assert armed[: len(plain)] == plain


# ---------------------------------------------------------------------------
# registry error contracts (unknown names must list what IS registered)
# ---------------------------------------------------------------------------


class TestRegistryErrors:
    def test_unknown_policy_lists_registered(self):
        with pytest.raises(ValueError, match="equal"):
            TrafficSimulator([], policy="nope")

    def test_unknown_dispatcher_lists_registered(self):
        with pytest.raises(ValueError, match="jsq"):
            TrafficSimulator([], dispatch="nope")

    def test_unknown_rebalancer_lists_registered(self):
        with pytest.raises(ValueError, match="migrate_on_pressure"):
            TrafficSimulator([], rebalance_interval=0.1, rebalancer="nope")

    def test_unknown_arrivals_lists_registered(self):
        with pytest.raises(ValueError, match="poisson"):
            TrafficSimulator("nope", rate=1.0, horizon=1.0)


# ---------------------------------------------------------------------------
# sharded pod faults
# ---------------------------------------------------------------------------


def _sharded(**kwargs):
    from repro.traffic.sharded import ShardedTrafficSimulator

    kwargs.setdefault("rate", 3000.0)
    kwargs.setdefault("horizon", 0.05)
    kwargs.setdefault("seed", 7)
    kwargs.setdefault("sync_every", 16)
    kwargs.setdefault("pool", "light")
    kwargs.setdefault("slo_s", 0.05)
    return ShardedTrafficSimulator("poisson", n_arrays=4, n_shards=2,
                                   **kwargs)


class TestShardedPodFaults:
    def test_serial_pod_kill_raises_naming_the_pod(self):
        sim = _sharded(parallel=False,
                       faults=FaultEvent(t=0.0, kind="pod_kill", node=1,
                                         epoch=1))
        with pytest.raises(RuntimeError, match=r"pod 1.*epoch 1"):
            sim.run()

    def test_forked_pod_kill_raises_instead_of_hanging(self):
        sim = _sharded(parallel=True, pod_timeout_s=60.0,
                       faults=FaultEvent(t=0.0, kind="pod_kill", node=1,
                                         epoch=1))
        with pytest.raises(RuntimeError, match="pod 1"):
            sim.run()

    def test_non_pod_kill_kinds_rejected(self):
        with pytest.raises(ValueError, match="pod_kill"):
            _sharded(faults=FaultEvent(t=0.01, kind="crash", node=0))

    def test_pod_index_out_of_range_rejected(self):
        with pytest.raises(ValueError, match="shards"):
            _sharded(faults=FaultEvent(t=0.0, kind="pod_kill", node=5,
                                       epoch=0))

    def test_unkilled_run_matches_fault_free(self):
        # a pod_kill scheduled past the last epoch never fires; the run
        # must be byte-identical to one with no faults at all
        a = _sharded(parallel=False).run()
        b = _sharded(parallel=False,
                     faults=FaultEvent(t=0.0, kind="pod_kill", node=0,
                                       epoch=10**6)).run()
        assert json.dumps(a.as_dict()) == json.dumps(b.as_dict())


# ---------------------------------------------------------------------------
# observability markers
# ---------------------------------------------------------------------------


class TestChaosObservability:
    def test_fault_detect_recover_markers_in_timeline(self):
        plan = FaultPlan.single("crash", t=0.02, node=1)
        res = _small_serve(faults=plan, obs=True)
        kinds = {e.kind for e in res.timeline.tracer.events()}
        assert {"fault", "detect"} <= kinds
        if res.chaos.jobs_recovered:
            assert "recover" in kinds

    def test_markers_export_to_chrome_trace(self):
        plan = FaultPlan.single("blackout", t=0.02, node=0, duration_s=0.01)
        res = _small_serve(faults=plan, obs=True)
        data = res.timeline.chrome_trace()
        names = {ev.get("name") for ev in data["traceEvents"]}
        assert "fault" in names and "detect" in names

    def test_controller_marks_without_tracer(self):
        # tracer=None is the common case: the controller must not touch it
        plan = FaultPlan.single("crash", t=0.02, node=1)
        res = _small_serve(faults=plan)
        assert res.timeline is None
        assert res.chaos.faults_injected == 1


class TestChaosStreamOrdering:
    def test_retry_arrivals_never_go_backwards(self):
        # pop_retry clamps releases to the stream cursor, so the merged
        # stream stays time-ordered and submit never sees past arrivals
        plan = FaultPlan(
            (FaultEvent(t=0.01, kind="crash", node=0),
             FaultEvent(t=0.02, kind="crash", node=1),
             FaultEvent(t=0.03, kind="crash", node=2)))
        res = _small_serve(faults=plan)
        assert res.chaos.jobs_lost > 0
        # every record well-formed: completion after arrival
        for r in res.records:
            if r.completed is not None:
                assert r.completed >= r.arrival

    def test_faults_after_last_arrival_still_apply(self):
        plan = FaultPlan.single("crash", t=0.2, node=0)  # past horizon
        res = _small_serve(faults=plan, horizon=0.05)
        assert res.chaos.faults_injected == 1
        assert res.metrics.duration_s >= 0.2

    def test_controller_rejects_seeded_rng_reuse(self):
        # two controllers with the same seed produce the same jitter
        plan = FaultPlan.single("crash", t=0.02, node=0)
        a = _small_serve(faults=plan, seed=3)
        b = _small_serve(faults=plan, seed=3)
        assert a.chaos.transitions == b.chaos.transitions
