"""Memory-contention model tests (core/scheduler MemorySystem + moca).

Covers the tentpole contracts directly:

* interference-curve shape (monotone, superlinear past capacity);
* ``bw_shares`` bit-exactness at share 1.0 against BOTH cost oracles;
* per-tenant cap enforcement in :class:`MemorySystem`;
* the ``moca`` policy's tier-0 bandwidth guarantee;
* default-off purity: an unarmed scheduler is byte-identical to one that
  carries a never-pressured contention model.
"""

import json

import pytest

from repro.api.policy import AssignContext
from repro.core.dataflow import GEMM, ws_cost, ws_cost_batch
from repro.core.dnng import LayerShape, chain
from repro.core.partition import ArrayShape, Partition
from repro.core.scheduler import (
    ContentionModel,
    DynamicScheduler,
    MemorySystem,
    SharedBandwidth,
    StageModel,
)
from repro.sim.systolic import SystolicConfig, layer_cost_batch, layer_time_fn

FC = LayerShape.fc
ARRAY = ArrayShape(128, 128)
TIME_FN = layer_time_fn(SystolicConfig())


def _dnng(name, n_layers=2, size=256, arrival=0.0):
    return chain(name, [FC(f"l{i}", size, size, batch=size)
                        for i in range(n_layers)], arrival_time=arrival)


class TestInterferenceCurve:
    def test_no_stretch_at_or_below_capacity(self):
        m = ContentionModel()
        for p in (0.0, 0.3, 0.999, 1.0):
            assert m.stretch(p) == 1.0

    def test_monotone_nondecreasing(self):
        m = ContentionModel(alpha=1.5, beta=2.0)
        ps = [i / 10.0 for i in range(0, 60)]
        ss = [m.stretch(p) for p in ps]
        assert all(b >= a for a, b in zip(ss, ss[1:]))

    def test_superlinear_past_capacity(self):
        # beta > 1: equal pressure increments cost increasingly more
        m = ContentionModel(beta=2.0)
        d1 = m.stretch(2.0) - m.stretch(1.0)
        d2 = m.stretch(3.0) - m.stretch(2.0)
        assert d2 > d1 > 0.0

    def test_shared_ledger_stretch_and_peak(self):
        c = ContentionModel(window_s=1e-4, capacity=1.0)
        shared = SharedBandwidth(c)
        # first booking half-fills the window: no stretch
        assert shared.book(0.0, 0.5e-4) == 1.0
        # second booking overcommits it 1.5x: stretch = 1 + 0.5^2
        assert shared.book(0.0, 1.0e-4) == pytest.approx(1.25)
        assert shared.peak_pressure == pytest.approx(1.5)
        # a later window starts clean
        assert shared.book(5e-4, 0.5e-4) == 1.0


class TestBwSharesBitExactness:
    PAIRS = [
        (GEMM(T=256, K=256, N=256), Partition(rows=128, col_start=0,
                                              cols=128)),
        (GEMM(T=100, K=300, N=50), Partition(rows=128, col_start=64,
                                             cols=64)),
        (GEMM(T=1, K=1, N=1), Partition(rows=128, col_start=96, cols=32)),
        (GEMM(T=4096, K=4096, N=4096), Partition(rows=128, col_start=0,
                                                 cols=16)),
    ]

    def test_share_one_identical_to_omitted(self):
        import numpy as np
        gemms = [g for g, _ in self.PAIRS]
        parts = [p for _, p in self.PAIRS]
        plain = ws_cost_batch(gemms, parts)
        shared = ws_cost_batch(gemms, parts, bw_shares=[1.0] * len(gemms))
        for name in ("cycles", "macs", "dram_reads", "dram_writes",
                     "pe_cycles", "feed_pe_cycles", "load_pe_cycles"):
            assert (getattr(plain, name) == getattr(shared, name)).all()
        assert plain.dram_stall_elems is None
        assert (shared.dram_stall_elems == np.zeros(len(gemms))).all()

    def test_rows_match_scalar_oracle_under_shares(self):
        # the int64 columns equal the scalar ws_cost even when priced
        # with a throttled share — the stall column is additive, never
        # a perturbation of the base costs
        gemms = [g for g, _ in self.PAIRS]
        parts = [p for _, p in self.PAIRS]
        table = ws_cost_batch(gemms, parts, bw_shares=[0.25] * len(gemms))
        for i, (g, p) in enumerate(self.PAIRS):
            assert table.row(i) == ws_cost(g, p)

    def test_stall_column_formula(self):
        g, p = self.PAIRS[0]
        table = ws_cost_batch([g], [p], bw_shares=[0.5])
        raw = g.K * g.N + g.T * g.K + g.T * g.N
        assert table.dram_stall_elems[0] == pytest.approx(raw * 1.0)

    def test_layer_cost_batch_passthrough(self):
        import numpy as np
        layers = [FC("a", 256, 256, batch=256), FC("b", 64, 512, batch=32)]
        parts = [Partition(rows=128, col_start=0, cols=64),
                 Partition(rows=128, col_start=64, cols=64)]
        plain = layer_cost_batch(layers, parts)
        shared = layer_cost_batch(layers, parts, bw_shares=[1.0, 1.0])
        assert (plain.cycles == shared.cycles).all()
        assert (shared.dram_stall_elems == np.zeros(2)).all()
        half = layer_cost_batch(layers, parts, bw_shares=[0.5, 1.0])
        assert half.dram_stall_elems[0] > 0.0
        assert half.dram_stall_elems[1] == 0.0

    def test_share_validation(self):
        g, p = self.PAIRS[0]
        with pytest.raises(ValueError, match="one share per pair"):
            ws_cost_batch([g], [p], bw_shares=[0.5, 0.5])
        for bad in (0.0, -0.5, 1.5):
            with pytest.raises(ValueError, match=r"\(0, 1\]"):
                ws_cost_batch([g], [p], bw_shares=[bad])


class TestMemorySystemCaps:
    def test_cap_divides_transfer_rate(self):
        bus = MemorySystem()
        bus.set_caps({"batch": 0.5})
        start, end = bus.acquire(0.0, 1e-4, tenant="batch")
        assert start == 0.0 and end == pytest.approx(2e-4)
        assert bus.stall_s == pytest.approx(1e-4)

    def test_uncapped_tenant_unaffected(self):
        bus = MemorySystem()
        bus.set_caps({"batch": 0.5})
        start, end = bus.acquire(0.0, 1e-4, tenant="urgent")
        assert end == 1e-4 and bus.stall_s == 0.0

    def test_degenerate_caps_ignored(self):
        # share >= 1.0 (or <= 0) is "no cap": never stretch, never divide
        bus = MemorySystem()
        bus.set_caps({"a": 1.0, "b": 0.0})
        assert bus.acquire(0.0, 1e-4, tenant="a")[1] == 1e-4
        assert bus.acquire(2e-4, 1e-4, tenant="b")[1] == pytest.approx(3e-4)
        assert bus.stall_s == 0.0

    def test_set_caps_replaces_previous_round(self):
        bus = MemorySystem()
        bus.set_caps({"batch": 0.5})
        bus.set_caps(None)      # policy relaxed every cap
        assert bus.acquire(0.0, 1e-4, tenant="batch")[1] == 1e-4

    def test_cap_composes_with_contention(self):
        c = ContentionModel(window_s=1e-3, capacity=1.0)
        bus = MemorySystem(contention=c, shared=SharedBandwidth(c))
        bus.set_caps({"batch": 0.5})
        # raw demand books into the window; the cap stretches the
        # transfer's own duration on top of any contention stretch
        start, end = bus.acquire(0.0, 2e-4, tenant="batch")
        assert end == pytest.approx(4e-4)   # pressure 0.2 -> stretch 1
        assert bus.stall_s == pytest.approx(2e-4)

    def test_unarmed_memory_system_has_no_overhead_state(self):
        bus = MemorySystem()
        s0, e0 = bus.acquire(0.0, 1e-4)
        s1, e1 = bus.acquire(0.0, 1e-4)
        assert (s0, e0, s1, e1) == (0.0, 1e-4, 1e-4, 2e-4)
        assert bus.stall_s == 0.0 and bus.busy_s == pytest.approx(2e-4)


class TestSchedulerPurity:
    def test_unpressured_contention_is_byte_identical(self):
        # a contention model that never overcommits (huge capacity) must
        # reproduce the unarmed schedule exactly
        gs = [_dnng("a", 3), _dnng("b", 2, size=128, arrival=1e-6)]
        stage = StageModel()

        def run(contention):
            sched = DynamicScheduler(ARRAY, TIME_FN, stage=stage,
                                     policy="equal", contention=contention)
            for g in gs:
                sched.submit(g)
            sched.run()
            return sched.result()

        plain = run(None)
        armed = run(ContentionModel(capacity=1e9))
        assert plain.completion == armed.completion
        assert plain.makespan == armed.makespan
        assert armed.bus_stall_s == 0.0

    def test_contention_stretches_contended_schedule(self):
        gs = [_dnng(f"t{i}", 3, size=1024) for i in range(4)]
        stage = StageModel()

        def run(contention):
            sched = DynamicScheduler(ARRAY, TIME_FN, stage=stage,
                                     policy="equal", contention=contention)
            for g in gs:
                sched.submit(g)
            sched.run()
            return sched.result()

        plain = run(None)
        tight = run(ContentionModel(window_s=1e-5, capacity=0.25))
        assert tight.bus_stall_s > 0.0
        assert tight.makespan > plain.makespan

    def test_default_policy_sets_no_caps(self):
        sched = DynamicScheduler(ARRAY, TIME_FN, stage=StageModel(),
                                 policy="equal",
                                 contention=ContentionModel())
        sched.submit(_dnng("a"))
        sched.submit(_dnng("b", arrival=1e-6))
        sched.run()
        assert sched.bus.caps == {}


class TestMocaTierGuarantee:
    def _ctx(self, tiers):
        return AssignContext(array=ARRAY, tiers=tiers)

    def _policy(self, **kw):
        from repro.api.policy import MocaPolicy
        return MocaPolicy(**kw)

    def test_tier0_never_capped(self):
        pol = self._policy()
        caps = pol.bandwidth(self._ctx({"u": 0, "b1": 1, "b2": 2}))
        assert "u" not in caps
        assert set(caps) == {"b1", "b2"}

    def test_batch_split_of_leftover_bandwidth(self):
        pol = self._policy(tier0_guarantee=0.7, min_share=0.01)
        caps = pol.bandwidth(self._ctx({"u": 0, "b1": 1, "b2": 1}))
        assert caps == {"b1": pytest.approx(0.15),
                        "b2": pytest.approx(0.15)}

    def test_min_share_floor(self):
        pol = self._policy(tier0_guarantee=0.7, min_share=0.1)
        tiers = {"u": 0} | {f"b{i}": 1 for i in range(6)}
        caps = pol.bandwidth(self._ctx(tiers))
        assert all(v == pytest.approx(0.1) for v in caps.values())

    def test_no_caps_without_tier_mix(self):
        pol = self._policy()
        assert pol.bandwidth(self._ctx({})) is None
        assert pol.bandwidth(self._ctx({"a": 0, "b": 0})) is None
        assert pol.bandwidth(self._ctx({"a": 1, "b": 2})) is None

    def test_degenerate_share_means_no_caps(self):
        pol = self._policy(tier0_guarantee=0.0, min_share=1.0)
        assert pol.bandwidth(self._ctx({"u": 0, "b": 1})) is None

    def test_param_validation(self):
        from repro.api.policy import MocaPolicy
        with pytest.raises(ValueError, match="tier0_guarantee"):
            MocaPolicy(tier0_guarantee=1.0)
        with pytest.raises(ValueError, match="min_share"):
            MocaPolicy(min_share=0.0)

    def test_scheduler_enforces_moca_caps_live(self):
        # a live tier mix installs caps on the scheduler's MemorySystem;
        # when the mix dissolves (batch tenant finishes last) the caps
        # are relaxed again by the end-of-round hook
        sched = DynamicScheduler(ARRAY, TIME_FN, stage=StageModel(),
                                 policy="moca",
                                 contention=ContentionModel())
        sched.submit(_dnng("urgent", n_layers=1, size=64), tier=0)
        sched.submit(_dnng("batch", n_layers=6, size=1024,
                           arrival=1e-9), tier=1)
        saw_caps = []
        orig = type(sched.bus).acquire

        def spy(bus, now, dur, tenant=None):
            saw_caps.append(dict(bus.caps))
            return orig(bus, now, dur, tenant=tenant)

        sched.bus.acquire = spy.__get__(sched.bus)
        sched.run()
        assert any(c.get("batch") for c in saw_caps)
        assert all("urgent" not in c for c in saw_caps)
        assert sched.bus.caps == {}   # no live tenants left -> no caps

    def test_moca_protects_tier0_under_contention(self):
        # end-to-end guarantee: under an overcommitted bus the tier-0
        # tenant finishes no later with moca than with the tier-blind
        # equal policy on the identical workload
        gs = ([_dnng("urgent", n_layers=2, size=512)]
              + [_dnng(f"batch{i}", n_layers=4, size=1024, arrival=1e-9)
                 for i in range(3)])
        tiers = {"urgent": 0, "batch0": 1, "batch1": 1, "batch2": 1}
        contention = ContentionModel(window_s=1e-5, capacity=0.25)

        def run(policy):
            sched = DynamicScheduler(ARRAY, TIME_FN, stage=StageModel(),
                                     policy=policy, contention=contention)
            for g in gs:
                sched.submit(g, tier=tiers[g.name])
            sched.run()
            return sched.result().completion["urgent"]

        assert run("moca") <= run("equal")


class TestServeMemoryGate:
    def test_serve_memory_stats_and_purity(self):
        from repro.traffic.simulator import serve

        def run(**kw):
            return serve("poisson", policy="equal", rate=1500.0,
                         horizon=0.01, seed=3, pool="light", slo_s=0.01,
                         n_arrays=2, max_concurrent=2, **kw)

        plain = run()
        armed = run(memory=ContentionModel(window_s=1e-5, capacity=0.5))
        m = armed.metrics
        assert m.memory_stall_s is not None and m.memory_stall_s >= 0.0
        assert set(m.memory_stall_by_node) == {0, 1}
        assert m.memory_peak_pressure >= 0.0
        # unarmed record carries no memory keys and is run-to-run stable
        assert "memory_stall_s" not in plain.metrics.as_dict()
        assert (json.dumps(plain.as_dict(), indent=1)
                == json.dumps(run().as_dict(), indent=1))
