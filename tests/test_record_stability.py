"""Byte-stability of the bench record formats (guards "regenerates
byte-identically" directly, not only via check_regression's full regen).

The committed BENCH_traffic.json / BENCH_preempt.json records are diffed
byte-for-byte across PRs; that invariant rests on (a) ``as_dict`` key
*order* being stable under the incremental engine and (b) identical runs
serializing to identical JSON.  A reordered dict would survive a
metric-value gate but break every committed record's byte identity.
"""

import json

from repro.traffic import TrafficSimulator
from repro.traffic.arrivals import PoissonArrivals
from repro.traffic.metrics import TrafficMetrics, summarize

# the exact serialized field orders; editing either list is a
# record-format change and must regenerate every committed BENCH_*.json
METRICS_KEYS = [
    "jobs_arrived", "jobs_rejected", "jobs_completed",
    "deadline_miss_rate", "rejection_rate",
    "p50_latency_s", "p95_latency_s", "p99_latency_s", "mean_latency_s",
    "goodput_jobs_per_s", "queue_depth_mean", "queue_depth_max",
    "utilization", "duration_s",
]
SERVE_PREFIX_KEYS = ["policy", "backend", "arrivals", "dispatch",
                     "n_arrays"]


def _small_run(**kwargs):
    arr = PoissonArrivals(rate=2000.0, horizon=0.01, seed=3, pool="light",
                          slo_s=0.01)
    return TrafficSimulator(arr, policy="equal", backend="sim",
                            max_concurrent=2, queue_cap=4, seed=3,
                            **kwargs).run()


class TestAsDictKeyOrder:
    def test_traffic_metrics_key_order(self):
        m = summarize([], duration_s=1.0)
        assert list(m.as_dict()) == METRICS_KEYS

    def test_serve_result_key_order_plain(self):
        res = _small_run()
        assert list(res.as_dict()) == SERVE_PREFIX_KEYS + METRICS_KEYS

    def test_serve_result_key_order_with_adaptation(self):
        # feature counters append AFTER the stable prefix, so records from
        # runs predating the features regenerate byte-identically
        res = _small_run(preemption=True, n_arrays=2,
                         rebalance_interval=0.5)
        assert list(res.as_dict()) == (
            SERVE_PREFIX_KEYS + METRICS_KEYS
            + ["preemption", "preemptions", "rebalance", "migrations"])

    def test_metrics_counters_stay_out_of_as_dict(self):
        m = TrafficMetrics(
            jobs_arrived=1, jobs_rejected=0, jobs_completed=1,
            deadline_misses=0, p50_latency_s=0.0, p95_latency_s=0.0,
            p99_latency_s=0.0, mean_latency_s=0.0, goodput_jobs_per_s=1.0,
            queue_depth_mean=0.0, queue_depth_max=0, utilization=0.5,
            duration_s=1.0, preemptions=7, migrations=9)
        assert "preemptions" not in m.as_dict()
        assert "migrations" not in m.as_dict()


class TestByteStability:
    def test_identical_runs_serialize_byte_identically(self):
        blobs = [json.dumps(_small_run().as_dict(), indent=1)
                 for _ in range(2)]
        assert blobs[0].encode() == blobs[1].encode()

    def test_invariant_checks_do_not_change_results(self):
        # the debug net is pure observation: arming it per event must not
        # perturb a single serialized byte
        fast = _small_run()
        checked = _small_run(check_invariants=True)
        assert json.dumps(fast.as_dict()) == json.dumps(checked.as_dict())


class TestFleetLoadsEquivalence:
    def test_tracked_jsq_matches_linear_scan(self):
        # the lazily-rebuilt load heap must reproduce the linear argmin —
        # including the lowest-index tie-break — under arbitrary updates
        import random

        from repro.traffic.cluster import FleetLoads, JoinShortestQueue

        class _Node:
            def __init__(self, index):
                self.index = index
                self.load = 0
                self.queue = ()

            @property
            def in_system(self):
                return self.load

        rng = random.Random(7)
        nodes = [_Node(i) for i in range(16)]
        fleet = FleetLoads(nodes)
        jsq = JoinShortestQueue()
        for _ in range(3000):
            node = nodes[rng.randrange(16)]
            node.load = max(0, node.load + rng.choice((-1, 1, 1)))
            fleet.update(node)
            want = jsq.choose([n.in_system for n in nodes], rng)
            assert fleet.min_index() == want
            assert jsq.choose_tracked(fleet, rng) == want
