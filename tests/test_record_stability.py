"""Byte-stability of the bench record formats (guards "regenerates
byte-identically" directly, not only via check_regression's full regen).

The committed BENCH_traffic.json / BENCH_preempt.json records are diffed
byte-for-byte across PRs; that invariant rests on (a) ``as_dict`` key
*order* being stable under the incremental engine and (b) identical runs
serializing to identical JSON.  A reordered dict would survive a
metric-value gate but break every committed record's byte identity.
"""

import json
import os
import sys

from repro.traffic import TrafficSimulator
from repro.traffic.arrivals import PoissonArrivals
from repro.traffic.metrics import TrafficMetrics, summarize

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)  # make `benchmarks.*` importable under pytest

# the exact serialized field orders; editing either list is a
# record-format change and must regenerate every committed BENCH_*.json
METRICS_KEYS = [
    "jobs_arrived", "jobs_rejected", "jobs_completed",
    "deadline_miss_rate", "rejection_rate",
    "p50_latency_s", "p95_latency_s", "p99_latency_s", "mean_latency_s",
    "goodput_jobs_per_s", "queue_depth_mean", "queue_depth_max",
    "utilization", "duration_s",
]
SERVE_PREFIX_KEYS = ["policy", "backend", "arrivals", "dispatch",
                     "n_arrays"]
# gated fairness keys (appear ONLY when the run armed fairness accounting,
# AFTER the stable base keys; two independent gates — see TrafficMetrics)
FAIRNESS_SLOWDOWN_KEYS = ["jain_fairness", "per_tenant_slowdown"]
FAIRNESS_SHARE_KEYS = ["jain_dominant_share", "dominant_share_mean"]
# gated chaos keys (appear ONLY when the run armed fault injection, AFTER
# the fairness gates; the ServeResult-level faults/recovery pair follows
# the metric counters, and the obs digest stays last)
CHAOS_METRICS_KEYS = ["faults_injected", "jobs_lost", "jobs_retried",
                      "jobs_recovered", "retries_exhausted", "jobs_shed",
                      "availability_by_tier"]
CHAOS_RESULT_KEYS = ["faults", "recovery"]
# gated memory-contention keys (appear ONLY when the run armed memory=,
# AFTER the chaos gates; the ServeResult-level "memory" descriptor follows
# faults/recovery, and the obs digest stays last)
MEMORY_METRICS_KEYS = ["memory_stall_s", "memory_stall_by_node",
                       "memory_peak_pressure"]
MEMORY_RESULT_KEYS = ["memory"]
# gated overload keys (appear ONLY when the run armed admission=/brownout=,
# AFTER the memory gates; the ServeResult-level "overload" descriptor
# follows "memory", and the obs digest stays last)
OVERLOAD_METRICS_KEYS = ["rejections_by_cause", "shed_by_tier",
                         "brownout_transitions", "brownout_energy_j"]
OVERLOAD_RESULT_KEYS = ["overload"]
# fixed serialization order inside rejections_by_cause (a dict key-order
# change there is a record-format change too)
REJECTION_CAUSES = ["queue_full", "admission_shed", "recovery_shed"]


def _small_run(**kwargs):
    arr = PoissonArrivals(rate=2000.0, horizon=0.01, seed=3, pool="light",
                          slo_s=0.01)
    return TrafficSimulator(arr, policy="equal", backend="sim",
                            max_concurrent=2, queue_cap=4, seed=3,
                            **kwargs).run()


class TestAsDictKeyOrder:
    def test_traffic_metrics_key_order(self):
        m = summarize([], duration_s=1.0)
        assert list(m.as_dict()) == METRICS_KEYS

    def test_serve_result_key_order_plain(self):
        res = _small_run()
        assert list(res.as_dict()) == SERVE_PREFIX_KEYS + METRICS_KEYS

    def test_serve_result_key_order_with_adaptation(self):
        # feature counters append AFTER the stable prefix, so records from
        # runs predating the features regenerate byte-identically
        res = _small_run(preemption=True, n_arrays=2,
                         rebalance_interval=0.5)
        assert list(res.as_dict()) == (
            SERVE_PREFIX_KEYS + METRICS_KEYS
            + ["preemption", "preemptions", "rebalance", "migrations"])

    def test_fairness_keys_absent_when_disabled(self):
        res = _small_run()
        got = set(res.as_dict())
        assert not got & set(FAIRNESS_SLOWDOWN_KEYS + FAIRNESS_SHARE_KEYS)

    def test_fairness_keys_append_after_stable_base(self):
        res = _small_run(fairness=True)
        assert list(res.as_dict()) == (
            SERVE_PREFIX_KEYS + METRICS_KEYS
            + FAIRNESS_SLOWDOWN_KEYS + FAIRNESS_SHARE_KEYS)

    def test_fairness_does_not_perturb_base_metrics(self):
        # arming the accounting is pure observation: every pre-existing
        # key keeps the identical serialized value
        plain = _small_run().as_dict()
        fair = _small_run(fairness=True).as_dict()
        assert json.dumps({k: fair[k] for k in plain}) == json.dumps(plain)

    def test_sharded_sets_only_the_slowdown_gate(self):
        # the sharded engine merges records (slowdown gate) but cannot
        # sample a global in-flight share series (share gate stays shut)
        from repro.traffic import ShardedTrafficSimulator
        res = ShardedTrafficSimulator(
            "poisson", policy="equal", backend="sim", n_arrays=2,
            n_shards=2, dispatch="rr", max_concurrent=2, queue_cap=4,
            seed=3, parallel=False, fairness=True,
            rate=2000.0, horizon=0.01, pool="light", slo_s=0.01).run()
        assert list(res.as_dict()) == (
            SERVE_PREFIX_KEYS + METRICS_KEYS + FAIRNESS_SLOWDOWN_KEYS)

    def test_obs_key_appends_last(self):
        # the gated obs digest is the LAST key, after every other gated
        # block, so pre-obs records regenerate byte-identically
        res = _small_run(obs=True, fairness=True)
        keys = list(res.as_dict())
        assert keys[-1] == "obs"
        assert keys[:-1] == (SERVE_PREFIX_KEYS + METRICS_KEYS
                             + FAIRNESS_SLOWDOWN_KEYS + FAIRNESS_SHARE_KEYS)

    def test_obs_key_absent_when_disabled(self):
        assert "obs" not in _small_run().as_dict()

    def test_obs_does_not_perturb_base_metrics(self):
        # observation purity at the record layer: arming obs leaves every
        # pre-existing key's serialized value identical
        plain = _small_run(preemption=True, n_arrays=2,
                           rebalance_interval=0.5).as_dict()
        armed = _small_run(preemption=True, n_arrays=2,
                           rebalance_interval=0.5, obs=True).as_dict()
        assert json.dumps({k: armed[k] for k in plain}) == json.dumps(plain)

    def test_chaos_keys_absent_when_unarmed(self):
        res = _small_run()
        got = set(res.as_dict())
        assert not got & set(CHAOS_METRICS_KEYS + CHAOS_RESULT_KEYS)

    def test_chaos_keys_append_after_fairness_gates(self):
        from repro.chaos import FaultPlan
        res = _small_run(fairness=True, obs=True,
                         faults=FaultPlan.single("crash", t=0.005, node=0))
        assert list(res.as_dict()) == (
            SERVE_PREFIX_KEYS + METRICS_KEYS
            + FAIRNESS_SLOWDOWN_KEYS + FAIRNESS_SHARE_KEYS
            + CHAOS_METRICS_KEYS + CHAOS_RESULT_KEYS + ["obs"])

    def test_chaos_unarmed_run_byte_identical_to_pre_chaos(self):
        # `serve(faults=None)` must be invisible at the byte level: the
        # chaos subsystem exists in the process, but an unarmed run
        # serializes exactly as one from a build that predates it
        plain = _small_run(preemption=True, n_arrays=2,
                           rebalance_interval=0.5).as_dict()
        assert list(plain) == (
            SERVE_PREFIX_KEYS + METRICS_KEYS
            + ["preemption", "preemptions", "rebalance", "migrations"])
        again = _small_run(preemption=True, n_arrays=2,
                           rebalance_interval=0.5).as_dict()
        assert json.dumps(plain, indent=1) == json.dumps(again, indent=1)

    def test_memory_keys_absent_when_unarmed(self):
        res = _small_run()
        got = set(res.as_dict())
        assert not got & set(MEMORY_METRICS_KEYS + MEMORY_RESULT_KEYS)

    def test_memory_keys_append_after_chaos_gates(self):
        from repro.chaos import FaultPlan
        res = _small_run(fairness=True, obs=True, memory=True,
                         faults=FaultPlan.single("crash", t=0.005, node=0))
        assert list(res.as_dict()) == (
            SERVE_PREFIX_KEYS + METRICS_KEYS
            + FAIRNESS_SLOWDOWN_KEYS + FAIRNESS_SHARE_KEYS
            + CHAOS_METRICS_KEYS + MEMORY_METRICS_KEYS
            + CHAOS_RESULT_KEYS + MEMORY_RESULT_KEYS + ["obs"])

    def test_memory_alone_appends_after_stable_base(self):
        res = _small_run(memory=True)
        assert list(res.as_dict()) == (
            SERVE_PREFIX_KEYS + METRICS_KEYS
            + MEMORY_METRICS_KEYS + MEMORY_RESULT_KEYS)

    def test_overload_keys_absent_when_unarmed(self):
        res = _small_run()
        got = set(res.as_dict())
        assert not got & set(OVERLOAD_METRICS_KEYS + OVERLOAD_RESULT_KEYS)

    def test_overload_alone_appends_after_stable_base(self):
        res = _small_run(admission="static")
        d = res.as_dict()
        assert list(d) == (SERVE_PREFIX_KEYS + METRICS_KEYS
                           + OVERLOAD_METRICS_KEYS + OVERLOAD_RESULT_KEYS)
        assert list(d["rejections_by_cause"]) == REJECTION_CAUSES

    def test_overload_keys_append_after_memory_gates(self):
        from repro.chaos import FaultPlan
        res = _small_run(fairness=True, obs=True, memory=True,
                         faults=FaultPlan.single("crash", t=0.005, node=0),
                         admission="static", brownout=True)
        assert list(res.as_dict()) == (
            SERVE_PREFIX_KEYS + METRICS_KEYS
            + FAIRNESS_SLOWDOWN_KEYS + FAIRNESS_SHARE_KEYS
            + CHAOS_METRICS_KEYS + MEMORY_METRICS_KEYS
            + OVERLOAD_METRICS_KEYS
            + CHAOS_RESULT_KEYS + MEMORY_RESULT_KEYS
            + OVERLOAD_RESULT_KEYS + ["obs"])

    def test_static_admission_does_not_perturb_base_metrics(self):
        # "static" is the pre-overload behavior as a named arm: every
        # pre-existing key keeps the identical serialized value
        plain = _small_run().as_dict()
        armed = _small_run(admission="static").as_dict()
        assert json.dumps({k: armed[k] for k in plain}) == \
            json.dumps(plain)

    def test_metrics_counters_stay_out_of_as_dict(self):
        m = TrafficMetrics(
            jobs_arrived=1, jobs_rejected=0, jobs_completed=1,
            deadline_misses=0, p50_latency_s=0.0, p95_latency_s=0.0,
            p99_latency_s=0.0, mean_latency_s=0.0, goodput_jobs_per_s=1.0,
            queue_depth_mean=0.0, queue_depth_max=0, utilization=0.5,
            duration_s=1.0, preemptions=7, migrations=9)
        assert "preemptions" not in m.as_dict()
        assert "migrations" not in m.as_dict()


class TestByteStability:
    def test_identical_runs_serialize_byte_identically(self):
        blobs = [json.dumps(_small_run().as_dict(), indent=1)
                 for _ in range(2)]
        assert blobs[0].encode() == blobs[1].encode()

    def test_invariant_checks_do_not_change_results(self):
        # the debug net is pure observation: arming it per event must not
        # perturb a single serialized byte
        fast = _small_run()
        checked = _small_run(check_invariants=True)
        assert json.dumps(fast.as_dict()) == json.dumps(checked.as_dict())


class TestServeConfigByteIdentity:
    """The ServeConfig spelling is pure plumbing: the same knobs expressed
    as a config object serialize byte-identically to the flat kwargs."""

    def _arrivals(self):
        return PoissonArrivals(rate=2000.0, horizon=0.01, seed=3,
                               pool="light", slo_s=0.01)

    def test_plain_run_config_equals_kwargs(self):
        from repro.api import SchedulingConfig, ServeConfig
        kw = _small_run().as_dict()
        cfg = ServeConfig(scheduling=SchedulingConfig(
            max_concurrent=2, queue_cap=4, seed=3))
        via_cfg = TrafficSimulator(self._arrivals(), policy="equal",
                                   backend="sim", config=cfg).run()
        assert (json.dumps(via_cfg.as_dict(), indent=1)
                == json.dumps(kw, indent=1))

    def test_full_feature_run_config_equals_kwargs(self):
        from repro.api import (MemoryConfig, RebalanceConfig,
                               SchedulingConfig, ServeConfig)
        kw = _small_run(preemption=True, n_arrays=2, rebalance_interval=0.5,
                        fairness=True, memory=True).as_dict()
        cfg = ServeConfig(
            scheduling=SchedulingConfig(n_arrays=2, max_concurrent=2,
                                        queue_cap=4, seed=3,
                                        preemption=True),
            rebalance=RebalanceConfig(interval=0.5),
            fairness=True,
            memory=MemoryConfig(contention=True))
        via_cfg = TrafficSimulator(self._arrivals(), policy="equal",
                                   backend="sim", config=cfg).run()
        assert (json.dumps(via_cfg.as_dict(), indent=1)
                == json.dumps(kw, indent=1))

    def test_mixed_spellings_rejected(self):
        import pytest

        from repro.api import ServeConfig
        with pytest.raises(ValueError, match="not both"):
            TrafficSimulator(self._arrivals(), config=ServeConfig(),
                             n_arrays=2)

    def test_rebalancer_sentinel_default_name_raises_too(self):
        # the fixed wart: the default strategy's own name without an
        # interval errors exactly like any other name
        import pytest
        for name in ("migrate_on_pressure", "other"):
            with pytest.raises(ValueError, match="no effect without"):
                TrafficSimulator(self._arrivals(), rebalancer=name)


class TestBenchRecordsRegenerate:
    """The committed BENCH_*.json records regenerate byte-identically with
    obs disabled (the null path records nothing and perturbs nothing).
    check_regression covers this via a metric-value gate; these tests pin
    the stronger byte contract directly for the deterministic records."""

    def _committed(self, name):
        with open(os.path.join(ROOT, name), "rb") as f:
            return f.read()

    def test_fig9_bytes(self, tmp_path):
        from benchmarks.run import emit_bench_json

        path = tmp_path / "fig9.json"
        emit_bench_json(str(path))
        assert path.read_bytes() == self._committed("BENCH_fig9.json")

    def test_traffic_bytes(self, tmp_path, capsys):
        from benchmarks import traffic_bench

        path = tmp_path / "traffic.json"
        traffic_bench.run(path=str(path))
        capsys.readouterr()
        assert path.read_bytes() == self._committed("BENCH_traffic.json")

    def test_fairness_blocks_bytes(self, tmp_path, capsys):
        # the sharded_scale cell is wall-clock-bound (scale-bench CI
        # re-validates it); the seeded policy/trace/identity blocks must
        # match the committed record byte-for-byte
        from benchmarks import fairness_bench

        path = tmp_path / "fairness.json"
        fresh = fairness_bench.run(path=str(path), include_scale=False)
        capsys.readouterr()
        committed = json.loads(self._committed("BENCH_fairness.json"))
        for block in ("policy_results", "trace_results", "identity"):
            assert (json.dumps(fresh[block], indent=1)
                    == json.dumps(committed[block], indent=1))


class TestFleetLoadsEquivalence:
    def test_tracked_jsq_matches_linear_scan(self):
        # the lazily-rebuilt load heap must reproduce the linear argmin —
        # including the lowest-index tie-break — under arbitrary updates
        import random

        from repro.traffic.cluster import FleetLoads, JoinShortestQueue

        class _Node:
            def __init__(self, index):
                self.index = index
                self.load = 0
                self.queue = ()

            @property
            def in_system(self):
                return self.load

        rng = random.Random(7)
        nodes = [_Node(i) for i in range(16)]
        fleet = FleetLoads(nodes)
        jsq = JoinShortestQueue()
        for _ in range(3000):
            node = nodes[rng.randrange(16)]
            node.load = max(0, node.load + rng.choice((-1, 1, 1)))
            fleet.update(node)
            want = jsq.choose([n.in_system for n in nodes], rng)
            assert fleet.min_index() == want
            assert jsq.choose_tracked(fleet, rng) == want

    def test_heap_matches_linear_under_migration_churn(self):
        # same equivalence, but against REAL ArrayNodes mutated through
        # every load-changing surface: admission, queue promotion on
        # completion, take_for_migration (queued AND pristine/withdraw
        # paths) and admit_migrated — the hooks the rebalancer drives
        import random

        from repro.api.backend import resolve_backend
        from repro.api.policy import resolve_policy
        from repro.traffic.cluster import ArrayNode, FleetLoads

        backend = resolve_backend("sim")
        state = {}
        nodes = [
            ArrayNode(i, backend.array, backend.time_fn(),
                      backend.stage_model(), resolve_policy("equal"),
                      max_concurrent=2, queue_cap=3,
                      on_complete=lambda node, tenant, t: None,
                      on_load_change=lambda n: state["fleet"].update(n))
            for i in range(4)]
        fleet = state["fleet"] = FleetLoads(nodes)

        def check():
            assert fleet.loads == [n.in_system for n in nodes]
            assert fleet.queued_total == sum(len(n.queue) for n in nodes)
            assert fleet.min_index() == min(
                range(len(nodes)), key=lambda i: (nodes[i].in_system, i))

        jobs = list(PoissonArrivals(rate=4000.0, horizon=0.05, seed=11,
                                    pool="light", slo_s=0.05))
        rng = random.Random(5)
        for job in jobs:
            for n in nodes:   # advance to the arrival (fires completions)
                if n.scheduler._events \
                        and n.scheduler._events[0][0] <= job.arrival:
                    n.scheduler.run_until(job.arrival)
            check()
            nodes[rng.randrange(4)].offer(job)
            check()
            if rng.random() < 0.4:
                src = nodes[rng.randrange(4)]
                if src.queue:                # a queued job…
                    name = src.queue[-1].dnng.name
                elif src.jobs:               # …or a maybe-pristine one
                    name = next(iter(src.jobs))   # (withdraw path)
                else:
                    continue
                taken = src.take_for_migration(name)
                check()
                if taken is None:
                    continue
                dst = next((n for n in nodes
                            if n.scheduler.n_active < n.max_concurrent
                            or len(n.queue) < n.queue_cap), None)
                if dst is None:
                    continue
                dst.admit_migrated(taken, job.arrival,
                                   job.arrival + 1e-4)
                check()
        for n in nodes:
            n.scheduler.run()
        check()
