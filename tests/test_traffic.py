"""Open-loop traffic subsystem tests (repro/traffic/*)."""

import dataclasses
import json
import math

import pytest

from repro.core.dnng import LayerShape, chain
from repro.core.partition import ArrayShape
from repro.core.scheduler import DynamicScheduler, schedule_dynamic
from repro.sim.systolic import SystolicConfig, layer_time_fn
from repro.sim.workloads import MODEL_POOLS, sample_dnng
from repro.traffic import (
    DiurnalArrivals,
    Job,
    JobRecord,
    MMPPArrivals,
    PoissonArrivals,
    TraceArrivals,
    TrafficSimulator,
    get_arrival_process,
    list_arrival_processes,
    list_dispatchers,
    percentile,
    resolve_dispatcher,
    summarize,
)

FC = LayerShape.fc
ARRAY = ArrayShape(128, 128)
TIME_FN = layer_time_fn(SystolicConfig())


def _dnng(name, n_layers, size=256, arrival=0.0):
    return chain(name, [FC(f"l{i}", size, size, batch=size)
                        for i in range(n_layers)], arrival_time=arrival)


def _job(jid, arrival, n_layers=2, size=256, slo=1.0):
    g = _dnng(f"J#{jid}", n_layers, size=size, arrival=arrival)
    return Job(job_id=jid, arrival=arrival, dnng=g, deadline=arrival + slo)


# ---------------------------------------------------------------------------
# arrivals
# ---------------------------------------------------------------------------

class TestArrivals:
    @pytest.mark.parametrize("proc", ["poisson", "mmpp", "diurnal"])
    def test_deterministic_replay(self, proc):
        arr = get_arrival_process(proc, rate=500.0, horizon=0.05, seed=7,
                                  pool="light")
        a = [(j.arrival, j.dnng.name, j.tier, j.deadline) for j in arr]
        b = [(j.arrival, j.dnng.name, j.tier, j.deadline) for j in arr]
        assert a and a == b

    @pytest.mark.parametrize("proc", ["poisson", "mmpp", "diurnal"])
    def test_seed_changes_stream(self, proc):
        def mk(s):
            return [j.arrival for j in get_arrival_process(
                proc, rate=500.0, horizon=0.05, seed=s, pool="light")]
        assert mk(0) != mk(1)

    def test_times_ordered_within_horizon(self):
        for proc in list_arrival_processes():
            if proc in ("trace", "batch_instance"):
                continue   # source-fed replays; covered in their own tests
            jobs = get_arrival_process(proc, rate=800.0, horizon=0.03,
                                       seed=3, pool="all").jobs()
            ts = [j.arrival for j in jobs]
            assert ts == sorted(ts)
            assert all(0.0 <= t < 0.03 for t in ts)
            # unique tenant names even when the same model repeats
            names = [j.dnng.name for j in jobs]
            assert len(set(names)) == len(names)

    def test_poisson_rate_roughly_holds(self):
        jobs = PoissonArrivals(rate=2000.0, horizon=0.5, seed=0).jobs()
        assert 2000.0 * 0.5 * 0.8 < len(jobs) < 2000.0 * 0.5 * 1.2

    def test_mmpp_is_burstier_than_poisson(self):
        """Index of dispersion of counts > 1 for MMPP (Poisson has ≈ 1)."""
        def idc(jobs, horizon, bins=50):
            counts = [0] * bins
            for j in jobs:
                counts[min(int(j.arrival / horizon * bins), bins - 1)] += 1
            mean = sum(counts) / bins
            var = sum((c - mean) ** 2 for c in counts) / bins
            return var / mean
        h = 1.0
        poisson = PoissonArrivals(rate=500.0, horizon=h, seed=1).jobs()
        mmpp = MMPPArrivals(rate=500.0, horizon=h, seed=1,
                            burst_factor=8.0, dwell_s=0.05).jobs()
        assert idc(mmpp, h) > idc(poisson, h)

    def test_diurnal_amplitude_validation(self):
        with pytest.raises(ValueError):
            DiurnalArrivals(rate=10.0, horizon=1.0, amplitude=1.5)

    def test_trace_replay(self, tmp_path):
        rows = [{"t": 0.002, "model": "NCF", "slo_s": 0.1, "tier": 1},
                {"t": 0.001, "model": "AlexNet"}]
        p = tmp_path / "trace.json"
        p.write_text(json.dumps(rows))
        jobs = TraceArrivals(str(p), slo_s=0.05).jobs()
        # sorted by t, defaults filled in
        assert [j.model for j in jobs] == ["AlexNet", "NCF"]
        assert jobs[0].deadline == pytest.approx(0.001 + 0.05)
        assert jobs[1].tier == 1 and jobs[1].slo_s == pytest.approx(0.1)

    def test_trace_unknown_model_rejected(self):
        with pytest.raises(ValueError, match="unknown model"):
            TraceArrivals([{"t": 0.0, "model": "NotANet"}])

    def test_sample_dnng_pools(self):
        import random
        rng = random.Random(0)
        for _ in range(16):
            g = sample_dnng(rng, pool="light", name="x#1", arrival_time=2.0)
            assert g.arrival_time == 2.0 and g.name == "x#1"
        with pytest.raises(ValueError):
            sample_dnng(rng, pool="bogus")
        assert set(MODEL_POOLS["all"]) >= set(MODEL_POOLS["heavy"])


# ---------------------------------------------------------------------------
# incremental scheduler
# ---------------------------------------------------------------------------

class TestDynamicSchedulerIncremental:
    def test_matches_batch_schedule(self):
        """Submitting everything then draining must equal schedule_dynamic."""
        gs = [_dnng(f"t{i}", 2 + i, arrival=i * 1e-6) for i in range(4)]
        batch = schedule_dynamic(gs, ARRAY, TIME_FN)
        sched = DynamicScheduler(ARRAY, TIME_FN)
        for g in gs:
            sched.submit(g)
        sched.run()
        inc = sched.result()
        assert inc.completion == batch.completion
        assert inc.trace == batch.trace
        assert inc.makespan == batch.makespan

    def test_submit_in_past_rejected(self):
        sched = DynamicScheduler(ARRAY, TIME_FN)
        sched.submit(_dnng("a", 1))
        sched.run()
        with pytest.raises(ValueError, match="past"):
            sched.submit(_dnng("b", 1, arrival=sched.now / 2))

    def test_duplicate_name_rejected_even_after_completion(self):
        sched = DynamicScheduler(ARRAY, TIME_FN)
        sched.submit(_dnng("a", 1))
        sched.run()
        with pytest.raises(ValueError, match="duplicate"):
            sched.submit(dataclasses.replace(_dnng("a", 1),
                                             arrival_time=sched.now))

    def test_on_complete_fires_once_per_dnng(self):
        done = []
        sched = DynamicScheduler(ARRAY, TIME_FN,
                                 on_complete=lambda n, t: done.append((n, t)))
        for i in range(3):
            sched.submit(_dnng(f"t{i}", 2))
        sched.run()
        assert sorted(n for n, _ in done) == ["t0", "t1", "t2"]
        assert all(t == sched.completion[n] for n, t in done)

    def test_keep_trace_false_still_counts_busy_pes(self):
        gs = [_dnng("a", 3), _dnng("b", 2, arrival=1e-9)]
        ref = DynamicScheduler(ARRAY, TIME_FN)
        lean = DynamicScheduler(ARRAY, TIME_FN, keep_trace=False)
        for g in gs:
            ref.submit(g)
            lean.submit(dataclasses.replace(g))
        ref.run()
        lean.run()
        assert lean.trace == []
        assert lean.pe_seconds_busy == pytest.approx(
            ref.result().pe_seconds_busy)

    def test_rebalance_on_arrival_narrows_then_widens(self):
        """§3.3 under open arrivals: a lone tenant's layers run full-width;
        once a competitor arrives mid-stream the next layers narrow; after
        the competitor drains, merge-on-free widens them back."""
        sched = DynamicScheduler(ARRAY, TIME_FN)
        a = _dnng("a", 6, size=256)
        sched.submit(a)
        # run until a's first layer completed, then inject a competitor
        sched.run_until(sched.next_event_time())
        t_mid = sched.now
        b = _dnng("b", 2, size=256, arrival=t_mid)
        sched.submit(b)
        sched.run()
        widths = {e.layer_index: e.partition.cols
                  for e in sched.result().trace if e.tenant == "a"}
        assert widths[0] == ARRAY.cols          # alone: full array
        assert min(widths.values()) < ARRAY.cols  # shared: narrowed
        assert widths[5] == ARRAY.cols          # competitor gone: widened

    def test_empty_scheduler_result(self):
        sched = DynamicScheduler(ARRAY, TIME_FN)
        sched.run()
        res = sched.result()
        assert res.makespan == 0.0 and res.trace == ()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_percentile_interpolates(self):
        xs = [1.0, 2.0, 3.0, 4.0]
        assert percentile(xs, 0) == 1.0
        assert percentile(xs, 100) == 4.0
        assert percentile(xs, 50) == pytest.approx(2.5)
        assert math.isnan(percentile([], 99))

    def test_summarize_accounting(self):
        recs = [
            JobRecord(0, "m", 0, arrival=0.0, deadline=1.0,
                      submitted=0.0, completed=0.5),   # met
            JobRecord(1, "m", 0, arrival=0.0, deadline=1.0,
                      submitted=0.0, completed=2.0),   # late
            JobRecord(2, "m", 0, arrival=0.0, deadline=1.0),  # rejected
        ]
        m = summarize(recs, duration_s=2.0, pe_seconds_busy=8.0,
                      total_pes=8, queue_depth_samples=[0, 2, 4])
        assert m.jobs_arrived == 3 and m.jobs_rejected == 1
        assert m.jobs_completed == 2
        assert m.deadline_misses == 2          # late + rejected
        assert m.deadline_miss_rate == pytest.approx(2 / 3)
        assert m.goodput_jobs_per_s == pytest.approx(0.5)  # 1 met / 2 s
        assert m.utilization == pytest.approx(0.5)
        assert m.queue_depth_mean == pytest.approx(2.0)
        assert m.queue_depth_max == 4


# ---------------------------------------------------------------------------
# simulator: admission control + SLA behaviour
# ---------------------------------------------------------------------------

class TestSimulator:
    def test_all_jobs_complete_under_light_load(self):
        arr = PoissonArrivals(rate=200.0, horizon=0.05, seed=0, pool="light",
                              slo_s=1.0)
        res = TrafficSimulator(arr, policy="equal").run()
        m = res.metrics
        assert m.jobs_rejected == 0
        assert m.jobs_completed == m.jobs_arrived > 0
        assert m.deadline_miss_rate == 0.0
        assert 0.0 < m.utilization <= 1.0

    def test_overload_rejects_and_bounds_queue(self):
        """Open-loop overload with a tiny queue: rejections must appear and
        the queue depth must never exceed its cap."""
        jobs = [_job(i, arrival=i * 1e-6, n_layers=4, size=1024)
                for i in range(20)]
        sim = TrafficSimulator(jobs, policy="equal", max_concurrent=2,
                               queue_cap=3)
        res = sim.run()
        m = res.metrics
        assert m.jobs_rejected > 0
        assert m.queue_depth_max <= 3
        assert m.jobs_completed == m.jobs_arrived - m.jobs_rejected
        # every non-rejected job has a submission and completion instant
        for r in res.records:
            if not r.rejected:
                assert r.submitted is not None and r.completed is not None
                assert r.arrival <= r.submitted <= r.completed

    def test_rejected_jobs_count_as_misses(self):
        jobs = [_job(i, arrival=0.0 if i == 0 else 1e-9, n_layers=2)
                for i in range(6)]
        res = TrafficSimulator(jobs, max_concurrent=1, queue_cap=0).run()
        m = res.metrics
        assert m.jobs_rejected == m.deadline_misses > 0

    def test_queued_job_latency_includes_wait(self):
        jobs = [_job(0, arrival=0.0, n_layers=3), _job(1, arrival=1e-9)]
        res = TrafficSimulator(jobs, max_concurrent=1, queue_cap=4).run()
        rec = {r.job_id: r for r in res.records}
        assert rec[1].submitted == pytest.approx(rec[0].completed)
        assert rec[1].latency > rec[0].latency

    def test_policies_run_unchanged(self):
        """Every registered policy plugs into the open-loop substrate."""
        from repro.api import list_policies
        arr = PoissonArrivals(rate=300.0, horizon=0.02, seed=5, pool="light")
        for pol in list_policies():
            res = TrafficSimulator(arr, policy=pol).run()
            assert res.metrics.jobs_completed == res.metrics.jobs_arrived
            assert res.policy == pol

    def test_deterministic_end_to_end(self):
        arr = MMPPArrivals(rate=400.0, horizon=0.04, seed=9, pool="light")
        r1 = TrafficSimulator(arr, policy="proportional", seed=1).run()
        r2 = TrafficSimulator(arr, policy="proportional", seed=1).run()
        assert r1.as_dict() == r2.as_dict()
        assert r1.records == r2.records

    def test_per_splits(self):
        arr = PoissonArrivals(rate=300.0, horizon=0.03, seed=2, pool="light",
                              tiers=(0, 1))
        res = TrafficSimulator(arr).run()
        by_tier = res.per("tier")
        assert set(by_tier) <= {0, 1}
        assert sum(m.jobs_arrived for m in by_tier.values()) \
            == res.metrics.jobs_arrived
        by_model = res.per("model")
        assert set(by_model) <= set(MODEL_POOLS["light"])

    def test_session_serve_front_door(self):
        from repro.api import Session
        res = Session(policy="equal", backend="sim").serve(
            "poisson", rate=300.0, horizon=0.02, seed=0, pool="light")
        assert res.metrics.jobs_completed == res.metrics.jobs_arrived > 0
        assert res.policy == "equal" and res.backend == "sim"
        assert res.arrivals == "poisson"


# ---------------------------------------------------------------------------
# cluster dispatch
# ---------------------------------------------------------------------------

class TestClusterDispatch:
    def _loads(self, res):
        counts = {}
        for r in res.records:
            if r.array is not None:
                counts[r.array] = counts.get(r.array, 0) + 1
        return counts

    def test_jsq_balances_across_arrays(self):
        arr = PoissonArrivals(rate=2000.0, horizon=0.05, seed=0,
                              pool="light")
        res = TrafficSimulator(arr, n_arrays=4, dispatch="jsq").run()
        counts = self._loads(res)
        assert set(counts) == {0, 1, 2, 3}
        # no array starves: JSQ keeps the split within a loose band
        assert min(counts.values()) > 0.25 * max(counts.values())

    def test_p2c_uses_multiple_arrays_and_is_seeded(self):
        arr = PoissonArrivals(rate=2000.0, horizon=0.05, seed=0,
                              pool="light")
        r1 = TrafficSimulator(arr, n_arrays=4, dispatch="p2c", seed=3).run()
        r2 = TrafficSimulator(arr, n_arrays=4, dispatch="p2c", seed=3).run()
        assert r1.records == r2.records
        assert len(self._loads(r1)) > 1

    def test_more_arrays_cut_latency_under_load(self):
        arr = MMPPArrivals(rate=1500.0, horizon=0.05, seed=4, pool="light",
                           slo_s=0.05)
        one = TrafficSimulator(arr, n_arrays=1, queue_cap=64,
                               max_concurrent=4).run()
        four = TrafficSimulator(arr, n_arrays=4, queue_cap=64,
                                max_concurrent=4).run()
        assert four.metrics.p99_latency_s < one.metrics.p99_latency_s
        assert four.metrics.deadline_miss_rate \
            <= one.metrics.deadline_miss_rate

    def test_dispatcher_registry(self):
        assert {"jsq", "p2c"} <= set(list_dispatchers())
        with pytest.raises(ValueError):
            resolve_dispatcher("bogus")
