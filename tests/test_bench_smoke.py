"""Smoke coverage for the pre-PR-1 benchmark utilities + the scale bench.

``benchmarks/roofline.py`` and ``benchmarks/perf_iter.py`` predate the
PR 1–4 refactors and had no tier-1 coverage — a rename in the modules they
import would only surface in a ~30-min dry-run session.  These tests keep
them importable and exercise their pure logic on synthetic inputs (no
XLA compiles).  The scale bench gets a tiny-cell determinism run.
"""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)  # make `benchmarks.*` importable under pytest


class TestRooflineSmoke:
    def test_imports(self):
        from benchmarks import roofline

        assert callable(roofline.analyse)
        assert roofline.PEAK_FLOPS > 0

    def test_analyse_skips_failed_records(self, tmp_path):
        from benchmarks import roofline

        dry = {"cellA": {"ok": False, "error": "OOM"},
               "cellB": {"ok": False}}
        path = tmp_path / "dryrun.json"
        path.write_text(json.dumps(dry))
        assert roofline.analyse(str(path)) == {}

    def test_to_markdown_renders_rows(self):
        from benchmarks import roofline

        rows = {"k": {
            "arch": "a", "cell": "train_4k", "mesh": "16x16", "chips": 256,
            "kind": "train", "t_compute_s": 1e-3, "t_memory_s": 2e-3,
            "t_collective_s": 3e-3, "dominant": "collective",
            "model_flops": 1e15, "useful_ratio": 0.5,
            "roofline_fraction": 0.25, "advice": "x"}}
        md = roofline.to_markdown(rows, "16x16")
        assert "train_4k" in md and "**collective**" in md
        assert roofline.to_markdown(rows, "2x16x16").count("|") > 0

    def test_advice_covers_every_wall(self):
        from benchmarks import roofline

        coll = roofline._advice("collective", "train",
                                {"collectives": {"all-reduce": (3, 100)}})
        assert "all-reduce" in coll
        assert "decode" in roofline._advice("memory", "decode", {})
        assert "HBM" in roofline._advice("memory", "train", {})
        assert "compute-bound" in roofline._advice("compute", "train", {})


class TestPerfIterSmoke:
    def test_imports_and_has_main(self):
        from benchmarks import perf_iter

        assert callable(perf_iter.main)

    def test_help_exits_cleanly(self):
        # --help parses after the jax/launch imports resolve, so this
        # catches renamed imports without paying a compile
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.perf_iter", "--help"],
            capture_output=True, text=True, timeout=300,
            cwd=ROOT, env={**os.environ,
                           "PYTHONPATH": os.path.join(ROOT, "src")})
        assert proc.returncode == 0, proc.stderr
        assert "--mesh-shape" in proc.stdout


class TestScaleBenchSmoke:
    def test_tiny_cell_deterministic(self, tmp_path):
        from benchmarks import scale_bench

        blobs = []
        for name in ("a.json", "b.json"):
            blob = scale_bench.run(path=str(tmp_path / name),
                                   cells=((25, 2),), check_budget=False,
                                   time_traffic=False)
            blobs.append(blob)
        r = blobs[0]["results"][0]
        assert r["n_arrays"] == 2 and r["events"] > 0
        assert r["oracle_calls"] > 0 and r["jobs_completed"] > 0
        assert 0.0 <= r["deadline_miss_rate"] <= 1.0
        assert r["events_per_s"] > 0
        gated = ("jobs_arrived", "jobs_completed", "events", "oracle_calls",
                 "oracle_calls_per_event", "deadline_miss_rate",
                 "rejection_rate")
        for key in gated:  # deterministic fields identical across runs
            assert blobs[0]["results"][0][key] == blobs[1]["results"][0][key]

    def test_budget_violation_fails(self, tmp_path, monkeypatch):
        from benchmarks import scale_bench

        monkeypatch.setattr(scale_bench, "TIME_BUDGET_S", 0.0)
        with pytest.raises(SystemExit):
            scale_bench.run(path=str(tmp_path / "s.json"),
                            cells=((25, 2),), check_budget=True,
                            time_traffic=False)


class TestProfileFlag:
    def test_profile_traffic_returns_stats(self, capsys):
        from benchmarks.run import profile_traffic

        stats = profile_traffic(top=5)
        out = capsys.readouterr().out
        assert "hot spots" in out
        assert stats.total_calls > 0
