"""Algorithm 1 unit + property tests (core/partition.py)."""

import pytest
pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.dnng import LayerShape
from repro.core.partition import (
    ArrayShape,
    Partition,
    PartitionSet,
    partition_calculation,
    task_assignment,
)


class TestPartitionCalculation:
    def test_paper_example(self):
        # §3.2: 128×128 with 4 partitions -> 128×32 each
        parts = partition_calculation(ArrayShape(128, 128), 4)
        assert len(parts) == 4
        assert all(p.rows == 128 for p in parts)
        assert all(p.cols == 32 for p in parts)

    def test_single(self):
        (p,) = partition_calculation(ArrayShape(128, 128), 1)
        assert (p.rows, p.cols, p.col_start) == (128, 128, 0)

    def test_remainder_goes_to_first(self):
        parts = partition_calculation(ArrayShape(128, 128), 3)
        assert [p.cols for p in parts] == [44, 42, 42]
        assert sum(p.cols for p in parts) == 128

    def test_more_tasks_than_columns(self):
        parts = partition_calculation(ArrayShape(8, 4), 100)
        assert len(parts) == 4  # clamped; no zero-width slices
        assert all(p.cols == 1 for p in parts)

    @given(cols=st.integers(1, 512), n=st.integers(1, 64))
    @settings(max_examples=200, deadline=None)
    def test_tiles_exactly(self, cols, n):
        parts = partition_calculation(ArrayShape(16, cols), n)
        assert sum(p.cols for p in parts) == cols
        cursor = 0
        for p in sorted(parts, key=lambda p: p.col_start):
            assert p.col_start == cursor
            cursor += p.cols


class TestTaskAssignment:
    def test_heaviest_to_largest(self):
        heavy = LayerShape.fc("h", 4096, 4096)
        light = LayerShape.fc("l", 16, 16)
        parts = [Partition(128, 0, 16), Partition(128, 16, 112)]
        out = task_assignment(
            [("a", 0, light), ("b", 0, heavy)], parts)
        by_tenant = {a.tenant: a.partition for a in out}
        assert by_tenant["b"].cols == 112
        assert by_tenant["a"].cols == 16

    def test_extra_layers_left_unmatched(self):
        fc = LayerShape.fc("l", 8, 8)
        out = task_assignment([("a", 0, fc), ("b", 0, fc)],
                              [Partition(4, 0, 4)])
        assert len(out) == 1


class TestPartitionSet:
    def test_allocate_free_merge(self):
        ps = PartitionSet(ArrayShape(128, 128))
        ps.allocate("a", 32)
        ps.allocate("b", 32)
        ps.allocate("c", 64)
        assert ps.utilization == 1.0
        ps.free("b")
        ps.check()
        ps.free("a")
        ps.check()
        # a+b must have merged into one 64-wide free slice
        assert any(p.cols == 64 for p in ps.free_partitions)
        ps.free("c")
        assert len(ps.free_partitions) == 1
        assert ps.free_partitions[0].cols == 128

    def test_double_allocate_rejected(self):
        ps = PartitionSet(ArrayShape(8, 8))
        ps.allocate("a", 4)
        with pytest.raises(ValueError):
            ps.allocate("a", 2)

    def test_free_unknown_rejected(self):
        ps = PartitionSet(ArrayShape(8, 8))
        with pytest.raises(KeyError):
            ps.free("ghost")

    @given(st.lists(
        st.tuples(st.sampled_from(["alloc", "free"]), st.integers(0, 9),
                  st.integers(1, 32)),
        min_size=1, max_size=60))
    @settings(max_examples=200, deadline=None)
    def test_invariants_under_random_ops(self, ops):
        """free+busy always tile [0, cols); free slices always maximal."""
        ps = PartitionSet(ArrayShape(16, 64))
        live = set()
        for kind, tid, cols in ops:
            name = f"t{tid}"
            if kind == "alloc" and name not in live:
                try:
                    ps.allocate(name, cols)
                    live.add(name)
                except ValueError:
                    pass  # no slice wide enough — legal outcome
            elif kind == "free" and name in live:
                ps.free(name)
                live.remove(name)
            ps.check()  # the invariant
