"""Shared hypothesis fallback: property tests skip, deterministic tests run.

Test modules do ``from _hypothesis_compat import given, settings, st``
(pytest puts each test file's directory on ``sys.path``).  With hypothesis
installed these are the real objects; without it (the no-extras CI leg)
``@given`` marks the test skipped before any placeholder strategy is drawn,
so the rest of the module's deterministic coverage still executes — unlike
a module-level ``pytest.importorskip`` which skips everything.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on the no-extras CI leg
    def given(*args, **kwargs):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*args, **kwargs):
        return lambda f: f

    class _NullStrategies:
        """Placeholder ``st``: @given skips before any strategy is drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NullStrategies()

__all__ = ["given", "settings", "st"]
