"""Per-arch smoke tests + decode-vs-forward consistency (all families)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, list_archs
from repro.models.model import (
    _encode,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)


def _inputs(cfg, key, B, S):
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                              cfg.vocab)
    kw = {}
    if cfg.frontend == "audio":
        kw["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.enc_seq, cfg.d_model)) * 0.02
    elif cfg.frontend == "vision":
        kw["patches"] = jax.random.normal(
            jax.random.fold_in(key, 3),
            (B, cfg.n_patches, cfg.d_model)) * 0.02
    return toks, kw


def _fill_cross_cache(cfg, params, cache, frames):
    B = frames.shape[0]
    mem = _encode(cfg, params, frames)

    def fill(bp, mem):
        kk = (mem @ bp["cross"]["wk"]).reshape(
            B, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim)
        vv = (mem @ bp["cross"]["wv"]).reshape(
            B, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qkv_bias:
            kk = kk + bp["cross"]["bk"].astype(kk.dtype).reshape(
                cfg.n_kv_heads, cfg.head_dim)
            vv = vv + bp["cross"]["bv"].astype(vv.dtype).reshape(
                cfg.n_kv_heads, cfg.head_dim)
        return kk, vv

    ks, vs = jax.vmap(fill, in_axes=(0, None))(params["dec_blocks"], mem)
    cache["cross_k"] = ks.astype(cache["cross_k"].dtype)
    cache["cross_v"] = vs.astype(cache["cross_v"].dtype)
    return cache


@pytest.mark.parametrize("arch", list_archs())
class TestArchSmoke:
    """One reduced-config forward + train-step + decode per assigned arch."""

    def test_forward_shapes_no_nans(self, arch):
        cfg = get(arch).smoke
        key = jax.random.key(0)
        params = init_params(cfg, key)
        B, S = 2, 16
        toks, kw = _inputs(cfg, key, B, S)
        logits = forward(cfg, params, toks, **kw)
        assert logits.shape == (B, S, cfg.vocab)
        assert not jnp.isnan(logits.astype(jnp.float32)).any()

    def test_train_step_loss_finite_grads_flow(self, arch):
        cfg = get(arch).smoke
        key = jax.random.key(1)
        params = init_params(cfg, key)
        B, S = 2, 16
        toks, kw = _inputs(cfg, key, B, S)
        batch = {"tokens": toks, "labels": toks, **kw}
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch))(params)
        assert jnp.isfinite(loss)
        gnorm = sum(float(jnp.abs(g.astype(jnp.float32)).sum())
                    for g in jax.tree.leaves(grads))
        assert np.isfinite(gnorm) and gnorm > 0

    def test_decode_step_shapes(self, arch):
        cfg = get(arch).smoke
        key = jax.random.key(2)
        params = init_params(cfg, key)
        B = 2
        cache = init_cache(cfg, B, 32)
        tok = jnp.zeros((B, 1), jnp.int32)
        logits, new_cache = decode_step(cfg, params, cache, tok,
                                        jnp.zeros((B,), jnp.int32))
        assert logits.shape == (B, 1, cfg.vocab)
        assert not jnp.isnan(logits.astype(jnp.float32)).any()
        assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "nemotron-4-15b",
                                  "mistral-nemo-12b", "deepseek-coder-33b",
                                  "internvl2-26b", "mamba2-780m",
                                  "recurrentgemma-2b", "whisper-small",
                                  "dbrx-132b", "phi3.5-moe-42b-a6.6b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full-sequence forward logits.

    The strongest cache-correctness property: catches ring-buffer indexing,
    SSM state updates, RoPE position handling, cross-attention freezing.
    MoE uses a generous capacity factor so no tokens are dropped (capacity
    dropping is the one *semantic* forward/decode difference).
    """
    cfg = get(arch).smoke
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    if cfg.frontend == "vision":
        # decode_step ingests token ids only; the patch prefix is a prefill
        # concern (serving covers it) — the backbone equivalence is what
        # this test checks.
        cfg = dataclasses.replace(cfg, frontend="none")
    key = jax.random.key(42)
    params = init_params(cfg, key)
    B, S = 2, 12
    toks, kw = _inputs(cfg, key, B, S)
    full = forward(cfg, params, toks, **kw)

    cache = init_cache(cfg, B, 16)
    if cfg.family == "encdec":
        cache = _fill_cross_cache(cfg, params, cache, kw["frames"])
    clen = jnp.zeros((B,), jnp.int32)
    outs = []
    for i in range(S):
        lg, cache = decode_step(cfg, params, cache, toks[:, i:i + 1], clen)
        clen = clen + 1
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    ref = full.astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(dec - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 2e-2, f"{arch}: decode diverges from forward (rel {rel})"


def test_prefill_matches_forward_last_position():
    cfg = get("llama3.2-3b").smoke
    key = jax.random.key(7)
    params = init_params(cfg, key)
    toks, _ = _inputs(cfg, key, 2, 16)
    full = forward(cfg, params, toks)
    last = prefill(cfg, params, toks)
    np.testing.assert_allclose(
        np.asarray(last[:, 0]).astype(np.float32),
        np.asarray(full[:, -1]).astype(np.float32), rtol=2e-2, atol=2e-2)


def test_local_attention_window_respected():
    """RecurrentGemma local attention must not see past the window."""
    spec = get("recurrentgemma-2b")
    cfg = spec.smoke  # window 16
    key = jax.random.key(3)
    params = init_params(cfg, key)
    B, S = 1, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    forward(cfg, params, toks)
    # perturb a token OUTSIDE the window of the last position
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    forward(cfg, params, toks2)
    # the recurrent (RG-LRU) path DOES carry long-range state, so full
    # equality is not expected — but attention contributions beyond the
    # window must be absent in an attention-only config.
    attn_only = dataclasses.replace(cfg, pattern=("attn",), n_layers=1)
    p2 = init_params(attn_only, key)
    a = forward(attn_only, p2, toks)
    b = forward(attn_only, p2, toks2)
    np.testing.assert_allclose(
        np.asarray(a[0, -1]).astype(np.float32),
        np.asarray(b[0, -1]).astype(np.float32), rtol=1e-5, atol=1e-5)


def test_full_configs_match_assignment():
    """The exact published dimensions from the assignment block."""
    expect = {
        "whisper-small": dict(n_layers=12, d_model=768, n_heads=12,
                              n_kv_heads=12, d_ff=3072, vocab=51865),
        "internvl2-26b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=92553),
        "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48,
                          n_kv_heads=8, d_ff=10752, vocab=100352,
                          n_experts=16, top_k=4),
        "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                     n_kv_heads=8, d_ff=6400, vocab=32064,
                                     n_experts=16, top_k=2),
        "deepseek-coder-33b": dict(n_layers=62, d_model=7168, n_heads=56,
                                   n_kv_heads=8, d_ff=19200, vocab=32256),
        "llama3.2-3b": dict(n_layers=28, d_model=3072, n_heads=24,
                            n_kv_heads=8, d_ff=8192, vocab=128256),
        "nemotron-4-15b": dict(n_layers=32, d_model=6144, n_heads=48,
                               n_kv_heads=8, d_ff=24576, vocab=256000,
                               mlp_kind="relu2"),
        "mistral-nemo-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                                 n_kv_heads=8, d_ff=14336, vocab=131072),
        "mamba2-780m": dict(n_layers=48, d_model=1536, vocab=50280,
                            ssm_state=128),
        "recurrentgemma-2b": dict(n_layers=26, d_model=2560, n_heads=10,
                                  n_kv_heads=1, d_ff=7680, vocab=256000,
                                  window=2048),
    }
    for arch, fields in expect.items():
        cfg = get(arch).model
        for f, v in fields.items():
            assert getattr(cfg, f) == v, (arch, f, getattr(cfg, f), v)
