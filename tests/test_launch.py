"""Launch-layer tests: lowerables on reduced configs, HLO analysis, mesh."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get, list_archs
from repro.launch.hlo_analysis import (
    _type_bytes,
    collective_stats,
    while_trip_counts,
)
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_lowerable


class TestHloAnalysis:
    def test_type_bytes(self):
        assert _type_bytes("f32[8,4]") == 128
        assert _type_bytes("bf16[2,2]{1,0}") == 8
        assert _type_bytes("(f32[4], s32[2])") == 24
        assert _type_bytes("pred[]") == 1  # scalar

    def test_collective_stats_synthetic(self):
        hlo = """
HloModule m

%cond (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %c = s32[] constant(7)
  %gte = s32[] get-tuple-element(%p), index=0
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%body (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %ar = f32[128]{0} all-reduce(%x), to_apply=%add
  ROOT %t = (s32[]) tuple(%gte)
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %ag = f32[256]{0} all-gather(%a), dimensions={0}
  %w = (s32[]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[128]{0} copy(%a)
}
"""
        st = collective_stats(hlo)
        # all-gather once (256 f32 = 1024B), all-reduce ×7 trips ×2 factor
        assert st.count_by_kind["all-gather"] == 1
        assert st.count_by_kind["all-reduce"] == 7
        assert st.bytes_by_kind["all-reduce"] == 7 * 2 * 128 * 4
        assert while_trip_counts(hlo) == [7]

    def test_real_lowering_has_layer_scaled_collectives(self):
        """On a real (1-dev) mesh there are no collectives; on the smoke
        configs the trip count of the layer scan must still be visible."""
        mesh = make_host_mesh()
        low = build_lowerable(get("llama3.2-3b"), "train_4k", mesh,
                              reduced=True)
        # reduced config still uses the full cell batch/seq — too big for
        # a real compile on CPU; .lower() alone proves traceability.
        lowered = low.lower()
        assert "while" in lowered.as_text()


class TestMesh:
    def test_host_mesh_axes(self):
        m = make_host_mesh()
        assert m.axis_names == ("data", "model")

    def test_production_mesh_requires_512_devices(self):
        # in-process we have 1 CPU device: make_mesh must fail loudly,
        # which is exactly why dryrun.py sets XLA_FLAGS first.
        from repro.launch.mesh import make_production_mesh
        with pytest.raises(Exception):
            make_production_mesh(multi_pod=True)


@pytest.mark.parametrize("arch", list_archs())
def test_lowerable_builds_for_every_arch_cell(arch):
    """Every (arch × cell) builds and abstract-evaluates on the host mesh
    with the REDUCED config (full configs are exercised by dryrun.py)."""
    spec = get(arch)
    mesh = make_host_mesh()
    for cell in spec.shapes():
        low = build_lowerable(spec, cell.name, mesh, reduced=True)
        assert low.kind in ("train", "prefill", "decode")
        # jax.eval_shape-level check: trace without compiling
        jax.eval_shape(low.jitted, *low.args)


class TestLoopAwareCost:
    def test_dot_flops_weighted_by_trips(self):
        from repro.launch.hlo_analysis import loop_aware_cost
        hlo = """
HloModule m

%cond (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %c = s32[] constant(5)
  %gte = s32[] get-tuple-element(%p), index=0
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%body (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %a = f32[8,16]{1,0} parameter(1)
  %b = f32[16,4]{1,0} parameter(2)
  %d = f32[8,4]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[]) tuple(%gte)
}

ENTRY %main (x: f32[8,16]) -> f32[8,4] {
  %x = f32[8,16]{1,0} parameter(0)
  %w = (s32[]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8,4]{1,0} copy(%d0)
}
"""
        c = loop_aware_cost(hlo)
        # dot flops = 2*8*4*16 = 1024 per trip x 5 trips
        assert c.flops == 5 * 1024

    def test_fusion_internals_excluded_from_bytes(self):
        from repro.launch.hlo_analysis import loop_aware_cost
        hlo = """
HloModule m

%fused (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %big = f32[1000]{0} copy(%p2)
  ROOT %r = f32[4]{0} add(%p, %p)
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  ROOT %f = f32[4]{0} fusion(%x), kind=kLoop, calls=%fused
}
"""
        c = loop_aware_cost(hlo)
        # the fusion op: result 16B + operand x (untracked param -> 0);
        # the 4000B copy INSIDE the fusion must not count as HBM traffic
        assert c.bytes_hbm < 100


class TestChooseMeshShape:
    def test_divisibility_rule(self):
        from repro.configs import get
        from repro.distributed.sharding import choose_mesh_shape
        # 12 heads: widest divisor of 12 in (16,8,4,2,1) on 256 chips is 4
        assert choose_mesh_shape(get("whisper-small").model) == (64, 4)
        # 24 heads + kv 8 -> 8
        assert choose_mesh_shape(get("llama3.2-3b").model) == (32, 8)
        # attention-free
        assert choose_mesh_shape(get("mamba2-780m").model) == (16, 16)
        # MQA kv=1 exempt: 10 heads -> tp 2
        assert choose_mesh_shape(get("recurrentgemma-2b").model) == (128, 2)

    def test_q_chunked_attention_matches_reference(self):
        import jax
        import jax.numpy as jnp
        from repro.models.layers import _chunked_attention
        key = jax.random.key(3)
        B, S, H, KV, D = 2, 48, 4, 2, 8
        q = jax.random.normal(key, (B, S, H, D), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, D),
                              jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, D),
                              jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        for window in (None, 16):
            ref = _chunked_attention(q, k, v, pos, pos, True, window, 8,
                                     q_chunks=1)
            for qc in (2, 4, 6):
                got = _chunked_attention(q, k, v, pos, pos, True, window,
                                         8, q_chunks=qc)
                assert float(jnp.abs(got - ref).max()) < 1e-4, (window, qc)
