"""Multi-tenant serving: the paper's partitioning algorithm at mesh level."""
