"""Serving-side cache/session management on top of ``models.model``.

A :class:`DecodeSession` owns a fixed-capacity batched cache for one tenant
model and multiplexes request slots into it (continuous batching): requests
claim a free row, prefill writes their prompt KV, decode steps advance every
live row together, finished rows are released for reuse.

The cache pytree itself comes from ``models.model.init_cache`` so every
family (KV / SSM state / RG-LRU ring window) gets the right structure.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import (
    ModelConfig,
    decode_step,
    init_cache,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class DecodeSession:
    """Fixed-slot continuous-batching session for one model/tenant."""

    def __init__(self, cfg: ModelConfig, params: Any, batch_slots: int,
                 max_seq: int):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.cache = init_cache(cfg, batch_slots, max_seq)
        self.cache_len = jnp.zeros((batch_slots,), jnp.int32)
        self.live: dict[int, Request] = {}     # slot -> request
        self._free = list(range(batch_slots))
        self._decode = jax.jit(
            lambda p, c, t, n: decode_step(cfg, p, c, t, n))

    # -- admission ----------------------------------------------------------
    def can_admit(self) -> bool:
        return bool(self._free)

    def admit(self, req: Request) -> None:
        if not self._free:
            raise RuntimeError("no free slots")
        slot = self._free.pop()
        req.slot = slot
        self.live[slot] = req
        # sequential prompt ingestion through decode_step (prefill-by-decode;
        # a fused prefill is the §Perf variant) — each prompt token advances
        # only this row; other rows are advanced by masking below.
        for tok in req.prompt:
            self._step_one_row(slot, tok)

    def _step_one_row(self, slot: int, token: int) -> None:
        toks = jnp.zeros((self.slots, 1), jnp.int32).at[slot, 0].set(token)
        logits, new_cache = self._decode(self.params, self.cache, toks,
                                         self.cache_len)
        # merge: only this row's cache mutates; others must stay untouched.
        row = jnp.arange(self.slots) == slot
        self.cache = jax.tree.map(
            lambda new, old: jnp.where(
                row.reshape((1, -1) + (1,) * (new.ndim - 2))
                if new.ndim >= 2 else row, new, old),
            new_cache, self.cache)
        self.cache_len = jnp.where(row, self.cache_len + 1, self.cache_len)

    # -- decode -------------------------------------------------------------
    def step(self) -> dict[int, int]:
        """One decode step for every live row; returns {rid: new_token}."""
        if not self.live:
            return {}
        # last emitted (or last prompt) token per row
        toks = jnp.zeros((self.slots, 1), jnp.int32)
        for slot, req in self.live.items():
            last = req.out[-1] if req.out else req.prompt[-1]
            toks = toks.at[slot, 0].set(last)
        logits, new_cache = self._decode(self.params, self.cache, toks,
                                         self.cache_len)
        live_mask = jnp.zeros((self.slots,), bool)
        for slot in self.live:
            live_mask = live_mask.at[slot].set(True)
        self.cache = jax.tree.map(
            lambda new, old: jnp.where(
                live_mask.reshape((1, -1) + (1,) * (new.ndim - 2))
                if new.ndim >= 2 else live_mask, new, old),
            new_cache, self.cache)
        self.cache_len = jnp.where(live_mask, self.cache_len + 1,
                                   self.cache_len)

        emitted: dict[int, int] = {}
        greedy = jnp.argmax(logits[:, 0, :], axis=-1)
        for slot, req in list(self.live.items()):
            tok = int(greedy[slot])
            req.out.append(tok)
            emitted[req.rid] = tok
            if req.done:
                self.release(slot)
        return emitted

    def release(self, slot: int) -> None:
        req = self.live.pop(slot)
        req.slot = -1
        self.cache_len = self.cache_len.at[slot].set(0)
        self._free.append(slot)

    @property
    def occupancy(self) -> float:
        return len(self.live) / self.slots
