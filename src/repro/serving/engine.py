"""Multi-tenant serving engine — Algorithm 1 driving live mesh tenancy.

The engine is the cluster-scale version of the paper's Fig. 4 timeline:

* tenants (models) arrive with a request queue; ``demand`` ≙ Opr — here the
  total outstanding decode work (tokens × per-token FLOPs);
* ``TenantMeshManager.rebalance`` is Partition_Calculation+Task_Assignment,
  generalised: the engine's ``policy`` (a `repro.api` registry name such as
  ``"equal"``, ``"proportional"`` or ``"priority"``, or a policy instance)
  splits the ``model``-axis columns over live tenant demands every round;
* when a tenant's queue drains it releases its slice; adjacent free slices
  merge and ``grow_into_free`` widens the survivors (merge-accelerate);
* a failed device column evicts its tenants, which simply re-enter the
  rebalance round — the paper's re-assignment IS the recovery path.

The engine is deliberately mesh-agnostic about execution: each admitted
tenant runs a :class:`DecodeSession` jit'd for its CURRENT slice width (on
real hardware the session's jit would target ``manager.submesh(name)``; on
the CPU test rig the submesh is 1 device wide and sessions run locally).
``width_history`` records every (time, tenant, width) grant — the serving
benchmark's equivalent of Fig. 9(c,d).
"""

from __future__ import annotations

import dataclasses
import itertools

from repro.distributed.tenancy import TenantMeshManager
from repro.serving.kv_cache import DecodeSession, Request


@dataclasses.dataclass
class TenantService:
    name: str
    session: DecodeSession
    queue: list[Request] = dataclasses.field(default_factory=list)
    flops_per_token: float = 1.0
    width: int = 0
    served: int = 0

    @property
    def outstanding_tokens(self) -> int:
        q = sum(r.max_new - len(r.out) + len(r.prompt) for r in self.queue)
        live = sum(r.max_new - len(r.out)
                   for r in self.session.live.values())
        return q + live

    @property
    def demand(self) -> float:
        """Opr analogue: outstanding work in FLOPs."""
        return self.outstanding_tokens * self.flops_per_token

    @property
    def drained(self) -> bool:
        return not self.queue and not self.session.live


class MultiTenantEngine:
    """Round-based multi-tenant decode executor over a device mesh.

    ``policy`` selects the partition policy used at every rebalance; it is
    forwarded to :meth:`TenantMeshManager.rebalance` (default ``"equal"``,
    the paper's Algorithm 1).
    """

    def __init__(self, manager: TenantMeshManager, policy="equal"):
        self.manager = manager
        self.policy = policy
        self.tenants: dict[str, TenantService] = {}
        self.width_history: list[tuple[int, str, int]] = []
        self.round = 0
        self._rid = itertools.count()
        self._dirty = False  # demand changed since the last rebalance

    # -- tenancy ------------------------------------------------------------
    def add_tenant(self, name: str, session: DecodeSession,
                   flops_per_token: float, min_cols: int = 1,
                   tier: int = 0) -> TenantService:
        """Admit a model; ``min_cols``/``tier`` feed policies that use
        reservation floors and SLA classes (``priority``)."""
        svc = TenantService(name=name, session=session,
                            flops_per_token=flops_per_token)
        self.tenants[name] = svc
        self.manager.admit(name, demand=svc.demand, min_cols=min_cols,
                           tier=tier)
        self._rebalance()
        return svc

    def submit(self, tenant: str, prompt: list[int], max_new: int) -> Request:
        """Enqueue a request — this *changes the tenant's demand*, so the
        partition split is stale: mark dirty and re-run the policy at the
        next :meth:`step` (batching all submits of a round into one
        rebalance instead of one re-shard storm per request)."""
        req = Request(rid=next(self._rid), prompt=prompt, max_new=max_new)
        self.tenants[tenant].queue.append(req)
        self._dirty = True
        return req

    def _rebalance(self) -> None:
        # policy.split over live tenant demands (via the mesh manager)
        for name, svc in self.tenants.items():
            self.manager.tenant(name).demand = svc.demand
        grants = self.manager.rebalance(policy=self.policy)
        for name, part in grants.items():
            self.tenants[name].width = part.cols
            self.width_history.append((self.round, name, part.cols))
        self._dirty = False

    def _retire_drained(self) -> list[str]:
        done = [n for n, s in self.tenants.items() if s.drained]
        for n in done:
            self.manager.release(n)
            del self.tenants[n]
        if done:
            # merge-accelerate survivors (paper §3.3) — no re-shard storm
            grown = self.manager.grow_into_free()
            for name, part in grown.items():
                if name in self.tenants:
                    self.tenants[name].width = part.cols
                    self.width_history.append((self.round, name, part.cols))
        return done

    # -- execution ----------------------------------------------------------
    def step(self) -> dict[str, dict[int, int]]:
        """One engine round: admit from queues, decode every tenant, retire.

        Returns {tenant: {rid: token}} of this round's emissions.
        """
        self.round += 1
        if self._dirty:
            # outstanding demand changed since the last split (submit);
            # widths must track demand, not just admit/retire/failure
            self._rebalance()
        out: dict[str, dict[int, int]] = {}
        for name, svc in self.tenants.items():
            while svc.queue and svc.session.can_admit():
                svc.session.admit(svc.queue.pop(0))
            if svc.session.live:
                emitted = svc.session.step()
                svc.served += len(emitted)
                out[name] = emitted
        self._retire_drained()
        return out

    def run_until_drained(self, max_rounds: int = 10_000) -> int:
        """Drive rounds until every tenant drains; returns rounds used."""
        r0 = self.round
        while self.tenants:
            if self.round - r0 >= max_rounds:
                raise RuntimeError(
                    f"engine did not drain in {max_rounds} rounds; "
                    f"live={list(self.tenants)}")
            self.step()
        return self.round - r0

    # -- fault handling -----------------------------------------------------
    def fail_column(self, col: int) -> list[str]:
        """Device-column failure: evict + immediately re-place tenants."""
        evicted = self.manager.mark_unhealthy(col)
        self._rebalance()
        return evicted

    def heal_column(self, col: int) -> None:
        self.manager.mark_healthy(col)
        self._rebalance()
