"""Token-choice top-k Mixture-of-Experts (DBRX 16e/top-4, Phi-3.5-MoE 16e/top-2).

Dispatch is capacity-based and sort-based (no (T × E × C) one-hot tensor):

1. router logits → top-k (expert, gate) per token;
2. flatten the T·k assignments, compute each assignment's *rank within its
   expert* via an argsort over expert ids (stable), positions past the
   capacity ``C = ceil(T·k/E · capacity_factor)`` are dropped;
3. scatter token activations into an (E, C, d) buffer, run the expert FFNs
   as one batched einsum, gather back and combine weighted by the gates.

Sharding: expert weights are laid out (E, d, ff); the ``ff`` dim is
tensor-parallel over the ``model`` mesh axis (same rule as dense MLPs) and
``E`` is FSDP-sharded over ``data``.  Dispatch/combine are local to a data
shard, so no all-to-all is required — the only collective is the same
output-reduction a dense TP MLP needs.  (An EP all-to-all layout is a
documented §Perf alternative.)
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Params = dict[str, Any]


def init_moe(key: jax.Array, d_model: int, d_ff: int, n_experts: int,
             kind: str = "swiglu") -> Params:
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d_model)

    def ew(k, a, b):
        return (jax.random.normal(k, (n_experts, a, b), jnp.float32)
                * scale).astype(jnp.bfloat16)

    p: Params = {
        "router": dense_init(ks[0], d_model, n_experts, dtype=jnp.float32),
        "down": ew(ks[1], d_ff, d_model),
    }
    if kind in ("swiglu", "geglu"):
        p["gate"] = ew(ks[2], d_model, d_ff)
        p["up"] = ew(ks[3], d_model, d_ff)
    else:
        p["up"] = ew(ks[2], d_model, d_ff)
    return p


def moe_ffn(p: Params, x: jax.Array, top_k: int, kind: str = "swiglu",
            capacity_factor: float = 1.25) -> jax.Array:
    """Apply the MoE FFN.  x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E = p["router"].shape[1]
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    gates_all = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(gates_all, top_k)   # (T, k)
    # renormalise the selected gates (standard for token-choice routing)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    A = T * top_k
    cap = int(math.ceil(T * top_k / E * capacity_factor))
    flat_expert = expert_ids.reshape(A)                       # (A,)
    flat_gate = gate_vals.reshape(A)
    flat_token = jnp.repeat(jnp.arange(T), top_k)

    # rank of each assignment within its expert (stable by token order)
    order = jnp.argsort(flat_expert, stable=True)             # (A,)
    sorted_expert = flat_expert[order]
    # position within run of equal expert ids
    idx_in_sorted = jnp.arange(A)
    seg_start = jnp.searchsorted(sorted_expert, jnp.arange(E), side="left")
    pos_sorted = idx_in_sorted - seg_start[sorted_expert]
    pos = jnp.zeros((A,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))

    keep = pos < cap
    safe_pos = jnp.where(keep, pos, 0)
    # scatter tokens into (E, C, d); dropped assignments write nothing
    buf = jnp.zeros((E, cap, d), x.dtype)
    buf = buf.at[flat_expert, safe_pos].add(
        jnp.where(keep[:, None], xt[flat_token], 0).astype(x.dtype))

    # batched expert FFN: (E, C, d) x (E, d, f) -> (E, C, f)
    if "gate" in p:
        h_g = jnp.einsum("ecd,edf->ecf", buf, p["gate"])
        h_u = jnp.einsum("ecd,edf->ecf", buf, p["up"])
        h = (jax.nn.silu(h_g) if kind == "swiglu" else jax.nn.gelu(h_g)) * h_u
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["up"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down"])        # (E, C, d)

    # gather back and combine
    picked = out_buf[flat_expert, safe_pos]                   # (A, d)
    picked = jnp.where(keep[:, None], picked, 0)
    weighted = picked * flat_gate[:, None].astype(picked.dtype)
    out = jnp.zeros((T, d), x.dtype).at[flat_token].add(
        weighted.astype(x.dtype))
    return out.reshape(B, S, d)


def aux_load_balance_loss(p: Params, x: jax.Array, top_k: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (mean fraction · prob)."""
    B, S, d = x.shape
    E = p["router"].shape[1]
    logits = (x.reshape(-1, d).astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, ids = jax.lax.top_k(probs, top_k)
    counts = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac = counts / counts.sum()
    return E * jnp.sum(frac * probs.mean(axis=0))
