"""Shared neural layers for the assigned architectures (pure JAX).

Design notes
------------
* Functional style: params are nested dicts of ``jnp`` arrays; every layer is
  ``init_*(key, ...) -> params`` + ``apply(params, x, ...) -> y``.
* Attention is **chunked** (flash-style online softmax over KV blocks) so the
  32k-prefill cells never materialise an (S × S) score tensor — required for
  the multi-pod dry-run to fit HBM.
* GQA throughout: ``n_kv_heads <= n_heads``; local (sliding-window) attention
  for RecurrentGemma; bidirectional for the Whisper encoder.
* Compute dtype is bf16, accumulation/softmax in f32.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]

DEFAULT_ATTN_CHUNK = 512


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, in_dim: int, out_dim: int,
               dtype=jnp.bfloat16) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, dim: int,
               dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int) -> Params:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def init_layernorm(dim: int) -> Params:
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Apply RoPE.  x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, chunked flash-style, causal / local / bidirectional)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    window: int | None = None          # local attention window (tokens back)
    use_rope: bool = True
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    chunk: int = DEFAULT_ATTN_CHUNK    # KV-block size for the online softmax
    q_chunks: int = 1                  # Q-block count: >1 enables STATIC
    #   causal/window skipping — each Q block scans only the KV chunks it
    #   can see (triangular ≈2× flop/byte saving at long S); block count is
    #   a trace-time constant so the saving is visible in the lowered HLO

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


def init_attention(key: jax.Array, cfg: AttnConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(k1, cfg.d_model, cfg.q_dim),
        "wk": dense_init(k2, cfg.d_model, cfg.kv_dim),
        "wv": dense_init(k3, cfg.d_model, cfg.kv_dim),
        "wo": dense_init(k4, cfg.q_dim, cfg.d_model),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
    return p


def _project_qkv(p: Params, cfg: AttnConfig, x: jax.Array,
                 positions: jax.Array):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       q_pos: jax.Array, kv_pos: jax.Array,
                       causal: bool, window: int | None,
                       chunk: int, q_chunks: int = 1) -> jax.Array:
    """Flash-style attention: scan over KV chunks with online softmax.

    q: (B, Sq, H, D); k/v: (B, Sk, KV, D); positions broadcastable (B, S).
    Never materialises (Sq × Sk); peak extra memory is (B, H, Sq/q_chunks,
    chunk).  With ``q_chunks > 1`` each Q block only scans the KV chunks it
    can actually see (causal lower-triangle / local window) — the trip
    counts are trace-time constants, so the ~2× triangular saving shows up
    in the compiled HLO, not just at runtime.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(D)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    # (n, B, chunk, KV, D)
    kc = k.reshape(B, n_chunks, chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, D).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def run_block(qf, qp, q_start, q_end):
        """Online softmax of one Q block over its visible KV chunks."""
        Sq_b = qf.shape[1]

        def _update(carry, kb, vb, s):
            m, lsum, acc = carry
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p_blk = jnp.exp(s - m_new[..., None])
            l_new = lsum * alpha + p_blk.sum(axis=-1)
            # PV product in bf16 (f32 accumulate): halves the HBM traffic
            # of the largest residual without touching softmax numerics
            acc_new = (acc * alpha[..., None]
                       + jnp.einsum("bhqk,bkhd->bhqd",
                                    p_blk.astype(jnp.bfloat16),
                                    vb.astype(jnp.bfloat16)
                                    ).astype(jnp.float32))
            return m_new, l_new, acc_new

        def body(carry, blk):
            kb, vb, pb = blk
            kb = jnp.repeat(kb, rep, axis=2)  # (B, c, H, D)
            vb = jnp.repeat(vb, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
            mask = (pb[:, None, None, :] >= 0)
            if causal:
                mask = mask & (pb[:, None, None, :]
                               <= qp[:, None, :, None])
            if window is not None:
                mask = mask & (pb[:, None, None, :]
                               > qp[:, None, :, None] - window)
            s = jnp.where(mask, s, -1e30)
            return _update(carry, kb, vb, s), None

        def body_nomask(carry, blk):
            # chunks strictly below this Q block's start are FULLY visible
            # under the causal mask — the mask/select chain (3 score-sized
            # tensors) is statically dead and skipped entirely.
            kb, vb, _pb = blk
            kb = jnp.repeat(kb, rep, axis=2)
            vb = jnp.repeat(vb, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
            return _update(carry, kb, vb, s), None

        m0 = jnp.full((B, H, Sq_b), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, Sq_b), jnp.float32)
        acc0 = jnp.zeros((B, H, Sq_b, D), jnp.float32)
        carry = (m0, l0, acc0)
        # static visibility bound: causal -> KV chunks past this Q block's
        # last position never contribute; window -> chunks before the
        # window's start never contribute.  Both are trace-time slices
        # (identity row->position layout, i.e. training/prefill).
        lo_c, hi_c, diag_c = 0, n_chunks, 0
        if causal and Sq == Sk and q_chunks > 1:
            hi_c = min(n_chunks, -(-q_end // chunk))
            if window is None and pad == 0:
                # chunks [lo_c, diag_c) need no masking at all
                diag_c = max(lo_c, q_start // chunk)
            else:
                lo_c = max(0, (q_start - window) // chunk) \
                    if window is not None else 0
        if diag_c > lo_c:
            carry, _ = jax.lax.scan(jax.checkpoint(body_nomask), carry,
                                    (kc[lo_c:diag_c], vc[lo_c:diag_c],
                                     pc[lo_c:diag_c]))
            lo_c = diag_c
        # remat the chunk body: backward recomputes the (B,H,Sq_b,chunk)
        # score block instead of saving one per scan step.
        (m, lsum, acc), _ = jax.lax.scan(jax.checkpoint(body), carry,
                                      (kc[lo_c:hi_c], vc[lo_c:hi_c],
                                       pc[lo_c:hi_c]))
        out = acc / jnp.maximum(lsum, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, Sq_b, H, D)

    qf_all = (q * scale).astype(jnp.float32)
    if q_chunks <= 1 or Sq % q_chunks or Sq != Sk:
        return run_block(qf_all, q_pos, 0, Sk)
    qb = Sq // q_chunks
    outs = []
    for i in range(q_chunks):
        outs.append(run_block(qf_all[:, i * qb:(i + 1) * qb],
                              q_pos[:, i * qb:(i + 1) * qb],
                              i * qb, (i + 1) * qb))
    return jnp.concatenate(outs, axis=1)


def attention(p: Params, cfg: AttnConfig, x: jax.Array,
              positions: jax.Array | None = None) -> jax.Array:
    """Self-attention over a full sequence (training / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = _chunked_attention(q, k, v, positions, positions,
                             cfg.causal, cfg.window, cfg.chunk,
                             q_chunks=cfg.q_chunks)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"]


def cross_attention(p: Params, cfg: AttnConfig, x: jax.Array,
                    kv: jax.Array) -> jax.Array:
    """Encoder-decoder cross attention (no RoPE on kv side)."""
    B, S, _ = x.shape
    Sk = kv.shape[1]
    pos_q = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    pos_k = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32), (B, Sk))
    cfg_nc = dataclasses.replace(cfg, causal=False, window=None,
                                 use_rope=False)
    q, _, _ = _project_qkv(p, cfg_nc, x, pos_q)
    k = (kv @ p["wk"]).reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim)
    v = (kv @ p["wv"]).reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(k.dtype).reshape(cfg.n_kv_heads, cfg.head_dim)
        v = v + p["bv"].astype(v.dtype).reshape(cfg.n_kv_heads, cfg.head_dim)
    out = _chunked_attention(q, k, v, pos_q, pos_k, causal=False, window=None,
                             chunk=cfg.chunk)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"]


def decode_attention(p: Params, cfg: AttnConfig, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array):
    """Single-token decode against a (B, S_max, KV, D) cache.

    Returns (out, new_k_cache, new_v_cache).  ``cache_len``: (B,) int32 —
    the number of valid entries; the new token is written at that index.
    """
    B, S1, _ = x.shape
    assert S1 == 1, "decode_attention expects a single new token"
    pos = cache_len[:, None].astype(jnp.int32)  # (B, 1)
    q, k, v = _project_qkv(p, cfg, x, pos)
    idx = cache_len.astype(jnp.int32)
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, idx].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, idx].set(v[:, 0].astype(v_cache.dtype))
    S = k_cache.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    # entries beyond cache_len are masked via the causal predicate
    out = _chunked_attention(q, k_cache, v_cache, pos, kv_pos,
                             causal=True, window=cfg.window, chunk=cfg.chunk)
    return out.reshape(B, 1, cfg.q_dim) @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, d_model: int, d_ff: int, kind: str) -> Params:
    """kind: 'swiglu' | 'geglu' | 'gelu' | 'relu2' (squared ReLU, Nemotron)."""
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"down": dense_init(k2, d_ff, d_model)}
    if kind in ("swiglu", "geglu"):
        p["gate"] = dense_init(k1, d_model, d_ff)
        p["up"] = dense_init(k3, d_model, d_ff)
    else:
        p["up"] = dense_init(k1, d_model, d_ff)
    return p


def mlp(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["gate"]) * (x @ p["up"])
    elif kind == "gelu":
        h = jax.nn.gelu(x @ p["up"])
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["up"]))
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return h @ p["down"]


# ---------------------------------------------------------------------------
# conv1d (short causal depthwise conv — Mamba/RecurrentGemma temporal mix)
# ---------------------------------------------------------------------------

def init_conv1d(key: jax.Array, dim: int, width: int) -> Params:
    scale = 1.0 / math.sqrt(width)
    return {"w": (jax.random.normal(key, (width, dim), jnp.float32)
                  * scale).astype(jnp.bfloat16),
            "b": jnp.zeros((dim,), jnp.float32)}


def causal_conv1d(p: Params, x: jax.Array) -> jax.Array:
    """Depthwise causal conv over time.  x: (B, S, dim)."""
    width = p["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * p["w"][i].astype(x.dtype)
              for i in range(width))
    return out + p["b"].astype(x.dtype)


def conv1d_step(p: Params, window: jax.Array, x_t: jax.Array):
    """Single decode step.  window: (B, width-1, dim) history; x_t: (B, dim).

    Returns (y_t, new_window).
    """
    full = jnp.concatenate([window, x_t[:, None, :]], axis=1)  # (B, width, d)
    y = jnp.einsum("bwd,wd->bd", full.astype(jnp.float32),
                   p["w"].astype(jnp.float32))
    y = (y + p["b"]).astype(x_t.dtype)
    return y, full[:, 1:, :]
