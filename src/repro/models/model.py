"""Model assembly for the 10 assigned architectures.

One :class:`ModelConfig` describes any of the five families:

* ``dense``  — llama-style decoder (deepseek-coder, llama3.2, nemotron,
  mistral-nemo) and the internvl2 VLM backbone (vision stub prefix);
* ``moe``    — dense backbone with MoE FFNs (dbrx, phi3.5-moe);
* ``ssm``    — Mamba-2 SSD stack (mamba2-780m), attention-free;
* ``hybrid`` — RecurrentGemma: repeating [RG-LRU, RG-LRU, local-attn]
  pattern, every block followed by an MLP;
* ``encdec`` — Whisper: bidirectional encoder over stubbed audio-frame
  embeddings + causal decoder with cross-attention.

Implementation notes
--------------------
* **scan over layers** with stacked params — keeps HLO size O(1) in depth so
  the 62-layer deepseek config lowers/compiles quickly for every dry-run cell;
* **remat** (``jax.checkpoint``) around each layer body: activations between
  layers are the only saved residuals in training;
* **prefill** uses chunked flash-style attention (no S×S buffer — mandatory
  at 32k); **decode** uses a plain einsum over the KV cache so GSPMD can
  shard the cache's *sequence* dimension over the ``model`` mesh axis (a
  single-query softmax over a sharded axis costs two tiny all-reduces);
* hybrid local attention decodes against a **ring-buffer window cache**
  (window 2048), which is what makes the 500k-decode cell O(window) instead
  of O(seq).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import AttnConfig
from repro.models.moe import init_moe, moe_ffn
from repro.models.rglru import (
    RGLRUConfig,
    init_rglru,
    rglru_forward,
    rglru_init_cache,
    rglru_step,
)
from repro.models.ssm import (
    SSMConfig,
    init_ssd,
    ssd_forward,
    ssd_init_cache,
    ssd_step,
)

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    mlp_kind: str = "swiglu"
    norm: str = "rms"            # rms | ln
    use_rope: bool = True
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    attn_chunk: int = 512
    attn_q_chunks: int = 1
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    # hybrid
    window: int = 2048
    lru_width: int = 0
    pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    # encdec
    n_enc_layers: int = 0
    enc_seq: int = 1500
    max_dec_seq: int = 8192      # learned decoder position-table size
    # frontend stub
    frontend: str = "none"       # none | audio | vision
    n_patches: int = 256
    # training
    remat: bool = True

    @property
    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            causal=True, window=None, use_rope=self.use_rope,
            rope_theta=self.rope_theta, qkv_bias=self.qkv_bias,
            chunk=self.attn_chunk, q_chunks=self.attn_q_chunks)

    @property
    def local_attn_cfg(self) -> AttnConfig:
        return dataclasses.replace(self.attn_cfg, window=self.window)

    @property
    def ssm_cfg(self) -> SSMConfig:
        return SSMConfig(d_model=self.d_model, d_state=self.ssm_state,
                         head_dim=self.ssm_head_dim, expand=self.ssm_expand)

    @property
    def rglru_cfg(self) -> RGLRUConfig:
        return RGLRUConfig(d_model=self.d_model,
                           lru_width=self.lru_width or self.d_model)

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Exact parameter count (for 6·N·D roofline accounting)."""
        counts = jax.tree.reduce(
            lambda a, b: a + b,
            jax.tree.map(lambda x: x.size,
                         jax.eval_shape(lambda: init_params(
                             self, jax.random.key(0)))),
            0)
        return int(counts)


def _norm_init(cfg: ModelConfig, dim: int) -> Params:
    return (L.init_rmsnorm(dim) if cfg.norm == "rms"
            else L.init_layernorm(dim))


def _norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    return L.rmsnorm(p, x) if cfg.norm == "rms" else L.layernorm(p, x)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, key: jax.Array) -> Params:
    """One decoder block of the dense/moe families."""
    k1, k2 = jax.random.split(key)
    p: Params = {
        "attn_norm": _norm_init(cfg, cfg.d_model),
        "attn": L.init_attention(k1, cfg.attn_cfg),
        "mlp_norm": _norm_init(cfg, cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                            cfg.mlp_kind)
    else:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    return p


def _init_hybrid_super(cfg: ModelConfig, key: jax.Array) -> Params:
    """One RecurrentGemma super-block following cfg.pattern."""
    p: Params = {}
    ks = jax.random.split(key, len(cfg.pattern) * 2)
    for i, kind in enumerate(cfg.pattern):
        sub: Params = {
            "temporal_norm": _norm_init(cfg, cfg.d_model),
            "mlp_norm": _norm_init(cfg, cfg.d_model),
            "mlp": L.init_mlp(ks[2 * i], cfg.d_model, cfg.d_ff, cfg.mlp_kind),
        }
        if kind == "rec":
            sub["rglru"] = init_rglru(ks[2 * i + 1], cfg.rglru_cfg)
        else:
            sub["attn"] = L.init_attention(ks[2 * i + 1], cfg.local_attn_cfg)
        p[f"sub{i}"] = sub
    return p


def _init_enc_block(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    enc_attn = dataclasses.replace(cfg.attn_cfg, causal=False, use_rope=False)
    return {
        "attn_norm": _norm_init(cfg, cfg.d_model),
        "attn": L.init_attention(k1, enc_attn),
        "mlp_norm": _norm_init(cfg, cfg.d_model),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind),
    }


def _init_dec_block(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    dec_attn = dataclasses.replace(cfg.attn_cfg, use_rope=False)
    return {
        "attn_norm": _norm_init(cfg, cfg.d_model),
        "attn": L.init_attention(k1, dec_attn),
        "cross_norm": _norm_init(cfg, cfg.d_model),
        "cross": L.init_attention(k2, dec_attn),
        "mlp_norm": _norm_init(cfg, cfg.d_model),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_kind),
    }


def _stack_init(fn, cfg: ModelConfig, key: jax.Array, n: int) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(cfg, k))(keys)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ke, kb, kh, ko = jax.random.split(key, 4)
    p: Params = {"embed": L.embed_init(ke, cfg.vocab, cfg.d_model),
                 "final_norm": _norm_init(cfg, cfg.d_model),
                 "lm_head": L.dense_init(ko, cfg.d_model, cfg.vocab)}
    if cfg.family in ("dense", "moe"):
        p["blocks"] = _stack_init(_init_block, cfg, kb, cfg.n_layers)
    elif cfg.family == "ssm":
        def blk(c, k):
            return {"norm": _norm_init(c, c.d_model),
                    "ssd": init_ssd(k, c.ssm_cfg)}
        p["blocks"] = _stack_init(blk, cfg, kb, cfg.n_layers)
    elif cfg.family == "hybrid":
        n_super, rem = divmod(cfg.n_layers, len(cfg.pattern))
        p["blocks"] = _stack_init(_init_hybrid_super, cfg, kb, n_super)
        # leftover layers (26 = 8*3 + 2 for recurrentgemma) are recurrent
        for i in range(rem):
            sub = {
                "temporal_norm": _norm_init(cfg, cfg.d_model),
                "mlp_norm": _norm_init(cfg, cfg.d_model),
                "mlp": L.init_mlp(jax.random.fold_in(kh, 2 * i), cfg.d_model,
                                  cfg.d_ff, cfg.mlp_kind),
                "rglru": init_rglru(jax.random.fold_in(kh, 2 * i + 1),
                                    cfg.rglru_cfg),
            }
            p[f"tail{i}"] = sub
    elif cfg.family == "encdec":
        p["enc_blocks"] = _stack_init(_init_enc_block, cfg, kb,
                                      cfg.n_enc_layers)
        p["dec_blocks"] = _stack_init(_init_dec_block, cfg, kh, cfg.n_layers)
        p["enc_final_norm"] = _norm_init(cfg, cfg.d_model)
        p["enc_pos"] = (jax.random.normal(
            jax.random.fold_in(ke, 1), (cfg.enc_seq, cfg.d_model),
            jnp.float32) * 0.02).astype(jnp.bfloat16)
        p["dec_pos"] = (jax.random.normal(
            jax.random.fold_in(ke, 2), (cfg.max_dec_seq, cfg.d_model),
            jnp.float32) * 0.02).astype(jnp.bfloat16)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return p


# ---------------------------------------------------------------------------
# full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------

def _dense_block_fwd(cfg: ModelConfig, bp: Params, x: jax.Array,
                     positions: jax.Array) -> jax.Array:
    h = x + L.attention(bp["attn"], cfg.attn_cfg,
                        _norm(cfg, bp["attn_norm"], x), positions)
    z = _norm(cfg, bp["mlp_norm"], h)
    if cfg.family == "moe":
        ff = moe_ffn(bp["moe"], z, cfg.top_k, cfg.mlp_kind,
                     capacity_factor=cfg.capacity_factor)
    else:
        ff = L.mlp(bp["mlp"], z, cfg.mlp_kind)
    return h + ff


def _hybrid_sub_fwd(cfg: ModelConfig, sp: Params, kind: str, x: jax.Array,
                    positions: jax.Array) -> jax.Array:
    z = _norm(cfg, sp["temporal_norm"], x)
    if kind == "rec":
        t = rglru_forward(sp["rglru"], cfg.rglru_cfg, z)
    else:
        t = L.attention(sp["attn"], cfg.local_attn_cfg, z, positions)
    h = x + t
    return h + L.mlp(sp["mlp"], _norm(cfg, sp["mlp_norm"], h), cfg.mlp_kind)


def _scan_blocks(cfg: ModelConfig, blocks: Params, x: jax.Array,
                 body) -> jax.Array:
    """lax.scan over stacked layer params with optional remat."""
    fn = jax.checkpoint(body) if cfg.remat else body

    def step(h, bp):
        return fn(bp, h), None

    out, _ = jax.lax.scan(step, x, blocks)
    return out


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array,
            frames: jax.Array | None = None,
            patches: jax.Array | None = None,
            return_hidden: bool = False) -> jax.Array:
    """Full-sequence logits (or final hidden states).

    tokens: (B, S) int32.  ``frames`` (audio stub, B×enc_seq×d) feeds the
    encdec encoder; ``patches`` (vision stub, B×n_patches×d) is prepended to
    the token embeddings (internvl2).  Returns (B, S, vocab) logits for the
    token positions, or the normed (B, S, d) hidden states when
    ``return_hidden`` (prefill needs only the last position's logits — the
    (B, S, vocab) tensor would dominate peak memory at 32k).
    """
    B, S = tokens.shape
    x = params["embed"][tokens].astype(jnp.bfloat16)
    n_prefix = 0
    if cfg.frontend == "vision":
        if patches is None:
            raise ValueError("vision frontend needs `patches`")
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        n_prefix = patches.shape[1]
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), (B, x.shape[1]))

    if cfg.family in ("dense", "moe"):
        x = _scan_blocks(cfg, params["blocks"], x,
                         lambda bp, h: _dense_block_fwd(cfg, bp, h, positions))
    elif cfg.family == "ssm":
        def body(bp, h):
            return h + ssd_forward(bp["ssd"], cfg.ssm_cfg,
                                   _norm(cfg, bp["norm"], h))
        x = _scan_blocks(cfg, params["blocks"], x, body)
    elif cfg.family == "hybrid":
        def super_body(bp, h):
            for i, kind in enumerate(cfg.pattern):
                h = _hybrid_sub_fwd(cfg, bp[f"sub{i}"], kind, h, positions)
            return h
        x = _scan_blocks(cfg, params["blocks"], x, super_body)
        i = 0
        while f"tail{i}" in params:
            x = _hybrid_sub_fwd(cfg, params[f"tail{i}"], "rec", x, positions)
            i += 1
    elif cfg.family == "encdec":
        if frames is None:
            raise ValueError("encdec needs `frames` (audio stub)")
        mem = _encode(cfg, params, frames)
        x = x + params["dec_pos"][:S].astype(x.dtype)

        def dec_body(bp, h):
            h = h + L.attention(bp["attn"],
                                dataclasses.replace(cfg.attn_cfg,
                                                    use_rope=False),
                                _norm(cfg, bp["attn_norm"], h), positions)
            h = h + L.cross_attention(
                bp["cross"], dataclasses.replace(cfg.attn_cfg,
                                                 use_rope=False),
                _norm(cfg, bp["cross_norm"], h), mem)
            return h + L.mlp(bp["mlp"], _norm(cfg, bp["mlp_norm"], h),
                             cfg.mlp_kind)
        x = _scan_blocks(cfg, params["dec_blocks"], x, dec_body)

    x = _norm(cfg, params["final_norm"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    if return_hidden:
        return x
    return x @ params["lm_head"]


def _encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stubbed frame embeddings (B, enc_seq, d)."""
    x = frames.astype(jnp.bfloat16) + params["enc_pos"].astype(jnp.bfloat16)
    Bz, Se, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (Bz, Se))
    enc_attn = dataclasses.replace(cfg.attn_cfg, causal=False, use_rope=False)

    def body(bp, h):
        h = h + L.attention(bp["attn"], enc_attn,
                            _norm(cfg, bp["attn_norm"], h), pos)
        return h + L.mlp(bp["mlp"], _norm(cfg, bp["mlp_norm"], h),
                         cfg.mlp_kind)
    x = _scan_blocks(cfg, params["enc_blocks"], x, body)
    return _norm(cfg, params["enc_final_norm"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict) -> jax.Array:
    """Mean next-token cross-entropy (labels = tokens shifted by caller)."""
    logits = forward(cfg, params, batch["tokens"],
                     frames=batch.get("frames"), patches=batch.get("patches"))
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# decode (single-token serve step against caches)
# ---------------------------------------------------------------------------

def _decode_attn(p: Params, cfg_a: AttnConfig, x: jax.Array,
                 k_cache: jax.Array, v_cache: jax.Array,
                 cache_len: jax.Array, ring: bool = False,
                 update_cache: bool = True):
    """Plain einsum attention for one new token.

    Cache: (B, S, KV, D).  Seq dim is shardable (softmax over the sharded
    axis costs two scalar-sized all-reduces under GSPMD).  ``ring=True``
    treats the cache as a ring buffer of a local-attention window.
    ``update_cache=False`` reads a frozen cache (cross-attention over
    precomputed encoder KV) — writing would corrupt the memory.
    """
    B = x.shape[0]
    S = k_cache.shape[1]
    pos = cache_len[:, None].astype(jnp.int32)
    q, k, v = L._project_qkv(p, cfg_a, x, pos)
    if ring:
        slot = (cache_len % S).astype(jnp.int32)
    else:
        slot = cache_len.astype(jnp.int32)
    bidx = jnp.arange(B)
    if update_cache:
        k_cache = k_cache.at[bidx, slot].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, slot].set(v[:, 0].astype(v_cache.dtype))
    if ring:
        base = jnp.arange(S, dtype=jnp.int32)[None, :]
        n_wrap = (cache_len[:, None] + 1 - base + S - 1) // S
        kv_pos = base + (jnp.maximum(n_wrap, 0) - 0) * 0  # placeholder
        # true position of ring slot s: the latest write w <= cache_len with
        # w % S == s:  w = cache_len - ((cache_len - s) % S)
        kv_pos = cache_len[:, None] - ((cache_len[:, None] - base) % S)
        valid = kv_pos >= 0
    else:
        kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        valid = kv_pos <= cache_len[:, None]
    rep = cfg_a.n_heads // cfg_a.n_kv_heads
    qf = q[:, 0].astype(jnp.float32)                       # (B, H, D)
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bhd,bskd->bhsk" if False else "bhd,bskd->bhks",
                   qf, kf)
    # group heads: (B, KV, rep, S)
    s = s.reshape(B, cfg_a.n_kv_heads, 1, -1) if False else s
    scale = 1.0 / math.sqrt(cfg_a.head_dim)
    qg = qf.reshape(B, cfg_a.n_kv_heads, rep, cfg_a.head_dim) * scale
    s = jnp.einsum("bkrd,bskd->bkrs", qg, kf)              # (B,KV,rep,S)
    mask = valid[:, None, None, :]
    if cfg_a.window is not None:
        mask = mask & (kv_pos[:, None, None, :]
                       > cache_len[:, None, None, None] - cfg_a.window)
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrs,bskd->bkrd", w, v_cache.astype(jnp.float32))
    out = out.reshape(B, 1, cfg_a.n_heads * cfg_a.head_dim).astype(x.dtype)
    return out @ p["wo"], k_cache, v_cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Params:
    """Decode cache pytree for a (batch, max_seq) serving session."""
    if cfg.family in ("dense", "moe"):
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
        }
    if cfg.family == "ssm":
        single = ssd_init_cache(cfg.ssm_cfg, batch, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
            single)
    if cfg.family == "hybrid":
        n_super, rem = divmod(cfg.n_layers, len(cfg.pattern))
        n_attn = sum(1 for k in cfg.pattern if k == "attn") * n_super
        n_rec = (sum(1 for k in cfg.pattern if k == "rec") * n_super) + rem
        win = min(cfg.window, max_seq)
        rec = rglru_init_cache(cfg.rglru_cfg, batch, dtype)
        return {
            "attn_k": jnp.zeros((n_attn, batch, win, cfg.n_kv_heads,
                                 cfg.head_dim), dtype),
            "attn_v": jnp.zeros((n_attn, batch, win, cfg.n_kv_heads,
                                 cfg.head_dim), dtype),
            "rec": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_rec,) + x.shape), rec),
        }
    if cfg.family == "encdec":
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
            "cross_k": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                                  cfg.n_kv_heads, cfg.head_dim), dtype),
            "cross_v": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                                  cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    raise ValueError(cfg.family)


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                token: jax.Array, cache_len: jax.Array):
    """One serving step: (B, 1) token ids -> (B, 1, vocab) logits + new cache.

    ``cache_len``: (B,) int32 — current sequence length per batch row.
    """
    x = params["embed"][token].astype(jnp.bfloat16)        # (B, 1, d)

    if cfg.family in ("dense", "moe"):
        def body(carry, xs):
            h = carry
            bp, kc, vc = xs
            z = _norm(cfg, bp["attn_norm"], h)
            a, kc, vc = _decode_attn(bp["attn"], cfg.attn_cfg, z, kc, vc,
                                     cache_len)
            h = h + a
            z = _norm(cfg, bp["mlp_norm"], h)
            if cfg.family == "moe":
                h = h + moe_ffn(bp["moe"], z, cfg.top_k, cfg.mlp_kind,
                                capacity_factor=cfg.capacity_factor)
            else:
                h = h + L.mlp(bp["mlp"], z, cfg.mlp_kind)
            return h, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}
    elif cfg.family == "ssm":
        def body(carry, xs):
            h = carry
            bp, c = xs
            y, c2 = ssd_step(bp["ssd"], cfg.ssm_cfg,
                             c, _norm(cfg, bp["norm"], h)[:, 0])
            return h + y[:, None, :], c2

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(cfg, params, cache, x, cache_len)
    elif cfg.family == "encdec":
        x, new_cache = _encdec_decode(cfg, params, cache, x, cache_len)
    else:
        raise ValueError(cfg.family)

    x = _norm(cfg, params["final_norm"], x)
    return x @ params["lm_head"], new_cache


def _hybrid_decode(cfg: ModelConfig, params: Params, cache: Params,
                   x: jax.Array, cache_len: jax.Array):
    n_super, rem = divmod(cfg.n_layers, len(cfg.pattern))
    ai = ri = 0
    ks, vs = cache["attn_k"], cache["attn_v"]
    rec = cache["rec"]
    # hybrid super-blocks are unrolled for decode (pattern is heterogeneous;
    # 26 layers decode fine without scan)
    for s in range(n_super):
        bp = jax.tree.map(lambda t: t[s], params["blocks"])
        for i, kind in enumerate(cfg.pattern):
            sp = bp[f"sub{i}"]
            z = _norm(cfg, sp["temporal_norm"], x)
            if kind == "rec":
                rc = jax.tree.map(lambda t: t[ri], rec)
                y, rc2 = rglru_step(sp["rglru"], cfg.rglru_cfg, rc, z[:, 0])
                rec = jax.tree.map(lambda full, new: full.at[ri].set(new),
                                   rec, rc2)
                x = x + y[:, None, :]
                ri += 1
            else:
                a, k2, v2 = _decode_attn(sp["attn"], cfg.local_attn_cfg, z,
                                         ks[ai], vs[ai], cache_len, ring=True)
                ks = ks.at[ai].set(k2)
                vs = vs.at[ai].set(v2)
                x = x + a
                ai += 1
            x = x + L.mlp(sp["mlp"], _norm(cfg, sp["mlp_norm"], x),
                          cfg.mlp_kind)
    for t in range(rem):
        sp = params[f"tail{t}"]
        z = _norm(cfg, sp["temporal_norm"], x)
        rc = jax.tree.map(lambda a: a[ri], rec)
        y, rc2 = rglru_step(sp["rglru"], cfg.rglru_cfg, rc, z[:, 0])
        rec = jax.tree.map(lambda full, new: full.at[ri].set(new), rec, rc2)
        x = x + y[:, None, :]
        x = x + L.mlp(sp["mlp"], _norm(cfg, sp["mlp_norm"], x), cfg.mlp_kind)
        ri += 1
    return x, {"attn_k": ks, "attn_v": vs, "rec": rec}


def _encdec_decode(cfg: ModelConfig, params: Params, cache: Params,
                   x: jax.Array, cache_len: jax.Array):
    pos = cache_len[:, None]
    x = x + jnp.take_along_axis(
        params["dec_pos"][None].astype(x.dtype),
        pos[..., None].astype(jnp.int32) % params["dec_pos"].shape[0],
        axis=1)
    a_cfg = dataclasses.replace(cfg.attn_cfg, use_rope=False)
    enc_len = jnp.full_like(cache_len, cfg.enc_seq - 1)

    def body(carry, xs):
        h = carry
        bp, kc, vc, xk, xv = xs
        z = _norm(cfg, bp["attn_norm"], h)
        a, kc, vc = _decode_attn(bp["attn"], a_cfg, z, kc, vc, cache_len)
        h = h + a
        z = _norm(cfg, bp["cross_norm"], h)
        # cross attention: query the (precomputed, frozen) encoder KV
        c, _, _ = _decode_attn(bp["cross"],
                               dataclasses.replace(a_cfg, causal=False),
                               z, xk, xv, enc_len, update_cache=False)
        h = h + c
        return h + L.mlp(bp["mlp"], _norm(cfg, bp["mlp_norm"], h),
                         cfg.mlp_kind), (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    return x, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
               "cross_v": cache["cross_v"]}


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            frames: jax.Array | None = None,
            patches: jax.Array | None = None) -> jax.Array:
    """Prefill = full forward returning last-position logits.

    The vocab projection runs on the last position only — at 32k the full
    (B, S, vocab) logits would be the single largest live tensor.
    (Cache materialisation for the serving engine lives in repro.serving.)
    """
    hidden = forward(cfg, params, tokens, frames=frames, patches=patches,
                     return_hidden=True)
    return hidden[:, -1:] @ params["lm_head"]
