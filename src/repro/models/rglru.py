"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence (per channel):

    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    a_t = exp(-c · softplus(Λ) · r_t)       # c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

computed over a sequence with ``jax.lax.associative_scan`` on the linear
recurrence pairs (a, b) ∘ (a', b') = (a·a', a'·b + b'); decode is a single
fused step.  The full residual block is: conv1d → RG-LRU, gated (GeGLU-like)
as in the Griffin paper.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import (
    causal_conv1d,
    conv1d_step,
    dense_init,
    init_conv1d,
)

Params = dict[str, Any]
_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    lru_width: int
    conv_width: int = 4


def init_rglru(key: jax.Array, cfg: RGLRUConfig) -> Params:
    ks = jax.random.split(key, 6)
    w = cfg.lru_width
    # Λ init so that a^c in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2.0 * _C)))
    return {
        "in_x": dense_init(ks[1], cfg.d_model, w),      # branch through conv/LRU
        "in_gate": dense_init(ks[2], cfg.d_model, w),   # multiplicative gate
        "conv": init_conv1d(ks[3], w, cfg.conv_width),
        "wa": dense_init(ks[4], w, w),
        "wx": dense_init(ks[5], w, w),
        "ba": jnp.zeros((w,), jnp.float32),
        "bx": jnp.zeros((w,), jnp.float32),
        "lambda": lam,
        "out": dense_init(jax.random.fold_in(key, 7), w, cfg.d_model),
    }


def _gates(p: Params, x: jax.Array):
    """Returns (a, beta·i·x) for the linear recurrence, in f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wx"].astype(jnp.float32) + p["bx"])
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * xf


def rglru_forward(p: Params, cfg: RGLRUConfig, u: jax.Array) -> jax.Array:
    """Full-sequence RG-LRU block.  u: (B, S, d_model)."""
    x = u @ p["in_x"]
    gate = jax.nn.gelu(u @ p["in_gate"])
    x = causal_conv1d(p["conv"], x)
    a, b = _gates(p, x)                      # (B, S, w) each, f32

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(u.dtype) * gate
    return h @ p["out"]


def rglru_init_cache(cfg: RGLRUConfig, batch: int, dtype=jnp.bfloat16) -> Params:
    return {
        "state": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
    }


def rglru_step(p: Params, cfg: RGLRUConfig, cache: Params, u_t: jax.Array):
    """Single decode step.  u_t: (B, d_model) -> (y_t, new_cache)."""
    x = u_t @ p["in_x"]
    gate = jax.nn.gelu(u_t @ p["in_gate"])
    x, conv_win = conv1d_step(p["conv"], cache["conv"], x)
    a, b = _gates(p, x)
    h = a * cache["state"] + b
    y = h.astype(u_t.dtype) * gate
    return y @ p["out"], {"state": h, "conv": conv_win}
