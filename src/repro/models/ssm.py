"""Mamba-2 (SSD — state-space duality) block, chunked, pure JAX.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): the selective
SSM   h_t = exp(A·dt_t)·h_{t-1} + dt_t·B_t·x_t ;  y_t = C_t·h_t + D·x_t
computed chunk-parallel:

* intra-chunk: a (Q × Q) masked "attention" with decay kernel
  L[i,j] = exp(sum_{j<m<=i} a_m);
* inter-chunk: per-chunk final states combined with a sequential
  ``lax.scan`` over chunks (the chunk count is small: S / 256).

Decode is O(1): carry (B, H, P, N) SSM state + conv window.

Shapes follow the Mamba-2 reference: d_inner = expand · d_model heads of
size ``head_dim`` (P), shared-across-head B/C of state size N (n_groups=1).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import (
    causal_conv1d,
    conv1d_step,
    dense_init,
    init_conv1d,
)

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128          # N
    head_dim: int = 64          # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256            # Q

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim


def init_ssd(key: jax.Array, cfg: SSMConfig) -> Params:
    ks = jax.random.split(key, 5)
    d_in = cfg.d_inner
    # fused input projection: [z (gate), x, B, C, dt]
    proj_out = 2 * d_in + 2 * cfg.d_state + cfg.n_heads
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, proj_out),
        "conv": init_conv1d(ks[1], d_in + 2 * cfg.d_state, cfg.conv_width),
        "A_log": jnp.zeros((cfg.n_heads,), jnp.float32),  # A = -exp(A_log)
        "D": jnp.ones((cfg.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((cfg.n_heads,), jnp.float32),
        "out_proj": dense_init(ks[2], d_in, cfg.d_model),
    }


def _split_proj(cfg: SSMConfig, zxbcdt: jax.Array):
    d_in, N, H = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:2 * d_in + 2 * N]
    dt = zxbcdt[..., 2 * d_in + 2 * N:]
    return z, xBC, dt


def ssd_forward(p: Params, cfg: SSMConfig, u: jax.Array) -> jax.Array:
    """Full-sequence SSD.  u: (B, S, d_model) -> (B, S, d_model)."""
    Bsz, S, _ = u.shape
    H, P, N, Q = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.chunk
    zxbcdt = u @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = jax.nn.silu(causal_conv1d(p["conv"], xBC))
    x = xBC[..., :cfg.d_inner].reshape(Bsz, S, H, P)
    Bmat = xBC[..., cfg.d_inner:cfg.d_inner + N]           # (B, S, N)
    Cmat = xBC[..., cfg.d_inner + N:]                      # (B, S, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])                   # (B, S, H)
    A = -jnp.exp(p["A_log"])                               # (H,)
    a = dt * A                                             # (B, S, H) log-decay
    xdt = x.astype(jnp.float32) * dt[..., None]            # dt-scaled input

    n_chunks = -(-S // Q)
    pad = n_chunks * Q - S
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))

    def chunked(t):  # (B, S', ...) -> (B, n, Q, ...)
        return t.reshape((Bsz, n_chunks, Q) + t.shape[2:])

    xc = chunked(xdt)                                      # (B,n,Q,H,P)
    ac = chunked(a)                                        # (B,n,Q,H)
    Bc = chunked(Bmat.astype(jnp.float32))                 # (B,n,Q,N)
    Cc = chunked(Cmat.astype(jnp.float32))                 # (B,n,Q,N)

    cum = jnp.cumsum(ac, axis=2)                           # (B,n,Q,H)
    # intra-chunk decay kernel L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,n,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    # intra-chunk output: y = (C_i . B_j) * L[i,j] * xdt_j
    G = jnp.einsum("bniN,bnjN->bnij", Cc, Bc)              # (B,n,Q,Q)
    y_intra = jnp.einsum("bnij,bnijh,bnjhp->bnihp", G, L, xc)

    # per-chunk final states: sum_j exp(cum_Q - cum_j) * B_j x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,n,Q,H)
    states = jnp.einsum("bnjN,bnjh,bnjhp->bnhpN",
                        Bc, decay_to_end, xc)              # (B,n,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,n,H)

    def scan_body(h, inp):
        st, dec = inp                                      # (B,H,P,N),(B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h                                    # emit PREVIOUS state

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, h_prev = jax.lax.scan(
        scan_body,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)               # (B,n,H,P,N)

    # inter-chunk contribution: C_i · (decay_from_start_i · h_prev)
    decay_from_start = jnp.exp(cum)                        # (B,n,Q,H)
    y_inter = jnp.einsum("bniN,bnih,bnhpN->bnihp",
                         Cc, decay_from_start, h_prev)
    y = (y_intra + y_inter).reshape(Bsz, n_chunks * Q, H, P)[:, :S]
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, cfg.d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def ssd_init_cache(cfg: SSMConfig, batch: int, dtype=jnp.float32) -> Params:
    return {
        "state": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                           jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                           cfg.d_inner + 2 * cfg.d_state), dtype),
    }


def ssd_step(p: Params, cfg: SSMConfig, cache: Params, u_t: jax.Array):
    """Single decode step.  u_t: (B, d_model).  Returns (y_t, new_cache)."""
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.d_state
    zxbcdt = u_t @ p["in_proj"]
    z = zxbcdt[..., :cfg.d_inner]
    xBC = zxbcdt[..., cfg.d_inner:2 * cfg.d_inner + 2 * N]
    dt = zxbcdt[..., 2 * cfg.d_inner + 2 * N:]
    xBC, conv_win = conv1d_step(p["conv"], cache["conv"], xBC)
    xBC = jax.nn.silu(xBC)
    x = xBC[..., :cfg.d_inner].reshape(-1, H, P).astype(jnp.float32)
    Bmat = xBC[..., cfg.d_inner:cfg.d_inner + N].astype(jnp.float32)
    Cmat = xBC[..., cfg.d_inner + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                       # (B, H)
    state = (cache["state"] * decay[..., None, None]
             + jnp.einsum("bhp,bN->bhpN", x * dt[..., None], Bmat))
    y = jnp.einsum("bhpN,bN->bhp", state, Cmat) + x * p["D"][None, :, None]
    y = y.reshape(-1, cfg.d_inner).astype(u_t.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], {"state": state, "conv": conv_win}
