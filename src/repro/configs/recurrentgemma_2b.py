"""recurrentgemma-2b — RG-LRU + local attention hybrid [arXiv:2402.19427].

26L d_model=2560 10H (kv=1) d_ff=7680 vocab=256000; block pattern
(rec, rec, attn) — two RG-LRU blocks per local-attention block (1:2),
window 2048.  Runs long_500k: decode state is O(window + lru_width).
"""

from repro.configs.base import ArchSpec
from repro.models.model import ModelConfig

SPEC = ArchSpec(
    arch_id="recurrentgemma-2b",
    model=ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
        d_ff=7680, vocab=256000,
        pattern=("rec", "rec", "attn"), window=2048, lru_width=2560,
        mlp_kind="geglu", norm="rms", use_rope=True,
    ),
    smoke=ModelConfig(
        name="recurrentgemma-2b-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=512,
        pattern=("rec", "rec", "attn"), window=16, lru_width=64,
        mlp_kind="geglu", norm="rms", use_rope=True, attn_chunk=8,
    ),
)
