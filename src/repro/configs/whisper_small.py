"""whisper-small — enc-dec audio backbone [arXiv:2212.04356].

12L (enc+dec) d_model=768 12H (kv=12) d_ff=3072 vocab=51865.  The conv/mel
frontend is a STUB per the assignment: ``input_specs`` supplies precomputed
(B, 1500, 768) frame embeddings.  Whisper is pre-RoPE: learned absolute
positions, LayerNorm, GELU MLP, qkv bias.
"""

from repro.configs.base import ArchSpec
from repro.models.model import ModelConfig

SPEC = ArchSpec(
    arch_id="whisper-small",
    model=ModelConfig(
        name="whisper-small", family="encdec",
        n_layers=12, n_enc_layers=12,
        d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab=51865,
        mlp_kind="gelu", norm="ln", use_rope=False, qkv_bias=True,
        enc_seq=1500, frontend="audio",
        # Whisper's real decoder max is 448; the assigned synthetic 32k
        # prefill/decode cells need a position table covering seq_len.
        max_dec_seq=32_768,
    ),
    smoke=ModelConfig(
        name="whisper-small-smoke", family="encdec",
        n_layers=2, n_enc_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512,
        mlp_kind="gelu", norm="ln", use_rope=False, qkv_bias=True,
        enc_seq=16, frontend="audio", attn_chunk=8, max_dec_seq=64,
    ),
    skip_shapes=("long_500k",),
    skip_reasons=(("long_500k", "full quadratic attention (enc-dec); "
                   "no sub-quadratic path"),),
)
