"""nemotron-4-15b — dense, squared-ReLU MLP [arXiv:2402.16819].

32L d_model=6144 48H (kv=8) d_ff=24576 vocab=256000.  Nemotron-4 uses
squared-ReLU (non-gated) MLPs, RoPE, LayerNorm.
"""

from repro.configs.base import ArchSpec
from repro.models.model import ModelConfig

SPEC = ArchSpec(
    arch_id="nemotron-4-15b",
    model=ModelConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab=256000,
        mlp_kind="relu2", norm="ln", use_rope=True,
    ),
    smoke=ModelConfig(
        name="nemotron-4-15b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=512,
        mlp_kind="relu2", norm="ln", use_rope=True, attn_chunk=8,
    ),
    skip_shapes=("long_500k",),
    skip_reasons=(("long_500k", "full quadratic attention"),),
)
