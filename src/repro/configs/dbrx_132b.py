"""dbrx-132b — fine-grained MoE [hf:databricks/dbrx-base].

40L d_model=6144 48H (kv=8) d_ff=10752 vocab=100352, MoE 16 experts top-4.
GLU MLP experts, RoPE, GQA.
"""

from repro.configs.base import ArchSpec
from repro.models.model import ModelConfig

SPEC = ArchSpec(
    arch_id="dbrx-132b",
    model=ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=10752, vocab=100352,
        n_experts=16, top_k=4,
        mlp_kind="swiglu", norm="ln", use_rope=True,
    ),
    smoke=ModelConfig(
        name="dbrx-132b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        n_experts=4, top_k=2,
        mlp_kind="swiglu", norm="ln", use_rope=True, attn_chunk=8,
    ),
    skip_shapes=("long_500k",),
    skip_reasons=(("long_500k", "full quadratic attention"),),
)
