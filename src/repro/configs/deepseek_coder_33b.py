"""deepseek-coder-33b — dense llama-arch [arXiv:2401.14196].

62L d_model=7168 56H (kv=8) d_ff=19200 vocab=32256.
"""

from repro.configs.base import ArchSpec
from repro.models.model import ModelConfig

SPEC = ArchSpec(
    arch_id="deepseek-coder-33b",
    model=ModelConfig(
        name="deepseek-coder-33b", family="dense",
        n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=19200, vocab=32256,
        mlp_kind="swiglu", norm="rms", use_rope=True,
    ),
    smoke=ModelConfig(
        name="deepseek-coder-33b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab=512,
        mlp_kind="swiglu", norm="rms", use_rope=True, attn_chunk=8,
    ),
    skip_shapes=("long_500k",),
    skip_reasons=(("long_500k", "full quadratic attention"),),
)
