"""Architecture/shape registry plumbing shared by all assigned-arch configs.

Every architecture file defines a ``SPEC: ArchSpec`` with

* ``model`` — the exact published configuration (the dry-run target);
* ``smoke`` — a reduced same-family configuration for CPU tests;
* ``skip_shapes`` — cells that do not apply (with reasons), e.g.
  ``long_500k`` for pure quadratic-attention archs.

``input_specs`` builds ShapeDtypeStruct stand-ins for every model input of a
(cell × config) pair — weak-type-correct, shardable, zero allocation — which
is what the multi-pod dry-run lowers against.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, init_cache


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell (seq_len × global_batch × step kind)."""

    name: str           # train_4k | prefill_32k | decode_32k | long_500k
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


STANDARD_SHAPES: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    model: ModelConfig
    smoke: ModelConfig
    skip_shapes: tuple[str, ...] = ()
    skip_reasons: tuple[tuple[str, str], ...] = ()

    def shapes(self) -> list[ShapeCell]:
        return [s for s in STANDARD_SHAPES if s.name not in self.skip_shapes]

    def cell(self, name: str) -> ShapeCell:
        for s in STANDARD_SHAPES:
            if s.name == name:
                if name in self.skip_shapes:
                    reasons = dict(self.skip_reasons)
                    raise ValueError(
                        f"{self.arch_id} skips {name}: "
                        f"{reasons.get(name, 'inapplicable')}")
                return s
        raise KeyError(name)


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell,
                batch: int | None = None,
                seq: int | None = None) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the cell's step.

    train  -> {tokens, labels [, frames | patches]}
    prefill-> {tokens [, frames | patches]}
    decode -> {token, cache, cache_len}  (cache of seq_len entries)
    """
    B = batch if batch is not None else cell.global_batch
    S = seq if seq is not None else cell.seq_len
    out: dict[str, Any] = {}
    if cell.kind in ("train", "prefill"):
        S_tok = S
        if cfg.frontend == "vision":
            # "seq_len" counts the backbone sequence: patches + text tokens.
            S_tok = max(S - cfg.n_patches, 1)
            out["patches"] = _sds((B, cfg.n_patches, cfg.d_model),
                                  jnp.bfloat16)
        elif cfg.frontend == "audio":
            out["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        out["tokens"] = _sds((B, S_tok), jnp.int32)
        if cell.kind == "train":
            out["labels"] = _sds((B, S_tok), jnp.int32)
        return out
    if cell.kind == "decode":
        out["token"] = _sds((B, 1), jnp.int32)
        out["cache_len"] = _sds((B,), jnp.int32)
        out["cache"] = jax.eval_shape(lambda: init_cache(cfg, B, S))
        return out
    raise ValueError(cell.kind)


def params_spec(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct pytree of the model parameters (no allocation)."""
    from repro.models.model import init_params
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
