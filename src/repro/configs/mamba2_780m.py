"""mamba2-780m — SSD state-space model [arXiv:2405.21060].

48L d_model=1536 (attention-free) vocab=50280, ssm_state=128.
d_inner = 2·1536 = 3072, head_dim 64 → 48 SSD heads.  Runs long_500k:
decode is O(1) in sequence length (constant-size SSM state).
"""

from repro.configs.base import ArchSpec
from repro.models.model import ModelConfig

SPEC = ArchSpec(
    arch_id="mamba2-780m",
    model=ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, vocab=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2,
        norm="rms",
    ),
    smoke=ModelConfig(
        name="mamba2-780m-smoke", family="ssm",
        n_layers=2, d_model=64, vocab=512,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2,
        norm="rms",
    ),
)
