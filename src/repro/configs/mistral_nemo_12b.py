"""mistral-nemo-12b — dense 128k-context [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (kv=8) d_ff=14336 vocab=131072.  head_dim=128,
rope_theta=1e6 for the long context.
"""

from repro.configs.base import ArchSpec
from repro.models.model import ModelConfig

SPEC = ArchSpec(
    arch_id="mistral-nemo-12b",
    model=ModelConfig(
        name="mistral-nemo-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=131072,
        mlp_kind="swiglu", norm="rms", use_rope=True, rope_theta=1e6,
    ),
    smoke=ModelConfig(
        name="mistral-nemo-12b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        mlp_kind="swiglu", norm="rms", use_rope=True, attn_chunk=8,
    ),
    skip_shapes=("long_500k",),
    skip_reasons=(("long_500k", "full quadratic attention; 128k-trained but "
                   "O(S^2) — see DESIGN.md §Arch-applicability"),),
)
