"""llama3.2-3b — small llama3 [hf:meta-llama/Llama-3.2-3B].

28L d_model=3072 24H (kv=8) d_ff=8192 vocab=128256.
"""

from repro.configs.base import ArchSpec
from repro.models.model import ModelConfig

SPEC = ArchSpec(
    arch_id="llama3.2-3b",
    model=ModelConfig(
        name="llama3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=128256,
        mlp_kind="swiglu", norm="rms", use_rope=True, rope_theta=500000.0,
    ),
    smoke=ModelConfig(
        name="llama3.2-3b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        mlp_kind="swiglu", norm="rms", use_rope=True, attn_chunk=8,
    ),
    skip_shapes=("long_500k",),
    skip_reasons=(("long_500k", "full quadratic attention"),),
)
