"""internvl2-26b — VLM backbone (InternViT-6B + InternLM2-20B) [arXiv:2404.16821].

Assigned backbone: 48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92553.
The vision frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed (B, 256, 6144) patch embeddings prepended to the token stream.
InternLM2 is llama-style: RMSNorm, RoPE, SwiGLU, GQA.
"""

from repro.configs.base import ArchSpec
from repro.models.model import ModelConfig

SPEC = ArchSpec(
    arch_id="internvl2-26b",
    model=ModelConfig(
        name="internvl2-26b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab=92553,
        mlp_kind="swiglu", norm="rms", use_rope=True,
        frontend="vision", n_patches=256,
    ),
    smoke=ModelConfig(
        name="internvl2-26b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        mlp_kind="swiglu", norm="rms", use_rope=True,
        frontend="vision", n_patches=4, attn_chunk=8,
    ),
    skip_shapes=("long_500k",),
    skip_reasons=(("long_500k", "full quadratic attention"),),
)
