"""phi3.5-moe-42b-a6.6b — MoE [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2.
"""

from repro.configs.base import ArchSpec
from repro.models.model import ModelConfig

SPEC = ArchSpec(
    arch_id="phi3.5-moe-42b-a6.6b",
    model=ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=6400, vocab=32064,
        n_experts=16, top_k=2,
        mlp_kind="swiglu", norm="ln", use_rope=True,
    ),
    smoke=ModelConfig(
        name="phi3.5-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=512,
        n_experts=4, top_k=2,
        mlp_kind="swiglu", norm="ln", use_rope=True, attn_chunk=8,
    ),
    skip_shapes=("long_500k",),
    skip_reasons=(("long_500k", "full quadratic attention"),),
)
