"""Registry of the 10 assigned architectures (``--arch <id>``)."""

from __future__ import annotations

from repro.configs import (
    dbrx_132b,
    deepseek_coder_33b,
    internvl2_26b,
    llama32_3b,
    mamba2_780m,
    mistral_nemo_12b,
    nemotron4_15b,
    phi35_moe,
    recurrentgemma_2b,
    whisper_small,
)
from repro.configs.base import (
    ArchSpec,
    ShapeCell,
    STANDARD_SHAPES,
    input_specs,
    params_spec,
)

_ALL = (
    whisper_small.SPEC,
    internvl2_26b.SPEC,
    dbrx_132b.SPEC,
    phi35_moe.SPEC,
    deepseek_coder_33b.SPEC,
    llama32_3b.SPEC,
    nemotron4_15b.SPEC,
    mistral_nemo_12b.SPEC,
    mamba2_780m.SPEC,
    recurrentgemma_2b.SPEC,
)

ARCHS: dict[str, ArchSpec] = {s.arch_id: s for s in _ALL}

# Measured per-arch tuned profiles (EXPERIMENTS.md §Perf, fleet table).
# The choose_mesh_shape divisibility heuristic is the PRIOR; these are the
# POSTERIOR after lowering both and comparing roofline terms — archs whose
# Q-heads already divide 16 keep the (16,16) default (replicating grouped
# KV is cheap; widening the FSDP axis is not), only archs with the
# score-all-reduce pathology (q-heads ∤ 16) change mesh.  Q-chunked causal
# attention helps everywhere it applies.
TUNED_PROFILES: dict[str, dict] = {
    "deepseek-coder-33b": {"mesh": (32, 8)},
    "llama3.2-3b": {"mesh": (32, 8)},
    "whisper-small": {"mesh": (32, 8)},
    # q-heads divide 16 → keep default mesh; Q-chunking only:
    "dbrx-132b": {"mesh": (16, 16)},
    "phi3.5-moe-42b-a6.6b": {"mesh": (16, 16)},
    "internvl2-26b": {"mesh": (16, 16)},
    "mistral-nemo-12b": {"mesh": (16, 16)},
    "nemotron-4-15b": {"mesh": (16, 16)},
    "mamba2-780m": {"mesh": (16, 16)},
    "recurrentgemma-2b": {"mesh": (16, 16)},
}
for _p in TUNED_PROFILES.values():
    _p.setdefault("q_chunks", 4)
    _p.setdefault("attn_chunk", 1024)
    _p.setdefault("microbatches", 32)


def get(arch_id: str) -> ArchSpec:
    try:
        return ARCHS[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; "
                       f"known: {sorted(ARCHS)}") from None


def list_archs() -> list[str]:
    return list(ARCHS)


__all__ = [
    "ARCHS", "ArchSpec", "ShapeCell", "STANDARD_SHAPES",
    "get", "list_archs", "input_specs", "params_spec",
]
