"""Trace/timeline exporters: Chrome trace-event (Perfetto) JSON and CSV.

``chrome_trace`` converts a :class:`~repro.obs.tracer.Tracer` buffer into
the Chrome trace-event format that https://ui.perfetto.dev (and
``chrome://tracing``) loads directly:

* one **process track per array node** (``pid`` = node index, named via
  ``process_name`` metadata);
* one **thread lane per tenant** within its node (``tid`` assigned in
  first-appearance order, named via ``thread_name`` metadata) — a
  tenant's stage-in / compute / stage-out / drain spans render as
  ``ph:"X"`` complete slices on its lane;
* **instant markers** (``ph:"i"``) for arrivals, dispatch choices,
  policy decision audits, preemptions, migrations and completions.

Timestamps are simulation seconds scaled to microseconds (the format's
unit), so a 3 ms serve run renders as a 3000 µs timeline.  Everything is
emitted in deterministic order — two exports of the same run are
byte-identical (the obs bench gates this).

``timeline_csv`` flattens a registry's retained series points into a
``series,t,value`` CSV string for spreadsheet/pandas consumption.
"""

from __future__ import annotations

import json

from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import Tracer

_US = 1e6  # trace-event timestamps are microseconds


def chrome_trace(tracer: Tracer, fleet_name: str = "repro") -> dict:
    """Build a Chrome trace-event JSON object from the tracer buffer."""
    events: list[dict] = []
    # lane assignment: tid 0 is the node's control lane (markers with no
    # tenant); tenants get 1.. in first-appearance order per node
    lanes: dict[tuple[int, str], int] = {}
    next_lane: dict[int, int] = {}
    nodes_seen: list[int] = []

    def lane(node: int, tenant: str | None) -> int:
        if node not in next_lane:
            next_lane[node] = 1
            nodes_seen.append(node)
        if tenant is None:
            return 0
        key = (node, tenant)
        tid = lanes.get(key)
        if tid is None:
            tid = lanes[key] = next_lane[node]
            next_lane[node] = tid + 1
        return tid

    for kind, t0, t1, node, tenant, args in tracer.raw():
        ev: dict = {
            "name": kind if tenant is None else f"{kind}:{tenant}",
            "cat": kind,
            "pid": node,
            "tid": lane(node, tenant),
            "ts": t0 * _US,
        }
        if t1 > t0:
            ev["ph"] = "X"
            ev["dur"] = (t1 - t0) * _US
        else:
            ev["ph"] = "i"
            ev["s"] = "t"  # thread-scoped instant marker
        if args:
            ev["args"] = dict(args)
        events.append(ev)

    meta: list[dict] = []
    for node in sorted(nodes_seen):
        meta.append(
            {
                "name": "process_name",
                "ph": "M",
                "pid": node,
                "tid": 0,
                "args": {"name": f"array-node-{node}"},
            }
        )
        meta.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": node,
                "tid": 0,
                "args": {"name": "scheduler"},
            }
        )
    for (node, tenant), tid in sorted(lanes.items(), key=lambda kv: kv[1]):
        meta.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": node,
                "tid": tid,
                "args": {"name": tenant},
            }
        )
    return {
        "traceEvents": meta + events,
        "displayTimeUnit": "ms",
        "otherData": {
            "fleet": fleet_name,
            "events_recorded": tracer.n_recorded,
            "events_dropped": tracer.n_dropped,
        },
    }


def write_chrome_trace(path: str, tracer: Tracer, fleet_name: str = "repro") -> dict:
    """Write the Perfetto-loadable JSON to ``path``; returns the object."""
    blob = chrome_trace(tracer, fleet_name=fleet_name)
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)
        f.write("\n")
    return blob


def timeline_csv(registry: MetricsRegistry) -> str:
    """Flatten every retained series point to ``series,t,value`` rows."""
    lines = ["series,t,value"]
    for name, series in sorted(registry.series_map.items()):
        for t, v in series.samples:
            lines.append(f"{name},{t!r},{v!r}")
    return "\n".join(lines) + "\n"
