"""Time-series metrics registry: counters, gauges, histograms, series.

The tracer (`repro.obs.tracer`) answers "what happened when"; the registry
answers "how did X evolve" — per-node utilization, queue depth, ready-set
size, bus occupancy, per-tenant dominant share and slowdown, oracle-call
counters.  Four instrument types:

* :class:`Counter` — monotone accumulator (``inc``);
* :class:`Gauge` — last-write-wins scalar (``set``);
* :class:`Histogram` — streaming count/sum/min/max (``observe``) — enough
  for deterministic summaries without committing to bucket boundaries;
* :class:`Series` — a bounded ``(t, value)`` time series with
  deterministic stride-doubling decimation: once ``max_samples`` points
  are held, every other point is dropped and the acceptance stride
  doubles, so memory stays bounded and the retained points are a uniform
  subsample regardless of run length (no RNG — byte-stable exports).

A :class:`MetricsRegistry` memoizes instruments by name, serializes to a
picklable state dict, and merges pod states for
:class:`~repro.traffic.sharded.ShardedTrafficSimulator` folds: counters
and histograms add, gauges keep the maximum, series interleave by
timestamp and re-decimate to the cap.
"""

from __future__ import annotations


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    __slots__ = ("count", "total", "min", "max")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def as_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
        }


class Series:
    """Bounded time series under deterministic stride-doubling decimation.

    Every ``stride``-th offered sample is retained; when the retained set
    reaches ``max_samples`` the odd-index points are dropped and the
    stride doubles.  The retained points therefore always form a uniform
    ``stride``-spaced subsample of the offered stream — a windowed view
    whose resolution degrades gracefully as the run grows, with no
    randomness (exports stay byte-stable).
    """

    __slots__ = ("max_samples", "stride", "samples", "n_offered", "_sum")

    def __init__(self, max_samples: int = 4096):
        if max_samples < 2:
            raise ValueError(f"max_samples must be >= 2, got {max_samples}")
        self.max_samples = max_samples
        self.stride = 1
        self.samples: list[tuple[float, float]] = []
        self.n_offered = 0
        # running sum of the retained values: summaries are O(1), not a
        # rescan of up to max_samples points per digest
        self._sum = 0.0

    def sample(self, t: float, v: float) -> None:
        if self.n_offered % self.stride == 0:
            self.samples.append((t, v))
            self._sum += v
            if len(self.samples) >= self.max_samples:
                del self.samples[1::2]
                self.stride *= 2
                self._sum = sum(p[1] for p in self.samples)
        self.n_offered += 1

    @property
    def last(self) -> float | None:
        return self.samples[-1][1] if self.samples else None

    @property
    def mean(self) -> float:
        if not self.samples:
            return float("nan")
        return self._sum / len(self.samples)

    def summary(self) -> dict:
        return {
            "n": self.n_offered,
            "retained": len(self.samples),
            "stride": self.stride,
            "last": self.last,
            "mean": self.mean,
        }


class MetricsRegistry:
    """Name-keyed instrument store with mergeable, picklable state."""

    def __init__(self, max_samples: int = 4096):
        self.max_samples = max_samples
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.series_map: dict[str, Series] = {}

    # -- instrument accessors (memoized by name) ----------------------------
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        return h

    def series(self, name: str) -> Series:
        s = self.series_map.get(name)
        if s is None:
            s = self.series_map[name] = Series(self.max_samples)
        return s

    # -- summaries ----------------------------------------------------------
    def as_dict(self) -> dict:
        """Deterministic JSON-ready summary (sorted names; series are
        summarized, not dumped — use :func:`repro.obs.export.timeline_csv`
        for the raw points)."""
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {
                k: h.as_dict() for k, h in sorted(self.histograms.items())
            },
            "series": {
                k: s.summary() for k, s in sorted(self.series_map.items())
            },
        }

    # -- sharded folding ----------------------------------------------------
    def state(self) -> dict:
        """Full picklable snapshot (includes raw series points)."""
        return {
            "max_samples": self.max_samples,
            "counters": {k: c.value for k, c in self.counters.items()},
            "gauges": {k: g.value for k, g in self.gauges.items()},
            "histograms": {
                k: (h.count, h.total, h.min, h.max)
                for k, h in self.histograms.items()
            },
            "series": {
                k: (s.n_offered, list(s.samples))
                for k, s in self.series_map.items()
            },
        }

    def merge(self, state: dict) -> None:
        """Fold one pod's :meth:`state` into this registry.

        Counters and histograms add; gauges keep the max (pods report
        disjoint node gauges, so collisions only happen for fleet-level
        maxima); same-name series interleave by timestamp and re-decimate
        down to the cap.
        """
        for k, v in state["counters"].items():
            self.counter(k).inc(v)
        for k, v in state["gauges"].items():
            g = self.gauge(k)
            if v > g.value:
                g.value = v
        for k, (count, total, mn, mx) in state["histograms"].items():
            h = self.histogram(k)
            h.count += count
            h.total += total
            if mn < h.min:
                h.min = mn
            if mx > h.max:
                h.max = mx
        for k, (n_offered, samples) in state["series"].items():
            s = self.series(k)
            s.n_offered += n_offered
            pts = sorted(s.samples + [tuple(p) for p in samples])
            while len(pts) >= s.max_samples:
                del pts[1::2]
                s.stride *= 2
            s.samples = pts
            s._sum = sum(p[1] for p in pts)
