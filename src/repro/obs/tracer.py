"""Ring-buffered structured tracer — bounded-memory span/event capture.

The serving stack records almost nothing while it runs.  Only *rare*
instants are recorded live against the simulation clock (preemption,
migration, policy decision audits — events whose inputs exist only at
the moment they fire); everything else is **derived lazily** from
records the baseline was building anyway:

* per-job instants (dispatch choice, scheduler arrival, completion)
  convert from the simulator's job-record builders at read time
  (:meth:`attach_source`);
* per-layer *spans* (a tenant's stage-in / compute / stage-out / drain
  window on one array node) convert from the
  :class:`~repro.core.scheduler.TraceEvent` records the scheduler
  maintains on its ``keep_trace=True`` path (:meth:`attach`).

That split is what keeps the armed overhead inside the traffic bench's
≤5% gate (``benchmarks/obs_bench.py``): the hot event loop pays for a
couple of attribute stores per job, while the event stream materializes
only when a trace is actually read or exported — recording it a second
time at run time would double the cost for zero information.

* one ``collections.deque(maxlen=...)`` holds the newest ``max_events``
  live records — memory is bounded no matter how long the open-loop
  horizon runs, and an overflowing ring silently drops the *oldest*
  events (``n_dropped`` counts them, the summary renderer surfaces it);
* lazy sources are registered at end-of-run, after they stopped
  growing, and converted+cached on first read.  Runs with
  ``keep_trace=False`` (bounded-memory serving mode) therefore carry no
  spans — the span source was explicitly dropped;
* timestamps are simulation seconds (the scheduler's event clock), never
  wall time, so a trace is deterministic under a fixed seed and two runs
  export byte-identical Chrome/Perfetto JSON.

Records are ``(kind, t0, t1, node, tenant, args)`` tuples; ``args`` is a
(possibly empty) tuple of ``(key, value)`` pairs.  Spans have ``t1 > t0``;
instants carry ``t1 == t0``.  :class:`TraceEvent` is the friendly read
view (:meth:`Tracer.events`); exporters may read the raw tuples.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Iterator

# span kinds (t1 > t0)
STAGE_IN = "stage_in"
COMPUTE = "compute"
STAGE_OUT = "stage_out"
DRAIN = "drain"
# instant kinds (t1 == t0)
ARRIVE = "arrive"
DISPATCH = "dispatch"
DECISION = "decision"
PREEMPT = "preempt"
MIGRATE = "migrate"
COMPLETE = "complete"
# fault-injection instants (repro.chaos): fault applied, belief
# transition detected, lost job's first post-retry completion
FAULT = "fault"
DETECT = "detect"
RECOVER = "recover"
# overload-control instant (repro.overload): brownout stage entry/exit
BROWNOUT = "brownout"

SPAN_KINDS = (STAGE_IN, COMPUTE, STAGE_OUT, DRAIN)
INSTANT_KINDS = (ARRIVE, DISPATCH, DECISION, PREEMPT, MIGRATE, COMPLETE,
                 FAULT, DETECT, RECOVER, BROWNOUT)


def _ORDER(r: tuple) -> tuple:
    """Merge order for materialized streams: start, end, node, kind,
    tenant — a total order over well-formed records, so exports are
    deterministic regardless of which buffer (ring, attached trace,
    absorbed pod) a record came from."""
    return (r[1], r[2], r[3], r[0], r[4] or "")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """Read view of one raw tracer record."""

    kind: str
    t0: float
    t1: float
    node: int
    tenant: str | None
    args: tuple

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    @property
    def is_span(self) -> bool:
        return self.t1 > self.t0


def _trace_spans(node: int, events) -> list[tuple]:
    """Convert one scheduler ``trace`` list into raw span tuples.

    One per-layer scheduler record fans out to up to three spans: the
    stage-in window (assignment → compute start: bus wait + transfer),
    the compute segment, and the tail — stage-out for a completed
    segment, partial-sum drain for a preempted one.  Preempt *instants*
    are emitted live by the scheduler (they must survive
    ``keep_trace=False``), so they are deliberately not derived here.
    """
    out = []
    for e in events:
        tenant = e.tenant
        if e.compute_start > e.start:
            out.append((STAGE_IN, e.start, e.compute_start, node, tenant, ()))
        if e.compute_end > e.compute_start:
            args = (
                ("layer", e.layer_name),
                ("cols", e.partition.cols),
                ("col_start", e.partition.col_start),
                ("fraction", e.fraction),
                ("resumed", e.resumed),
            )
            if e.preempted:
                args += (("preempted", True),)
            out.append((COMPUTE, e.compute_start, e.compute_end, node, tenant, args))
        if e.end > e.compute_end:
            kind = DRAIN if e.preempted else STAGE_OUT
            out.append((kind, e.compute_end, e.end, node, tenant, ()))
    return out


class Tracer:
    """Bounded ring buffer of live records + lazily-converted span sources.

    ``max_events`` bounds the ring; the newest events win.  Record
    methods are plain tuple appends — callers guard with ``if tracer is
    not None`` so the disabled path costs nothing.
    """

    __slots__ = ("max_events", "_n", "_buf", "_attached")

    def __init__(self, max_events: int = 65536):
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        self.max_events = max_events
        self._n = 0  # live records ever offered to the ring
        self._buf: collections.deque = collections.deque(maxlen=max_events)
        # [zero-arg conversion callable, cached record list | None];
        # sources are attached at end-of-run, after they stopped
        # growing, so the conversion is cached on first read
        self._attached: list[list] = []

    # -- recording (hot path) ------------------------------------------------
    def span(
        self,
        kind: str,
        t0: float,
        t1: float,
        node: int = 0,
        tenant: str | None = None,
        args: tuple = (),
    ) -> None:
        self._n += 1
        self._buf.append((kind, t0, t1, node, tenant, args))

    def instant(
        self,
        kind: str,
        t: float,
        node: int = 0,
        tenant: str | None = None,
        args: tuple = (),
    ) -> None:
        self._n += 1
        self._buf.append((kind, t, t, node, tenant, args))

    def attach(self, node: int, trace: list) -> None:
        """Register one scheduler's per-layer ``trace`` as a span source.

        Zero-copy: the list is held by reference and converted to span
        tuples on first read.  Call once per node at end of run (the
        simulator does this automatically when ``keep_trace`` is on).
        """
        self._attached.append([lambda: _trace_spans(node, trace), None])

    def attach_source(self, convert) -> None:
        """Register any zero-argument callable returning a list of raw
        record tuples as a lazy source, evaluated and cached on first
        read.  The simulator uses this to derive per-job instants from
        the job records it builds anyway — nothing is recorded on the
        serving path."""
        self._attached.append([convert, None])

    # -- reading -------------------------------------------------------------
    def _attached_records(self) -> list[tuple]:
        out: list[tuple] = []
        for entry in self._attached:
            cached = entry[1]
            if cached is None:
                cached = entry[1] = entry[0]()
            out.extend(cached)
        return out

    def __len__(self) -> int:
        return len(self._buf) + len(self._attached_records())

    @property
    def n_recorded(self) -> int:
        """Total events captured: live ring records (including any the
        ring has since dropped) plus spans derived from attached traces."""
        return self._n + len(self._attached_records())

    @property
    def n_dropped(self) -> int:
        """Live events lost to ring overflow (oldest-first).  Attached
        spans never drop — they live in the scheduler's own trace."""
        return self._n - len(self._buf)

    def raw(self) -> list[tuple]:
        """The materialized record stream (ring + derived spans), merged
        into deterministic ``(t0, t1, node, kind, tenant)`` order."""
        return sorted(list(self._buf) + self._attached_records(), key=_ORDER)

    def events(self) -> Iterator[TraceEvent]:
        """The materialized records as :class:`TraceEvent` views."""
        for kind, t0, t1, node, tenant, args in self.raw():
            yield TraceEvent(kind, t0, t1, node, tenant, args)

    def counts_by_kind(self) -> dict[str, int]:
        """Histogram by kind over the *retained* stream (sorted keys):
        ring survivors plus derived spans; ``n_dropped`` says how many
        live records overflowed out before counting."""
        counts: dict[str, int] = {}
        for r in self._buf:
            k = r[0]
            counts[k] = counts.get(k, 0) + 1
        for r in self._attached_records():
            k = r[0]
            counts[k] = counts.get(k, 0) + 1
        return dict(sorted(counts.items()))

    # -- merging (sharded pods) ----------------------------------------------
    def state(self) -> dict:
        """Picklable snapshot for cross-process folding: the materialized
        stream bounded to the newest ``max_events`` records."""
        return {
            "max_events": self.max_events,
            "n_recorded": self.n_recorded,
            "records": self.raw()[-self.max_events :],
        }

    def absorb(self, state: dict) -> None:
        """Fold one pod's :meth:`state` into this tracer.  Records are
        interleaved by start time with a stable tie-break so the merged
        stream is deterministic regardless of pod arrival order; overflow
        drops the oldest merged records, same as live recording."""
        self._n += state["n_recorded"]
        merged = sorted(
            list(self._buf) + [tuple(r) for r in state["records"]],
            key=_ORDER,
        )
        self._buf.clear()
        self._buf.extend(merged[-self.max_events :])
