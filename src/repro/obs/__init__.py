"""`repro.obs` — zero-cost-when-disabled observability for the serving stack.

    from repro.api import Session
    from repro.obs import Observability

    obs = Observability()
    res = Session(policy="equal").serve("poisson", rate=500.0, horizon=0.1,
                                        pool="light", slo_s=0.01, obs=obs)
    print(res.timeline.render())             # terminal summary
    res.timeline.write_chrome_trace("t.json")  # load in ui.perfetto.dev

One :class:`Observability` object bundles the two collection surfaces:

* ``obs.tracer`` — ring-buffered span/event capture
  (`repro.obs.tracer`): scheduler lifecycle spans, preemption/migration
  markers, policy decision audits;
* ``obs.registry`` — the time-series metrics registry
  (`repro.obs.registry`): per-node/per-tenant counters, gauges and
  bounded series.

Every instrumentation point in the stack is guarded by ``if obs is not
None`` (or the per-surface ``tracer``/``registry`` handles), so the
disabled path adds no work and every committed ``BENCH_*.json`` stays
byte-identical — enforced by ``benchmarks/obs_bench.py``, which also
gates the *armed* overhead at ≤5% wall on the traffic bench.

Observation is pure: arming obs never changes event order, RNG
consumption, or any serialized result byte.
"""

from __future__ import annotations

from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Series,
)
from repro.obs.tracer import TraceEvent, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Observability",
    "Series",
    "Timeline",
    "TraceEvent",
    "Tracer",
    "resolve_obs",
]


class Observability:
    """Bundle of tracer + registry with arm/disarm flags.

    ``tracer=False`` / ``metrics=False`` disarm one surface (its handle is
    None and instrumentation points skip it).  ``audit=True`` additionally
    records a per-scheduling-round policy decision audit (ready
    candidates, offered widths, grants, declines, oracle probes) — by far
    the chattiest and most expensive event class, priced well outside the
    default overhead budget (``benchmarks/obs_bench.py`` records its cost
    as ``overhead_ratio_audit``), so it is opt-in for targeted policy
    debugging rather than part of the default bundle.

    ``sample_every`` strides the simulator's arrival-synchronous
    time-series pulse (per-node utilization / queue depth / ready-set /
    bus series): every ``sample_every``-th arrival is sampled.  The
    default keeps the armed overhead inside the ≤5% traffic-bench gate;
    set ``1`` for full per-arrival resolution on short runs (the
    :class:`~repro.obs.registry.Series` stride-doubling cap still bounds
    memory either way).
    """

    def __init__(
        self,
        tracer: bool = True,
        metrics: bool = True,
        audit: bool = False,
        max_events: int = 65536,
        max_samples: int = 4096,
        sample_every: int = 8,
    ):
        if sample_every < 1:
            raise ValueError(f"sample_every must be >= 1, got {sample_every}")
        self.tracer: Tracer | None = Tracer(max_events) if tracer else None
        self.registry: MetricsRegistry | None = (
            MetricsRegistry(max_samples) if metrics else None
        )
        self.audit = bool(audit) and tracer
        self.sample_every = sample_every

    # -- sharded folding -----------------------------------------------------
    def state(self) -> dict:
        """Picklable snapshot for cross-process pod folds."""
        return {
            "tracer": self.tracer.state() if self.tracer else None,
            "registry": self.registry.state() if self.registry else None,
        }

    def absorb(self, state: dict) -> None:
        """Merge one pod's :meth:`state` into this bundle."""
        if self.tracer is not None and state.get("tracer") is not None:
            self.tracer.absorb(state["tracer"])
        if self.registry is not None and state.get("registry") is not None:
            self.registry.merge(state["registry"])


def resolve_obs(obs) -> Observability | None:
    """Normalize the ``obs=`` front-door argument.

    ``None``/``False`` → disabled; ``True`` → a fresh default
    :class:`Observability`; an :class:`Observability` instance passes
    through (the caller reads the collected state off it afterwards).
    """
    if obs is None or obs is False:
        return None
    if obs is True:
        return Observability()
    if isinstance(obs, Observability):
        return obs
    raise ValueError(
        f"obs= takes None/bool or an Observability, got {type(obs).__name__}"
    )


class Timeline:
    """The ``ServeResult.timeline`` view of one run's collected obs state.

    Thin handle over the run's :class:`Observability`: summaries for the
    gated ``as_dict`` key, plus exporter shortcuts.
    """

    def __init__(self, obs: Observability):
        self._obs = obs

    @property
    def tracer(self) -> Tracer | None:
        return self._obs.tracer

    @property
    def registry(self) -> MetricsRegistry | None:
        return self._obs.registry

    def summary(self) -> dict:
        """Deterministic JSON-ready digest (the gated ``obs`` record key)."""
        out: dict = {}
        if self._obs.tracer is not None:
            out["events_recorded"] = self._obs.tracer.n_recorded
            out["events_dropped"] = self._obs.tracer.n_dropped
            out["events_by_kind"] = self._obs.tracer.counts_by_kind()
        if self._obs.registry is not None:
            out["metrics"] = self._obs.registry.as_dict()
        return out

    def render(self, title: str = "obs summary") -> str:
        from repro.obs.render import render_summary

        return render_summary(self._obs.registry, self._obs.tracer, title=title)

    def chrome_trace(self) -> dict:
        from repro.obs.export import chrome_trace

        if self._obs.tracer is None:
            raise ValueError("tracer was disarmed for this run")
        return chrome_trace(self._obs.tracer)

    def write_chrome_trace(self, path: str) -> dict:
        from repro.obs.export import write_chrome_trace

        if self._obs.tracer is None:
            raise ValueError("tracer was disarmed for this run")
        return write_chrome_trace(path, self._obs.tracer)

    def timeline_csv(self) -> str:
        from repro.obs.export import timeline_csv

        if self._obs.registry is None:
            raise ValueError("metrics registry was disarmed for this run")
        return timeline_csv(self._obs.registry)
