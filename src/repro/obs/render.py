"""Terminal rendering of registry/tracer summaries + host-cache snapshots.

``render_summary`` prints the aligned counter/gauge/histogram/series table
the benchmarks show after each run — one canonical renderer instead of the
per-bench ad-hoc cache printing it replaced.

``snapshot_host_caches`` folds the process-global memo/cache statistics of
the costing and kernel paths into registry counters:

* ``oracle.layer_cost.{hits,misses}`` — the simulator backend's per-layer
  cost LRU (`repro.sim.systolic.layer_cost`);
* ``oracle.ws_cost.{hits,misses}`` — the dataflow cost memo
  (`repro.core.dataflow.ws_cost_cache_stats`);
* ``kernel.autotune.{hits,misses}`` — the fused-GEMM block autotuner LRU
  (`repro.kernels.ops.autotune_blocks`), skipped silently when the jax
  kernel stack is unavailable.

These are *cumulative process-wide* numbers (lru_cache has no reset), so
snapshot deltas across calls are the per-run view.
"""

from __future__ import annotations

from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import Tracer


def snapshot_host_caches(
    registry: MetricsRegistry | None = None,
) -> MetricsRegistry:
    """Fold the host-side cost/kernel cache stats into ``registry`` (a new
    one when None) as counters; returns the registry."""
    reg = registry if registry is not None else MetricsRegistry()
    try:
        from repro.core.dataflow import ws_cost_cache_stats

        ws = ws_cost_cache_stats()
        reg.counter("oracle.ws_cost.hits").value = ws["hits"]
        reg.counter("oracle.ws_cost.misses").value = ws["misses"]
    except ImportError:  # pragma: no cover - core is always present
        pass
    try:
        from repro.sim.systolic import layer_cost

        info = layer_cost.cache_info()
        reg.counter("oracle.layer_cost.hits").value = info.hits
        reg.counter("oracle.layer_cost.misses").value = info.misses
    except ImportError:  # pragma: no cover - sim is always present
        pass
    try:
        from repro.kernels.ops import autotune_blocks

        info = autotune_blocks.cache_info()
        reg.counter("kernel.autotune.hits").value = info.hits
        reg.counter("kernel.autotune.misses").value = info.misses
    except Exception:
        # kernels need jax at import time; a slim environment still gets
        # the oracle counters above
        pass
    return reg


def _hit_rate(hits: int, misses: int) -> str:
    total = hits + misses
    if not total:
        return "-"
    return f"{100.0 * hits / total:.1f}%"


def render_summary(
    registry: MetricsRegistry | None = None,
    tracer: Tracer | None = None,
    title: str = "obs summary",
) -> str:
    """Aligned terminal table of one registry (+ optional tracer) state."""
    lines = [f"# {title}"]
    if registry is not None:
        counters = dict(sorted(registry.counters.items()))
        # pair up ".hits"/".misses" counters into one hit-rate row
        done = set()
        for name in counters:
            if name.endswith(".hits"):
                base = name[: -len(".hits")]
                m = f"{base}.misses"
                if m in counters:
                    h, mi = counters[name].value, counters[m].value
                    lines.append(
                        f"{base:<40}{h + mi:>12} calls  "
                        f"{_hit_rate(h, mi):>7} hit"
                    )
                    done.update((name, m))
        for name, c in counters.items():
            if name not in done:
                lines.append(f"{name:<40}{c.value:>12}")
        for name, g in sorted(registry.gauges.items()):
            lines.append(f"{name:<40}{g.value:>12.6g}")
        for name, h in sorted(registry.histograms.items()):
            lines.append(
                f"{name:<40}{h.count:>12} obs    mean {h.mean:.6g}  "
                f"max {h.max if h.count else float('nan'):.6g}"
            )
        for name, s in sorted(registry.series_map.items()):
            lines.append(
                f"{name:<40}{s.n_offered:>12} pts    mean {s.mean:.6g}  "
                f"last {s.last if s.last is not None else float('nan'):.6g}"
            )
    if tracer is not None:
        for kind, n in tracer.counts_by_kind().items():
            lines.append(f"trace.{kind:<34}{n:>12}")
        if tracer.n_dropped:
            lines.append(
                f"{'trace.dropped(ring overflow)':<40}"
                f"{tracer.n_dropped:>12}"
            )
    if len(lines) == 1:
        lines.append("(empty)")
    return "\n".join(lines)
