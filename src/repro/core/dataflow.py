"""Partitioned weight-stationary dataflow model (paper §3.4, Fig. 5 lines 28–42).

The three phases of the paper's loop-nest — ① *load* (weights → PE load
registers), ② *feed* (IFMap streamed left-to-right), ③ *drain* (OFMap columns
→ drain buffer) — are modelled analytically per (GEMM × partition) pair.

GEMM convention (see ``repro.core.dnng``):

    stationary:  K × N     (K on PE rows, N on PE columns — N is partitioned)
    streamed:    T × K     (T im2col rows fed through the array)
    output:      T × N

A partition of ``R`` rows × ``C`` columns executes the GEMM in
``ceil(K/R) · ceil(N/C)`` *folds*; each fold costs the classic Scale-Sim
weight-stationary cycle count ``2R + C + T - 2``:

    R      cycles  — ① load R weight rows (down the same vertical wires)
    T      cycles  — ② feed T streamed rows
    R+C-2  cycles  — ② / ③ pipeline fill + drain skew

Modelling assumption inherited from the paper (documented in DESIGN.md §2):
partitions behave as independent sub-accelerators — the paper partitions all
three SRAM buffers alongside the PE columns, so per-partition feed bandwidth
is private; `Mul_En` only provides logical isolation for pass-through data.
A tenant whose partition starts at column ``c0`` pays ``c0`` extra fill
cycles once per fold (data crosses foreign partitions tri-stated).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Sequence

from repro.core.dnng import LayerShape
from repro.core.partition import Partition

if TYPE_CHECKING:  # numpy is imported lazily: only the batch oracle needs
    import numpy as np  # it, and `import repro.core` must stay lightweight


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class GEMM:
    """A (T × K) · (K × N) matmul in the WS orientation."""

    T: int  # streamed rows (N·P·Q of the layer)
    K: int  # reduction (C·R·S)
    N: int  # output channels (M) — the partitioned dimension

    @staticmethod
    def of_layer(layer: LayerShape) -> "GEMM":
        return GEMM(T=layer.gemm_m, K=layer.gemm_k, N=layer.gemm_n)

    @property
    def macs(self) -> int:
        return self.T * self.K * self.N


@dataclasses.dataclass(frozen=True)
class DataflowCost:
    """Cycle & access-count breakdown of one GEMM on one partition."""

    cycles: int
    folds_k: int
    folds_n: int
    macs: int
    # SRAM access counts (elements, not bytes)
    load_buf_reads: int    # ① weights read from load buffer
    feed_buf_reads: int    # ② ifmap rows read from feed buffer (re-read per N-fold)
    drain_buf_writes: int  # ③ psums/ofmap written to drain buffer (per K-fold)
    # DRAM traffic (elements)
    dram_reads: int
    dram_writes: int
    # PE-cycle occupancy of the partition (for leakage/idle accounting)
    pe_cycles: int         # cycles × partition PEs
    active_pe_cycles: int  # cycles in which a PE performs a useful MAC
    # Mul_En energy accounting (paper Fig. 7): with the proposed PE the
    # multiplier fires only while the partition's own feed data streams
    # through (T rows cross every PE per fold); during the ① load phase the
    # multiplier is tri-stated and only the load-register latch toggles.
    feed_pe_cycles: int    # fk·fn·T·R·C — multiplier-enabled PE-cycles
    load_pe_cycles: int    # fk·fn·R·R·C — load-phase latch-only PE-cycles


@functools.lru_cache(maxsize=1 << 16)
def ws_cost(gemm: GEMM, part: Partition) -> DataflowCost:
    """Analytic partitioned-WS cost of ``gemm`` on ``part`` (Fig. 5 loop-nest).

    Memoized: both arguments are frozen (hashable) dataclasses and the
    result is pure, while the dynamic scheduler re-derives the SAME
    (layer, partition) costs on every arrival/completion rebalance — under
    open-loop traffic that is the host hot path.  The LRU turns those
    re-derivations into dict hits; :func:`ws_cost_cache_stats` exposes the
    hit rate and :func:`ws_cost_cache_clear` resets it (tests, memory).
    """
    R, C = part.rows, part.cols
    fk = _ceil_div(gemm.K, R)
    fn = _ceil_div(gemm.N, C)
    # per-fold cycles: load R + feed T + pipeline skew (R + C - 2),
    # plus the pass-through offset for partitions not starting at column 0.
    per_fold = 2 * R + C + gemm.T - 2 + part.col_start
    cycles = fk * fn * per_fold
    macs = gemm.macs
    # ① each weight is loaded exactly once over all folds
    load_reads = gemm.K * gemm.N
    # ② the T×K ifmap is re-streamed for every N-fold
    feed_reads = gemm.T * gemm.K * fn
    # ③ each K-fold drains a T×N partial-sum tile (accumulated in drain buffer)
    drain_writes = gemm.T * gemm.N * fk
    dram_reads = gemm.K * gemm.N + gemm.T * gemm.K   # weights + ifmap once
    dram_writes = gemm.T * gemm.N                    # ofmap once
    return DataflowCost(
        cycles=cycles,
        folds_k=fk,
        folds_n=fn,
        macs=macs,
        load_buf_reads=load_reads,
        feed_buf_reads=feed_reads,
        drain_buf_writes=drain_writes,
        dram_reads=dram_reads,
        dram_writes=dram_writes,
        pe_cycles=cycles * part.n_pes,
        active_pe_cycles=macs,  # one MAC ≡ one active PE-cycle
        feed_pe_cycles=fk * fn * gemm.T * part.n_pes,
        load_pe_cycles=fk * fn * R * part.n_pes,
    )


def ws_cost_cache_stats() -> dict:
    """``ws_cost`` LRU counters: hits / misses / currsize / maxsize."""
    info = ws_cost.cache_info()
    return {"hits": info.hits, "misses": info.misses,
            "currsize": info.currsize, "maxsize": info.maxsize}


def ws_cost_cache_clear() -> None:
    ws_cost.cache_clear()


# ---------------------------------------------------------------------------
# Batch cost oracle — one NumPy pass over pre-packed shape arrays.
#
# A rebalance round prices many (layer, width) candidates at once (policy
# probes, preempt-hook pressure checks); the scalar :func:`ws_cost` walks
# them one Python call at a time.  :func:`ws_cost_batch` evaluates n pairs
# elementwise over int64 arrays with the *same* integer arithmetic, so every
# field is bit-identical to the scalar path (property-tested in
# tests/test_batch_oracle.py).  All counts stay well inside int64: the
# largest product formed is ``cycles × n_pes`` ≲ 1e17 for the paper's
# Table-1 shapes on a 128×128 array.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchCost:
    """Structure-of-arrays :class:`DataflowCost` for n (GEMM, partition)
    pairs — the batch cost oracle's result table (all fields int64)."""

    cycles: "np.ndarray"
    folds_k: "np.ndarray"
    folds_n: "np.ndarray"
    macs: "np.ndarray"
    load_buf_reads: "np.ndarray"
    feed_buf_reads: "np.ndarray"
    drain_buf_writes: "np.ndarray"
    dram_reads: "np.ndarray"
    dram_writes: "np.ndarray"
    pe_cycles: "np.ndarray"
    active_pe_cycles: "np.ndarray"
    feed_pe_cycles: "np.ndarray"
    load_pe_cycles: "np.ndarray"
    # extra DRAM element-transfer slots lost to a reduced bandwidth share
    # (float64; all-zero at share 1.0).  None unless the batch was priced
    # with ``bw_shares=`` — the int64 DataflowCost columns above are
    # computed identically either way.
    dram_stall_elems: "np.ndarray | None" = None

    def __len__(self) -> int:
        return len(self.cycles)

    def row(self, i: int) -> DataflowCost:
        """The i-th pair as a scalar :class:`DataflowCost` (Python ints)."""
        return DataflowCost(
            *(int(getattr(self, f.name)[i])
              for f in dataclasses.fields(DataflowCost)))


def pack_gemms(gemms: Sequence[GEMM]) -> "np.ndarray":
    """(n, 3) int64 array of (T, K, N) — the pre-packed shape side."""
    import numpy as np
    return np.array([(g.T, g.K, g.N) for g in gemms],
                    dtype=np.int64).reshape(-1, 3)


def pack_partitions(parts: Sequence[Partition]) -> "np.ndarray":
    """(n, 3) int64 array of (rows, col_start, cols)."""
    import numpy as np
    return np.array([(p.rows, p.col_start, p.cols) for p in parts],
                    dtype=np.int64).reshape(-1, 3)


_BATCH_STATS = {"calls": 0, "pairs": 0}


def ws_cost_batch(gemms: "Sequence[GEMM] | np.ndarray",
                  parts: "Sequence[Partition] | np.ndarray",
                  bw_shares: "Sequence[float] | np.ndarray | None" = None
                  ) -> BatchCost:
    """Vectorized :func:`ws_cost` over paired candidates.

    ``gemms[i]`` is priced on ``parts[i]`` (build the cross product on the
    caller's side when needed).  Accepts pre-packed ``(n, 3)`` int64 arrays
    (:func:`pack_gemms` / :func:`pack_partitions`) or the dataclass
    sequences directly.  Every output field equals the scalar
    :func:`ws_cost` exactly — same integer arithmetic, elementwise.

    ``bw_shares`` (optional) is the memory-bandwidth share in ``(0, 1]``
    each pair's tenant holds (per-tenant caps, see
    :meth:`repro.core.scheduler.MemorySystem.set_caps`): it fills the
    ``dram_stall_elems`` column with the extra DRAM element-slots the
    throttled tenant's traffic occupies, ``(dram_reads + dram_writes) ×
    (1/share − 1)`` — exactly zero at share 1.0.  The int64 columns never
    depend on it, so a ``bw_shares`` of all-ones is bit-identical to
    omitting it.
    """
    import numpy as np
    gm = gemms if isinstance(gemms, np.ndarray) else pack_gemms(gemms)
    pm = parts if isinstance(parts, np.ndarray) else pack_partitions(parts)
    if gm.shape != pm.shape:
        raise ValueError(f"paired batch needs matching shapes, got "
                         f"{gm.shape} vs {pm.shape}")
    _BATCH_STATS["calls"] += 1
    _BATCH_STATS["pairs"] += len(gm)
    T, K, N = gm[:, 0], gm[:, 1], gm[:, 2]
    R, c0, C = pm[:, 0], pm[:, 1], pm[:, 2]
    fk = (K + R - 1) // R
    fn = (N + C - 1) // C
    folds = fk * fn
    per_fold = 2 * R + C + T - 2 + c0
    cycles = folds * per_fold
    n_pes = R * C
    macs = T * K * N
    stall = None
    if bw_shares is not None:
        bw = np.asarray(bw_shares, dtype=np.float64).reshape(-1)
        if len(bw) != len(gm):
            raise ValueError(f"bw_shares needs one share per pair, got "
                             f"{len(bw)} for {len(gm)} pairs")
        if np.any(bw <= 0.0) or np.any(bw > 1.0):
            raise ValueError("bw_shares must lie in (0, 1]")
        stall = (K * N + T * K + T * N) * (1.0 / bw - 1.0)
    return BatchCost(
        cycles=cycles,
        folds_k=fk,
        folds_n=fn,
        macs=macs,
        load_buf_reads=K * N,
        feed_buf_reads=T * K * fn,
        drain_buf_writes=T * N * fk,
        dram_reads=K * N + T * K,
        dram_writes=T * N,
        pe_cycles=cycles * n_pes,
        active_pe_cycles=macs,
        feed_pe_cycles=folds * T * n_pes,
        load_pe_cycles=folds * R * n_pes,
        dram_stall_elems=stall,
    )


def ws_cost_batch_stats() -> dict:
    """Batch-oracle counters: calls made / pairs evaluated."""
    return dict(_BATCH_STATS)


def ws_cost_batch_stats_clear() -> None:
    _BATCH_STATS["calls"] = 0
    _BATCH_STATS["pairs"] = 0


def utilization(gemm: GEMM, part: Partition) -> float:
    """Fraction of PE-cycles doing useful MACs (the paper's headline metric)."""
    c = ws_cost(gemm, part)
    return c.active_pe_cycles / c.pe_cycles if c.pe_cycles else 0.0


# ---------------------------------------------------------------------------
# Loop-nest description (Fig. 6(c)) — machine-checkable form of the paper's
# Parallel_for / Temporal_for schedule.  Used by tests to assert that the
# Pallas kernel's grid enumerates exactly these tiles, and by DESIGN.md docs.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LoopNest:
    """One partition's 3-phase schedule as (kind, axis, extent) triples."""

    partition: Partition
    load: tuple[tuple[str, str, int], ...]
    feed: tuple[tuple[str, str, int], ...]
    drain: tuple[tuple[str, str, int], ...]


def partitioned_ws_loopnest(gemm: GEMM, part: Partition) -> LoopNest:
    """Fig. 5 lines 28–42 for a single partition."""
    R, C = part.rows, part.cols
    return LoopNest(
        partition=part,
        # step ① — two Parallel_for: weights spatially mapped to rows & cols
        load=(("parallel", "row", min(R, gemm.K)),
              ("parallel", "col", min(C, gemm.N))),
        # step ② — feed: spatial rows, temporal columns (stream T values)
        feed=(("parallel", "row", min(R, gemm.K)),
              ("temporal", "col", gemm.T)),
        # step ③ — drain: spatial cols, temporal rows
        drain=(("parallel", "col", min(C, gemm.N)),
               ("temporal", "row", gemm.T)),
    )
