"""Event-driven multi-tenant scheduler — Algorithm 1's runtime dynamics (§3.3).

Drives :mod:`repro.core.partition` over time:

* the **first** layer of the **first** DNNG runs on the whole array
  (Fig. 5 lines 5–6);
* when several DNNGs are waiting, the array is split by
  :func:`partition_calculation` and ready layers are bound heaviest-first by
  :func:`task_assignment` (lines 8–12);
* a tenant executes its layers sequentially (DAG order); when a layer
  finishes, its partition is released, adjacent free slices **merge**, and
  assignment re-runs — so surviving tenants inherit wider partitions exactly
  as in Fig. 9(c,d) (128×16 → 128×32 → 128×64 → 128×128).

Layer lifecycle (matching Scale-Sim's non-overlapped DRAM model, which the
paper's toolchain uses):

    assign → [bus] stage-in (weights+IFMap DRAM→SRAM) → compute → [bus]
    stage-out (OFMap SRAM→DRAM) → release partition

The DRAM bus is a shared FCFS resource; *this* is one of the two slack pools
multi-tenancy exploits (tenant A computes while tenant B stages — the
sequential baseline idles the whole array during every stage phase).  The
other pool is column slack: layers with ``N < array cols`` idle columns in
the baseline which concurrent tenants reclaim.

The scheduler is execution-backend agnostic: it takes a ``time_fn(layer,
partition) -> seconds`` compute oracle and an optional :class:`StageModel`.
`repro.sim` supplies the Scale-Sim-style analytic models;
`repro.distributed.tenancy` reuses the same scheduler with a mesh-slice
latency estimator at cluster scale.

The *grant rule* itself — how a free array is split and which ready layer
takes which slice — is delegated to a :class:`repro.api.policy
.PartitionPolicy`.  ``policy`` may be a policy object or a registry name
(``"equal"``, ``"proportional"``, ``"best_fit"``, ``"priority"``,
``"width_aware"``); the legacy string ``"paper"`` is an alias for
``"equal"``, which is Algorithm 1 verbatim.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Sequence

from repro.core.dnng import DNNG, LayerShape
from repro.core.partition import (
    ArrayShape,
    Partition,
    PartitionSet,
)

TimeFn = Callable[[LayerShape, Partition], float]


@dataclasses.dataclass(frozen=True)
class StageModel:
    """DRAM staging times for a layer (shared-bus FCFS service times)."""

    dram_bw_bytes: float = 64e9
    bytes_per_elem: int = 2

    def stage_in_s(self, layer: LayerShape) -> float:
        elems = layer.gemm_k * layer.gemm_n + layer.gemm_m * layer.gemm_k
        return elems * self.bytes_per_elem / self.dram_bw_bytes

    def stage_out_s(self, layer: LayerShape) -> float:
        return (layer.gemm_m * layer.gemm_n * self.bytes_per_elem
                / self.dram_bw_bytes)


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One executed layer: who, what, where, when (Fig. 9(c,d) raw data).

    ``start``/``end`` bound the full lifecycle on the partition;
    ``compute_start``/``compute_end`` bound the PE-array-active phase.
    """

    tenant: str
    layer_index: int
    layer_name: str
    partition: Partition
    start: float
    end: float
    compute_start: float
    compute_end: float

    @property
    def duration(self) -> float:
        return self.end - self.start

    @property
    def compute_duration(self) -> float:
        return self.compute_end - self.compute_start


@dataclasses.dataclass(frozen=True)
class ScheduleResult:
    trace: tuple[TraceEvent, ...]
    completion: dict[str, float]   # per-DNNG completion time (Fig. 9(a,b))
    makespan: float
    array: ArrayShape
    # exact compute-busy accumulator from the event loop; None = derive from
    # the trace.  Keeps utilization correct when the trace was dropped
    # (DynamicScheduler(keep_trace=False) over long open-loop horizons).
    busy_pe_seconds: float | None = None

    def tenant_trace(self, tenant: str) -> list[TraceEvent]:
        return [e for e in self.trace if e.tenant == tenant]

    @property
    def pe_seconds_busy(self) -> float:
        if self.busy_pe_seconds is not None:
            return self.busy_pe_seconds
        return sum(e.compute_duration * e.partition.n_pes for e in self.trace)

    @property
    def utilization(self) -> float:
        """Compute-busy PE-seconds / total PE-seconds over the makespan."""
        total = self.makespan * self.array.rows * self.array.cols
        return self.pe_seconds_busy / total if total else 0.0


class _Bus:
    """Shared DRAM channel: FCFS, single server."""

    def __init__(self) -> None:
        self.free_at = 0.0
        self.busy_s = 0.0

    def acquire(self, now: float, dur: float) -> tuple[float, float]:
        start = max(now, self.free_at)
        self.free_at = start + dur
        self.busy_s += dur
        return start, start + dur


class _Tenant:
    __slots__ = ("dnng", "next_layer", "running", "done_layers")

    def __init__(self, dnng: DNNG):
        self.dnng = dnng
        self.next_layer = 0
        self.running = False
        self.done_layers: set[int] = set()

    @property
    def finished(self) -> bool:
        return self.next_layer >= len(self.dnng.layers)

    def ready_layer(self) -> tuple[int, LayerShape] | None:
        """Next layer whose DAG predecessors are all complete."""
        if self.finished or self.running:
            return None
        idx = self.next_layer
        preds = self.dnng.predecessors(idx)
        if all(p in self.done_layers for p in preds):
            return idx, self.dnng.layers[idx]
        return None


class DynamicScheduler:
    """Incremental, resumable form of Algorithm 1's event loop.

    The closed-workload entry point :func:`schedule_dynamic` submits every
    DNNG up front and drains; the open-loop traffic simulator
    (`repro.traffic`) instead interleaves :meth:`submit` calls with
    :meth:`run_until` so DNNGs arrive *while* others execute, and the policy
    re-runs its split+assign at every arrival and completion event — the
    paper's Fig. 4 timeline under live load.

    * :meth:`submit`      — admit one DNNG (its ``arrival_time`` is the event
      timestamp; must be >= the current clock).
    * :meth:`run_until`   — process every event with timestamp <= ``t``.
    * :meth:`run`         — drain all pending events (closed-workload mode).
    * ``on_complete``     — optional ``(tenant, time)`` callback fired when a
      DNNG finishes its last layer (the traffic simulator's queue-pop hook).
    * ``keep_trace=False``— bounded-memory mode for long open-loop runs:
      per-layer :class:`TraceEvent` records AND the per-tenant completion
      dict are dropped (each would grow O(total jobs served)); busy
      PE-seconds, completion count and last completion time are still
      accumulated, and per-job completion instants flow through
      ``on_complete``.
    """

    def __init__(self, array: ArrayShape, time_fn: TimeFn,
                 stage: StageModel | None = None, policy="paper",
                 on_complete: Callable[[str, float], None] | None = None,
                 keep_trace: bool = True, start_time: float = 0.0):
        # lazy import: repro.api builds on this module (no import cycle)
        from repro.api.policy import resolve_policy
        self.array = array
        self.time_fn = time_fn
        self.stage = stage
        self.pol = resolve_policy(policy)
        self.on_complete = on_complete
        self.keep_trace = keep_trace
        self.tenants: dict[str, _Tenant] = {}
        self.pset = PartitionSet(array)
        self.bus = _Bus()
        self.trace: list[TraceEvent] = []
        self.completion: dict[str, float] = {}
        self.now = start_time
        self.pe_seconds_busy = 0.0
        self.n_completed = 0
        self.last_completion = start_time
        # in-flight state: tenant -> (idx, layer, part, t_assign, t_cstart, t_cend)
        self._inflight: dict[str, tuple] = {}
        # event heap: (time, seq, kind, tenant); kinds: "arrive", "cdone", "done"
        self._seq = itertools.count()
        self._events: list[tuple[float, int, str, str]] = []

    # -- queries ------------------------------------------------------------
    @property
    def n_active(self) -> int:
        """DNNGs submitted but not yet complete (the in-system count)."""
        return len(self.tenants)

    def pending(self) -> bool:
        return bool(self._events)

    def next_event_time(self) -> float | None:
        return self._events[0][0] if self._events else None

    # -- admission ----------------------------------------------------------
    def submit(self, dnng: DNNG) -> None:
        """Admit one DNNG; its layers become schedulable at ``arrival_time``.

        Names must be unique per scheduler.  In ``keep_trace=False`` mode
        completed names are not remembered (bounded memory), so collisions
        with *retired* tenants are only caught by the caller — the traffic
        simulator enforces uniqueness across the whole arrival stream.
        """
        if dnng.name in self.tenants or dnng.name in self.completion:
            raise ValueError(f"duplicate DNNG name: {dnng.name!r}")
        if dnng.arrival_time < self.now:
            raise ValueError(
                f"cannot submit {dnng.name!r} at t={dnng.arrival_time} in "
                f"the past (clock is at {self.now})")
        self.tenants[dnng.name] = _Tenant(dnng)
        heapq.heappush(self._events, (dnng.arrival_time, next(self._seq),
                                      "arrive", dnng.name))

    # -- event loop ---------------------------------------------------------
    def _ready_tenants(self, now: float) -> list[tuple[str, int, LayerShape]]:
        out = []
        for name, t in self.tenants.items():
            if t.dnng.arrival_time > now:
                continue
            rl = t.ready_layer()
            if rl is not None:
                out.append((name, rl[0], rl[1]))
        return out

    def _launch(self, now: float, tenant: str, layer_idx: int,
                layer: LayerShape, part: Partition) -> None:
        t = self.tenants[tenant]
        t.running = True
        # stage-in on the shared bus, then compute; stage-out acquires the
        # bus only when compute actually completes (see "cdone" handler).
        if self.stage is not None:
            _, si_end = self.bus.acquire(now, self.stage.stage_in_s(layer))
        else:
            si_end = now
        c_dur = self.time_fn(layer, part)
        if c_dur <= 0:
            raise ValueError(f"time_fn returned non-positive duration {c_dur}")
        c_end = si_end + c_dur
        self._inflight[tenant] = (layer_idx, layer, part, now, si_end, c_end)
        heapq.heappush(self._events, (c_end, next(self._seq), "cdone", tenant))

    def _demands(self, ready: Sequence[tuple[str, int, LayerShape]]):
        from repro.api.policy import TenantDemand
        return [TenantDemand(name=tenant, demand=float(layer.opr),
                             width_demand=max(1, min(layer.gemm_n,
                                                     self.array.cols)))
                for tenant, _idx, layer in ready]

    def _assign(self, now: float) -> None:
        """(Re-)run the policy's split + assign steps at time ``now``."""
        from repro.api.policy import AssignContext
        array, pset, pol = self.array, self.pset, self.pol
        ready = self._ready_tenants(now)
        if not ready:
            return
        # one (layer, partition) -> seconds memo per rebalance round: the
        # steady-state loop below re-offers after every grant, re-probing
        # pairings the round has already priced
        cost_cache: dict = {}
        whole_array_free = (not pset.busy_partitions
                            and len(pset.free_partitions) == 1)
        if whole_array_free:
            ctx = AssignContext(array=array, time_fn=self.time_fn, busy={},
                                cost_cache=cost_cache)
            if len(ready) == 1:
                # Fig. 5 lines 5–6: single available task -> offer all PEs.
                offered = [Partition(rows=array.rows, col_start=0,
                                     cols=array.cols)]
            else:
                # fresh split among all available layers (lines 8–10)
                offered = pol.split(array, self._demands(ready))
            for a in pol.assign(ready, offered, ctx):
                got = pset.allocate_exact(a.tenant, a.partition)
                self._launch(now, a.tenant, a.layer_index, a.layer, got)
            return
        # steady state: policy matches ready layers to merged free slices,
        # one grant at a time (trimmed grants change the free list, so
        # re-offer after every allocation).
        progressed = True
        while progressed:
            progressed = False
            free = pset.free_partitions
            ready = self._ready_tenants(now)
            if not free or not ready:
                break
            ctx = AssignContext(array=array, time_fn=self.time_fn,
                                busy=pset.busy_partitions,
                                cost_cache=cost_cache)
            for a in pol.assign(ready, free, ctx):
                got = pset.allocate_exact(a.tenant, a.partition)
                self._launch(now, a.tenant, a.layer_index, a.layer, got)
                progressed = True
                break  # free list changed; re-sort and re-match

    def _compute_done(self, tenant: str, now: float) -> None:
        idx, layer, part, t_assign, t_cstart, t_cend = self._inflight[tenant]
        if self.stage is not None:
            _, so_end = self.bus.acquire(now, self.stage.stage_out_s(layer))
        else:
            so_end = now
        self.pe_seconds_busy += (t_cend - t_cstart) * part.n_pes
        if self.keep_trace:
            self.trace.append(TraceEvent(
                tenant=tenant, layer_index=idx,
                layer_name=layer.name or f"L{idx}",
                partition=part, start=t_assign, end=so_end,
                compute_start=t_cstart, compute_end=t_cend))
        heapq.heappush(self._events, (so_end, next(self._seq), "done", tenant))

    def _finish(self, tenant: str, now: float) -> None:
        t = self.tenants[tenant]
        t.running = False
        t.done_layers.add(t.next_layer)
        t.next_layer += 1
        self._inflight.pop(tenant, None)
        self.pset.free(tenant)  # eager merge (§3.3)
        if t.finished:
            if self.keep_trace:
                self.completion[tenant] = now
            self.n_completed += 1
            self.last_completion = now
            # retired tenants never become ready again; drop them so the
            # ready scan stays O(live tenants) over open-loop horizons
            del self.tenants[tenant]
            if self.on_complete is not None:
                self.on_complete(tenant, now)

    def _dispatch(self, kind: str, name: str, now: float) -> None:
        if kind == "done":
            self._finish(name, now)
        elif kind == "cdone":
            self._compute_done(name, now)
        # "arrive" has no state change — it exists to trigger _assign(now)

    def _step(self) -> None:
        """Pop one event timestamp: handle every event at that instant, then
        re-run the policy (the rebalance-on-arrival/-completion point)."""
        now, _, kind, name = heapq.heappop(self._events)
        self.now = now
        self._dispatch(kind, name, now)
        # drain all events at the same timestamp before re-assigning
        while self._events and self._events[0][0] == now:
            _, _, k2, n2 = heapq.heappop(self._events)
            self._dispatch(k2, n2, now)
        self._assign(now)
        self.pset.check()

    def run_until(self, t: float) -> None:
        """Process every pending event with timestamp <= ``t``."""
        while self._events and self._events[0][0] <= t:
            self._step()
        self.now = max(self.now, t)

    def run(self) -> None:
        """Drain every pending event (closed-workload mode)."""
        while self._events:
            self._step()

    # -- results ------------------------------------------------------------
    def result(self) -> ScheduleResult:
        if self.completion:
            makespan = max(self.completion.values())
        elif self.n_completed:
            makespan = self.last_completion  # lean mode: dict not retained
        else:
            makespan = self.now
        return ScheduleResult(trace=tuple(self.trace),
                              completion=dict(self.completion),
                              makespan=makespan, array=self.array,
                              busy_pe_seconds=self.pe_seconds_busy)


def schedule_dynamic(
    dnngs: Sequence[DNNG],
    array: ArrayShape,
    time_fn: TimeFn,
    stage: StageModel | None = None,
    policy="paper",
) -> ScheduleResult:
    """Run Algorithm 1's runtime dynamics end-to-end and return the trace.

    ``policy`` is a :class:`repro.api.policy.PartitionPolicy` instance or a
    registry name (see :func:`repro.api.policy.list_policies`).  The default
    ``"paper"`` is an alias for ``"equal"`` — Algorithm 1 verbatim: the
    heaviest-``Opr`` ready layer takes the largest free slice, whole.  The
    pre-API string ``"width_aware"`` also still resolves: grants trimmed to
    ``min(N, cols)`` plus the hold-for-width decline rule (EXPERIMENTS.md
    §Perf) that keeps width-critical layers off slivers.

    This is the closed-workload wrapper over :class:`DynamicScheduler`:
    submit everything, drain, report.
    """
    if not dnngs:
        return ScheduleResult(trace=(), completion={}, makespan=0.0, array=array)
    names = [g.name for g in dnngs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate DNNG names: {names}")
    # negative arrival times are legal in batch mode: start the clock there
    start = min(0.0, min(g.arrival_time for g in dnngs))
    sched = DynamicScheduler(array, time_fn, stage=stage, policy=policy,
                             start_time=start)
    for g in dnngs:
        sched.submit(g)
    sched.run()
    if len(sched.completion) != len(dnngs):
        missing = set(names) - set(sched.completion)
        raise RuntimeError(f"scheduler deadlock: {missing} never completed")
    return sched.result()


def schedule_sequential(
    dnngs: Sequence[DNNG],
    array: ArrayShape,
    time_fn: TimeFn,
    stage: StageModel | None = None,
) -> ScheduleResult:
    """Single-tenancy baseline: DNNs strictly in arrival order, every layer on
    the full array, stage-in/compute/stage-out fully serialised (the paper's
    Fig. 9 'baseline systolic array' under Scale-Sim's non-overlapped DRAM
    model)."""
    full = Partition(rows=array.rows, col_start=0, cols=array.cols)
    trace: list[TraceEvent] = []
    completion: dict[str, float] = {}
    now = 0.0
    for g in sorted(dnngs, key=lambda g: (g.arrival_time, g.name)):
        now = max(now, g.arrival_time)
        for i, layer in enumerate(g.layers):
            si = stage.stage_in_s(layer) if stage else 0.0
            so = stage.stage_out_s(layer) if stage else 0.0
            c = time_fn(layer, full)
            trace.append(TraceEvent(
                tenant=g.name, layer_index=i,
                layer_name=layer.name or f"L{i}", partition=full,
                start=now, end=now + si + c + so,
                compute_start=now + si, compute_end=now + si + c))
            now += si + c + so
        completion[g.name] = now
    return ScheduleResult(trace=tuple(trace), completion=completion,
                          makespan=now, array=array)
