"""Event-driven multi-tenant scheduler — Algorithm 1's runtime dynamics (§3.3).

Drives :mod:`repro.core.partition` over time:

* the **first** layer of the **first** DNNG runs on the whole array
  (Fig. 5 lines 5–6);
* when several DNNGs are waiting, the array is split by
  :func:`partition_calculation` and ready layers are bound heaviest-first by
  :func:`task_assignment` (lines 8–12);
* a tenant executes its layers sequentially (DAG order); when a layer
  finishes, its partition is released, adjacent free slices **merge**, and
  assignment re-runs — so surviving tenants inherit wider partitions exactly
  as in Fig. 9(c,d) (128×16 → 128×32 → 128×64 → 128×128).

Layer lifecycle (matching Scale-Sim's non-overlapped DRAM model, which the
paper's toolchain uses):

    assign → [bus] stage-in (weights+IFMap DRAM→SRAM) → compute → [bus]
    stage-out (OFMap SRAM→DRAM) → release partition

The DRAM bus is a shared FCFS resource; *this* is one of the two slack pools
multi-tenancy exploits (tenant A computes while tenant B stages — the
sequential baseline idles the whole array during every stage phase).  The
other pool is column slack: layers with ``N < array cols`` idle columns in
the baseline which concurrent tenants reclaim.

The scheduler is execution-backend agnostic: it takes a ``time_fn(layer,
partition) -> seconds`` compute oracle and an optional :class:`StageModel`.
`repro.sim` supplies the Scale-Sim-style analytic models;
`repro.distributed.tenancy` reuses the same scheduler with a mesh-slice
latency estimator at cluster scale.

The *grant rule* itself — how a free array is split and which ready layer
takes which slice — is delegated to a :class:`repro.api.policy
.PartitionPolicy`.  ``policy`` may be a policy object or a registry name
(``"equal"``, ``"proportional"``, ``"best_fit"``, ``"priority"``,
``"width_aware"``); the legacy string ``"paper"`` is an alias for
``"equal"``, which is Algorithm 1 verbatim.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Sequence

from repro.core.dnng import DNNG, LayerShape
from repro.core.partition import (
    ArrayShape,
    Partition,
    PartitionSet,
)

TimeFn = Callable[[LayerShape, Partition], float]


@dataclasses.dataclass(frozen=True)
class StageModel:
    """DRAM staging times for a layer (shared-bus FCFS service times)."""

    dram_bw_bytes: float = 64e9
    bytes_per_elem: int = 2

    def stage_in_s(self, layer: LayerShape) -> float:
        elems = layer.gemm_k * layer.gemm_n + layer.gemm_m * layer.gemm_k
        return elems * self.bytes_per_elem / self.dram_bw_bytes

    def stage_out_s(self, layer: LayerShape) -> float:
        return (layer.gemm_m * layer.gemm_n * self.bytes_per_elem
                / self.dram_bw_bytes)


@dataclasses.dataclass(frozen=True)
class PreemptionModel:
    """Cost model of preempting an in-flight layer (§3.3 taken further).

    Preempting a layer mid-compute is not free: the partition's in-array
    partial sums (one fp32 accumulator per PE of the column group) must be
    drained to the output SRAM/DRAM over the shared bus before the columns
    can be handed to another tenant — the already-computed OFMap rows stay
    in the output buffer and flow out with the layer's normal stage-out.
    The victim pays the normal :class:`StageModel` stage-in again on
    resume (weights are stationary — they are gone once the columns are
    reassigned), so the restore side is simply the relaunch's stage-in and
    needs no extra model here.

    ``fixed_overhead_s`` is the control-path cost of quiescing the column
    group (pipeline flush + reconfiguration), paid once per preemption —
    it is the whole cost when a layer is caught during stage-in, before
    any partial sums exist.
    """

    dram_bw_bytes: float = 64e9
    psum_bytes_per_elem: int = 4      # partial sums are fp32 accumulators
    fixed_overhead_s: float = 2e-6

    def drain_s(self, part: Partition) -> float:
        psum_bytes = part.n_pes * self.psum_bytes_per_elem
        return self.fixed_overhead_s + psum_bytes / self.dram_bw_bytes


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One executed layer *segment*: who, what, where, when (Fig. 9(c,d)).

    ``start``/``end`` bound the full lifecycle on the partition;
    ``compute_start``/``compute_end`` bound the PE-array-active phase.

    Without preemption every layer is exactly one segment with
    ``fraction == 1.0`` and both flags False — byte-identical to the
    pre-preemption trace format.  A preempted layer emits one event per
    executed segment: ``fraction`` is the share of the layer's total
    compute done in this segment (segment fractions sum to 1.0 across the
    layer), ``preempted`` marks a segment that ended in a drain (its
    ``end`` includes the partial-sum drain), and ``resumed`` marks a
    segment that began with a weight re-stage.  Energy accounting in
    `repro.sim.energy` scales per-layer access counts by ``fraction`` and
    adds the drain/restore DRAM traffic, so the books stay exact.
    """

    tenant: str
    layer_index: int
    layer_name: str
    partition: Partition
    start: float
    end: float
    compute_start: float
    compute_end: float
    fraction: float = 1.0
    resumed: bool = False
    preempted: bool = False

    @property
    def duration(self) -> float:
        return self.end - self.start

    @property
    def compute_duration(self) -> float:
        return self.compute_end - self.compute_start


@dataclasses.dataclass(frozen=True)
class ScheduleResult:
    trace: tuple[TraceEvent, ...]
    completion: dict[str, float]   # per-DNNG completion time (Fig. 9(a,b))
    makespan: float
    array: ArrayShape
    # exact compute-busy accumulator from the event loop; None = derive from
    # the trace.  Keeps utilization correct when the trace was dropped
    # (DynamicScheduler(keep_trace=False) over long open-loop horizons).
    busy_pe_seconds: float | None = None
    preemptions: int = 0
    # seconds of extra bus occupancy from memory contention + per-tenant
    # bandwidth caps (MemorySystem.stall_s); 0.0 when contention is unarmed
    bus_stall_s: float = 0.0

    def tenant_trace(self, tenant: str) -> list[TraceEvent]:
        return [e for e in self.trace if e.tenant == tenant]

    @property
    def pe_seconds_busy(self) -> float:
        if self.busy_pe_seconds is not None:
            return self.busy_pe_seconds
        return sum(e.compute_duration * e.partition.n_pes for e in self.trace)

    @property
    def utilization(self) -> float:
        """Compute-busy PE-seconds / total PE-seconds over the makespan."""
        total = self.makespan * self.array.rows * self.array.cols
        return self.pe_seconds_busy / total if total else 0.0


@dataclasses.dataclass(frozen=True)
class ContentionModel:
    """MoCA-style shared-HBM interference curve (exact and deterministic).

    Fleet DRAM demand is bucketed into fixed windows of ``window_s``
    seconds.  ``capacity`` is the fleet bandwidth in units of one node's
    bus (``capacity=1.0``: the whole fleet shares what a single node's
    :class:`StageModel` assumes, so any co-residency overcommits).  A
    window whose booked demand exceeds capacity stretches every transfer
    it serves superlinearly:

        stretch(p) = 1                      for p <= 1
                   = 1 + alpha * (p - 1)^beta   otherwise

    with ``p = demand_seconds / (window_s * capacity)``.  ``beta > 1``
    gives the superlinear slowdown MoCA measures when co-resident tenants
    fight over shared memory bandwidth; the curve is monotone
    nondecreasing in ``p`` for any ``alpha >= 0, beta >= 1``.
    """

    window_s: float = 100e-6
    capacity: float = 1.0
    alpha: float = 1.0
    beta: float = 2.0

    def stretch(self, pressure: float) -> float:
        if pressure <= 1.0:
            return 1.0
        return 1.0 + self.alpha * (pressure - 1.0) ** self.beta


class SharedBandwidth:
    """Fleet-shared per-window DRAM demand ledger.

    One instance is shared by every node's :class:`MemorySystem` in a
    fleet: each transfer books its *raw* (uncontended) duration into the
    window containing its start instant, and the stretch it suffers is
    read off the window's resulting pressure.  Nodes are advanced in a
    fixed order by the traffic simulator, so the booking order — and
    therefore every stretch — is deterministic run-to-run.
    """

    def __init__(self, contention: ContentionModel):
        self.contention = contention
        self._demand: dict[int, float] = {}   # window index -> booked seconds
        self.peak_pressure = 0.0

    def book(self, start: float, dur: float) -> float:
        """Book ``dur`` seconds of raw demand at ``start``; return the
        contention stretch factor the transfer suffers."""
        c = self.contention
        w = int(start / c.window_s)
        d = self._demand.get(w, 0.0) + dur
        self._demand[w] = d
        pressure = d / (c.window_s * c.capacity)
        if pressure > self.peak_pressure:
            self.peak_pressure = pressure
        return c.stretch(pressure)


class MemorySystem:
    """Shared DRAM channel: FCFS, single server — optionally contention-
    aware and per-tenant rate-capped.

    Unarmed (``contention is None``, no caps — the default) this is the
    original ``_Bus``: ``acquire`` runs the identical float operations, so
    schedules are byte-identical to the pre-memory-model engine.

    Armed, two effects stack on every transfer:

    * **fleet contention** — the raw duration is booked into the shared
      per-window ledger (:class:`SharedBandwidth`) and stretched by the
      window's MoCA interference curve;
    * **per-tenant caps** — ``caps[tenant] = share in (0, 1)`` rate-limits
      the tenant's DRAM access: the transfer *completes* for its owner at
      ``duration / share``, but the bus is held only for the (contention-
      stretched) wire time — a rate limit spreads the tenant's traffic,
      it does not congest the channel, so the slack is immediately usable
      by co-resident tenants.  That asymmetry is the whole point: capping
      batch tenants delays *their* next demand into later windows while
      tier-0 transfers find the bus free sooner.  Caps are set by the
      policy's ``bandwidth(ctx)`` hook via :meth:`set_caps`; a throttled
      transfer still books only its raw demand in the window ledger.

    ``stall_s`` accumulates the extra transfer time beyond raw (contention
    stretch + cap spreading) for the energy report and traffic metrics.
    """

    def __init__(self, contention: "ContentionModel | None" = None,
                 shared: "SharedBandwidth | None" = None) -> None:
        self.free_at = 0.0
        self.busy_s = 0.0
        self.stall_s = 0.0
        self.caps: dict[str, float] = {}
        if shared is None and contention is not None:
            shared = SharedBandwidth(contention)
        self.shared = shared

    def set_caps(self, caps) -> None:
        """Replace the per-tenant bandwidth caps (``None``/empty clears).
        Mutates in place so live views held by policy contexts stay
        current."""
        self.caps.clear()
        if caps:
            self.caps.update(caps)

    def acquire(self, now: float, dur: float,
                tenant: str | None = None) -> tuple[float, float]:
        start = max(now, self.free_at)
        if self.shared is not None or self.caps:
            raw = dur
            if self.shared is not None:
                dur = raw * self.shared.book(start, raw)
            hold = dur                       # bus occupancy: wire time only
            cap = self.caps.get(tenant)
            if cap is not None and 0.0 < cap < 1.0:
                dur = dur / cap              # owner finishes later...
            self.stall_s += dur - raw
            self.free_at = start + hold      # ...but the bus frees at hold
            self.busy_s += hold
            return start, start + dur
        self.free_at = start + dur
        self.busy_s += dur
        return start, start + dur

    def abort_reservation(self, now: float, start: float, end: float) -> None:
        """Cancel the unperformed part of the reservation ``[start, end)``
        (a preempted stage-in).  Only possible while it is still the bus's
        LAST reservation — transfers already committed behind it keep
        their windows, so the slot is sunk cost then and nothing is
        reclaimed.  Window demand already booked in the shared ledger
        stays booked for the same reason."""
        if self.free_at != end:
            return
        cut_from = max(now, start)
        self.busy_s -= end - cut_from
        self.free_at = cut_from


# the pre-memory-model name: MemorySystem with no contention and no caps
# IS the original shared FCFS bus
_Bus = MemorySystem


class _Tenant:
    __slots__ = ("dnng", "next_layer", "running", "draining",
                 "done_frac", "seq", "n_layers")

    def __init__(self, dnng: DNNG, seq: int = 0):
        self.dnng = dnng
        self.seq = seq              # submit order (ready-list sort key)
        self.next_layer = 0
        self.n_layers = len(dnng.layers)
        self.running = False
        self.draining = False       # preempted: partition frees at drain end
        self.done_frac: dict[int, float] = {}  # layer idx -> compute done

    @property
    def finished(self) -> bool:
        return self.next_layer >= self.n_layers

    def ready_layer(self) -> tuple[int, LayerShape] | None:
        """Next schedulable layer.

        Layers execute strictly in index order and ``DNNG.__post_init__``
        enforces topological edges (``s < d``), so the predecessors of
        ``next_layer`` are complete by construction — the per-event DAG
        membership scan the pre-PR-5 engine did here was provably
        constant-true and is gone from the hot path.
        """
        if self.running or self.draining or self.next_layer >= self.n_layers:
            return None
        return self.next_layer, self.dnng.layers[self.next_layer]


@dataclasses.dataclass
class _InFlight:
    """One launched layer segment (scheduler-internal mutable record)."""

    __slots__ = ("idx", "layer", "part", "t_assign", "si_start", "c_start",
                 "c_end", "base_frac", "share", "resumed", "token")

    idx: int
    layer: LayerShape
    part: Partition
    t_assign: float
    si_start: float      # stage-in bus reservation start (== t_assign if
                         # the bus was free; == c_start when stage is None)
    c_start: float
    c_end: float
    base_frac: float     # compute fraction done before this segment
    share: float         # compute fraction this segment covers (1 - base)
    resumed: bool        # a prior segment of this layer was preempted
    token: int           # invalidates stale "cdone" events after preemption


class DynamicScheduler:
    """Incremental, resumable form of Algorithm 1's event loop.

    The closed-workload entry point :func:`schedule_dynamic` submits every
    DNNG up front and drains; the open-loop traffic simulator
    (`repro.traffic`) instead interleaves :meth:`submit` calls with
    :meth:`run_until` so DNNGs arrive *while* others execute, and the policy
    re-runs its split+assign at every arrival and completion event — the
    paper's Fig. 4 timeline under live load.

    * :meth:`submit`      — admit one DNNG (its ``arrival_time`` is the event
      timestamp; must be >= the current clock).
    * :meth:`run_until`   — process every event with timestamp <= ``t``.
    * :meth:`run`         — drain all pending events (closed-workload mode).
    * ``on_complete``     — optional ``(tenant, time)`` callback fired when a
      DNNG finishes its last layer (the traffic simulator's queue-pop hook).
    * ``keep_trace=False``— bounded-memory mode for long open-loop runs:
      per-layer :class:`TraceEvent` records AND the per-tenant completion
      dict are dropped (each would grow O(total jobs served)); busy
      PE-seconds, completion count and last completion time are still
      accumulated, and per-job completion instants flow through
      ``on_complete``.
    * ``preemption``      — a :class:`PreemptionModel` arms layer-granular
      preemption: at every rebalance point the policy's optional
      ``preempt(ctx)`` hook may name in-flight victims, whose partial sums
      are drained over the bus (partition frees at drain end) and whose
      remaining compute re-enters the ready set, paying stage-in again on
      resume.  ``None`` (default) or a policy without the hook keeps the
      event stream — and therefore the trace — byte-identical to the
      preemption-free scheduler.
    * ``check_invariants`` — run the :class:`PartitionSet` tiling check
      after every event (O(tenants log tenants) — a debug net, off by
      default on the serving hot path; :func:`schedule_dynamic` keeps it
      on for closed workloads).
    * ``obs``             — a :class:`repro.obs.Observability` arms the
      ring-buffered structured tracer on this scheduler: arrival /
      completion / preemption instants, stage-in / compute / stage-out /
      drain spans, and per-round policy decision audits.  ``node_index``
      labels this scheduler's track in fleet traces.  Pure observation —
      arming it never changes the event stream.

    The event engine is *incremental*: the ready set, per-tenant demand
    vectors, and DAG-predecessor tables are maintained by delta at the
    state transitions that can change them, and a policy round is skipped
    outright when the events at an instant left (ready, free) state
    untouched — ``n_events`` counts processed events for the
    events-per-second benchmarks.
    """

    def __init__(self, array: ArrayShape, time_fn: TimeFn,
                 stage: StageModel | None = None, policy="paper",
                 on_complete: Callable[[str, float], None] | None = None,
                 keep_trace: bool = True, start_time: float = 0.0,
                 preemption: "PreemptionModel | None" = None,
                 check_invariants: bool = False,
                 obs=None, node_index: int = 0,
                 contention: "ContentionModel | None" = None,
                 shared_bandwidth: "SharedBandwidth | None" = None):
        # lazy import: repro.api builds on this module (no import cycle)
        from repro.api.policy import AssignContext, PartitionPolicy, \
            TenantDemand, resolve_policy
        self.array = array
        self.time_fn = time_fn
        self.stage = stage
        self.pol = resolve_policy(policy)
        self.on_complete = on_complete
        self.keep_trace = keep_trace
        self.preemption = preemption
        self.check_invariants = check_invariants
        # observability (repro.obs.Observability) — pure observation: every
        # emit below is behind an `is not None` guard and never touches
        # event order, rng or scheduler state.  ``node_index`` labels this
        # scheduler's track in fleet traces (ArrayNode passes its index).
        self.node_index = node_index
        self._tr = getattr(obs, "tracer", None)
        self._audit = (self._tr is not None
                       and bool(getattr(obs, "audit", False)))
        self.tenants: dict[str, _Tenant] = {}
        self.deadlines: dict[str, float] = {}
        self.tiers: dict[str, int] = {}
        self.pset = PartitionSet(array)
        self.bus = MemorySystem(contention=contention,
                                shared=shared_bandwidth)
        self.trace: list[TraceEvent] = []
        self.completion: dict[str, float] = {}
        self.now = start_time
        self.pe_seconds_busy = 0.0
        self.n_completed = 0
        self.n_preemptions = 0
        self.n_events = 0
        self.last_completion = start_time
        self._inflight: dict[str, _InFlight] = {}
        # maintained ready set: tenant -> (layer_idx, layer, TenantDemand),
        # updated by delta on arrive/launch/finish/pfree/withdraw instead of
        # rescanning every tenant per event (the pre-PR-5 hot path)
        # [layer_idx, layer, TenantDemand | None] per ready tenant
        self._ready: dict[str, list] = {}
        self._TenantDemand = TenantDemand
        self._stage_memo: dict[LayerShape, tuple[float, float]] = {}
        # ONE reusable policy context: every field is a live view (the busy
        # mapping and deadlines mutate in place, the cost cache is cleared
        # per round), so each policy call still sees exactly the state a
        # freshly built per-round context would
        self._round_cache: dict = {}
        self._ctx = AssignContext(array=array, time_fn=time_fn,
                                  busy=self.pset.busy_view(),
                                  cost_cache=self._round_cache,
                                  deadlines=self.deadlines,
                                  tiers=self.tiers,
                                  bandwidth=self.bus.caps)
        # a rebalance round is skipped while the dirty flag is clear: only
        # arrive/done/pfree events change the (ready, free-partition) state
        # assign() depends on.  AssignContext deliberately carries no clock,
        # so split/assign are time-independent and the skip is exact; a
        # policy preempt(ctx) hook DOES see the clock (deadline slack), so
        # an armed hook disables the skip.
        self._dirty = False
        self._has_preempt_hook = (
            preemption is not None
            and getattr(self.pol, "preempt", None) is not None
            and getattr(type(self.pol), "preempt", None)
            is not PartitionPolicy.preempt)
        # the memory-cap hook mirrors the preempt hook's presence check:
        # the base implementation returns None (no caps), so a policy
        # without an override keeps the bus byte-identical to _Bus.  The
        # hook sees only (busy, ready, tiers) state — no clock — so the
        # dirty-skip above stays exact with it armed.
        self._has_bandwidth_hook = (
            getattr(self.pol, "bandwidth", None) is not None
            and getattr(type(self.pol), "bandwidth", None)
            is not PartitionPolicy.bandwidth)
        self._tenant_seq = itertools.count()
        # event heap: (time, seq, kind, payload); kinds: "arrive", "cdone",
        # "done", "pfree".  payload is the tenant name, except "cdone" which
        # carries (tenant, token) so preemption can invalidate stale events.
        self._seq = itertools.count()
        self._tokens = itertools.count()
        self._events: list[tuple] = []
        # fault-injection multipliers (repro.chaos): straggler compute
        # inflation and bus-stall transfer inflation.  At the 1.0 default
        # every ``x * scale`` is IEEE-exact (x * 1.0 == x), so the
        # fault-free path produces bit-identical schedules.
        self.time_scale = 1.0
        self.bus_scale = 1.0
        # brownout floor shrink (repro.overload): batch tenants' (tier > 0)
        # column demand is multiplied by this factor.  At the 1.0 default
        # the scaling branch in _demands never fires, so plain runs derive
        # bit-identical demand vectors.
        self.batch_demand_scale = 1.0

    # -- queries ------------------------------------------------------------
    @property
    def n_active(self) -> int:
        """DNNGs submitted but not yet complete (the in-system count)."""
        return len(self.tenants)

    def progress(self) -> dict[str, int]:
        """Checkpoint surface: completed-layer count per live tenant (the
        layers whose outputs have been staged out — what a warm restart
        can skip).  In-flight fractions are deliberately not counted."""
        return {name: t.next_layer for name, t in self.tenants.items()}

    def pending(self) -> bool:
        return bool(self._events)

    def inflight_allocations(self) -> dict[str, tuple[LayerShape, Partition]]:
        """Snapshot of the live column occupancy: tenant -> (layer,
        partition) for every launched-but-unfinished layer segment.

        This is the fairness-accounting sampling surface
        (`repro.fairness.accounting` reads dominant resource shares off it
        at arrival instants); pure observation — the returned dict is a
        copy, mutating it cannot corrupt scheduler state."""
        return {name: (inf.layer, inf.part)
                for name, inf in self._inflight.items()}

    def next_event_time(self) -> float | None:
        return self._events[0][0] if self._events else None

    # -- admission ----------------------------------------------------------
    def submit(self, dnng: DNNG, deadline: float | None = None,
               tier: int = 0) -> None:
        """Admit one DNNG; its layers become schedulable at ``arrival_time``.

        Names must be unique per scheduler.  In ``keep_trace=False`` mode
        completed names are not remembered (bounded memory), so collisions
        with *retired* tenants are only caught by the caller — the traffic
        simulator enforces uniqueness across the whole arrival stream.

        ``deadline`` (absolute seconds) is optional SLA metadata surfaced to
        the policy's ``preempt(ctx)`` hook; it never affects scheduling
        unless a policy acts on it.  ``tier`` is the job's latency class
        (0 = latency-critical), surfaced to the policy via
        ``AssignContext.tiers`` and ``TenantDemand.tier`` — likewise inert
        unless a policy acts on it.
        """
        if dnng.name in self.tenants or dnng.name in self.completion:
            raise ValueError(f"duplicate DNNG name: {dnng.name!r}")
        if dnng.arrival_time < self.now:
            raise ValueError(
                f"cannot submit {dnng.name!r} at t={dnng.arrival_time} in "
                f"the past (clock is at {self.now})")
        self.tenants[dnng.name] = _Tenant(dnng, seq=next(self._tenant_seq))
        self.tiers[dnng.name] = tier
        if deadline is not None:
            self.deadlines[dnng.name] = deadline
        heapq.heappush(self._events, (dnng.arrival_time, next(self._seq),
                                      "arrive", dnng.name))

    def withdraw(self, name: str) -> bool:
        """Remove a submitted tenant that has not touched the array yet.

        Only *pristine* tenants — no layer completed, none in flight, not
        draining — can be withdrawn; this is the cross-node migration hook
        (`repro.traffic.rebalance` moves the job to another array).  Returns
        False when the tenant is unknown or has already made progress.  The
        tenant's pending "arrive" event becomes a harmless no-op.
        """
        t = self.tenants.get(name)
        if (t is None or t.running or t.draining or t.next_layer > 0
                or name in self._inflight):
            return False
        del self.tenants[name]
        self._ready.pop(name, None)
        self.deadlines.pop(name, None)
        self.tiers.pop(name, None)
        # the ready set changed: the next event's policy round must run
        # even if that event alone would not dirty the state (dirty-skip
        # exactness — see _step)
        self._dirty = True
        return True

    # -- event loop ---------------------------------------------------------
    def _mark_ready(self, name: str, now: float) -> None:
        """Insert ``name`` into the maintained ready set (if its next layer
        is in fact schedulable).  Called at exactly the state transitions
        that can make a tenant ready: its arrive event, a layer completion,
        and the post-preemption partition free."""
        t = self.tenants.get(name)
        if t is None or t.dnng.arrival_time > now:
            # withdrawn before its arrive event fired (the event is a
            # harmless no-op), or a stale arrive event of a re-submitted
            # name — the live event marks it at the proper instant
            return
        rl = t.ready_layer()
        if rl is None:
            return
        # [sort seq, layer idx, layer, lazy TenantDemand]: the demand slot
        # is filled by _demands on the first round that needs the vector
        # and survives with the entry; seq rides along so the ready-list
        # sort never re-touches the tenant table
        self._ready[name] = [t.seq, rl[0], rl[1], None]
        self._dirty = True

    def _ready_tenants(self, now: float) -> list[tuple[str, int, LayerShape]]:
        """Ready (tenant, layer_idx, layer) triples in submit order — read
        straight off the maintained set (kept exactly in sync by
        :meth:`_mark_ready` / launch / withdraw), sorted by the tenants'
        submit sequence to reproduce the pre-incremental scan order."""
        ready = self._ready
        if not ready:
            return []
        if len(ready) == 1:
            name, e = next(iter(ready.items()))
            return [(name, e[1], e[2])]
        return [(name, e[1], e[2]) for name, e in
                sorted(ready.items(), key=lambda kv: kv[1][0])]

    def _launch(self, now: float, tenant: str, layer_idx: int,
                layer: LayerShape, part: Partition) -> None:
        t = self.tenants[tenant]
        t.running = True
        self._ready.pop(tenant, None)
        # stage-in on the shared bus, then compute; stage-out acquires the
        # bus only when compute actually completes (see "cdone" handler).
        # A resumed (previously preempted) segment pays stage-in again —
        # this IS the restore cost: stationary weights were lost with the
        # columns (PreemptionModel docstring).
        if self.stage is not None:
            si_start, si_end = self.bus.acquire(
                now, self._stage_costs(layer)[0] * self.bus_scale,
                tenant=tenant)
        else:
            si_start = si_end = now
        c_dur = self.time_fn(layer, part) * self.time_scale
        if c_dur <= 0:
            raise ValueError(f"time_fn returned non-positive duration {c_dur}")
        base = t.done_frac.get(layer_idx, 0.0)
        share = 1.0 - base
        c_end = si_end + c_dur * share
        token = next(self._tokens)
        self._inflight[tenant] = _InFlight(
            idx=layer_idx, layer=layer, part=part, t_assign=now,
            si_start=si_start, c_start=si_end, c_end=c_end,
            base_frac=base, share=share,
            resumed=layer_idx in t.done_frac, token=token)
        # no tracer emit here: stage-in/compute/stage-out spans derive
        # lazily from the keep_trace record (Tracer.attach) — re-recording
        # them live would double the hot-path cost for zero information
        heapq.heappush(self._events, (c_end, next(self._seq), "cdone",
                                      (tenant, token)))

    def _demands(self, ready: Sequence[tuple[str, int, LayerShape]]):
        # demand vectors live in the maintained ready entries: built on the
        # first round that needs them, reused for as long as the entry
        # survives (delta-updated, not rebuilt per event)
        out = []
        cols = self.array.cols
        scale = self.batch_demand_scale
        for tenant, _idx, layer in ready:
            entry = self._ready[tenant]
            d = entry[3]
            if d is None:
                demand = float(layer.opr)
                width = max(1, min(layer.gemm_n, cols))
                tier = self.tiers.get(tenant, 0)
                if scale != 1.0 and tier > 0:
                    # brownout floor shrink: batch tenants ask for less,
                    # the policy hands the freed columns to tier 0
                    demand = demand * scale
                    width = max(1, int(width * scale))
                d = entry[3] = self._TenantDemand(
                    name=tenant, demand=demand,
                    width_demand=width,
                    tier=tier,
                    layer=layer)
            out.append(d)
        return out

    def set_batch_demand_scale(self, factor: float) -> None:
        """Brownout floor shrink (`repro.overload`): scale batch tenants'
        column demand by ``factor`` in (0, 1]; ``1.0`` restores nominal
        demand.  Cached demand vectors are invalidated so the next
        rebalance round re-derives them under the new factor."""
        if not 0.0 < factor <= 1.0:
            raise ValueError(f"batch_demand_scale must be in (0, 1], got "
                             f"{factor}")
        if factor == self.batch_demand_scale:
            return
        self.batch_demand_scale = factor
        for entry in self._ready.values():
            entry[3] = None
        self._dirty = True

    def _maybe_preempt(self, now: float, cost_cache: dict) -> None:
        """Offer the policy's ``preempt(ctx)`` hook the in-flight set.

        Armed only when a :class:`PreemptionModel` was configured.  Any
        layer that has not finished computing is eligible — including one
        still in stage-in, which has no partial sums yet and so pays only
        the fixed quiesce overhead on eviction.  Layers already draining
        (past ``c_end``) are not; invalid names are ignored rather than
        fatal so third-party hooks cannot corrupt scheduler state.

        ``cost_cache`` is the rebalance round's shared oracle memo — the
        same dict the :class:`AssignContext`\\ s of this round use.
        """
        from repro.api.policy import InFlightLayer, PreemptContext
        if not self._has_preempt_hook:
            return  # base hook never preempts: skip building the context
        hook = self.pol.preempt
        eligible = {
            name: inf for name, inf in self._inflight.items()
            if now < inf.c_end  # mid-stage-in layers are evictable too
        }
        if not eligible:
            return
        ready = self._ready_tenants(now)
        if not ready:
            return
        ctx = PreemptContext(
            array=self.array, now=now,
            ready=tuple(ready),
            free=tuple(self.pset.free_partitions),
            inflight={name: InFlightLayer(
                tenant=name, layer_index=inf.idx, layer=inf.layer,
                partition=inf.part, compute_start=inf.c_start,
                compute_end=inf.c_end, remaining_s=inf.c_end - now,
                fraction_done=inf.base_frac + inf.share
                * max(0.0, now - inf.c_start) / (inf.c_end - inf.c_start))
                for name, inf in eligible.items()},
            deadlines=dict(self.deadlines),
            tiers=dict(self.tiers),
            bandwidth=dict(self.bus.caps),
            time_fn=self.time_fn,
            cost_cache=cost_cache,
            drain_s=self.preemption.drain_s,
            stage_in_s=(self.stage.stage_in_s if self.stage is not None
                        else lambda layer: 0.0))
        for victim in hook(ctx):
            if victim in eligible and victim in self._inflight:
                self._preempt(victim, now)

    def _assign(self, now: float) -> None:
        """(Re-)run the policy's split + assign steps at time ``now``."""
        array, pset, pol = self.array, self.pset, self.pol
        # one (layer, partition) -> seconds memo per rebalance round: the
        # preempt hook and the steady-state loop below re-probe pairings
        # the round has already priced
        cost_cache = self._round_cache
        cost_cache.clear()
        if self.preemption is not None:
            self._maybe_preempt(now, cost_cache)
        ready = self._ready_tenants(now)
        if not ready:
            if self._has_bandwidth_hook:
                # a round with nothing to place still refreshes the caps:
                # tenants finishing must relax stale throttles
                self.bus.set_caps(self.pol.bandwidth(self._ctx))
            return
        # the reusable context: its ``busy`` live view tracks allocations
        # exactly as the per-iteration snapshots of the pre-incremental
        # engine did at each policy call
        busy = pset.busy_view()
        ctx = self._ctx
        free = pset.free_partitions
        audit = self._audit
        if audit:
            # pre-round snapshot for the decision audit: candidates the
            # policy will score, the offered free widths, and the oracle
            # memo size (probe count = its growth over the round)
            cand = tuple((name, layer.name or f"L{idx}")
                         for name, idx, layer in ready)
            offer_cols = tuple(p.cols for p in free)
            before = set(self._inflight)
        if not busy and len(free) == 1:
            if len(ready) == 1:
                # Fig. 5 lines 5–6: single available task -> offer all PEs
                # (the lone free slice IS the whole array here).
                offered = free
            else:
                # fresh split among all available layers (lines 8–10)
                offered = pol.split(array, self._demands(ready))
            for a in pol.assign(ready, offered, ctx):
                got = pset.allocate_exact(a.tenant, a.partition)
                self._launch(now, a.tenant, a.layer_index, a.layer, got)
        else:
            # steady state: policy matches ready layers to merged free
            # slices, one grant at a time (trimmed grants change the free
            # list, so re-offer after every allocation).
            while free and ready:
                progressed = False
                for a in pol.assign(ready, free, ctx):
                    got = pset.allocate_exact(a.tenant, a.partition)
                    self._launch(now, a.tenant, a.layer_index, a.layer, got)
                    progressed = True
                    break  # free list changed; re-sort and re-match
                if not progressed:
                    break
                free = pset.free_partitions
                ready = self._ready_tenants(now)
        if audit:
            grants = tuple((name, inf.part.cols)
                           for name, inf in self._inflight.items()
                           if name not in before)
            granted = {n for n, _c in grants}
            self._tr.instant(
                "decision", now, self.node_index, None,
                (("ready", cand), ("free_cols", offer_cols),
                 ("grants", grants),
                 ("declined", tuple(n for n, _l in cand
                                    if n not in granted)),
                 ("oracle_probes", len(cost_cache))))
        if self._has_bandwidth_hook:
            # post-grant state: the caps the policy sets here govern every
            # bus transfer until the next policy round (dirty-skip keeps
            # this exact — a skipped round would recompute the same caps
            # from the same (busy, ready, tiers) state)
            self.bus.set_caps(self.pol.bandwidth(self._ctx))

    def _stage_costs(self, layer: LayerShape) -> tuple[float, float]:
        """(stage_in_s, stage_out_s) memoized per layer shape — jobs of one
        model share their (frozen) layer objects, so these hit."""
        c = self._stage_memo.get(layer)
        if c is None:
            c = self._stage_memo[layer] = (self.stage.stage_in_s(layer),
                                           self.stage.stage_out_s(layer))
        return c

    def _compute_done(self, tenant: str, now: float) -> None:
        inf = self._inflight[tenant]
        if self.stage is not None:
            _, so_end = self.bus.acquire(
                now, self._stage_costs(inf.layer)[1] * self.bus_scale,
                tenant=tenant)
        else:
            so_end = now
        self.pe_seconds_busy += (inf.c_end - inf.c_start) * inf.part.n_pes
        if self.keep_trace:
            self.trace.append(TraceEvent(
                tenant=tenant, layer_index=inf.idx,
                layer_name=inf.layer.name or f"L{inf.idx}",
                partition=inf.part, start=inf.t_assign, end=so_end,
                compute_start=inf.c_start, compute_end=inf.c_end,
                fraction=inf.share, resumed=inf.resumed))
        heapq.heappush(self._events, (so_end, next(self._seq), "done", tenant))

    def _preempt(self, tenant: str, now: float) -> None:
        """Evict ``tenant``'s in-flight layer: emit the partial segment,
        drain partial sums over the bus, free the partition at drain end,
        and return the remaining compute to the ready set.

        A layer caught during stage-in (compute not yet started) has no
        partial sums in the array: it pays only the fixed quiesce overhead,
        and its wasted stage-in bus time is already sunk.
        """
        inf = self._inflight.pop(tenant)
        t = self.tenants[tenant]
        run_s = max(0.0, now - inf.c_start)
        frac_seg = inf.share * run_s / (inf.c_end - inf.c_start)
        t.done_frac[inf.idx] = inf.base_frac + frac_seg
        t.running = False
        t.draining = True
        self.n_preemptions += 1
        self.pe_seconds_busy += run_s * inf.part.n_pes
        if run_s > 0.0:
            drain = self.preemption.drain_s(inf.part)
        else:
            # caught mid-stage-in: nothing in the array to drain, and the
            # unperformed part of the stage-in transfer is reclaimed (only
            # if it is still the bus's last reservation — committed
            # transfers behind it keep their windows)
            self.bus.abort_reservation(now, inf.si_start, inf.c_start)
            drain = self.preemption.fixed_overhead_s
        _, dr_end = self.bus.acquire(now, drain * self.bus_scale,
                                     tenant=tenant)
        if self.keep_trace:
            self.trace.append(TraceEvent(
                tenant=tenant, layer_index=inf.idx,
                layer_name=inf.layer.name or f"L{inf.idx}",
                partition=inf.part, start=inf.t_assign, end=dr_end,
                compute_start=min(inf.c_start, now), compute_end=now,
                fraction=frac_seg, resumed=inf.resumed,
                preempted=True))
        if self._tr is not None:
            # emitted live (not derived from the keep_trace record) so the
            # marker survives keep_trace=False bounded-memory runs; the
            # partial compute span and drain window derive from the record
            self._tr.instant("preempt", now, self.node_index, tenant,
                             (("layer_index", inf.idx),
                              ("fraction_done", inf.base_frac + frac_seg)))
        heapq.heappush(self._events, (dr_end, next(self._seq), "pfree",
                                      tenant))

    def _finish(self, tenant: str, now: float) -> None:
        t = self.tenants[tenant]
        t.running = False
        t.done_frac.pop(t.next_layer, None)
        t.next_layer += 1
        self._inflight.pop(tenant, None)
        self.pset.free(tenant)  # eager merge (§3.3)
        self._dirty = True      # columns freed (and maybe a new ready layer)
        if t.finished:
            if self.keep_trace:
                self.completion[tenant] = now
            self.n_completed += 1
            self.last_completion = now
            self.deadlines.pop(tenant, None)
            self.tiers.pop(tenant, None)
            # retired tenants never become ready again; drop them so the
            # ready scan stays O(live tenants) over open-loop horizons
            del self.tenants[tenant]
            # no tracer emit here: completion instants derive lazily from
            # the simulator's job records (Tracer.attach_source)
            if self.on_complete is not None:
                self.on_complete(tenant, now)
        else:
            self._mark_ready(tenant, now)

    def _dispatch(self, kind: str, payload, now: float) -> None:
        if kind == "done":
            self._finish(payload, now)
        elif kind == "cdone":
            name, token = payload
            inf = self._inflight.get(name)
            if inf is not None and inf.token == token:
                self._compute_done(name, now)
            # else: stale event — the segment was preempted first.  Either
            # way partition/ready state is untouched: cdone never dirties.
        elif kind == "pfree":
            self.pset.free(payload)
            self.tenants[payload].draining = False
            self._dirty = True
            self._mark_ready(payload, now)
        else:  # "arrive": the tenant's layers become schedulable now
            self._dirty = True
            # no tracer emit here: arrival instants derive lazily from
            # the simulator's job records (Tracer.attach_source)
            self._mark_ready(payload, now)

    def _step(self) -> None:
        """Pop one event timestamp: handle every event at that instant, then
        re-run the policy (the rebalance-on-arrival/-completion point).

        The policy round is *skipped* when no event at this instant dirtied
        the (ready, free) state — e.g. a compute-done instant, which only
        books the stage-out.  ``split``/``assign`` are deterministic in that
        state (AssignContext carries no clock), so a clean-state round could
        only repeat the previous round's declines; with an armed preempt
        hook (which does see the clock) every round runs.
        """
        events = self._events
        now, _, kind, name = heapq.heappop(events)
        self.now = now
        self.n_events += 1
        self._dispatch(kind, name, now)
        # drain all events at the same timestamp before re-assigning
        while events and events[0][0] == now:
            _, _, k2, n2 = heapq.heappop(events)
            self.n_events += 1
            self._dispatch(k2, n2, now)
        if self._dirty or self._has_preempt_hook:
            self._dirty = False
            self._assign(now)
        if self.check_invariants:
            self.pset.check()

    def run_until(self, t: float) -> None:
        """Process every pending event with timestamp <= ``t``."""
        events = self._events  # the heap list object is never reassigned
        step = self._step
        while events and events[0][0] <= t:
            step()
        if t > self.now:
            self.now = t

    def run(self) -> None:
        """Drain every pending event (closed-workload mode)."""
        while self._events:
            self._step()

    # -- results ------------------------------------------------------------
    def result(self) -> ScheduleResult:
        if self.completion:
            makespan = max(self.completion.values())
        elif self.n_completed:
            makespan = self.last_completion  # lean mode: dict not retained
        else:
            makespan = self.now
        return ScheduleResult(trace=tuple(self.trace),
                              completion=dict(self.completion),
                              makespan=makespan, array=self.array,
                              busy_pe_seconds=self.pe_seconds_busy,
                              preemptions=self.n_preemptions,
                              bus_stall_s=self.bus.stall_s)


def schedule_dynamic(
    dnngs: Sequence[DNNG],
    array: ArrayShape,
    time_fn: TimeFn,
    stage: StageModel | None = None,
    policy="paper",
    preemption: PreemptionModel | None = None,
) -> ScheduleResult:
    """Run Algorithm 1's runtime dynamics end-to-end and return the trace.

    ``policy`` is a :class:`repro.api.policy.PartitionPolicy` instance or a
    registry name (see :func:`repro.api.policy.list_policies`).  The default
    ``"paper"`` is an alias for ``"equal"`` — Algorithm 1 verbatim: the
    heaviest-``Opr`` ready layer takes the largest free slice, whole.  The
    pre-API string ``"width_aware"`` also still resolves: grants trimmed to
    ``min(N, cols)`` plus the hold-for-width decline rule (EXPERIMENTS.md
    §Perf) that keeps width-critical layers off slivers.

    This is the closed-workload wrapper over :class:`DynamicScheduler`:
    submit everything, drain, report.
    """
    if not dnngs:
        return ScheduleResult(trace=(), completion={}, makespan=0.0, array=array)
    names = [g.name for g in dnngs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate DNNG names: {names}")
    # negative arrival times are legal in batch mode: start the clock there
    start = min(0.0, min(g.arrival_time for g in dnngs))
    # closed workloads are small: keep the PartitionSet invariant check as a
    # safety net here (the open-loop traffic path leaves it off for speed)
    sched = DynamicScheduler(array, time_fn, stage=stage, policy=policy,
                             start_time=start, preemption=preemption,
                             check_invariants=True)
    for g in dnngs:
        sched.submit(g)
    sched.run()
    if len(sched.completion) != len(dnngs):
        missing = set(names) - set(sched.completion)
        raise RuntimeError(f"scheduler deadlock: {missing} never completed")
    return sched.result()


def schedule_sequential(
    dnngs: Sequence[DNNG],
    array: ArrayShape,
    time_fn: TimeFn,
    stage: StageModel | None = None,
) -> ScheduleResult:
    """Single-tenancy baseline: DNNs strictly in arrival order, every layer on
    the full array, stage-in/compute/stage-out fully serialised (the paper's
    Fig. 9 'baseline systolic array' under Scale-Sim's non-overlapped DRAM
    model)."""
    full = Partition(rows=array.rows, col_start=0, cols=array.cols)
    trace: list[TraceEvent] = []
    completion: dict[str, float] = {}
    now = 0.0
    for g in sorted(dnngs, key=lambda g: (g.arrival_time, g.name)):
        now = max(now, g.arrival_time)
        for i, layer in enumerate(g.layers):
            si = stage.stage_in_s(layer) if stage else 0.0
            so = stage.stage_out_s(layer) if stage else 0.0
            c = time_fn(layer, full)
            trace.append(TraceEvent(
                tenant=g.name, layer_index=i,
                layer_name=layer.name or f"L{i}", partition=full,
                start=now, end=now + si + c + so,
                compute_start=now + si, compute_end=now + si + c))
            now += si + c + so
        completion[g.name] = now
    return ScheduleResult(trace=tuple(trace), completion=completion,
                          makespan=now, array=array)
