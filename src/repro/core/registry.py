"""One name→class registry, four users.

`repro.api.policy`, `repro.api.backend`, `repro.traffic.arrivals` and
`repro.traffic.cluster` all expose the same plugin surface: a decorator to
register a class under a string key, a sorted listing, and construct-by-name
with a helpful error.  This helper is that pattern, written once.

``items`` is the live dict (exposed so tests can surgically remove a
throwaway plugin); ``aliases`` maps legacy names onto canonical keys.
"""

from __future__ import annotations

from typing import Mapping, Optional


class Registry:
    """String-keyed class registry with register/names/get."""

    def __init__(self, kind: str,
                 aliases: Optional[Mapping[str, str]] = None):
        self.kind = kind
        self.items: dict[str, type] = {}
        self.aliases = dict(aliases or {})

    def register(self, name: str):
        """Class decorator: register ``cls`` under ``name`` and stamp
        ``cls.name`` (duplicate names are a programming error)."""

        def deco(cls: type) -> type:
            if name in self.items:
                raise ValueError(f"{self.kind} {name!r} already registered")
            cls.name = name
            self.items[name] = cls
            return cls

        return deco

    def names(self) -> list[str]:
        return sorted(self.items)

    def get(self, name: str, **kwargs):
        key = self.aliases.get(name, name)
        if key not in self.items:
            raise ValueError(f"unknown {self.kind} {name!r}; registered: "
                             f"{self.names()}")
        return self.items[key](**kwargs)

    def resolve(self, obj, base: type, **kwargs):
        """Accept a registry name (constructed with ``kwargs``) or an
        instance of ``base`` (passed through; ``kwargs`` then illegal)."""
        if isinstance(obj, str):
            return self.get(obj, **kwargs)
        if kwargs:
            raise ValueError(f"{self.kind} kwargs only apply to "
                             f"string-keyed names")
        if isinstance(obj, base):
            return obj
        raise ValueError(f"not a {self.kind}: {obj!r}")
