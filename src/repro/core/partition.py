"""Algorithm 1 — Dynamic Resource Partitioning (paper Fig. 5).

The systolic array ``PE(x, y)`` (x = rows, y = columns) is split **vertically
only**: every partition spans all ``x`` rows and a contiguous range of columns
(paper §3.2 — horizontal splits would mix partial sums of different tenants on
the shared column adders).

Three pieces, named as in the paper:

* :func:`partition_calculation` — ``PE(x', y') = (PE_x, ⌊PE_y / n_available⌋)``
  (Fig. 5 lines 15–19).
* :func:`task_assignment`       — sort ready layers by ``Opr`` descending and
  assign heaviest → largest free partition (lines 20–27).
* :class:`PartitionSet`         — the mutable column-interval state: allocate,
  free, and **merge adjacent free partitions** (§3.3, "partition merging").

The same object drives both the cycle/energy simulator (`repro.sim`) and the
mesh-level tenancy manager (`repro.distributed.tenancy`), where "columns"
become devices along the ``model`` mesh axis.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

from repro.core.dnng import LayerShape


@dataclasses.dataclass(frozen=True)
class ArrayShape:
    """Systolic-array geometry PE(x, y): x rows × y columns."""

    rows: int
    cols: int

    def __post_init__(self) -> None:
        if self.rows < 1 or self.cols < 1:
            raise ValueError(f"invalid array shape {self.rows}x{self.cols}")


@dataclasses.dataclass(frozen=True)
class Partition:
    """A vertical slice: all rows × columns [col_start, col_start+cols)."""

    rows: int
    col_start: int
    cols: int

    def __post_init__(self) -> None:
        if self.cols < 1 or self.col_start < 0 or self.rows < 1:
            raise ValueError(f"invalid partition {self!r}")

    @property
    def col_end(self) -> int:
        return self.col_start + self.cols

    @property
    def n_pes(self) -> int:
        return self.rows * self.cols

    def adjacent(self, other: "Partition") -> bool:
        return self.col_end == other.col_start or other.col_end == self.col_start

    def merge(self, other: "Partition") -> "Partition":
        if not self.adjacent(other):
            raise ValueError(f"cannot merge non-adjacent {self} and {other}")
        return Partition(rows=self.rows,
                         col_start=min(self.col_start, other.col_start),
                         cols=self.cols + other.cols)

    def __str__(self) -> str:  # matches the paper's "128x16" notation
        return f"{self.rows}x{self.cols}@{self.col_start}"


def partition_calculation(array: ArrayShape, n_available: int) -> list[Partition]:
    """Fig. 5 lines 15–19: split into ``n_available`` equal vertical slices.

    ``PE_x' = PE_x`` (rows untouched); ``PE_y' = ⌊PE_y / n⌋``.  Any remainder
    columns are given to the *first* partition (the paper floors every
    partition; leaving remainder columns dark would waste PEs, and
    Task_Assignment's heaviest-first order puts the largest layer there).
    """
    if n_available < 1:
        raise ValueError("n_available must be >= 1")
    n = min(n_available, array.cols)  # cannot have zero-width partitions
    base = array.cols // n
    rem = array.cols - base * n
    parts: list[Partition] = []
    col = 0
    for i in range(n):
        width = base + (rem if i == 0 else 0)
        parts.append(Partition(rows=array.rows, col_start=col, cols=width))
        col += width
    return parts


@dataclasses.dataclass(frozen=True)
class Assignment:
    """One Task_Assignment result: a ready layer bound to a partition."""

    tenant: str          # DNNG name
    layer_index: int
    layer: LayerShape
    partition: Partition


def task_assignment(
    ready: Sequence[tuple[str, int, LayerShape]],
    partitions: Sequence[Partition],
) -> list[Assignment]:
    """Fig. 5 lines 20–27: heaviest layer (by ``Opr``) → largest partition.

    ``ready`` holds (tenant, layer_index, layer) tuples.  Returns one
    :class:`Assignment` per matched (layer, partition) pair; extra layers (if
    more layers than partitions) or extra partitions are left unmatched —
    the scheduler re-runs on the next event.
    """
    if len(ready) == 1 and len(partitions) == 1:
        # the steady-state common case under open-loop load: one waiting
        # layer, one merged free slice — no sorts needed
        tenant, idx, layer = ready[0]
        return [Assignment(tenant=tenant, layer_index=idx, layer=layer,
                           partition=partitions[0])]
    layers = sorted(ready, key=lambda t: t[2].opr, reverse=True)
    parts = sorted(partitions, key=lambda p: p.n_pes, reverse=True)
    out: list[Assignment] = []
    for (tenant, idx, layer), part in zip(layers, parts):
        out.append(Assignment(tenant=tenant, layer_index=idx, layer=layer,
                              partition=part))
    return out


class PartitionSet:
    """Mutable free/busy column-interval state with merge-on-free (§3.3).

    Invariants (checked by :meth:`check`):
      * free + busy intervals exactly tile [0, cols) with no overlap;
      * free intervals are maximal (no two adjacent free intervals) after any
        public mutation — i.e. merging is eager, as in the paper.
    """

    def __init__(self, array: ArrayShape):
        self.array = array
        self._free: list[Partition] = [
            Partition(rows=array.rows, col_start=0, cols=array.cols)
        ]
        self._busy: dict[str, Partition] = {}  # tenant -> partition

    # -- queries -----------------------------------------------------------
    @property
    def free_partitions(self) -> list[Partition]:
        if len(self._free) <= 1:
            return list(self._free)
        return sorted(self._free, key=lambda p: p.col_start)

    @property
    def busy_partitions(self) -> dict[str, Partition]:
        return dict(self._busy)

    def busy_view(self) -> dict[str, Partition]:
        """The live tenant→partition mapping, WITHOUT the defensive copy of
        :attr:`busy_partitions`.  Read-only by contract — the scheduler
        hands it to policy contexts once per rebalance round so every
        policy call sees current occupancy with zero per-round copies."""
        return self._busy

    def largest_free(self) -> Optional[Partition]:
        return max(self._free, key=lambda p: p.n_pes, default=None)

    @property
    def utilization(self) -> float:
        busy = sum(p.n_pes for p in self._busy.values())
        return busy / (self.array.rows * self.array.cols)

    # -- mutations ----------------------------------------------------------
    def allocate(self, tenant: str, cols: int) -> Partition:
        """Carve ``cols`` columns for ``tenant`` from the largest free slice."""
        if tenant in self._busy:
            raise ValueError(f"tenant {tenant!r} already holds {self._busy[tenant]}")
        slot = None
        # best-fit: smallest free slice that still fits, to keep big slices whole
        for p in sorted(self._free, key=lambda p: p.n_pes):
            if p.cols >= cols:
                slot = p
                break
        if slot is None:
            raise ValueError(f"no free slice with {cols} columns "
                             f"(free={self.free_partitions})")
        self._free.remove(slot)
        got = Partition(rows=slot.rows, col_start=slot.col_start, cols=cols)
        if slot.cols > cols:
            self._free.append(Partition(rows=slot.rows,
                                        col_start=slot.col_start + cols,
                                        cols=slot.cols - cols))
        self._busy[tenant] = got
        return got

    def allocate_exact(self, tenant: str, part: Partition) -> Partition:
        """Claim an exact free slice (used when following task_assignment)."""
        if tenant in self._busy:
            raise ValueError(f"tenant {tenant!r} already holds a partition")
        for p in self._free:
            if p.col_start <= part.col_start and p.col_end >= part.col_end:
                self._free.remove(p)
                if p.col_start < part.col_start:
                    self._free.append(Partition(rows=p.rows, col_start=p.col_start,
                                                cols=part.col_start - p.col_start))
                if p.col_end > part.col_end:
                    self._free.append(Partition(rows=p.rows, col_start=part.col_end,
                                                cols=p.col_end - part.col_end))
                self._busy[tenant] = part
                return part
        raise ValueError(f"{part} is not inside any free slice")

    def free(self, tenant: str) -> Partition:
        """Release a tenant's partition and eagerly merge adjacent free slices."""
        part = self._busy.pop(tenant, None)
        if part is None:
            raise KeyError(f"tenant {tenant!r} holds no partition")
        self._free.append(part)
        self._merge_free()
        return part

    def _merge_free(self) -> None:
        if len(self._free) <= 1:
            return
        self._free.sort(key=lambda p: p.col_start)
        merged: list[Partition] = []
        for p in self._free:
            if merged and merged[-1].col_end == p.col_start:
                merged[-1] = merged[-1].merge(p)
            else:
                merged.append(p)
        self._free = merged

    # -- invariant check (used by hypothesis property tests) ----------------
    def check(self) -> None:
        ivals = sorted(
            [(p.col_start, p.col_end, "free") for p in self._free]
            + [(p.col_start, p.col_end, t) for t, p in self._busy.items()]
        )
        cursor = 0
        for s, e, _tag in ivals:
            if s != cursor:
                raise AssertionError(f"gap/overlap at column {cursor}: {ivals}")
            cursor = e
        if cursor != self.array.cols:
            raise AssertionError(f"intervals end at {cursor} != {self.array.cols}")
        frees = sorted(self._free, key=lambda p: p.col_start)
        for a, b in itertools.pairwise(frees):
            if a.col_end == b.col_start:
                raise AssertionError(f"unmerged adjacent free slices {a},{b}")
