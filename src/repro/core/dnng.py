"""Deep Neural Network Graph (DNNG) — the paper's workload abstraction (§2.1).

A DNNG is a weighted DAG ``G(V, E)`` whose vertices are layers and whose edges
encode execution precedence.  Each layer carries the 9 convolution shape
parameters ``{M, N, C, R, S, H, W, P, Q}`` (paper Eq. 1):

    FW    ∈ R^{M×C×R×S}   — filter weights   (M filters, C channels, R×S kernel)
    IFMap ∈ R^{N×C×H×W}   — input feature map (N batch, H×W spatial)
    OFMap ∈ R^{N×M×P×Q}   — output feature map (P×Q output spatial)

``Opr(l) = M·N·C·R·S·H·W`` (paper Eq. 2) estimates the MAC count and is the
priority key of the Task_Assignment step of Algorithm 1.

Every layer lowers to a GEMM for the weight-stationary systolic array:

    stationary (weights):  K × M   with K = C·R·S   (K on PE rows, M on PE cols)
    streamed  (im2col):    T × K   with T = N·P·Q   (T input rows streamed)

Fully connected / recurrent layers are expressed with R=S=1, H=W=P=Q=1 and the
batch/time steps folded into N.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterator, Sequence


@functools.lru_cache(maxsize=4096)
def _chain_edges(n_layers: int) -> tuple[tuple[int, int], ...]:
    """Edge list of the default linear chain (shared across the thousands
    of per-job DNNG clones the open-loop traffic generator stamps out)."""
    return tuple((i, i + 1) for i in range(n_layers - 1))


@functools.lru_cache(maxsize=4096)
def _pred_table(edges: tuple[tuple[int, int], ...],
                n_layers: int) -> tuple[tuple[int, ...], ...]:
    """Predecessor indices per layer, precomputed once per graph shape.

    The dynamic scheduler asks for predecessors on every ready-set update;
    rebuilding the edge scan per query was the single hottest line of the
    serving hot path before this cache (see benchmarks/scale_bench.py).
    """
    preds: list[list[int]] = [[] for _ in range(n_layers)]
    for s, d in edges:
        preds[d].append(s)
    return tuple(tuple(p) for p in preds)


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """The 9 shape parameters of one DNN layer (paper Eq. 1)."""

    M: int  # number of filters (output channels)
    N: int  # batch size
    C: int  # input channels
    R: int  # filter height
    S: int  # filter width
    H: int  # input height
    W: int  # input width
    P: int  # output height
    Q: int  # output width
    name: str = ""

    def __post_init__(self) -> None:
        for f in ("M", "N", "C", "R", "S", "H", "W", "P", "Q"):
            v = getattr(self, f)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"LayerShape.{f} must be a positive int, got {v!r}")

    def __hash__(self) -> int:
        # memoized: LayerShape keys every hot cost-oracle memo (ws_cost /
        # layer_cost LRUs, stage-cost dicts), and the generated dataclass
        # hash re-tuples all 10 fields per lookup.  Frozen blocks setattr
        # but not __dict__ writes; equal instances hash equal because the
        # memo is derived from the same field tuple eq compares.
        h = self.__dict__.get("_hash")
        if h is None:
            self.__dict__["_hash"] = h = hash(
                (self.M, self.N, self.C, self.R, self.S,
                 self.H, self.W, self.P, self.Q, self.name))
        return h

    # -- paper Eq. 2 ------------------------------------------------------
    @property
    def opr(self) -> int:
        """MAC-operation count ``Opr(l) = M·N·C·R·S·H·W``.

        Note: the paper uses H·W (input spatial) rather than P·Q; we keep the
        paper's formula for priority ordering and expose :meth:`macs` as the
        exact count used by the cycle/energy models.  Memoized like
        ``__hash__``: it is the sort key of every Task_Assignment round.
        """
        v = self.__dict__.get("_opr")
        if v is None:
            self.__dict__["_opr"] = v = (self.M * self.N * self.C * self.R
                                         * self.S * self.H * self.W)
        return v

    @property
    def macs(self) -> int:
        """Exact MAC count of the lowered GEMM: M·N·C·R·S·P·Q."""
        return self.M * self.N * self.C * self.R * self.S * self.P * self.Q

    # -- GEMM lowering (weight stationary) --------------------------------
    @property
    def gemm_k(self) -> int:
        """Reduction dim = C·R·S (maps to PE rows; weights are stationary)."""
        return self.C * self.R * self.S

    @property
    def gemm_n(self) -> int:
        """Output-channel dim = M (maps to PE columns — the partitioned dim)."""
        return self.M

    @property
    def gemm_m(self) -> int:
        """Streamed dim = N·P·Q (rows of im2col input fed through the array)."""
        return self.N * self.P * self.Q

    @property
    def weight_bytes(self) -> int:
        return 2 * self.gemm_k * self.gemm_n  # bf16/int16 as in Scale-Sim configs

    @property
    def ifmap_elems(self) -> int:
        return self.N * self.C * self.H * self.W

    @property
    def ofmap_elems(self) -> int:
        return self.N * self.M * self.P * self.Q

    @staticmethod
    def conv(name: str, M: int, C: int, R: int, S: int, H: int, W: int,
             stride: int = 1, pad: int | None = None, N: int = 1) -> "LayerShape":
        """Build a conv layer; output spatial derived from stride/padding."""
        if pad is None:
            pad = R // 2
        P = (H + 2 * pad - R) // stride + 1
        Q = (W + 2 * pad - S) // stride + 1
        return LayerShape(M=M, N=N, C=C, R=R, S=S, H=H, W=W, P=max(P, 1),
                          Q=max(Q, 1), name=name)

    @staticmethod
    def fc(name: str, in_features: int, out_features: int, batch: int = 1) -> "LayerShape":
        """Fully connected layer: GEMM (batch × in) · (in × out)."""
        return LayerShape(M=out_features, N=batch, C=in_features, R=1, S=1,
                          H=1, W=1, P=1, Q=1, name=name)

    @staticmethod
    def lstm_cell(name: str, input_size: int, hidden: int, steps: int,
                  batch: int = 1) -> "LayerShape":
        """LSTM cell unrolled over ``steps``: 4 gate GEMMs of (in+hid)→hid.

        Expressed as one GEMM with K = input_size + hidden, M = 4·hidden and
        the time steps folded into the streamed dimension.
        """
        return LayerShape(M=4 * hidden, N=batch * steps, C=input_size + hidden,
                          R=1, S=1, H=1, W=1, P=1, Q=1, name=name)


@dataclasses.dataclass(frozen=True)
class DNNG:
    """A DNN graph: a named chain/DAG of layers with an arrival time (§2.1).

    ``edges`` holds (src, dst) layer-index pairs.  The common case (and all the
    paper's workloads) is a linear chain, which is the default when ``edges``
    is None.  ``arrival_time`` is A_t in cycles (or seconds — units follow the
    simulator's clock).
    """

    name: str
    layers: tuple[LayerShape, ...]
    arrival_time: float = 0.0
    edges: tuple[tuple[int, int], ...] | None = None

    def __post_init__(self) -> None:
        if not self.layers:
            raise ValueError(f"DNNG {self.name!r} has no layers")
        n = len(self.layers)
        if self.edges is not None:
            for s, d in self.edges:
                if not (0 <= s < n and 0 <= d < n):
                    raise ValueError(f"edge ({s},{d}) out of range for {n} layers")
                if s >= d:
                    raise ValueError(f"edge ({s},{d}) violates topological order")

    @property
    def edge_list(self) -> tuple[tuple[int, int], ...]:
        if self.edges is not None:
            return self.edges
        return _chain_edges(len(self.layers))

    @property
    def pred_table(self) -> tuple[tuple[int, ...], ...]:
        """Predecessors per layer index, cached per graph shape — the
        scheduler's O(1) DAG-readiness lookup."""
        return _pred_table(self.edge_list, len(self.layers))

    def predecessors(self, idx: int) -> list[int]:
        return list(self.pred_table[idx])

    def successors(self, idx: int) -> list[int]:
        return [d for s, d in self.edge_list if s == idx]

    def roots(self) -> list[int]:
        """Layers with no predecessors (ready at arrival)."""
        dsts = {d for _, d in self.edge_list}
        return [i for i in range(len(self.layers)) if i not in dsts]

    def clone(self, name: str | None = None,
              arrival_time: float | None = None) -> "DNNG":
        """Re-stamp a validated template with a new name / arrival.

        The open-loop traffic generator clones one Table-1 template per
        arriving job; this skips ``dataclasses.replace``'s re-validation
        (the layer tuple and edges are shared, already-validated objects)
        — measurably cheaper at thousands of jobs per run.
        """
        g = object.__new__(DNNG)
        d = g.__dict__
        d["name"] = self.name if name is None else name
        d["layers"] = self.layers
        d["arrival_time"] = (self.arrival_time if arrival_time is None
                             else arrival_time)
        d["edges"] = self.edges
        return g

    @property
    def total_macs(self) -> int:
        return sum(layer.macs for layer in self.layers)

    @property
    def total_opr(self) -> int:
        return sum(layer.opr for layer in self.layers)

    def __iter__(self) -> Iterator[LayerShape]:
        return iter(self.layers)

    def __len__(self) -> int:
        return len(self.layers)


def chain(name: str, layers: Sequence[LayerShape], arrival_time: float = 0.0) -> DNNG:
    """Convenience constructor for the (ubiquitous) linear-chain DNNG."""
    return DNNG(name=name, layers=tuple(layers), arrival_time=arrival_time)


def validate_dag(g: DNNG) -> bool:
    """Property-test hook: the edge list must be acyclic & topologically sorted."""
    seen: set[int] = set()
    for s, d in g.edge_list:
        if d in seen and s not in seen:
            return False
        seen.add(s)
        seen.add(d)
    return all(s < d for s, d in g.edge_list)


def estimated_execution_time(g: DNNG, macs_per_cycle: float) -> float:
    """E_t estimate used by Algorithm 1 line 8 (coarse: MACs / throughput)."""
    if macs_per_cycle <= 0:
        raise ValueError("macs_per_cycle must be positive")
    return g.total_macs / macs_per_cycle
