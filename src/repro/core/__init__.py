"""The paper's primary contribution: DNNG model, Algorithm 1, scheduler, dataflow."""

from repro.core.dnng import DNNG, LayerShape, chain
from repro.core.partition import (
    ArrayShape,
    Assignment,
    Partition,
    PartitionSet,
    partition_calculation,
    task_assignment,
)
from repro.core.scheduler import (
    DynamicScheduler,
    ScheduleResult,
    TraceEvent,
    schedule_dynamic,
    schedule_sequential,
)
from repro.core.dataflow import GEMM, DataflowCost, ws_cost, utilization

__all__ = [
    "DNNG", "LayerShape", "chain",
    "ArrayShape", "Assignment", "Partition", "PartitionSet",
    "partition_calculation", "task_assignment",
    "DynamicScheduler",
    "ScheduleResult", "TraceEvent", "schedule_dynamic", "schedule_sequential",
    "GEMM", "DataflowCost", "ws_cost", "utilization",
]
