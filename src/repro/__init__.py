"""`repro` — dynamic resource partitioning for multi-tenant systolic arrays.

The stable top-level surface:

    from repro import Session, ServeConfig, serve, list_policies

    res = Session(policy="moca").serve("mmpp", rate=40.0, horizon=1.0,
                                       memory=True)

Everything here is a lazy re-export (PEP 562): ``import repro`` stays
cheap, and each subsystem (`repro.traffic`, `repro.chaos`, `repro.obs`)
is only imported when its name is actually touched — the package keeps
the "api importable without traffic" layering the submodules promise.
"""

from __future__ import annotations

__all__ = [
    "Session",
    "serve",
    "ServeConfig",
    "list_policies",
    "FaultPlan",
    "Observability",
]

#: public name -> defining module (resolved on first attribute access)
_EXPORTS = {
    "Session": "repro.api.session",
    "ServeConfig": "repro.api.config",
    "list_policies": "repro.api.policy",
    "serve": "repro.traffic.simulator",
    "FaultPlan": "repro.chaos",
    "Observability": "repro.obs",
}


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value       # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
