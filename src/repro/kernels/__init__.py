"""Pallas TPU kernels: the paper's partitioned-WS GEMM (+ oracle & wrappers)."""

from repro.kernels.ops import build_owner_map, fused_tenant_gemm
from repro.kernels.partitioned_matmul import partitioned_matmul
from repro.kernels.ref import matmul_ref, partitioned_matmul_ref

__all__ = [
    "build_owner_map",
    "fused_tenant_gemm",
    "partitioned_matmul",
    "matmul_ref",
    "partitioned_matmul_ref",
]
