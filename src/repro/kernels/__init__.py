"""Pallas TPU kernels: the paper's partitioned-WS GEMM (+ oracle & wrappers)."""

from repro.kernels.ops import (
    BLOCK_CANDIDATES,
    FusedGemmStats,
    autotune_blocks,
    build_owner_map,
    fused_tenant_gemm,
)
from repro.kernels.partitioned_matmul import (
    GRID_MODES,
    VMEM_BUDGET_BYTES,
    BlockAccounting,
    block_vmem_bytes,
    grid_accounting,
    live_block_tables,
    partitioned_matmul,
)
from repro.kernels.ref import matmul_ref, partitioned_matmul_ref

__all__ = [
    "BLOCK_CANDIDATES",
    "BlockAccounting",
    "FusedGemmStats",
    "GRID_MODES",
    "VMEM_BUDGET_BYTES",
    "autotune_blocks",
    "block_vmem_bytes",
    "build_owner_map",
    "fused_tenant_gemm",
    "grid_accounting",
    "live_block_tables",
    "matmul_ref",
    "partitioned_matmul",
    "partitioned_matmul_ref",
]
