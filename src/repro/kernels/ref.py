"""Pure-jnp oracles for the Pallas kernels (the allclose references).

Semantics contract shared with ``partitioned_matmul.py``:

* ``xs``      — (E, T, K): one (padded) activation matrix per tenant.  Rows
  at/after ``valid_t[e]`` and K-columns beyond the tenant's true K MUST be
  zero-padded by the caller (zeros contribute nothing to any dot product —
  this is how per-tenant ragged shapes stay exact inside one fused grid).
* ``w``       — (K, N): all tenants' weight matrices concatenated along N —
  the *column/partition* dimension of the paper's systolic array.
* ``owner``   — (N // block_n,) int32: which tenant owns each column block
  (the partition map of Algorithm 1; contiguous runs = vertical partitions).
* ``valid_t`` — (E,) int32: number of valid streamed rows per tenant.  Blocks
  entirely past ``valid_t[owner]`` are skipped by the kernel (the ``Mul_En``
  tri-state analogue); the oracle zeroes them explicitly.

Output — (T, N) f32: column block j equals ``xs[owner[j]] @ w[:, block j]``
with rows >= valid_t[owner[j]] equal to zero.
"""

from __future__ import annotations

import jax.numpy as jnp


def partitioned_matmul_ref(xs: jnp.ndarray, w: jnp.ndarray,
                           owner: jnp.ndarray, valid_t: jnp.ndarray,
                           block_n: int) -> jnp.ndarray:
    """O(E·T·K·N) reference for the multi-tenant partitioned GEMM."""
    E, T, K = xs.shape
    K2, N = w.shape
    assert K2 == K, (K2, K)
    assert N % block_n == 0
    n_blocks = N // block_n
    assert owner.shape == (n_blocks,)

    # out[:, j] = xs[owner[j]] @ w[:, j] — computed densely then masked.
    # (E, T, N) full cross-product, then select the owner's plane per block.
    full = jnp.einsum("etk,kn->etn", xs.astype(jnp.float32),
                      w.astype(jnp.float32))
    owner_per_col = jnp.repeat(owner, block_n)              # (N,)
    out = jnp.take_along_axis(
        full, owner_per_col[None, None, :].repeat(T, axis=1), axis=0)[0]
    # Mul_En masking: rows past the owning tenant's valid_t are zero.
    rows = jnp.arange(T)[:, None]
    live = rows < valid_t[owner_per_col][None, :]
    return jnp.where(live, out, 0.0)


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain GEMM oracle (single-tenant baseline)."""
    return x.astype(jnp.float32) @ w.astype(jnp.float32)
