"""Multi-tenant partitioned weight-stationary GEMM — the paper's kernel on TPU.

The paper partitions a 128×128 weight-stationary systolic array *vertically*:
every tenant owns all PE rows and a contiguous range of PE **columns**, and a
one-gate PE change (``Mul_En``) keeps foreign data flowing through without
firing the multiplier.  The TPU has no per-PE enable, so the insight is
re-expressed structurally (DESIGN.md §2):

* PE columns        →  the GEMM **N dimension** (output channels / lanes);
* vertical slices   →  disjoint contiguous **N-block ranges**, one per tenant
  (``owner`` map — the partition table of Algorithm 1);
* ``Mul_En`` gating →  a three-rung ladder, each rung cheaper than the last:
  (a) the grid's index map never routes tenant A's activations against
  tenant B's weight columns; (b) in ``grid_mode="dense"`` a ``pl.when``
  keeps dead blocks (past a tenant's valid streamed rows / reduction depth)
  from firing the MXU — compute is *gated*, but the block still costs a
  grid step and its HBM→VMEM fetches; (c) in ``grid_mode="compact"``
  host-built scalar-prefetch index tables enumerate **only the live
  blocks**, so dead work is *not scheduled* and its operands are *not
  fetched* — the true zero-cost ``Mul_En``: gated → not-scheduled →
  not-fetched;
* load/feed/drain SRAM buffers → the HBM→VMEM BlockSpec pipeline (weights
  double-buffered into VMEM = ① load; activation stream = ② feed; the f32
  accumulator flushed at the last K step = ③ drain).

All tenants execute inside ONE fused ``pallas_call`` grid, so a single TPU
core is time/space-shared among tenants exactly like the paper's single
systolic array — no per-tenant kernel launches, no dead lanes between
partitions (ragged edges are zero-padded, not recomputed).

Dense grid layout: ``(n_blocks, t_blocks, k_blocks)`` with K innermost — the
f32 accumulator tile stays resident in VMEM across the K reduction (the TPU
analogue of partial sums flowing down the array's columns) and is drained
once per (n, t) tile.  The compact grid flattens the same iteration space to
a 1-D walk over live ``(n, t, k)`` triples with every K-run kept contiguous,
so the accumulator discipline is unchanged — only the dead steps between
runs disappear.

Scalar-prefetch operands (``owner``, ``valid_t``, ``valid_k`` — and in
compact mode the live-block index tables) are the dynamic partition state:
Algorithm 1 re-computes them per scheduling round on the host, and the SAME
compiled kernel serves any partition layout of the same geometry — that is
what makes the partitioning *dynamic* at zero recompile cost.  (The compact
grid's *length* is the live-block count, so layouts with different padding
compile separate grids; :func:`repro.kernels.ops.fused_tenant_gemm` weighs
that trade when ``grid_mode="auto"``.)
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:  # pragma: no cover - depends on jax version
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported by "
        "repro.kernels.partitioned_matmul")

# MXU/VREG-aligned defaults: 128-multiples on the matmul dims.
DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_N = 128

# Per-core VMEM capacity the block working set must fit in (TPU v3/v4 class
# hardware carries ~16 MiB of VMEM per core).  ``partitioned_matmul``
# enforces this budget explicitly — see :func:`block_vmem_bytes`.
VMEM_BUDGET_BYTES = 16 * 2 ** 20

_ALLOWED_DTYPES = (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32))

GRID_MODES = ("dense", "compact")


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def block_vmem_bytes(block_t: int, block_k: int, block_n: int,
                     x_dtype, w_dtype) -> int:
    """VMEM working set of one grid step: double-buffered x/w/out tiles
    (Pallas overlaps the next fetch with the current compute) plus the
    grid-resident f32 accumulator tile."""
    x_tile = block_t * block_k * jnp.dtype(x_dtype).itemsize
    w_tile = block_k * block_n * jnp.dtype(w_dtype).itemsize
    out_tile = block_t * block_n * 4  # f32 output
    acc_tile = block_t * block_n * 4  # f32 scratch accumulator
    return 2 * (x_tile + w_tile + out_tile) + acc_tile


def _validate_promote(xs: jax.Array, w: jax.Array) -> tuple[jax.Array,
                                                            jax.Array]:
    """Enforce the bf16/f32 operand contract; promote mixed pairs to f32."""
    for name, arr in (("xs", xs), ("w", w)):
        if jnp.dtype(arr.dtype) not in _ALLOWED_DTYPES:
            raise TypeError(
                f"{name} dtype {arr.dtype} unsupported: the partitioned-WS "
                "kernel accepts bfloat16 or float32 operands (cast ints / "
                "f16 / f64 on the host first)")
    if xs.dtype != w.dtype:  # bf16 × f32 → promote both to f32
        common = jnp.promote_types(xs.dtype, w.dtype)
        xs, w = xs.astype(common), w.astype(common)
    return xs, w


# ---------------------------------------------------------------------------
# live-block enumeration + accounting (host side, concrete partition state)
# ---------------------------------------------------------------------------

def _live_extents(owner: np.ndarray, valid_t: np.ndarray,
                  valid_k: np.ndarray, *, T: int, K: int, block_t: int,
                  block_k: int) -> tuple[np.ndarray, np.ndarray]:
    """Per N-block live extents: (t_blocks_live, k_blocks_live) arrays.

    A block column owned by tenant ``e`` has ``ceil(valid_t[e]/block_t)``
    live T-blocks and ``ceil(valid_k[e]/block_k)`` live K-blocks — live
    blocks always form a contiguous prefix, which is what keeps compact
    K-runs contiguous for the VMEM accumulator.
    """
    vt = np.clip(valid_t[owner], 0, T)
    vk = np.clip(valid_k[owner], 0, K)
    tl = -(-vt // block_t)
    kl = -(-vk // block_k)
    tl = np.where(kl > 0, tl, 0)  # a zero-depth reduction has no live tiles
    return tl.astype(np.int64), kl.astype(np.int64)


def _tables_from_extents(tl: np.ndarray, kl: np.ndarray
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]:
    nidx, tidx, kidx, last = [], [], [], []
    for n in range(tl.shape[0]):
        kn = int(kl[n])
        for t in range(int(tl[n])):
            for k in range(kn):
                nidx.append(n)
                tidx.append(t)
                kidx.append(k)
                last.append(1 if k == kn - 1 else 0)
    return (np.asarray(nidx, np.int32), np.asarray(tidx, np.int32),
            np.asarray(kidx, np.int32), np.asarray(last, np.int32))


def live_block_tables(owner, valid_t, valid_k, *, T: int, K: int,
                      block_t: int = DEFAULT_BLOCK_T,
                      block_k: int = DEFAULT_BLOCK_K
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """Flattened compact-grid index tables ``(nidx, tidx, kidx, last_k)``.

    Entry ``i`` names the ``(n, t, k)`` block the ``i``-th grid step should
    execute; ``last_k[i]`` flags the final step of its K-run (the drain
    point).  K is innermost and every K-run is contiguous, so the resident
    accumulator works exactly as in the dense grid.
    """
    tl, kl = _live_extents(np.asarray(owner, np.int64),
                           np.asarray(valid_t, np.int64),
                           np.asarray(valid_k, np.int64),
                           T=T, K=K, block_t=block_t, block_k=block_k)
    return _tables_from_extents(tl, kl)


@dataclasses.dataclass(frozen=True)
class BlockAccounting:
    """Per-call grid/traffic accounting of one ``partitioned_matmul``.

    ``blocks_total`` is the dense iteration space ``n·t·k``;
    ``blocks_scheduled`` is what the chosen grid mode actually walks
    (dense: all of it; compact: live blocks only); ``blocks_live`` is the
    MXU-firing subset; ``blocks_skipped`` are scheduled-but-gated steps —
    each one still pays its grid step and HBM→VMEM block fetches, which is
    precisely the waste the compact grid deletes.  Byte counts follow the
    one-fetch-per-scheduled-step pipeline model (x and w tiles in, one
    f32 out tile per drained (n, t) run).
    """

    grid_mode: str
    block_t: int
    block_k: int
    block_n: int
    blocks_total: int
    blocks_scheduled: int
    blocks_live: int
    blocks_skipped: int
    x_bytes_fetched: int
    w_bytes_fetched: int
    out_bytes_written: int

    @property
    def bytes_fetched(self) -> int:
        return self.x_bytes_fetched + self.w_bytes_fetched

    @property
    def schedule_efficiency(self) -> float:
        """Live fraction of scheduled steps (1.0 = zero dead work)."""
        return (self.blocks_live / self.blocks_scheduled
                if self.blocks_scheduled else 1.0)

    def as_dict(self) -> dict:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)} | {
                    "bytes_fetched": self.bytes_fetched,
                    "schedule_efficiency": self.schedule_efficiency}


def grid_accounting(*, T: int, K: int, N: int, owner, valid_t, valid_k=None,
                    block_t: int = DEFAULT_BLOCK_T,
                    block_k: int = DEFAULT_BLOCK_K,
                    block_n: int = DEFAULT_BLOCK_N,
                    x_dtype=jnp.float32, w_dtype=jnp.float32,
                    grid_mode: str = "dense") -> BlockAccounting:
    """Predict the grid/traffic accounting of a ``partitioned_matmul`` call.

    Pure host arithmetic over the concrete partition state — the same
    numbers the compact path realises, usable as a pre-flight cost model
    (the block-size autotuner ranks candidates with it).
    """
    if grid_mode not in GRID_MODES:
        raise ValueError(f"grid_mode must be one of {GRID_MODES}, "
                         f"got {grid_mode!r}")
    owner = np.asarray(owner, np.int64)
    valid_t = np.asarray(valid_t, np.int64)
    valid_k = (np.full(valid_t.shape, K, np.int64) if valid_k is None
               else np.asarray(valid_k, np.int64))
    n_blocks = _ceil_div(N, block_n)
    t_blocks = _ceil_div(T, block_t)
    k_blocks = _ceil_div(K, block_k)
    tl, kl = _live_extents(owner, valid_t, valid_k, T=T, K=K,
                           block_t=block_t, block_k=block_k)
    live = int((tl * kl).sum())
    live_runs = int(tl.sum())          # drained (n, t) tiles
    total = n_blocks * t_blocks * k_blocks
    if grid_mode == "dense":
        scheduled, runs = total, n_blocks * t_blocks
    else:
        scheduled, runs = live, live_runs
    x_item = jnp.dtype(x_dtype).itemsize
    w_item = jnp.dtype(w_dtype).itemsize
    return BlockAccounting(
        grid_mode=grid_mode, block_t=block_t, block_k=block_k,
        block_n=block_n, blocks_total=total, blocks_scheduled=scheduled,
        blocks_live=live, blocks_skipped=scheduled - live,
        x_bytes_fetched=scheduled * block_t * block_k * x_item,
        w_bytes_fetched=scheduled * block_k * block_n * w_item,
        out_bytes_written=runs * block_t * block_n * 4)


# ---------------------------------------------------------------------------
# dense grid (every (n, t, k) scheduled; dead blocks gated by pl.when)
# ---------------------------------------------------------------------------

def _dense_kernel(owner_ref, valid_t_ref, valid_k_ref, x_ref, w_ref, o_ref,
                  acc_ref, *, n_k_blocks: int, block_t: int, block_k: int):
    """One (n, t, k) grid step: acc += x_blk @ w_blk for the owning tenant."""
    t = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Mul_En rung (b): blocks entirely past the owning tenant's valid rows
    # (T) or valid reduction depth (K) never fire the MXU — but they are
    # still scheduled and fetched; the compact grid deletes even that.
    n = pl.program_id(0)
    tenant = owner_ref[n]
    live = (t * block_t < valid_t_ref[tenant]) \
        & (k * block_k < valid_k_ref[tenant])

    @pl.when(live)
    def _mac():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0], w_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == n_k_blocks - 1)
    def _drain():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_t", "block_k", "block_n", "interpret"))
def _dense_call(xs: jax.Array, w: jax.Array, owner: jax.Array,
                valid_t: jax.Array, valid_k: jax.Array, *,
                block_t: int, block_k: int, block_n: int,
                interpret: bool) -> jax.Array:
    E, T, K = xs.shape
    _, N = w.shape
    n_blocks, t_blocks, k_blocks = N // block_n, T // block_t, K // block_k
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_blocks, t_blocks, k_blocks),
        in_specs=[
            # ② feed: the OWNING tenant's activation block — the index map
            # is the partition routing (never crosses a partition edge).
            pl.BlockSpec((1, block_t, block_k),
                         lambda n, t, k, owner, vt, vk: (owner[n], t, k)),
            # ① load: stationary weight column-block of this partition.
            pl.BlockSpec((block_k, block_n),
                         lambda n, t, k, owner, vt, vk: (k, n)),
        ],
        # ③ drain: one output tile per (t, n), revisited across k.
        out_specs=pl.BlockSpec((block_t, block_n),
                               lambda n, t, k, owner, vt, vk: (t, n)),
        scratch_shapes=[pltpu.VMEM((block_t, block_n), jnp.float32)],
    )
    kernel = functools.partial(_dense_kernel, n_k_blocks=k_blocks,
                               block_t=block_t, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, N), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(owner.astype(jnp.int32), valid_t.astype(jnp.int32),
      valid_k.astype(jnp.int32), xs, w)


# ---------------------------------------------------------------------------
# compact grid (live blocks only, via scalar-prefetch index tables)
# ---------------------------------------------------------------------------

def _compact_kernel(xidx_ref, nidx_ref, tidx_ref, kidx_ref, last_ref,
                    x_ref, w_ref, o_ref, acc_ref):
    """One live block.  Every scheduled step fires the MXU — no gating."""
    i = pl.program_id(0)

    @pl.when(kidx_ref[i] == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(last_ref[i] == 1)
    def _drain():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _compact_call(xs: jax.Array, w: jax.Array, owner: np.ndarray,
                  valid_t: np.ndarray, valid_k: np.ndarray, *,
                  block_t: int, block_k: int, block_n: int,
                  interpret: bool) -> jax.Array:
    E, T, K = xs.shape
    _, N = w.shape
    tl, kl = _live_extents(np.asarray(owner, np.int64),
                           np.asarray(valid_t, np.int64),
                           np.asarray(valid_k, np.int64),
                           T=T, K=K, block_t=block_t, block_k=block_k)
    nidx, tidx, kidx, last = _tables_from_extents(tl, kl)
    if nidx.size == 0:  # nothing live: the contract output is all zeros
        return jnp.zeros((T, N), jnp.float32)
    xidx = np.asarray(owner, np.int32)[nidx]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(int(nidx.size),),
        in_specs=[
            pl.BlockSpec((1, block_t, block_k),
                         lambda i, xi, ni, ti, ki, la: (xi[i], ti[i], ki[i])),
            pl.BlockSpec((block_k, block_n),
                         lambda i, xi, ni, ti, ki, la: (ki[i], ni[i])),
        ],
        out_specs=pl.BlockSpec((block_t, block_n),
                               lambda i, xi, ni, ti, ki, la: (ti[i], ni[i])),
        scratch_shapes=[pltpu.VMEM((block_t, block_n), jnp.float32)],
    )
    out = pl.pallas_call(
        _compact_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, N), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(jnp.asarray(xidx), jnp.asarray(nidx), jnp.asarray(tidx),
      jnp.asarray(kidx), jnp.asarray(last), xs, w)
    # Tiles with no live block are never visited (never drained), so their
    # VMEM-backed output is unspecified; the contract says they are zero.
    # One host-side mask restores it — still no grid steps, no fetches.
    live_rows = np.repeat(tl * block_t, block_n)               # (N,)
    if (live_rows >= T).all():
        return out
    mask = np.arange(T)[:, None] < live_rows[None, :]
    return jnp.where(jnp.asarray(mask), out, 0.0)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

def partitioned_matmul(xs: jax.Array, w: jax.Array, owner: jax.Array,
                       valid_t: jax.Array, valid_k: jax.Array | None = None,
                       *,
                       block_t: int = DEFAULT_BLOCK_T,
                       block_k: int = DEFAULT_BLOCK_K,
                       block_n: int = DEFAULT_BLOCK_N,
                       grid_mode: str = "dense",
                       vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                       interpret: bool = False) -> jax.Array:
    """Fused multi-tenant GEMM.  See ``ref.partitioned_matmul_ref``.

    xs:      (E, T, K) — per-tenant activations, zero-padded to shared T/K.
    w:       (K, N)    — tenant weights concatenated along N.
    owner:   (N // block_n,) int32 — column-block → tenant (partition map).
    valid_t: (E,) int32 — valid streamed rows per tenant.
    valid_k: (E,) int32 — valid reduction depth per tenant (default: K).
    Returns  (T, N) f32.

    ``grid_mode="dense"`` schedules the full (n, t, k) grid and gates dead
    blocks; ``"compact"`` schedules only the live blocks via host-built
    scalar-prefetch index tables — identical results (same per-block f32
    accumulation, same K order), fewer grid steps and fetches.  Compact
    mode derives the tables from the *values* of ``owner``/``valid_t``/
    ``valid_k``, so those must be concrete (not jit tracers).

    Operands must be bfloat16 or float32 (mixed pairs promote to float32),
    and the block working set must fit ``vmem_budget_bytes`` (see
    :func:`block_vmem_bytes`).
    """
    xs, w = _validate_promote(xs, w)
    E, T, K = xs.shape
    if valid_k is None:
        valid_k = jnp.full((E,), K, jnp.int32)
    K2, N = w.shape
    if K2 != K:
        raise ValueError(f"K mismatch: xs {K} vs w {K2}")
    for name, dim, blk in (("T", T, block_t), ("K", K, block_k),
                           ("N", N, block_n)):
        if dim % blk:
            raise ValueError(f"{name}={dim} not divisible by block {blk}; "
                             "pad in ops.fused_tenant_gemm")
    need = block_vmem_bytes(block_t, block_k, block_n, xs.dtype, w.dtype)
    if need > vmem_budget_bytes:
        raise ValueError(
            f"blocks ({block_t}, {block_k}, {block_n}) need {need} B of "
            f"VMEM (double-buffered tiles + accumulator) but the budget is "
            f"{vmem_budget_bytes} B — shrink the blocks or raise "
            "vmem_budget_bytes")
    n_blocks = N // block_n
    if owner.shape != (n_blocks,):
        raise ValueError(f"owner must be ({n_blocks},), got {owner.shape}")
    if grid_mode not in GRID_MODES:
        raise ValueError(f"grid_mode must be one of {GRID_MODES}, "
                         f"got {grid_mode!r}")
    if grid_mode == "dense":
        return _dense_call(xs, w, owner, valid_t, valid_k,
                           block_t=block_t, block_k=block_k,
                           block_n=block_n, interpret=interpret)
    if any(isinstance(a, jax.core.Tracer) for a in (owner, valid_t, valid_k)):
        raise ValueError(
            "grid_mode='compact' builds host-side index tables from the "
            "partition state, so owner/valid_t/valid_k must be concrete "
            "arrays — call it outside jit (or use grid_mode='dense')")
    return _compact_call(xs, w, np.asarray(owner), np.asarray(valid_t),
                         np.asarray(valid_k), block_t=block_t,
                         block_k=block_k, block_n=block_n,
                         interpret=interpret)
