"""Multi-tenant partitioned weight-stationary GEMM — the paper's kernel on TPU.

The paper partitions a 128×128 weight-stationary systolic array *vertically*:
every tenant owns all PE rows and a contiguous range of PE **columns**, and a
one-gate PE change (``Mul_En``) keeps foreign data flowing through without
firing the multiplier.  The TPU has no per-PE enable, so the insight is
re-expressed structurally (DESIGN.md §2):

* PE columns        →  the GEMM **N dimension** (output channels / lanes);
* vertical slices   →  disjoint contiguous **N-block ranges**, one per tenant
  (``owner`` map — the partition table of Algorithm 1);
* ``Mul_En`` gating →  (a) the grid's index map never routes tenant A's
  activations against tenant B's weight columns, and (b) ``pl.when`` skips
  whole blocks beyond a tenant's valid streamed rows — compute is *not
  scheduled* rather than masked, so the "gate" costs zero cycles;
* load/feed/drain SRAM buffers → the HBM→VMEM BlockSpec pipeline (weights
  double-buffered into VMEM = ① load; activation stream = ② feed; the f32
  accumulator flushed at the last K step = ③ drain).

All tenants execute inside ONE fused ``pallas_call`` grid, so a single TPU
core is time/space-shared among tenants exactly like the paper's single
systolic array — no per-tenant kernel launches, no dead lanes between
partitions (ragged edges are zero-padded, not recomputed).

Grid layout: ``(n_blocks, t_blocks, k_blocks)`` with K innermost — the f32
accumulator tile stays resident in VMEM across the K reduction (the TPU
analogue of partial sums flowing down the array's columns) and is drained
once per (n, t) tile.

Scalar-prefetch operands (``owner``, ``valid_t``) are the dynamic partition
state: Algorithm 1 re-computes them per scheduling round on the host, and
the SAME compiled kernel serves any partition layout of the same geometry —
that is what makes the partitioning *dynamic* at zero recompile cost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:  # pragma: no cover - depends on jax version
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported by "
        "repro.kernels.partitioned_matmul")

# MXU/VREG-aligned defaults: 128-multiples on the matmul dims; the f32
# accumulator tile (block_t × block_n) plus the two operand tiles must fit
# VMEM (~16 MiB/core): 128·512·4 B + 128·512·2 B·2 ≈ 0.5 MiB per buffer set,
# leaving room for Pallas' double buffering.
DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_N = 128


def _kernel(owner_ref, valid_t_ref, valid_k_ref, x_ref, w_ref, o_ref,
            acc_ref, *, n_k_blocks: int, block_t: int, block_k: int):
    """One (n, t, k) grid step: acc += x_blk @ w_blk for the owning tenant."""
    t = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Mul_En analogue: blocks entirely past the owning tenant's valid rows
    # (T) or valid reduction depth (K) never fire the MXU.  The paper gates
    # per-PE pass-through; block-granular work-skipping is the TPU-native
    # equivalent — and skipping dead K-blocks is a beyond-paper extension
    # (the padded shared grid makes ragged K otherwise costly).
    n = pl.program_id(0)
    tenant = owner_ref[n]
    live = (t * block_t < valid_t_ref[tenant]) \
        & (k * block_k < valid_k_ref[tenant])

    @pl.when(live)
    def _mac():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0], w_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == n_k_blocks - 1)
    def _drain():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_t", "block_k", "block_n", "interpret"))
def partitioned_matmul(xs: jax.Array, w: jax.Array, owner: jax.Array,
                       valid_t: jax.Array, valid_k: jax.Array | None = None,
                       *,
                       block_t: int = DEFAULT_BLOCK_T,
                       block_k: int = DEFAULT_BLOCK_K,
                       block_n: int = DEFAULT_BLOCK_N,
                       interpret: bool = False) -> jax.Array:
    """Fused multi-tenant GEMM.  See ``ref.partitioned_matmul_ref``.

    xs:      (E, T, K) — per-tenant activations, zero-padded to shared T/K.
    w:       (K, N)    — tenant weights concatenated along N.
    owner:   (N // block_n,) int32 — column-block → tenant (partition map).
    valid_t: (E,) int32 — valid streamed rows per tenant.
    valid_k: (E,) int32 — valid reduction depth per tenant (default: K).
    Returns  (T, N) f32.
    """
    E, T, K = xs.shape
    if valid_k is None:
        valid_k = jnp.full((E,), K, jnp.int32)
    K2, N = w.shape
    if K2 != K:
        raise ValueError(f"K mismatch: xs {K} vs w {K2}")
    for name, dim, blk in (("T", T, block_t), ("K", K, block_k),
                           ("N", N, block_n)):
        if dim % blk:
            raise ValueError(f"{name}={dim} not divisible by block {blk}; "
                             "pad in ops.fused_tenant_gemm")
    n_blocks, t_blocks, k_blocks = N // block_n, T // block_t, K // block_k
    if owner.shape != (n_blocks,):
        raise ValueError(f"owner must be ({n_blocks},), got {owner.shape}")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_blocks, t_blocks, k_blocks),
        in_specs=[
            # ② feed: the OWNING tenant's activation block — the index map
            # is the partition routing (never crosses a partition edge).
            pl.BlockSpec((1, block_t, block_k),
                         lambda n, t, k, owner, vt, vk: (owner[n], t, k)),
            # ① load: stationary weight column-block of this partition.
            pl.BlockSpec((block_k, block_n),
                         lambda n, t, k, owner, vt, vk: (k, n)),
        ],
        # ③ drain: one output tile per (t, n), revisited across k.
        out_specs=pl.BlockSpec((block_t, block_n),
                               lambda n, t, k, owner, vt, vk: (t, n)),
        scratch_shapes=[pltpu.VMEM((block_t, block_n), jnp.float32)],
    )
    kernel = functools.partial(_kernel, n_k_blocks=k_blocks,
                               block_t=block_t, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, N), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(owner.astype(jnp.int32), valid_t.astype(jnp.int32),
      valid_k.astype(jnp.int32), xs, w)
