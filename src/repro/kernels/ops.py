"""Public jit'd wrappers around the Pallas kernels.

``fused_tenant_gemm`` is the host-facing API the serving engine uses: it
takes one (x, w) GEMM per tenant — arbitrary ragged shapes — pads them to a
shared grid geometry, builds the column-block ``owner`` map with the SAME
column-splitting rule as Algorithm 1 (``partition_calculation`` over N
blocks), invokes the fused kernel once, and splits the outputs back out.

The padding contract (zeros in the padded region of xs/w) is what makes the
ragged fusion exact — see ``ref.py``.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.partitioned_matmul import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_N,
    DEFAULT_BLOCK_T,
    partitioned_matmul,
)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def build_owner_map(n_cols: Sequence[int], block_n: int) -> jnp.ndarray:
    """Column-block owner map for tenants with ``n_cols[i]`` output columns.

    Each tenant's columns are padded up to a whole number of blocks, so
    partitions are contiguous block runs — the kernel-level mirror of the
    paper's vertical slices.
    """
    owners = []
    for i, n in enumerate(n_cols):
        owners += [i] * (_round_up(n, block_n) // block_n)
    return jnp.asarray(owners, jnp.int32)


def fused_tenant_gemm(xs: Sequence[jax.Array], ws: Sequence[jax.Array], *,
                      block_t: int = DEFAULT_BLOCK_T,
                      block_k: int = DEFAULT_BLOCK_K,
                      block_n: int = DEFAULT_BLOCK_N,
                      interpret: bool = False) -> list[jax.Array]:
    """Run every tenant's GEMM ``xs[i] @ ws[i]`` in ONE fused kernel call.

    xs[i]: (T_i, K_i);  ws[i]: (K_i, N_i).  Returns [(T_i, N_i) f32, ...].
    """
    if len(xs) != len(ws) or not xs:
        raise ValueError("need one (x, w) pair per tenant")
    E = len(xs)
    for i, (x, w) in enumerate(zip(xs, ws)):
        if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
            raise ValueError(f"tenant {i}: bad shapes {x.shape} @ {w.shape}")

    T = _round_up(max(x.shape[0] for x in xs), block_t)
    K = _round_up(max(x.shape[1] for x in xs), block_k)
    xs_pad = jnp.stack([
        jnp.pad(x, ((0, T - x.shape[0]), (0, K - x.shape[1])))
        for x in xs])                                     # (E, T, K)
    w_pad = jnp.concatenate([
        jnp.pad(w, ((0, K - w.shape[0]),
                    (0, _round_up(w.shape[1], block_n) - w.shape[1])))
        for w in ws], axis=1)                             # (K, N_total)

    owner = build_owner_map([w.shape[1] for w in ws], block_n)
    valid_t = jnp.asarray([x.shape[0] for x in xs], jnp.int32)
    valid_k = jnp.asarray([x.shape[1] for x in xs], jnp.int32)

    out = partitioned_matmul(xs_pad, w_pad, owner, valid_t, valid_k,
                             block_t=block_t, block_k=block_k,
                             block_n=block_n, interpret=interpret)

    outs = []
    col = 0
    for i, w in enumerate(ws):
        n_pad = _round_up(w.shape[1], block_n)
        outs.append(out[:xs[i].shape[0], col:col + w.shape[1]])
        col += n_pad
    return outs


@functools.partial(jax.jit, static_argnames=("interpret",))
def sequential_tenant_gemm(xs: Sequence[jax.Array],
                           ws: Sequence[jax.Array],
                           interpret: bool = False) -> list[jax.Array]:
    """Single-tenancy baseline: one dense GEMM per tenant, run back-to-back
    (what a non-partitioned accelerator does — the Fig. 9 baseline)."""
    return [x.astype(jnp.float32) @ w.astype(jnp.float32)
            for x, w in zip(xs, ws)]
