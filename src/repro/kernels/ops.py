"""Public jit'd wrappers around the Pallas kernels.

``fused_tenant_gemm`` is the host-facing API the serving engine uses: it
takes one (x, w) GEMM per tenant — arbitrary ragged shapes — pads them to a
shared grid geometry, builds the column-block ``owner`` map with the SAME
column-splitting rule as Algorithm 1 (``partition_calculation`` over N
blocks), invokes the fused kernel once, and splits the outputs back out.

The padding contract (zeros in the padded region of xs/w) is what makes the
ragged fusion exact — see ``ref.py``.

On top of the raw kernel this layer makes the performance decisions:

* **grid mode** — ``"auto"`` (default) schedules the compact live-block
  grid whenever the ragged mix leaves dead blocks in the dense iteration
  space, and falls back to the dense grid when every block is live (no
  index-table overhead to pay for nothing);
* **block sizes** — when not pinned by the caller, a dtype-aware autotuner
  searches MXU-aligned ``(block_t, block_k, block_n)`` candidates that fit
  the VMEM budget, ranks them by predicted HBM-fetch bytes per useful MAC
  (:func:`repro.kernels.partitioned_matmul.grid_accounting` is the cost
  model) and caches the winner per problem geometry.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.partitioned_matmul import (
    VMEM_BUDGET_BYTES,
    BlockAccounting,
    block_vmem_bytes,
    grid_accounting,
    partitioned_matmul,
)

# MXU-aligned candidate edge lengths the autotuner searches per dimension.
BLOCK_CANDIDATES = (128, 256, 512)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def build_owner_map(n_cols: Sequence[int], block_n: int) -> jnp.ndarray:
    """Column-block owner map for tenants with ``n_cols[i]`` output columns.

    Each tenant's columns are padded up to a whole number of blocks, so
    partitions are contiguous block runs — the kernel-level mirror of the
    paper's vertical slices.
    """
    owners = []
    for i, n in enumerate(n_cols):
        owners += [i] * (_round_up(n, block_n) // block_n)
    return jnp.asarray(owners, jnp.int32)


# ---------------------------------------------------------------------------
# geometry accounting + block-size autotuner
# ---------------------------------------------------------------------------

def _geometry_accounting(shapes: tuple[tuple[int, int, int], ...],
                         block_t: int, block_k: int, block_n: int,
                         x_dtype: str, w_dtype: str,
                         grid_mode: str) -> BlockAccounting:
    """Accounting for a fused call over per-tenant ``(T, K, N)`` shapes,
    after the shared-grid padding ``fused_tenant_gemm`` applies."""
    T = _round_up(max(t for t, _, _ in shapes), block_t)
    K = _round_up(max(k for _, k, _ in shapes), block_k)
    owner = np.asarray(build_owner_map([n for _, _, n in shapes], block_n))
    valid_t = np.asarray([t for t, _, _ in shapes], np.int64)
    valid_k = np.asarray([k for _, k, _ in shapes], np.int64)
    return grid_accounting(
        T=T, K=K, N=int(owner.size) * block_n, owner=owner,
        valid_t=valid_t, valid_k=valid_k, block_t=block_t, block_k=block_k,
        block_n=block_n, x_dtype=x_dtype, w_dtype=w_dtype,
        grid_mode=grid_mode)


@functools.lru_cache(maxsize=1024)
def autotune_blocks(shapes: tuple[tuple[int, int, int], ...],
                    x_dtype: str = "float32", w_dtype: str = "float32",
                    grid_mode: str = "compact",
                    vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                    candidates: tuple[int, ...] = BLOCK_CANDIDATES
                    ) -> tuple[int, int, int]:
    """Pick ``(block_t, block_k, block_n)`` for a fused-GEMM geometry.

    Exhaustive search over ``candidates³`` MXU-aligned blockings: candidates
    whose working set busts the dtype-aware VMEM budget are discarded, the
    rest are ranked by predicted fetched bytes per useful MAC (padding
    inflates fetches, so the model self-penalises oversized blocks), ties
    broken toward fewer grid steps, then smaller tiles.  Results are cached
    per geometry (``autotune_blocks.cache_info()`` exposes the hit rate) —
    serving re-tunes a layer mix once, not per batch.
    """
    useful_macs = sum(t * k * n for t, k, n in shapes) or 1
    best, best_key = None, None
    for bt in candidates:
        for bk in candidates:
            for bn in candidates:
                if block_vmem_bytes(bt, bk, bn, x_dtype,
                                    w_dtype) > vmem_budget_bytes:
                    continue
                acc = _geometry_accounting(shapes, bt, bk, bn,
                                           x_dtype, w_dtype, grid_mode)
                key = (acc.bytes_fetched / useful_macs,
                       acc.blocks_scheduled, bt * bk * bn)
                if best_key is None or key < best_key:
                    best, best_key = (bt, bk, bn), key
    if best is None:
        raise ValueError(
            f"no block candidate from {candidates} fits the VMEM budget "
            f"{vmem_budget_bytes} B for dtypes ({x_dtype}, {w_dtype})")
    return best


@dataclasses.dataclass(frozen=True)
class FusedGemmStats:
    """What one :func:`fused_tenant_gemm` call actually scheduled."""

    grid_mode: str
    block_t: int
    block_k: int
    block_n: int
    accounting: BlockAccounting

    def as_dict(self) -> dict:
        return {"grid_mode": self.grid_mode, "block_t": self.block_t,
                "block_k": self.block_k, "block_n": self.block_n,
                **self.accounting.as_dict()}


def record_gemm_stats(registry, stats: FusedGemmStats) -> None:
    """Fold one fused-call :class:`FusedGemmStats` into a
    `repro.obs` :class:`~repro.obs.registry.MetricsRegistry`.

    Block/traffic accounting accumulates as ``kernel.gemm.*`` counters
    (monotone totals across calls); the chosen block geometry lands in
    last-write gauges and the per-call schedule efficiency in a histogram,
    so a serving run's kernel-side dead-work fraction shows up next to the
    scheduler metrics in one ``res.timeline.render()``."""
    registry.counter("kernel.gemm.calls").inc()
    registry.gauge("kernel.gemm.block_t").set(stats.block_t)
    registry.gauge("kernel.gemm.block_k").set(stats.block_k)
    registry.gauge("kernel.gemm.block_n").set(stats.block_n)
    registry.histogram("kernel.gemm.schedule_efficiency").observe(
        stats.accounting.schedule_efficiency)
    acc = stats.accounting
    for key in ("blocks_total", "blocks_scheduled", "blocks_live",
                "blocks_skipped", "x_bytes_fetched", "w_bytes_fetched",
                "out_bytes_written"):
        registry.counter(f"kernel.gemm.{key}").inc(getattr(acc, key))


# ---------------------------------------------------------------------------
# fused multi-tenant GEMM
# ---------------------------------------------------------------------------

def fused_tenant_gemm(xs: Sequence[jax.Array], ws: Sequence[jax.Array], *,
                      block_t: Optional[int] = None,
                      block_k: Optional[int] = None,
                      block_n: Optional[int] = None,
                      grid_mode: str = "auto",
                      vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                      interpret: bool = False,
                      return_stats: bool = False):
    """Run every tenant's GEMM ``xs[i] @ ws[i]`` in ONE fused kernel call.

    xs[i]: (T_i, K_i);  ws[i]: (K_i, N_i).  Returns [(T_i, N_i) f32, ...]
    — or ``(outs, FusedGemmStats)`` with ``return_stats=True``.

    Block sizes left as ``None`` are autotuned per geometry (see
    :func:`autotune_blocks`); ``grid_mode`` is ``"dense"``, ``"compact"``
    or ``"auto"`` (compact exactly when the ragged mix leaves dead blocks).
    """
    if len(xs) != len(ws) or not xs:
        raise ValueError("need one (x, w) pair per tenant")
    for i, (x, w) in enumerate(zip(xs, ws)):
        if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
            raise ValueError(f"tenant {i}: bad shapes {x.shape} @ {w.shape}")
    if grid_mode not in ("auto", "dense", "compact"):
        raise ValueError(f"grid_mode must be 'auto', 'dense' or 'compact', "
                         f"got {grid_mode!r}")

    shapes = tuple((int(x.shape[0]), int(x.shape[1]), int(w.shape[1]))
                   for x, w in zip(xs, ws))
    # mirror the kernel's operand contract: mixed x/w dtypes promote to a
    # common type BEFORE the VMEM-budget filter and byte accounting, so the
    # autotuner never approves blocks the promoted call would reject
    x_dt = jnp.result_type(*(x.dtype for x in xs))
    w_dt = jnp.result_type(*(w.dtype for w in ws))
    if x_dt != w_dt:
        x_dt = w_dt = jnp.promote_types(x_dt, w_dt)
    x_dtype, w_dtype = str(x_dt), str(w_dt)
    if block_t is None or block_k is None or block_n is None:
        tuned = autotune_blocks(
            shapes, x_dtype, w_dtype,
            grid_mode="compact" if grid_mode == "auto" else grid_mode,
            vmem_budget_bytes=vmem_budget_bytes)
        block_t = block_t if block_t is not None else tuned[0]
        block_k = block_k if block_k is not None else tuned[1]
        block_n = block_n if block_n is not None else tuned[2]

    probe = None
    if grid_mode == "auto":
        probe = _geometry_accounting(shapes, block_t, block_k, block_n,
                                     x_dtype, w_dtype, "dense")
        grid_mode = ("compact" if probe.blocks_live < probe.blocks_total
                     else "dense")

    T = _round_up(max(x.shape[0] for x in xs), block_t)
    K = _round_up(max(x.shape[1] for x in xs), block_k)
    xs_pad = jnp.stack([
        jnp.pad(x, ((0, T - x.shape[0]), (0, K - x.shape[1])))
        for x in xs])                                     # (E, T, K)
    w_pad = jnp.concatenate([
        jnp.pad(w, ((0, K - w.shape[0]),
                    (0, _round_up(w.shape[1], block_n) - w.shape[1])))
        for w in ws], axis=1)                             # (K, N_total)

    owner = build_owner_map([w.shape[1] for w in ws], block_n)
    valid_t = jnp.asarray([x.shape[0] for x in xs], jnp.int32)
    valid_k = jnp.asarray([x.shape[1] for x in xs], jnp.int32)

    out = partitioned_matmul(xs_pad, w_pad, owner, valid_t, valid_k,
                             block_t=block_t, block_k=block_k,
                             block_n=block_n, grid_mode=grid_mode,
                             vmem_budget_bytes=vmem_budget_bytes,
                             interpret=interpret)

    outs = []
    col = 0
    for i, w in enumerate(ws):
        n_pad = _round_up(w.shape[1], block_n)
        outs.append(out[:xs[i].shape[0], col:col + w.shape[1]])
        col += n_pad
    if not return_stats:
        return outs
    acc = (probe if probe is not None and grid_mode == "dense"
           else _geometry_accounting(shapes, block_t, block_k, block_n,
                                     x_dtype, w_dtype, grid_mode))
    return outs, FusedGemmStats(grid_mode=grid_mode, block_t=block_t,
                                block_k=block_k, block_n=block_n,
                                accounting=acc)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sequential_tenant_gemm(xs: Sequence[jax.Array],
                           ws: Sequence[jax.Array],
                           interpret: bool = False) -> list[jax.Array]:
    """Single-tenancy baseline: one dense GEMM per tenant, run back-to-back
    (what a non-partitioned accelerator does — the Fig. 9 baseline)."""
    return [x.astype(jnp.float32) @ w.astype(jnp.float32)
            for x, w in zip(xs, ws)]
