"""Admission policies — who gets into the fleet when it is overdriven.

The historical admission control is *structural*: a job that finds no
run slot parks in the node's bounded FIFO, and a full FIFO rejects it
(``ArrayNode.offer``).  That is tier-blind — under a 1.5× overdrive the
queue fills with batch work and latency-critical arrivals are shed with
the same probability as throughput tenants.  An :class:`AdmissionPolicy`
sits *in front of* the dispatcher and decides per arrival whether the
job enters the fleet at all, reading the same queue-delay signal CoDel
reads off a router queue (the fleet's best-case
:meth:`~repro.traffic.cluster.ArrayNode.wait_estimate`).

Contract shared by every registered policy: **tier 0 is never shed** —
admission pressure lands entirely on batch tiers, which is the point of
tiered overload control.  All state is deterministic (no rng), so runs
are seed-stable and the serialized records replay byte-identically.

Registry names:

* ``static`` — admit everything; the bounded node queue stays the only
  shedding mechanism (rejection cause ``queue_full``).  This *is* the
  pre-overload behavior, expressed as a policy so arms are comparable.
* ``codel`` — tier-aware CoDel: while the fleet's minimum queue-delay
  estimate has stayed above ``target_delay_s`` for a full
  ``interval_s``, batch arrivals are shed at the sqrt-spaced CoDel drop
  schedule (cause ``admission_shed``).
* ``token_bucket`` — per-tier token buckets (``rate`` admits/s, depth
  ``burst``) on batch tiers; tier 0 bypasses the buckets entirely.
"""

from __future__ import annotations

import abc
import math

from repro.core.registry import Registry


class AdmissionPolicy(abc.ABC):
    """Per-arrival admit/shed decision at the fleet front door.

    ``admit`` sees the job's SLA tier, the arrival instant and the
    fleet's current best-case queue-delay estimate (seconds a queued job
    would wait for a run slot on the least-loaded node).  Implementations
    may keep state across calls — one instance drives one run.
    """

    name: str = ""

    @abc.abstractmethod
    def admit(self, tier: int, now: float, delay_s: float) -> bool:
        """True to let the arrival through to the dispatcher, False to
        shed it (counted under the ``admission_shed`` cause)."""


_REGISTRY = Registry("admission policy")


def register_admission(name: str):
    return _REGISTRY.register(name)


def list_admissions() -> list[str]:
    return _REGISTRY.names()


def resolve_admission(admission) -> AdmissionPolicy:
    return _REGISTRY.resolve(admission, AdmissionPolicy)


@register_admission("static")
class StaticAdmission(AdmissionPolicy):
    """Admit everything — the bounded node queue does the shedding.

    The pre-overload behavior as a named arm: running with
    ``admission="static"`` changes no routing or offer decision, it only
    turns on the gated rejection-cause accounting so the arm is directly
    comparable to ``codel``/``token_bucket`` on the same stream.
    """

    def admit(self, tier: int, now: float, delay_s: float) -> bool:
        return True


@register_admission("codel")
class CoDelAdmission(AdmissionPolicy):
    """Tier-aware CoDel on the fleet queue-delay estimate.

    Classic CoDel watches the *sojourn time* of a router queue: nothing
    happens until the delay has stayed above ``target_delay_s`` for one
    full ``interval_s``; then drops fire at intervals shrinking with
    ``interval_s / sqrt(drop_count)`` until the delay dips back under
    the target.  Here a "drop" sheds a **batch** arrival — tier 0 rides
    through every drop window untouched, which is the tier-awareness the
    plain algorithm lacks.
    """

    def __init__(self, target_delay_s: float = 5e-3,
                 interval_s: float = 10e-3):
        if target_delay_s <= 0 or interval_s <= 0:
            raise ValueError(
                f"target_delay_s and interval_s must be positive, got "
                f"{target_delay_s} / {interval_s}")
        self.target_delay_s = target_delay_s
        self.interval_s = interval_s
        self._first_above: float | None = None
        self._dropping = False
        self._drop_next = 0.0
        self._drop_count = 0

    def admit(self, tier: int, now: float, delay_s: float) -> bool:
        if delay_s < self.target_delay_s:
            # back under target: leave the dropping state entirely
            self._first_above = None
            self._dropping = False
            self._drop_count = 0
            return True
        if tier <= 0:
            # latency-critical arrivals never shed; the delay stays
            # "above target" for the batch bookkeeping either way
            return True
        if self._first_above is None:
            self._first_above = now + self.interval_s
            return True
        if not self._dropping:
            if now >= self._first_above:
                self._dropping = True
                self._drop_count = 1
                self._drop_next = now + self.interval_s
                return False
            return True
        if now >= self._drop_next:
            self._drop_count += 1
            self._drop_next = now + self.interval_s / math.sqrt(
                self._drop_count)
            return False
        return True


@register_admission("token_bucket")
class TokenBucketAdmission(AdmissionPolicy):
    """Per-tier token buckets on batch tiers; tier 0 is exempt.

    Each batch tier owns a bucket of depth ``burst`` refilled at
    ``rate`` tokens per second of *simulated* time; an arrival spends
    one token or is shed.  The invariant the property test pins: over
    any window, a tier's admits never exceed ``burst + rate × elapsed``,
    and tier-0 admits are a superset of what any capacity-equivalent
    policy admits (they bypass the buckets).
    """

    def __init__(self, rate: float = 500.0, burst: float = 20.0):
        if rate <= 0 or burst < 1:
            raise ValueError(
                f"need rate > 0 and burst >= 1, got {rate} / {burst}")
        self.rate = rate
        self.burst = burst
        # tier -> [tokens, last refill instant]
        self._buckets: dict[int, list[float]] = {}

    def admit(self, tier: int, now: float, delay_s: float) -> bool:
        if tier <= 0:
            return True
        b = self._buckets.get(tier)
        if b is None:
            b = self._buckets[tier] = [float(self.burst), now]
        tokens = min(float(self.burst), b[0] + self.rate * (now - b[1]))
        b[1] = now
        if tokens >= 1.0:
            b[0] = tokens - 1.0
            return True
        b[0] = tokens
        return False
