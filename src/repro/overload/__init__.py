"""`repro.overload` — closed-loop overload control for the serving stack.

The paper keeps SLAs intact by *partitioning* one array; "No DNN Left
Behind" (PAPERS.md) argues the fleet-level corollary: an inference
service is judged under overload, not at nominal load.  This package is
the degrade-before-drop layer the traffic simulator drives when its
``admission=`` / ``brownout=`` knobs are armed:

* :mod:`repro.overload.admission` — the :class:`AdmissionPolicy`
  registry.  ``static`` is the historical behavior (admit everything,
  let the bounded node queue shed); ``codel`` sheds batch tiers on a
  CoDel-style queue-delay target with sqrt-spaced drops; ``token_bucket``
  rate-limits batch tiers through per-tier buckets.  Tier 0 is never
  shed by any registered policy — batch tenants absorb the rejections.
* :mod:`repro.overload.brownout` — :class:`BrownoutController`, a
  feedback loop over queue delay and detected-healthy capacity that
  walks a declared :class:`BrownoutStage` ladder *before* dropping
  anything: tighten batch bandwidth caps, shrink batch column floors,
  stretch batch deadlines, then shed.  Every stage entry/exit is a
  tracer instant and is priced in energy.

With both knobs at their ``None`` defaults nothing here is imported and
every serialized record stays byte-identical to pre-overload runs — the
purity contract ``BENCH_overload.json`` and the record-stability tests
pin.
"""

from repro.overload.admission import (
    AdmissionPolicy,
    CoDelAdmission,
    StaticAdmission,
    TokenBucketAdmission,
    list_admissions,
    register_admission,
    resolve_admission,
)
from repro.overload.brownout import (
    DEFAULT_STAGES,
    BrownoutController,
    BrownoutReport,
    BrownoutStage,
)

__all__ = [
    "AdmissionPolicy",
    "StaticAdmission",
    "CoDelAdmission",
    "TokenBucketAdmission",
    "register_admission",
    "list_admissions",
    "resolve_admission",
    "BrownoutStage",
    "BrownoutController",
    "BrownoutReport",
    "DEFAULT_STAGES",
]
