"""Brownout: degrade batch service in stages before dropping anything.

Brownout (Klein et al.) keeps a saturated service inside its SLA by
switching off *optional* work instead of shedding requests.  The serving
analogue here: when the fleet's queue-delay estimate stays above target
— or detected-healthy capacity drops below the floor — the controller
walks DOWN a declared :class:`BrownoutStage` ladder, and walks back UP
when the pressure clears.  The default ladder degrades batch tenants in
escalating steps, shedding only as the last resort:

1. ``cap_bandwidth``    — tighten batch tenants' DRAM-bandwidth caps
   (the PR-9 ``bandwidth`` hook surface: ``MemorySystem.set_caps``);
2. ``shrink_floors``    — scale batch tenants' column demand down so the
   partition policy hands their columns to tier 0;
3. ``stretch_deadlines``— relax batch deadlines (batch throughput is an
   SLO of *eventually*, not *now*);
4. ``shed``             — drop batch arrivals at admission.

Stage transitions are hysteresis-guarded (``enter_after`` consecutive
over-target samples to escalate, ``exit_after`` under-target samples to
relax), recorded as ``brownout`` tracer instants, and priced at
``transition_energy_j`` each — reconfiguring caps/floors re-stages
weights, which is not free.

The controller itself only *decides*; the
:class:`~repro.traffic.simulator.TrafficSimulator` applies the active
stage's caps/floors/stretches to the fleet (it owns the nodes).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class BrownoutStage:
    """One rung of the degradation ladder (all knobs target batch tiers).

    ``batch_bw_cap`` — per-tenant DRAM bandwidth share in (0, 1] for
    every batch tenant (None = leave caps alone); ``batch_demand_scale``
    — multiplier in (0, 1] on batch tenants' column demand (1 = no
    shrink); ``deadline_stretch`` — multiplier >= 1 on batch jobs'
    arrival-to-deadline slack; ``shed_batch`` — drop batch arrivals at
    admission while this stage is active.
    """

    name: str
    batch_bw_cap: Optional[float] = None
    batch_demand_scale: float = 1.0
    deadline_stretch: float = 1.0
    shed_batch: bool = False

    def __post_init__(self) -> None:
        if self.batch_bw_cap is not None and not 0.0 < self.batch_bw_cap <= 1.0:
            raise ValueError(
                f"batch_bw_cap must be in (0, 1], got {self.batch_bw_cap}")
        if not 0.0 < self.batch_demand_scale <= 1.0:
            raise ValueError(f"batch_demand_scale must be in (0, 1], got "
                             f"{self.batch_demand_scale}")
        if self.deadline_stretch < 1.0:
            raise ValueError(f"deadline_stretch must be >= 1, got "
                             f"{self.deadline_stretch}")


#: the declared degradation ladder: bandwidth -> floors -> deadlines ->
#: shed.  Later stages keep the earlier stages' knobs tightened — the
#: ladder is cumulative by construction, not by controller logic.
DEFAULT_STAGES: tuple[BrownoutStage, ...] = (
    BrownoutStage("cap_bandwidth", batch_bw_cap=0.25),
    BrownoutStage("shrink_floors", batch_bw_cap=0.2,
                  batch_demand_scale=0.5),
    BrownoutStage("stretch_deadlines", batch_bw_cap=0.15,
                  batch_demand_scale=0.35, deadline_stretch=2.0),
    BrownoutStage("shed", batch_bw_cap=0.1, batch_demand_scale=0.25,
                  deadline_stretch=2.0, shed_batch=True),
)


@dataclasses.dataclass(frozen=True)
class BrownoutReport:
    """End-of-run brownout accounting (``ServeResult.brownout``)."""

    stages: tuple[str, ...]
    transitions: int
    energy_overhead_j: float
    final_stage: Optional[str]
    # (t, from_stage_or_None, to_stage_or_None) per transition
    log: tuple[tuple, ...] = ()


class BrownoutController:
    """The feedback loop: sample pressure, walk the stage ladder.

    ``delay_target_s`` is the queue-delay setpoint; ``capacity_floor``
    (optional) additionally treats detected-healthy capacity below the
    floor as overload, so a half-dead fleet browns out even at nominal
    arrival rate.  ``enter_after``/``exit_after`` are the hysteresis
    lengths in arrival samples; exit is deliberately slower than entry
    so the controller does not flap around the setpoint.
    """

    def __init__(self, stages: tuple = DEFAULT_STAGES,
                 delay_target_s: float = 5e-3,
                 enter_after: int = 4, exit_after: int = 12,
                 capacity_floor: Optional[float] = None,
                 transition_energy_j: float = 0.05):
        if not stages:
            raise ValueError("brownout needs at least one stage")
        if delay_target_s <= 0:
            raise ValueError(f"delay_target_s must be positive, got "
                             f"{delay_target_s}")
        if enter_after < 1 or exit_after < 1:
            raise ValueError(f"hysteresis lengths must be >= 1, got "
                             f"enter_after={enter_after}, "
                             f"exit_after={exit_after}")
        if capacity_floor is not None and not 0.0 < capacity_floor <= 1.0:
            raise ValueError(f"capacity_floor must be in (0, 1], got "
                             f"{capacity_floor}")
        if transition_energy_j < 0:
            raise ValueError(f"transition_energy_j must be >= 0, got "
                             f"{transition_energy_j}")
        self.stages = tuple(stages)
        self.delay_target_s = delay_target_s
        self.enter_after = enter_after
        self.exit_after = exit_after
        self.capacity_floor = capacity_floor
        self.transition_energy_j = transition_energy_j
        self.stage_idx = -1            # -1 = ladder off (normal service)
        self.transitions = 0
        self.energy_overhead_j = 0.0
        self.log: list[tuple] = []     # (t, from_name, to_name)
        self._over = 0
        self._under = 0

    @property
    def stage(self) -> Optional[BrownoutStage]:
        """The active stage, or None while the ladder is off."""
        return self.stages[self.stage_idx] if self.stage_idx >= 0 else None

    def observe(self, now: float, delay_s: float,
                healthy_frac: float = 1.0) -> bool:
        """Feed one pressure sample; returns True when the active stage
        changed (the caller then re-applies caps/floors to the fleet and
        emits the tracer instant)."""
        overloaded = delay_s > self.delay_target_s or (
            self.capacity_floor is not None
            and healthy_frac < self.capacity_floor)
        if overloaded:
            self._over += 1
            self._under = 0
            if (self._over >= self.enter_after
                    and self.stage_idx < len(self.stages) - 1):
                self._over = 0
                return self._shift(now, self.stage_idx + 1)
        else:
            self._under += 1
            self._over = 0
            if self._under >= self.exit_after and self.stage_idx >= 0:
                self._under = 0
                return self._shift(now, self.stage_idx - 1)
        return False

    def _shift(self, now: float, new_idx: int) -> bool:
        old = self.stage
        self.stage_idx = new_idx
        new = self.stage
        self.transitions += 1
        self.energy_overhead_j += self.transition_energy_j
        self.log.append((now, old.name if old is not None else None,
                         new.name if new is not None else None))
        return True

    def shed(self, tier: int) -> bool:
        """Drop this arrival?  Only batch tiers, only in a shed stage —
        everything milder ran out first (degrade before drop)."""
        s = self.stage
        return s is not None and s.shed_batch and tier > 0

    def stretch_deadline(self, tier: int, arrival: float,
                         deadline: float) -> float:
        """The (possibly stretched) deadline for an arriving job."""
        s = self.stage
        if s is None or tier <= 0 or s.deadline_stretch == 1.0:
            return deadline
        return arrival + (deadline - arrival) * s.deadline_stretch

    def report(self) -> BrownoutReport:
        return BrownoutReport(
            stages=tuple(s.name for s in self.stages),
            transitions=self.transitions,
            energy_overhead_j=self.energy_overhead_j,
            final_stage=(self.stage.name
                         if self.stage is not None else None),
            log=tuple(self.log))
