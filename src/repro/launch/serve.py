"""Multi-tenant serving driver — the paper's Fig. 4 timeline, live.

Admits several architectures as tenants of ONE device mesh, feeds each a
request stream, and runs the engine until drained, printing the partition
width history (the serving analogue of Fig. 9(c,d))::

    PYTHONPATH=src python -m repro.launch.serve \
        --tenants llama3.2-3b,mamba2-780m,recurrentgemma-2b \
        --requests 4 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get
from repro.distributed.tenancy import TenantMeshManager
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_params
from repro.serving.engine import MultiTenantEngine
from repro.serving.kv_cache import DecodeSession


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--tenants",
                   default="llama3.2-3b,mamba2-780m,recurrentgemma-2b")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--slots", type=int, default=2)
    p.add_argument("--max-seq", type=int, default=64)
    p.add_argument("--model-cols", type=int, default=0,
                   help="width of the model axis (0 = all devices)")
    args = p.parse_args(argv)

    n_dev = len(jax.devices())
    cols = args.model_cols or n_dev
    mesh = make_host_mesh(model=cols, data=n_dev // cols)
    mgr = TenantMeshManager(mesh, "model")
    eng = MultiTenantEngine(mgr)

    key = jax.random.key(0)
    for i, name in enumerate(args.tenants.split(",")):
        spec = get(name)
        cfg = spec.smoke
        params = init_params(cfg, jax.random.fold_in(key, i))
        sess = DecodeSession(cfg, params, batch_slots=args.slots,
                             max_seq=args.max_seq)
        # demand proxy: params × 2 FLOPs/token
        flops_tok = 2.0 * sum(x.size for x in jax.tree.leaves(params))
        eng.add_tenant(name, sess, flops_per_token=flops_tok)
        for r in range(args.requests):
            eng.submit(name, prompt=[1 + r, 2, 3], max_new=args.max_new)
        print(f"tenant {name}: {args.requests} requests queued")

    t0 = time.time()
    rounds = eng.run_until_drained()
    dt = time.time() - t0
    print(f"\ndrained in {rounds} rounds, {dt:.1f}s")
    print("partition width history (round, tenant, cols):")
    for rec in eng.width_history:
        print(f"  {rec}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
