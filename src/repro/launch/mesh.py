"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init;
tests import this with 1 CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axes: ("data", "model") — FSDP/DP over "data", TP over "model";
    multi-pod adds a leading pure-DP "pod" axis (DCN-level).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int | None = None):
    """Small mesh over whatever devices exist (CPU tests, examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
