"""HLO-text analysis: collective bytes + schedule for the roofline terms.

``cost_analysis()`` has no collective-traffic entry, so we parse the
compiled (post-SPMD-partitioning) HLO text, build the computation call
graph, and sum the bytes moved by every collective op **per execution** —
collectives inside a ``while`` body (e.g. the lax.scan over layers) are
multiplied by the loop trip count recovered from the condition computation
(`compare(iv, constant(N)), direction=LT`).

Per-device wire-byte convention (ring algorithms; asymptotic factors):

    all-reduce        2 × tensor bytes   (reduce-scatter + all-gather)
    all-gather        1 × output bytes
    reduce-scatter    1 × input bytes ≈ output × group size ≈ gathered size
    all-to-all        1 × tensor bytes
    collective-permute 1 × tensor bytes
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_OP_RE = re.compile(
    r"=\s+(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")

# `%name (args) -> type {`   — a computation definition header
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-_]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\）?.*?condition=%?([\w\.\-_]+), body=%?([\w\.\-_]+)")
_CALL_RE = re.compile(r"(?:call|fusion|async-start)\(.*?"
                      r"(?:to_apply|calls|called_computation)=%?([\w\.\-_]+)")
_COND_RE = re.compile(r"conditional\(.*?branch_computations=\{([^}]*)\}")
_TRUE_FALSE_RE = re.compile(
    r"conditional\(.*?true_computation=%?([\w\.\-_]+).*?"
    r"false_computation=%?([\w\.\-_]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Comp:
    name: str
    coll_bytes: Counter
    coll_count: Counter
    whiles: list[tuple[str, str]]          # (condition, body)
    calls: list[tuple[str, str]]           # (kind: call|fusion, name)
    conds: list[list[str]]                 # branch computation groups


def _split_computations(hlo_text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and "->" in line \
                and stripped.endswith("{"):
            # `[ENTRY ]%name (params…) -> type {` — params may nest parens
            head = stripped.removeprefix("ENTRY ").strip()
            name = head.split("(", 1)[0].strip().lstrip("%")
            if name:
                cur = _Comp(name, Counter(), Counter(), [], [], [])
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        m = _OP_RE.search(stripped)
        if m and m.group(3) != "-done":
            kind = m.group(2)
            cur.coll_bytes[kind] += _type_bytes(m.group(1)) \
                * _COLLECTIVES[kind]
            cur.coll_count[kind] += 1
        mw = re.search(r"condition=%?([\w\.\-_]+), body=%?([\w\.\-_]+)",
                       stripped)
        if mw and " while(" in stripped:
            cur.whiles.append((mw.group(1), mw.group(2)))
        is_fusion = " fusion(" in stripped
        for mc in re.finditer(
                r"(?:to_apply|calls|called_computation)=%?([\w\.\-_]+)",
                stripped):
            if " while(" not in stripped:
                cur.calls.append(("fusion" if is_fusion else "call",
                                  mc.group(1)))
        mb = _COND_RE.search(stripped)
        if mb:
            cur.conds.append([b.strip().lstrip("%")
                              for b in mb.group(1).split(",")])
        mt = _TRUE_FALSE_RE.search(stripped)
        if mt:
            cur.conds.append([mt.group(1), mt.group(2)])
    return comps


def _trip_count(cond: _Comp | None, raw_text: str) -> int:
    """Loop bound from `compare(iv, constant(N)), direction=LT` patterns."""
    if cond is None:
        return 1
    block = _comp_block(raw_text, cond.name)
    consts = [int(x) for x in _CONST_RE.findall(block)]
    return max(consts) if consts else 1


def _comp_block(hlo_text: str, name: str) -> str:
    idx = hlo_text.find(f"%{name} ")
    if idx < 0:
        idx = hlo_text.find(f"{name} ")
    if idx < 0:
        return ""
    end = hlo_text.find("\n}", idx)
    return hlo_text[idx:end if end > 0 else len(hlo_text)]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        parts = [f"{k}×{int(self.count_by_kind[k])}:"
                 f"{self.bytes_by_kind[k]/1e6:.1f}MB"
                 for k in sorted(self.bytes_by_kind)]
        return " ".join(parts) if parts else "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Per-device, per-execution collective wire bytes (loop-aware)."""
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            entry = (line.strip().removeprefix("ENTRY ").split("(", 1)[0]
                     .strip().lstrip("%"))
            break
    if entry is None or entry not in comps:
        # fall back: flat sum (no loop weighting)
        flat_b: Counter = Counter()
        flat_c: Counter = Counter()
        for c in comps.values():
            flat_b.update(c.coll_bytes)
            flat_c.update(c.coll_count)
        return CollectiveStats(dict(flat_b), dict(flat_c))

    memo: dict[str, tuple[Counter, Counter]] = {}
    visiting: set[str] = set()

    def visit(name: str) -> tuple[Counter, Counter]:
        if name in memo:
            return memo[name]
        if name in visiting or name not in comps:
            return Counter(), Counter()
        visiting.add(name)
        c = comps[name]
        b = Counter(c.coll_bytes)
        n = Counter(c.coll_count)
        for _kind, callee in c.calls:
            cb, cn = visit(callee)
            b.update(cb)
            n.update(cn)
        for branches in c.conds:
            # worst-case branch
            best: tuple[Counter, Counter] = (Counter(), Counter())
            for br in branches:
                cb, cn = visit(br)
                if sum(cb.values()) > sum(best[0].values()):
                    best = (cb, cn)
            b.update(best[0])
            n.update(best[1])
        for cond_name, body_name in c.whiles:
            trips = _trip_count(comps.get(cond_name), hlo_text)
            cb, cn = visit(body_name)
            for k, v in cb.items():
                b[k] += v * trips
            for k, v in cn.items():
                n[k] += v * trips
        visiting.discard(name)
        memo[name] = (b, n)
        return b, n

    b, n = visit(entry)
    return CollectiveStats(dict(b), dict(n))


def while_trip_counts(hlo_text: str) -> list[int]:
    """Trip counts of every while loop (scan extents) for sanity checks."""
    comps = _split_computations(hlo_text)
    out = []
    for c in comps.values():
        for cond_name, _body in c.whiles:
            out.append(_trip_count(comps.get(cond_name), hlo_text))
    return out


# ---------------------------------------------------------------------------
# Loop-aware FLOP / HBM-byte cost (XLA's cost_analysis() counts each while
# body ONCE — useless for lax.scan-over-layers models; this walk multiplies
# by trip counts exactly like collective_stats above).
# ---------------------------------------------------------------------------

_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-_]+)\s*=\s*(.+?)\s+"
                       r"([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"\(((?:%[\w\.\-_]+(?:,\s*)?)+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(type_str: str) -> tuple[list[int], float]:
    """First shape's dims + TOTAL bytes of (possibly tuple) type."""
    dims: list[int] | None = None
    total = 0.0
    for dt, ds in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in ds.split(",")] if ds else []
        if dims is None:
            dims = d
        n = 1
        for x in d:
            n *= x
        total += n * _DTYPE_BYTES[dt]
    return dims if dims is not None else [], total


@dataclasses.dataclass
class _CompCost:
    flops: float = 0.0
    bytes_hbm: float = 0.0


def _comp_costs(hlo_text: str) -> tuple[dict[str, _CompCost],
                                        dict[str, _Comp]]:
    """Per-computation direct FLOPs (dot ops) + HBM bytes (fusion/dot/copy
    parameter+result traffic, XLA's bytes-accessed convention)."""
    comps = _split_computations(hlo_text)
    costs: dict[str, _CompCost] = {name: _CompCost() for name in comps}
    # %name identifiers repeat across computations — scope per computation
    shapes: dict[str, list[int]] = {}
    bytes_of: dict[str, float] = {}
    cur: str | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and "->" in line \
                and stripped.endswith("{"):
            head = stripped.removeprefix("ENTRY ").strip()
            cur = head.split("(", 1)[0].strip().lstrip("%")
            shapes, bytes_of = {}, {}
            continue
        if cur is None or cur not in costs:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        dims, nbytes = _shape_dims(type_str)
        is_tuple = type_str.lstrip().startswith("(")
        shapes[name] = dims
        # tuples are passed by reference — only materialised elements
        # (via get-tuple-element) count as traffic
        bytes_of[name] = 0.0 if is_tuple else nbytes
        cc = costs[cur]
        if op == "dot":
            # flops = 2 × result elements × product(contracting dims)
            res_elems = 1
            for d in dims:
                res_elems *= d
            k = 1
            mc = _CONTRACT_RE.search(line)
            ops = _OPERANDS_RE.search(line[m.end() - 1:])
            if mc and ops:
                lhs = ops.group(1).split(",")[0].strip().lstrip("%")
                lhs_dims = shapes.get(lhs, [])
                for ci in (int(x) for x in mc.group(1).split(",") if x):
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
            cc.flops += 2.0 * res_elems * k
        if op in ("dynamic-slice", "gather"):
            # reads only the slice, writes the result: ≈ 2 × result bytes
            # (charging the full stacked-weights operand would overcount
            # every scan iteration by the whole stack)
            cc.bytes_hbm += 2.0 * nbytes
        elif op in ("dynamic-update-slice", "scatter"):
            # in-place: reads the update, writes the slice ≈ 2 × update
            ops_m = _OPERANDS_RE.search(line[m.end() - 1:])
            upd = 0.0
            if ops_m:
                names = [o.strip().lstrip("%")
                         for o in ops_m.group(1).split(",")]
                if len(names) >= 2:
                    upd = bytes_of.get(names[1], 0.0)
            cc.bytes_hbm += 2.0 * upd
        elif op in ("dot", "fusion", "copy", "custom-call", "convolution",
                    "reduce", "sort", "select-and-scatter"):
            # XLA bytes-accessed convention: operands + result, for ops
            # that really touch memory after fusion (layout ops excluded —
            # a TPU compile fuses them; the CPU dump leaves them around).
            total = 0.0 if is_tuple else nbytes
            ops_m = _OPERANDS_RE.search(line[m.end() - 1:])
            if ops_m:
                for o in ops_m.group(1).split(","):
                    total += bytes_of.get(o.strip().lstrip("%"), 0.0)
            cc.bytes_hbm += total
    return costs, comps


@dataclasses.dataclass
class LoopAwareCost:
    flops: float
    bytes_hbm: float


def loop_aware_cost(hlo_text: str) -> LoopAwareCost:
    """Per-device, per-execution dot-FLOPs + HBM-byte traffic."""
    costs, comps = _comp_costs(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            entry = (line.strip().removeprefix("ENTRY ").split("(", 1)[0]
                     .strip().lstrip("%"))
            break
    if entry is None or entry not in comps:
        total = _CompCost()
        for c in costs.values():
            total.flops += c.flops
            total.bytes_hbm += c.bytes_hbm
        return LoopAwareCost(total.flops, total.bytes_hbm)

    memo: dict[str, tuple[float, float]] = {}
    visiting: set[str] = set()

    def visit(name: str) -> tuple[float, float]:
        if name in memo:
            return memo[name]
        if name in visiting or name not in comps:
            return 0.0, 0.0
        visiting.add(name)
        c = comps[name]
        fl = costs[name].flops
        by = costs[name].bytes_hbm
        for kind, callee in c.calls:
            f2, b2 = visit(callee)
            fl += f2
            # fusion internals live in registers/VMEM — their dots are real
            # compute but their intermediate tensors are not HBM traffic
            # (the fusion op itself already contributed operand+result bytes)
            by += 0.0 if kind == "fusion" else b2
        for branches in c.conds:
            best = (0.0, 0.0)
            for br in branches:
                got = visit(br)
                if got[0] + got[1] > best[0] + best[1]:
                    best = got
            fl += best[0]
            by += best[1]
        for cond_name, body_name in c.whiles:
            trips = _trip_count(comps.get(cond_name), hlo_text)
            f2, b2 = visit(body_name)
            fl += f2 * trips
            by += b2 * trips
        visiting.discard(name)
        memo[name] = (fl, by)
        return fl, by

    fl, by = visit(entry)
    return LoopAwareCost(fl, by)
