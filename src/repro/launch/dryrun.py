import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices host the production mesh topology;
``jax.jit(...).lower(ShapeDtypeStructs).compile()`` must succeed for every
cell, and the compiled artifact yields

* ``memory_analysis()``  — per-device bytes (does it fit 16 GB HBM?),
* ``cost_analysis()``    — per-device HLO FLOPs / bytes accessed,
* the collective schedule (parsed from the partitioned HLO text),

which EXPERIMENTS.md §Dry-run and §Roofline are built from.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                   # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --cell train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --out results.json
"""

import argparse
import json
import time
import traceback


# NOTE: jax and repro imports happen *after* the XLA_FLAGS line above —
# jax locks the device count on first init.
def _run():
    import jax

    from repro.configs import ARCHS, get
    from repro.launch.hlo_analysis import collective_stats, loop_aware_cost
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_lowerable
    from repro.training.train_loop import TrainConfig

    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="all")
    p.add_argument("--cell", default="all")
    p.add_argument("--mesh", default="both", choices=["single", "multi",
                                                      "both"])
    p.add_argument("--out", default="benchmarks/results/dryrun.json")
    p.add_argument("--microbatches", type=int, default=16,
                   help="grad-accumulation for train cells (memory)")
    p.add_argument("--tuned", action="store_true",
                   help="per-arch optimized profile (EXPERIMENTS.md §Perf): "
                        "choose_mesh_shape factorization + Q-chunked causal "
                        "attention + microbatch-32")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args()

    arch_ids = list(ARCHS) if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results: dict[str, dict] = {}
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    import dataclasses

    import jax as _jax

    from repro.distributed.sharding import choose_mesh_shape

    n_ok = n_fail = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "2x16x16" if multi_pod else "16x16"
        n_chips = 512 if multi_pod else 256
        for arch_id in arch_ids:
            spec = get(arch_id)
            mb = args.microbatches
            if args.tuned:
                from repro.configs import TUNED_PROFILES
                prof = TUNED_PROFILES.get(arch_id)
                data_w, model_w = (prof["mesh"] if prof
                                   else choose_mesh_shape(spec.model, 256))
                shape = ((2, data_w, model_w) if multi_pod
                         else (data_w, model_w))
                axes = (("pod", "data", "model") if multi_pod
                        else ("data", "model"))
                mesh = _jax.make_mesh(shape, axes)
                mesh_name = ("2x" if multi_pod else "") \
                    + f"{data_w}x{model_w}"
                spec = dataclasses.replace(
                    spec, model=dataclasses.replace(
                        spec.model,
                        attn_q_chunks=(prof or {}).get("q_chunks", 4),
                        attn_chunk=(prof or {}).get("attn_chunk", 1024)))
                mb = (prof or {}).get("microbatches", 32)
            cells = ([c.name for c in spec.shapes()] if args.cell == "all"
                     else [args.cell])
            for cell_name in cells:
                if cell_name in spec.skip_shapes:
                    continue
                key = f"{arch_id}|{cell_name}|{mesh_name}"
                t0 = time.time()
                try:
                    low = build_lowerable(
                        spec, cell_name, mesh,
                        train=TrainConfig(microbatches=mb))
                    lowered = low.lower()
                    compiled = lowered.compile()
                    ma = compiled.memory_analysis()
                    ca = compiled.cost_analysis()
                    hlo_text = compiled.as_text()
                    stats = collective_stats(hlo_text)
                    cost = loop_aware_cost(hlo_text)
                    rec = {
                        "arch": arch_id, "cell": cell_name,
                        "mesh": mesh_name, "chips": n_chips,
                        "ok": True,
                        "compile_s": round(time.time() - t0, 1),
                        # loop-aware (while bodies × trip counts) — XLA's
                        # cost_analysis counts scan bodies once, which is
                        # useless for scan-over-layers models
                        "flops_per_device": cost.flops,
                        "bytes_per_device": cost.bytes_hbm,
                        "flops_xla_raw": ca.get("flops", 0.0),
                        "bytes_xla_raw": ca.get("bytes accessed", 0.0),
                        "transcendentals": ca.get("transcendentals", 0.0),
                        "arg_bytes": ma.argument_size_in_bytes,
                        "out_bytes": ma.output_size_in_bytes,
                        "temp_bytes": ma.temp_size_in_bytes,
                        "collective_bytes": stats.total_bytes,
                        "collectives": {k: [stats.count_by_kind[k],
                                            stats.bytes_by_kind[k]]
                                        for k in stats.bytes_by_kind},
                    }
                    n_ok += 1
                    if not args.quiet:
                        print(f"OK   {key:55s} {rec['compile_s']:6.1f}s "
                              f"flops={rec['flops_per_device']:.3g} "
                              f"temp={rec['temp_bytes']/1e9:.2f}GB "
                              f"coll={rec['collective_bytes']/1e6:.1f}MB "
                              f"[{stats.summary()}]", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch_id, "cell": cell_name,
                           "mesh": mesh_name, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "compile_s": round(time.time() - t0, 1)}
                    n_fail += 1
                    print(f"FAIL {key}: {rec['error'][:300]}", flush=True)
                    if not args.quiet:
                        traceback.print_exc()
                results[key] = rec
                if args.out:
                    os.makedirs(os.path.dirname(args.out), exist_ok=True)
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1, sort_keys=True)

    print(f"\ndry-run: {n_ok} ok, {n_fail} failed "
          f"({len(results)} cells recorded)")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(_run())
