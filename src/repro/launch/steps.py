"""Lowerable step builders shared by dryrun / roofline / perf benchmarks.

``build_lowerable(spec, cell, mesh)`` returns ``(jitted, args)`` such that
``jitted.lower(*args).compile()`` exercises exactly the computation of that
(architecture × input-shape) cell on that mesh:

* ``train_4k``    → full train step (fwd + bwd + AdamW update), FSDP+TP;
* ``prefill_32k`` → chunked-attention forward, last-position logits;
* ``decode_*``    → single-token ``decode_step`` against a seq_len cache.

All arguments are ShapeDtypeStructs — nothing is allocated; this is the
pattern that lets a CPU host validate a 512-chip lowering.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, input_specs, params_spec
from repro.distributed.sharding import (
    FSDP_TP,
    MeshRules,
    batch_shardings,
    cache_shardings,
    params_shardings,
)
from repro.models.model import decode_step, forward, prefill
from repro.training.optimizer import adamw_update, init_opt_state
from repro.training.train_loop import TrainConfig, loss_and_grads


@dataclasses.dataclass(frozen=True)
class Lowerable:
    """A jit'd step plus its abstract arguments (SDS pytrees)."""

    jitted: Any
    args: tuple
    kind: str
    arch_id: str
    cell_name: str

    def lower(self):
        return self.jitted.lower(*self.args)


def _opt_shardings(o_sds, mesh: Mesh, rules: MeshRules):
    return {"master": params_shardings(o_sds["master"], mesh, rules),
            "m": params_shardings(o_sds["m"], mesh, rules),
            "v": params_shardings(o_sds["v"], mesh, rules),
            "step": NamedSharding(mesh, P())}


def build_lowerable(spec: ArchSpec, cell_name: str, mesh: Mesh,
                    rules: MeshRules = FSDP_TP,
                    train: TrainConfig = TrainConfig(),
                    reduced: bool = False) -> Lowerable:
    cfg = spec.smoke if reduced else spec.model
    cell = spec.cell(cell_name)
    if reduced:
        # shrink the cell to smoke-config scale (CPU trace/compile tests)
        specs = input_specs(cfg, cell, batch=min(cell.global_batch, 4),
                            seq=min(cell.seq_len, 32))
    else:
        specs = input_specs(cfg, cell)
    p_sds = params_spec(cfg)
    p_sh = params_shardings(p_sds, mesh, rules)

    if cell.kind == "train":
        batch_sds = specs
        b_sh = batch_shardings(batch_sds, mesh)
        o_sds = jax.eval_shape(init_opt_state, p_sds)
        o_sh = _opt_shardings(o_sds, mesh, rules)

        def step(params, opt_state, batch):
            loss, grads = loss_and_grads(cfg, params, batch,
                                         train.microbatches)
            new_p, new_o = adamw_update(train.opt, params, grads, opt_state)
            return new_p, new_o, loss

        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())),
            donate_argnums=(0, 1))
        return Lowerable(jitted, (p_sds, o_sds, batch_sds), "train",
                         spec.arch_id, cell_name)

    if cell.kind == "prefill":
        batch_sds = specs
        b_sh = batch_shardings(batch_sds, mesh)

        def step(params, batch):
            return prefill(cfg, params, batch["tokens"],
                           frames=batch.get("frames"),
                           patches=batch.get("patches"))

        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        return Lowerable(jitted, (p_sds, batch_sds), "prefill",
                         spec.arch_id, cell_name)

    if cell.kind == "decode":
        cache_sds = specs["cache"]
        c_sh = cache_shardings(cache_sds, mesh)
        tok_sh = batch_shardings(
            {"token": specs["token"], "cache_len": specs["cache_len"]}, mesh)

        def step(params, cache, token, cache_len):
            return decode_step(cfg, params, cache, token, cache_len)

        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, tok_sh["token"], tok_sh["cache_len"]),
            out_shardings=(None, c_sh),
            donate_argnums=(1,))
        return Lowerable(jitted,
                         (p_sds, cache_sds, specs["token"],
                          specs["cache_len"]),
                         "decode", spec.arch_id, cell_name)

    raise ValueError(cell.kind)
