"""End-to-end training driver.

Runs a real training loop (CPU: smoke configs; TPU: full configs) with
checkpoint/restart, deterministic data, and optional gradient compression::

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --smoke --steps 200 --batch 16 --seq 64 --ckpt-dir /tmp/ckpt

Fault tolerance demonstrated by construction: kill the process at any step
and re-run the same command — it resumes from the latest committed
checkpoint and regenerates the exact data stream from (seed, step).
"""

from __future__ import annotations

import argparse
import time


from repro.configs import get, list_archs
from repro.launch.mesh import make_host_mesh
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, make_batch
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (
    TrainConfig,
    init_sharded,
    make_train_step,
)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-3b", choices=list_archs())
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    spec = get(args.arch)
    cfg = spec.smoke if args.smoke else spec.model
    mesh = make_host_mesh()
    print(f"arch={args.arch} family={cfg.family} mesh={mesh.devices.shape} "
          f"{mesh.axis_names}")

    params, opt_state = init_sharded(cfg, mesh, seed=args.seed)
    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps),
        microbatches=args.microbatches)
    _, jitted = make_train_step(cfg, mesh, tcfg)

    start = 0
    if args.ckpt_dir:
        got = ckpt.latest_step(args.ckpt_dir)
        if got is not None:
            state = ckpt.restore(args.ckpt_dir, got,
                                 {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = got
            print(f"resumed from step {start}")

    dcfg = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                      seed=args.seed)
    extras = {}
    if cfg.frontend == "audio":
        extras["frames"] = (args.batch, cfg.enc_seq, cfg.d_model)
    elif cfg.frontend == "vision":
        extras["patches"] = (args.batch, cfg.n_patches, cfg.d_model)

    step_fn = None
    t0 = time.time()
    for step in range(start, args.steps):
        batch = make_batch(dcfg, step, mesh, extras)
        if step_fn is None:
            step_fn = jitted(params, opt_state, batch)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            print(f"step {step + 1:5d}  loss {float(m['loss']):7.4f}  "
                  f"gnorm {float(m['grad_norm']):8.3f}  {dt*1e3:6.1f} ms/it",
                  flush=True)
            t0 = time.time()
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            d = ckpt.save(args.ckpt_dir, step + 1,
                          {"params": params, "opt": opt_state})
            print(f"checkpointed -> {d}")
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
