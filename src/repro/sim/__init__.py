"""Scale-Sim + Accelergy analogue: the paper's evaluation toolchain in Python.

``systolic``  — analytic weight-stationary cycle model (partition-aware).
``energy``    — 45 nm per-access/per-cycle energy model with documented constants.
``workloads`` — the paper's 12 DNNs (heavy multi-domain + light RNN) as DNNGs.
``runner``    — baseline-vs-partitioned experiment driver (reproduces Fig. 9).
"""

from repro.sim.systolic import SystolicConfig, layer_time_fn
from repro.sim.energy import EnergyModel, EnergyBreakdown
from repro.sim.runner import run_experiment, ExperimentResult

__all__ = [
    "SystolicConfig",
    "layer_time_fn",
    "EnergyModel",
    "EnergyBreakdown",
    "run_experiment",
    "ExperimentResult",
]
