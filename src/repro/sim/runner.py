"""Baseline-vs-partitioned experiment driver — reproduces paper Fig. 9.

Since the `repro.api` redesign this module is a thin compatibility shim over
:class:`repro.api.Session` with the ``sim`` backend: ``run_experiment`` binds
a policy (default ``"paper"`` = the paper's ``equal``) to the Scale-Sim-style
analytic backend and returns the Session's result.  New code should use
`repro.api` directly:

    from repro.api import Session
    res = Session(policy="equal", backend="sim").run("heavy")

For each workload the driver runs:

* **baseline**   — sequential single-tenancy, every layer on the full array,
  unmodified PE (no ``Mul_En``; all PEs toggle every cycle);
* **partitioned** — dynamic partitioning under the selected policy with the
  ``Mul_En`` PE.

and reports per-DNN completion times (Fig. 9 a–d), partition-size usage
histograms (Fig. 9 c,d) and the energy breakdown (Fig. 9 e,f).
"""

from __future__ import annotations

from repro.api.backend import SimBackend
from repro.api.session import Session, SessionResult
from repro.sim.energy import EnergyModel
from repro.sim.systolic import SystolicConfig

# deprecated alias — the experiment result IS the Session result now
ExperimentResult = SessionResult


def run_experiment(
    workload: str,
    cfg: SystolicConfig | None = None,
    energy: EnergyModel | None = None,
    policy="paper",
) -> SessionResult:
    """Deprecated shim: ``Session(policy, backend="sim").run(workload)``."""
    backend = SimBackend(config=cfg, energy=energy)
    return Session(policy=policy, backend=backend).run(workload)


def format_report(res: SessionResult) -> str:
    lines = [f"== workload: {res.workload} (policy: {res.policy}) =="]
    lines.append(f"baseline makespan:     {res.baseline.makespan * 1e3:10.3f} ms")
    lines.append(f"partitioned makespan:  {res.partitioned.makespan * 1e3:10.3f} ms")
    lines.append(f"time saving (makespan):{res.time_saving * 100:10.1f} %")
    lines.append(f"time saving (turnarnd):{res.turnaround_saving * 100:10.1f} %")
    lines.append(f"baseline energy:       {res.baseline_energy.total * 1e3:10.3f} mJ")
    lines.append(f"partitioned energy:    {res.partitioned_energy.total * 1e3:10.3f} mJ")
    lines.append(f"energy saving:         {res.energy_saving * 100:10.1f} %")
    lines.append(f"baseline utilization:  {res.baseline.utilization * 100:10.1f} %")
    lines.append(f"partition utilization: {res.partitioned.utilization * 100:10.1f} %")
    lines.append("per-DNN completion (ms), baseline vs partitioned:")
    for name in res.baseline.completion:
        b = res.baseline.completion[name] * 1e3
        p = res.partitioned.completion[name] * 1e3
        lines.append(f"  {name:<18} {b:10.3f}  ->  {p:10.3f}")
    lines.append(f"partition sizes used: {res.partition_histogram()}")
    return "\n".join(lines)


def main() -> None:
    for wl in ("heavy", "light"):
        print(format_report(run_experiment(wl)))
        print()


if __name__ == "__main__":
    main()
