"""Baseline-vs-partitioned experiment driver — reproduces paper Fig. 9.

For each workload group the driver runs:

* **baseline**   — sequential single-tenancy, every layer on the full array,
  unmodified PE (no ``Mul_En``; all PEs toggle every cycle);
* **partitioned** — Algorithm 1 dynamic partitioning with the ``Mul_En`` PE.

and reports per-DNN completion times (Fig. 9 a–d), partition-size usage
histograms (Fig. 9 c,d) and the energy breakdown (Fig. 9 e,f).
"""

from __future__ import annotations

import dataclasses
from collections import Counter

from repro.core.dnng import DNNG
from repro.core.scheduler import (
    ScheduleResult,
    StageModel,
    schedule_dynamic,
    schedule_sequential,
)
from repro.sim.energy import (
    EnergyBreakdown,
    EnergyModel,
    schedule_energy_with_layers,
)
from repro.sim.systolic import SystolicConfig, layer_time_fn
from repro.sim.workloads import WORKLOADS


@dataclasses.dataclass(frozen=True)
class ExperimentResult:
    workload: str
    baseline: ScheduleResult
    partitioned: ScheduleResult
    baseline_energy: EnergyBreakdown
    partitioned_energy: EnergyBreakdown

    @property
    def time_saving(self) -> float:
        """Fractional makespan reduction (paper: 56 % heavy / 44 % light)."""
        return 1.0 - self.partitioned.makespan / self.baseline.makespan

    @property
    def turnaround_saving(self) -> float:
        """Fractional mean per-DNN completion-time reduction.

        Fig. 9(a,b) plots per-DNN completion times; multi-tenancy's headline
        win is that small DNNs no longer queue behind large ones, so mean
        turnaround drops much more than the makespan.
        """
        bsum = sum(self.baseline.completion.values())
        psum = sum(self.partitioned.completion.values())
        return 1.0 - psum / bsum

    @property
    def energy_saving(self) -> float:
        """Fractional energy reduction (paper: 35 % heavy / 62 % light)."""
        return 1.0 - self.partitioned_energy.total / self.baseline_energy.total

    def partition_histogram(self) -> dict[str, int]:
        """How many layers ran on each partition width (Fig. 9 c,d)."""
        c = Counter(f"{e.partition.rows}x{e.partition.cols}"
                    for e in self.partitioned.trace)
        return dict(sorted(c.items()))


def _layers_by_key(dnngs: list[DNNG]) -> dict[tuple[str, int], object]:
    return {(g.name, i): layer for g in dnngs for i, layer in
            enumerate(g.layers)}


def run_experiment(
    workload: str,
    cfg: SystolicConfig | None = None,
    energy: EnergyModel | None = None,
    policy: str = "paper",
) -> ExperimentResult:
    cfg = cfg or SystolicConfig()
    energy = energy or EnergyModel()
    dnngs = WORKLOADS[workload]()
    time_fn = layer_time_fn(cfg)
    stage = StageModel(dram_bw_bytes=cfg.dram_bw_bytes)
    layers = _layers_by_key(dnngs)

    base = schedule_sequential(dnngs, cfg.array, time_fn, stage=stage)
    part = schedule_dynamic(dnngs, cfg.array, time_fn, stage=stage,
                            policy=policy)

    e_base = schedule_energy_with_layers(base, layers, cfg, energy,
                                         baseline_pe=True)
    e_part = schedule_energy_with_layers(part, layers, cfg, energy,
                                         baseline_pe=False)
    return ExperimentResult(workload=workload, baseline=base,
                            partitioned=part, baseline_energy=e_base,
                            partitioned_energy=e_part)


def format_report(res: ExperimentResult) -> str:
    lines = [f"== workload: {res.workload} =="]
    lines.append(f"baseline makespan:     {res.baseline.makespan * 1e3:10.3f} ms")
    lines.append(f"partitioned makespan:  {res.partitioned.makespan * 1e3:10.3f} ms")
    lines.append(f"time saving (makespan):{res.time_saving * 100:10.1f} %")
    lines.append(f"time saving (turnarnd):{res.turnaround_saving * 100:10.1f} %")
    lines.append(f"baseline energy:       {res.baseline_energy.total * 1e3:10.3f} mJ")
    lines.append(f"partitioned energy:    {res.partitioned_energy.total * 1e3:10.3f} mJ")
    lines.append(f"energy saving:         {res.energy_saving * 100:10.1f} %")
    lines.append(f"baseline utilization:  {res.baseline.utilization * 100:10.1f} %")
    lines.append(f"partition utilization: {res.partitioned.utilization * 100:10.1f} %")
    lines.append("per-DNN completion (ms), baseline vs partitioned:")
    for name in res.baseline.completion:
        b = res.baseline.completion[name] * 1e3
        p = res.partitioned.completion[name] * 1e3
        lines.append(f"  {name:<18} {b:10.3f}  ->  {p:10.3f}")
    lines.append(f"partition sizes used: {res.partition_histogram()}")
    return "\n".join(lines)


def main() -> None:
    for wl in ("heavy", "light"):
        print(format_report(run_experiment(wl)))
        print()


if __name__ == "__main__":
    main()
