"""The paper's 12 simulation workloads (Table 1) as DNNGs.

Two groups (§4.1): *heavy* multi-domain (AlexNet, ResNet-50, GoogLeNet,
SA_CNN, SA_LSTM, NCF, AlphaGoZero, Transformer) and *light* RNN
(Melody-LSTM, Google-Translate/GNMT, DeepVoice, Handwriting-LSTM).

The paper does not publish per-layer dimensions, so layers use the standard
published configurations of each model (original papers / torchvision), at
inference batch 1.  LSTMs lower to one GEMM per layer with the 4 gates fused
(M = 4·hidden, K = input+hidden) and time steps folded into the streamed
dimension — the same lowering Scale-Sim's topology files use.

Calibration notes (EXPERIMENTS.md §Fig9):

* Sequence lengths are **inference-request scale** (the paper's INFaaS
  multi-tenant serving context): one 1 s melody chunk (100 × 10 ms frames),
  one 0.1 s vocoder chunk (1600 samples), one 200-point pen stroke, one
  20-token sentence.  The paper does not publish these; magnitudes of the
  reported savings are sensitive to them (longer sequences raise useful-MAC
  density and shrink the baseline's idle-multiplier waste that the Mul_En
  PE eliminates).
* Arrivals are staggered inside the first layer's execution window exactly
  as the paper's Fig. 4 timeline shows (A_t1..A_tn ≤ A_t0 + τ0), so the
  first DNNG's first layer runs on the whole array (Fig. 5 line 5).
"""

from __future__ import annotations

from repro.core.dnng import DNNG, LayerShape, chain

Conv = LayerShape.conv
FC = LayerShape.fc
LSTM = LayerShape.lstm_cell


# ---------------------------------------------------------------------------
# Heavy multi-domain workload
# ---------------------------------------------------------------------------

def alexnet() -> DNNG:
    return chain("AlexNet", [
        Conv("conv1", M=96, C=3, R=11, S=11, H=227, W=227, stride=4, pad=0),
        Conv("conv2", M=256, C=96, R=5, S=5, H=27, W=27, pad=2),
        Conv("conv3", M=384, C=256, R=3, S=3, H=13, W=13),
        Conv("conv4", M=384, C=384, R=3, S=3, H=13, W=13),
        Conv("conv5", M=256, C=384, R=3, S=3, H=13, W=13),
        FC("fc6", 9216, 4096),
        FC("fc7", 4096, 4096),
        FC("fc8", 4096, 1000),
    ])


def resnet50() -> DNNG:
    layers: list[LayerShape] = [
        Conv("conv1", M=64, C=3, R=7, S=7, H=224, W=224, stride=2, pad=3)]
    spatial = 56
    in_ch = 64
    stage_cfg = [  # (n_blocks, mid_channels, out_channels, first_stride)
        (3, 64, 256, 1), (4, 128, 512, 2), (6, 256, 1024, 2), (3, 512, 2048, 2)]
    for s, (blocks, mid, out, stride0) in enumerate(stage_cfg):
        for b in range(blocks):
            stride = stride0 if b == 0 else 1
            h = spatial
            layers.append(Conv(f"s{s}b{b}_1x1a", M=mid, C=in_ch, R=1, S=1,
                               H=h, W=h, stride=stride, pad=0))
            h2 = h // stride
            layers.append(Conv(f"s{s}b{b}_3x3", M=mid, C=mid, R=3, S=3,
                               H=h2, W=h2))
            layers.append(Conv(f"s{s}b{b}_1x1b", M=out, C=mid, R=1, S=1,
                               H=h2, W=h2, pad=0))
            if b == 0:
                layers.append(Conv(f"s{s}b{b}_down", M=out, C=in_ch, R=1, S=1,
                                   H=h, W=h, stride=stride, pad=0))
            in_ch = out
            spatial = h2
    layers.append(FC("fc", 2048, 1000))
    return chain("ResNet50", layers)


def googlenet() -> DNNG:
    """GoogLeNet (Inception v1) — the 9 inception modules, standard table."""
    layers: list[LayerShape] = [
        Conv("conv1", M=64, C=3, R=7, S=7, H=224, W=224, stride=2, pad=3),
        Conv("conv2r", M=64, C=64, R=1, S=1, H=56, W=56, pad=0),
        Conv("conv2", M=192, C=64, R=3, S=3, H=56, W=56),
    ]
    # (name, H, C_in, #1x1, #3x3red, #3x3, #5x5red, #5x5, pool_proj)
    inception = [
        ("3a", 28, 192, 64, 96, 128, 16, 32, 32),
        ("3b", 28, 256, 128, 128, 192, 32, 96, 64),
        ("4a", 14, 480, 192, 96, 208, 16, 48, 64),
        ("4b", 14, 512, 160, 112, 224, 24, 64, 64),
        ("4c", 14, 512, 128, 128, 256, 24, 64, 64),
        ("4d", 14, 512, 112, 144, 288, 32, 64, 64),
        ("4e", 14, 528, 256, 160, 320, 32, 128, 128),
        ("5a", 7, 832, 256, 160, 320, 32, 128, 128),
        ("5b", 7, 832, 384, 192, 384, 48, 128, 128),
    ]
    for nm, h, cin, c1, c3r, c3, c5r, c5, pp in inception:
        layers += [
            Conv(f"i{nm}_1x1", M=c1, C=cin, R=1, S=1, H=h, W=h, pad=0),
            Conv(f"i{nm}_3x3r", M=c3r, C=cin, R=1, S=1, H=h, W=h, pad=0),
            Conv(f"i{nm}_3x3", M=c3, C=c3r, R=3, S=3, H=h, W=h),
            Conv(f"i{nm}_5x5r", M=c5r, C=cin, R=1, S=1, H=h, W=h, pad=0),
            Conv(f"i{nm}_5x5", M=c5, C=c5r, R=5, S=5, H=h, W=h, pad=2),
            Conv(f"i{nm}_pool", M=pp, C=cin, R=1, S=1, H=h, W=h, pad=0),
        ]
    layers.append(FC("fc", 1024, 1000))
    return chain("GoogleNet", layers)


def sa_cnn() -> DNNG:
    """Sentiment-analysis CNN [23]: conv windows over fastText embeddings."""
    seq, emb = 56, 300
    return chain("SA_CNN", [
        LayerShape(M=100, N=1, C=emb, R=3, S=1, H=seq, W=1, P=seq - 2, Q=1,
                   name="conv3"),
        LayerShape(M=100, N=1, C=emb, R=4, S=1, H=seq, W=1, P=seq - 3, Q=1,
                   name="conv4"),
        LayerShape(M=100, N=1, C=emb, R=5, S=1, H=seq, W=1, P=seq - 4, Q=1,
                   name="conv5"),
        FC("fc", 300, 2),
    ])


def sa_lstm() -> DNNG:
    """Regional CNN-LSTM for dimensional sentiment [24]."""
    return chain("SA_LSTM", [
        LayerShape(M=64, N=1, C=300, R=3, S=1, H=56, W=1, P=54, Q=1,
                   name="region_conv"),
        LSTM("lstm1", input_size=64, hidden=512, steps=54),
        LSTM("lstm2", input_size=512, hidden=512, steps=54),
        FC("fc", 512, 2),
    ])


def ncf() -> DNNG:
    """Neural collaborative filtering [25]: small MLP tower, batch folded."""
    batch = 256
    return chain("NCF", [
        FC("mlp1", 256, 256, batch=batch),
        FC("mlp2", 256, 128, batch=batch),
        FC("mlp3", 128, 64, batch=batch),
        FC("mlp4", 64, 32, batch=batch),
        FC("predict", 32, 1, batch=batch),
    ])


def alphagozero() -> DNNG:
    layers: list[LayerShape] = [
        Conv("stem", M=256, C=17, R=3, S=3, H=19, W=19)]
    for i in range(19):
        layers.append(Conv(f"res{i}a", M=256, C=256, R=3, S=3, H=19, W=19))
        layers.append(Conv(f"res{i}b", M=256, C=256, R=3, S=3, H=19, W=19))
    layers += [
        Conv("policy_conv", M=2, C=256, R=1, S=1, H=19, W=19, pad=0),
        FC("policy_fc", 722, 362),
        Conv("value_conv", M=1, C=256, R=1, S=1, H=19, W=19, pad=0),
        FC("value_fc1", 361, 256),
        FC("value_fc2", 256, 1),
    ]
    return chain("AlphaGoZero", layers)


def transformer() -> DNNG:
    """Transformer-base [27]: 6 enc + 6 dec, d=512, d_ff=2048, seq 128.

    Block GEMMs only — the vocab projection is excluded, consistent with
    Scale-Sim topology files which model the recurrent/attention/FF GEMMs.
    """
    d, dff, seq = 512, 2048, 128
    layers: list[LayerShape] = []
    for i in range(6):
        layers += [
            FC(f"enc{i}_qkv", d, 3 * d, batch=seq),
            FC(f"enc{i}_attn_out", d, d, batch=seq),
            FC(f"enc{i}_ff1", d, dff, batch=seq),
            FC(f"enc{i}_ff2", dff, d, batch=seq),
        ]
    for i in range(6):
        layers += [
            FC(f"dec{i}_qkv", d, 3 * d, batch=seq),
            FC(f"dec{i}_attn_out", d, d, batch=seq),
            FC(f"dec{i}_xqkv", d, 3 * d, batch=seq),
            FC(f"dec{i}_xattn_out", d, d, batch=seq),
            FC(f"dec{i}_ff1", d, dff, batch=seq),
            FC(f"dec{i}_ff2", dff, d, batch=seq),
        ]
    return chain("Transformer", layers)


# ---------------------------------------------------------------------------
# Light RNN workload
# ---------------------------------------------------------------------------

def melody_lstm() -> DNNG:
    """Melody extraction LSTM-RNN [28]: spectrogram frames -> pitch labels.

    Audio workload: 10 ms frames, one 1 s request chunk = 100 frames,
    512-unit 3-layer stack.
    """
    steps = 100
    return chain("MelodyLSTM", [
        LSTM("lstm1", input_size=513, hidden=512, steps=steps),
        LSTM("lstm2", input_size=512, hidden=512, steps=steps),
        LSTM("lstm3", input_size=512, hidden=512, steps=steps),
        FC("out", 512, 722, batch=steps),
    ])


def google_translate() -> DNNG:
    """GNMT [29]: 8 encoder + 8 decoder LSTM(1024) layers + attention.

    One 20-token sentence (typical MT inference length).  The vocab softmax
    projection is excluded, consistent with Scale-Sim topology convention.
    """
    steps = 20
    layers: list[LayerShape] = [
        LSTM("enc_bi_fwd", input_size=1024, hidden=1024, steps=steps),
        LSTM("enc_bi_bwd", input_size=1024, hidden=1024, steps=steps),
    ]
    for i in range(6):
        layers.append(LSTM(f"enc{i + 2}", input_size=1024, hidden=1024,
                           steps=steps))
    layers.append(FC("attention", 1024, 1024, batch=steps))
    for i in range(8):
        layers.append(LSTM(f"dec{i}", input_size=1024 if i else 2048,
                           hidden=1024, steps=steps))
    return chain("GoogleTranslate", layers)


def deep_voice() -> DNNG:
    """Deep Voice [30]: segmentation/duration/f0 GRUs + vocoder stack.

    The vocoder dominates: Deep Voice's synthesis RNN runs per audio sample
    (one 0.1 s request chunk at 16 kHz = 1600 steps, hidden 512).
    """
    return chain("DeepVoice", [
        LSTM("g2p_enc", input_size=256, hidden=256, steps=40),
        LSTM("g2p_dec", input_size=256, hidden=256, steps=40),
        LSTM("duration", input_size=256, hidden=256, steps=40),
        LSTM("f0_rnn", input_size=256, hidden=256, steps=80),
        LSTM("vocoder_rnn", input_size=512, hidden=512, steps=1600),
        FC("vocoder_proj", 512, 513, batch=1600),
    ])


def handwriting_lstm() -> DNNG:
    """Fast multi-language online handwriting LSTM [31]: 3xLSTM over one
    200-point pen-stroke sequence."""
    steps = 200
    return chain("HandwritingLSTM", [
        LSTM("lstm1", input_size=32, hidden=128, steps=steps),
        LSTM("lstm2", input_size=128, hidden=128, steps=steps),
        LSTM("lstm3", input_size=128, hidden=128, steps=steps),
        FC("ctc_out", 128, 100, batch=steps),
    ])


# ---------------------------------------------------------------------------

def _stagger(dnngs: list[DNNG], step_s: float) -> list[DNNG]:
    """Arrival times per Fig. 4: A_t1..A_tn land inside L0 of DNNG_0."""
    import dataclasses as _dc
    return [_dc.replace(g, arrival_time=i * step_s)
            for i, g in enumerate(dnngs)]


def heavy_workload(stagger_s: float = 2e-6) -> list[DNNG]:
    """Table 1, group 1 — multi-domain heavy load."""
    return _stagger([alexnet(), resnet50(), googlenet(), sa_cnn(), sa_lstm(),
                     ncf(), alphagozero(), transformer()], stagger_s)


def light_workload(stagger_s: float = 2e-6) -> list[DNNG]:
    """Table 1, group 2 — RNN light load."""
    return _stagger([melody_lstm(), google_translate(), deep_voice(),
                     handwriting_lstm()], stagger_s)


WORKLOADS = {
    "heavy": heavy_workload,
    "light": light_workload,
}

# Table-1 models individually, for per-job sampling by the open-loop traffic
# generator (`repro.traffic.arrivals`): each arrival picks ONE model from a
# pool instead of replaying the whole closed workload at t≈0.
MODELS = {
    "AlexNet": alexnet,
    "ResNet50": resnet50,
    "GoogleNet": googlenet,
    "SA_CNN": sa_cnn,
    "SA_LSTM": sa_lstm,
    "NCF": ncf,
    "AlphaGoZero": alphagozero,
    "Transformer": transformer,
    "MelodyLSTM": melody_lstm,
    "GoogleTranslate": google_translate,
    "DeepVoice": deep_voice,
    "HandwritingLSTM": handwriting_lstm,
}

MODEL_POOLS = {
    "heavy": ("AlexNet", "ResNet50", "GoogleNet", "SA_CNN", "SA_LSTM",
              "NCF", "AlphaGoZero", "Transformer"),
    "light": ("MelodyLSTM", "GoogleTranslate", "DeepVoice",
              "HandwritingLSTM"),
    "all": tuple(MODELS),
}


_TEMPLATES: dict = {}


def _model_template(model: str) -> DNNG:
    """Memoized Table-1 template: model constructors are pure, and the
    open-loop generator stamps thousands of per-job clones — rebuilding
    every LayerShape per arrival was measurable on the serving hot path.
    Cloning via ``dataclasses.replace`` shares the (frozen) layer tuple, so
    all jobs of one model also share the scheduler's cost-oracle cache
    entries.  Keyed by the constructor object itself, so a patched
    ``MODELS`` registry (ablations, tests) misses the cache as it should."""
    fn = MODELS[model]
    g = _TEMPLATES.get(fn)
    if g is None:
        g = _TEMPLATES[fn] = fn()
    return g


def sample_dnng(rng, pool: str = "all", name: str | None = None,
                arrival_time: float = 0.0) -> DNNG:
    """One fresh Table-1 DNNG for an arriving job.

    ``rng`` is a seeded ``random.Random`` (determinism lives with the
    caller); ``pool`` selects the sampling universe (``MODEL_POOLS``);
    ``name`` overrides the tenant name so concurrent jobs of the same model
    stay distinct in the scheduler.
    """
    if pool not in MODEL_POOLS:
        raise ValueError(f"unknown pool {pool!r}; known: "
                         f"{sorted(MODEL_POOLS)}")
    model = rng.choice(MODEL_POOLS[pool])
    return _model_template(model).clone(name=name, arrival_time=arrival_time)
