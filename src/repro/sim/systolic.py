"""Analytic weight-stationary systolic-array timing model (Scale-Sim analogue).

The paper evaluates with Scale-Sim [16] in analytical mode; this module is the
equivalent closed-form model, extended to be **partition-aware** (col offsets,
per-partition folds) via :func:`repro.core.dataflow.ws_cost`.

Array config follows the paper §4.2: a TPU-v3-like 128×128 PE array.
"""

from __future__ import annotations

import dataclasses
import functools

from repro.core.dataflow import GEMM, DataflowCost, ws_cost
from repro.core.dnng import LayerShape
from repro.core.partition import ArrayShape, Partition
from repro.core.scheduler import TimeFn


@dataclasses.dataclass(frozen=True)
class SystolicConfig:
    """Hardware parameters of the simulated accelerator (paper §4.2)."""

    rows: int = 128
    cols: int = 128
    clock_hz: float = 940e6          # TPU v3 core clock
    dram_bw_bytes: float = 64e9      # off-chip staging bandwidth (shared bus)

    @property
    def array(self) -> ArrayShape:
        return ArrayShape(rows=self.rows, cols=self.cols)

    @property
    def macs_per_cycle(self) -> int:
        return self.rows * self.cols


@functools.lru_cache(maxsize=1 << 16)
def layer_cost(layer: LayerShape, part: Partition) -> DataflowCost:
    """Cycle/access breakdown of one layer on one partition.

    Memoized on top of the (also memoized) :func:`ws_cost`: the extra LRU
    level skips even the layer→GEMM lowering for the exact repeats the
    scheduler's rebalance loop generates.
    """
    return ws_cost(GEMM.of_layer(layer), part)


def layer_cycles(layer: LayerShape, part: Partition) -> int:
    return layer_cost(layer, part).cycles


def layer_time_fn(cfg: SystolicConfig) -> TimeFn:
    """Scheduler oracle: seconds for ``layer`` on ``part`` at ``cfg.clock_hz``."""

    def fn(layer: LayerShape, part: Partition) -> float:
        return layer_cycles(layer, part) / cfg.clock_hz

    return fn
