"""Analytic weight-stationary systolic-array timing model (Scale-Sim analogue).

The paper evaluates with Scale-Sim [16] in analytical mode; this module is the
equivalent closed-form model, extended to be **partition-aware** (col offsets,
per-partition folds) via :func:`repro.core.dataflow.ws_cost`.

Array config follows the paper §4.2: a TPU-v3-like 128×128 PE array.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

from repro.core.dataflow import (
    GEMM,
    BatchCost,
    DataflowCost,
    ws_cost,
    ws_cost_batch,
)
from repro.core.dnng import LayerShape
from repro.core.partition import ArrayShape, Partition
from repro.core.scheduler import TimeFn


@dataclasses.dataclass(frozen=True)
class SystolicConfig:
    """Hardware parameters of the simulated accelerator (paper §4.2)."""

    rows: int = 128
    cols: int = 128
    clock_hz: float = 940e6          # TPU v3 core clock
    dram_bw_bytes: float = 64e9      # off-chip staging bandwidth (shared bus)

    @property
    def array(self) -> ArrayShape:
        return ArrayShape(rows=self.rows, cols=self.cols)

    @property
    def macs_per_cycle(self) -> int:
        return self.rows * self.cols


@functools.lru_cache(maxsize=1 << 16)
def layer_cost(layer: LayerShape, part: Partition) -> DataflowCost:
    """Cycle/access breakdown of one layer on one partition.

    Memoized on top of the (also memoized) :func:`ws_cost`: the extra LRU
    level skips even the layer→GEMM lowering for the exact repeats the
    scheduler's rebalance loop generates.
    """
    return ws_cost(GEMM.of_layer(layer), part)


def layer_cycles(layer: LayerShape, part: Partition) -> int:
    return layer_cost(layer, part).cycles


def layer_cost_batch(layers: Sequence[LayerShape],
                     parts: Sequence[Partition],
                     bw_shares: "Sequence[float] | None" = None
                     ) -> BatchCost:
    """Vectorized :func:`layer_cost` over paired (layer, partition)
    candidates — one :func:`repro.core.dataflow.ws_cost_batch` NumPy pass
    after the layer→GEMM lowering.  Bit-identical to the scalar path.

    ``bw_shares`` (optional per-pair memory-bandwidth shares) fills the
    table's ``dram_stall_elems`` column — zeros at share 1.0, and the
    int64 columns are untouched by it (see
    :func:`repro.core.dataflow.ws_cost_batch`)."""
    return ws_cost_batch([GEMM.of_layer(layer) for layer in layers], parts,
                         bw_shares=bw_shares)


class _BatchTimeOracle:
    """Memoized vectorized seconds oracle — ``time_fn.batch``.

    ``pairs`` → seconds for each (layer, partition), serving exact repeats
    from a dict memo (the batch analogue of the ``layer_cost`` LRU: the
    rebalance loop re-prices the same pairings round after round).  Misses
    are evaluated in ONE :func:`layer_cost_batch` NumPy pass when the
    batch is large enough to amortize array packing; small miss sets go
    through the (globally warm) ``layer_cost`` LRU instead — the NumPy
    fixed cost loses below a few dozen pairs.  Seconds always come from
    Python-int cycles divided by ``clock_hz`` — the very float op of the
    scalar path, so values are bit-identical either way.

    The memo is shared per ``clock_hz`` across all oracle instances (one
    serving fleet spawns one backend per node/cell), mirroring the global
    scalar LRUs.
    """

    __slots__ = ("clock_hz", "_memo", "hits", "misses")

    #: below this many missing pairs the scalar LRU path is used
    VECTOR_THRESHOLD = 32
    #: memo reset bound — mirrors the scalar LRUs' maxsize so the shared
    #: dict cannot grow without bound over long geometry sweeps (a full
    #: reset is the cheap bound: entries are pure and re-derivable)
    MAX_ENTRIES = 1 << 16

    _shared_memos: dict = {}

    @classmethod
    def clear_all(cls) -> None:
        """Drop every shared memo (tests, memory) — the batch analogue of
        :func:`repro.core.dataflow.ws_cost_cache_clear`."""
        cls._shared_memos.clear()

    def __init__(self, clock_hz: float):
        self.clock_hz = clock_hz
        self._memo = self._shared_memos.setdefault(clock_hz, {})
        self.hits = 0
        self.misses = 0

    def __call__(self, pairs: Sequence[tuple[LayerShape, Partition]]
                 ) -> list[float]:
        memo = self._memo
        missing = [pair for pair in pairs if pair not in memo]
        if missing:
            missing = list(dict.fromkeys(missing))  # dedupe, order kept
            self.misses += len(missing)
            if len(memo) + len(missing) > self.MAX_ENTRIES:
                # reset, but keep the entries this very call still serves
                keep = {p: memo[p] for p in pairs if p in memo}
                memo.clear()
                memo.update(keep)
            if len(missing) < self.VECTOR_THRESHOLD:
                clock = self.clock_hz
                for pair in missing:
                    memo[pair] = layer_cost(*pair).cycles / clock
            else:
                table = layer_cost_batch([la for la, _ in missing],
                                         [p for _, p in missing])
                for pair, cyc in zip(missing, table.cycles.tolist()):
                    memo[pair] = cyc / self.clock_hz
        self.hits += len(pairs) - len(missing)
        return [memo[pair] for pair in pairs]

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "currsize": len(self._memo)}


def layer_time_fn(cfg: SystolicConfig) -> TimeFn:
    """Scheduler oracle: seconds for ``layer`` on ``part`` at ``cfg.clock_hz``.

    The returned callable carries a ``batch`` attribute (a
    :class:`_BatchTimeOracle`): consumers holding many candidates price
    them in one vectorized pass via ``time_fn.batch(pairs)`` —
    :meth:`repro.api.policy.AssignContext.time_batch` discovers it by
    ``getattr`` and falls back to the scalar loop for oracles without one.
    """

    clock = cfg.clock_hz

    def fn(layer: LayerShape, part: Partition) -> float:
        return layer_cost(layer, part).cycles / clock

    fn.batch = _BatchTimeOracle(clock)
    return fn
