"""Accelergy-style 45 nm energy model (paper §4.2 toolchain analogue).

Constants are 16-bit-datapath energies at 45 nm, taken from the standard
Eyeriss/Horowitz relative-cost tables that Accelergy's Cacti/Aladdin plugins
reproduce (ALU : SRAM : DRAM ≈ 1 : 6 : 200):

=====================  =========  ====================================
component              energy     source / rationale
=====================  =========  ====================================
16-bit MAC             1.0  pJ    Horowitz ISSCC'14 (0.4 pJ mult + add,
                                  reg toggles); Eyeriss "1x" reference
pass-through forward   0.12 pJ    one pipeline latch + wire segment —
                                  the Mul_En=0 tri-stated PE (paper Fig.7)
SRAM access (16-bit)   6.0  pJ    ~100 KB buffer, Eyeriss "6x"
DRAM access (16-bit)   200  pJ    Eyeriss "200x"
PE leakage             25 µW      45 nm MAC+regs static power
=====================  =========  ====================================

**The tri-state gate is the dynamic-energy mechanism** (paper §3.4): the
baseline PE (Fig. 7b) has no ``Mul_En``, so every clocked PE in the array
multiplies whatever streams through it — columns not covered by the layer's
``N`` burn full MAC energy on discarded products.  The proposed PE (Fig. 7a)
tri-states the multiplier for pass-through traffic, paying only the forward
latch.  Hence:

* baseline      — MAC energy ∝ (cycles × *all* array PEs)
* partitioned   — MAC energy ∝ (cycles × *own partition's* PEs)
                  + forward energy ∝ (cycles × rows × col_start) pass-through

Static leakage accrues over the whole array for the whole makespan in both
modes, so the makespan reduction is the second saving mechanism.
"""

from __future__ import annotations

import dataclasses

from repro.core.partition import ArrayShape
from repro.core.scheduler import ScheduleResult
from repro.sim.systolic import SystolicConfig, layer_cost


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    e_mac_pj: float = 1.0
    e_fwd_pj: float = 0.12
    e_sram_pj: float = 6.0
    # feed re-reads hit a small banked lane buffer (~8 KB/row), far cheaper
    # than the big load/drain SRAMs
    e_feed_pj: float = 2.0
    e_dram_pj: float = 200.0
    p_leak_pe_w: float = 25e-6
    # clock-tree + always-on control dynamic power, per PE per cycle while
    # the accelerator is powered (≈30 % of a PE's active dynamic at 45 nm)
    e_clk_pj: float = 0.30
    # memory-contention stall overheads, per stalled bus cycle
    # (ScheduleResult.bus_stall_s × clock): the DRAM interface keeps
    # banks active/precharging without moving useful data, and the
    # staging SRAM port toggles waiting on it.  Both fold into the
    # existing sram_j/dram_j buckets, priced only when a schedule
    # actually stalled (bus_stall_s == 0.0 ⇒ byte-identical books).
    e_stall_sram_pj: float = 0.6
    e_stall_dram_pj: float = 1.2

    def leak_power(self, array: ArrayShape) -> float:
        return self.p_leak_pe_w * array.rows * array.cols


@dataclasses.dataclass(frozen=True)
class EnergyBreakdown:
    """Per-mechanism energy in joules; ``total`` is the Fig. 9(e,f) number."""

    mac_j: float
    forward_j: float
    sram_j: float
    dram_j: float
    clock_j: float
    leakage_j: float

    @property
    def total(self) -> float:
        return (self.mac_j + self.forward_j + self.sram_j + self.dram_j
                + self.clock_j + self.leakage_j)

    @property
    def dynamic(self) -> float:
        return self.total - self.leakage_j

    def as_dict(self) -> dict[str, float]:
        return {
            "mac_j": self.mac_j,
            "forward_j": self.forward_j,
            "sram_j": self.sram_j,
            "dram_j": self.dram_j,
            "clock_j": self.clock_j,
            "leakage_j": self.leakage_j,
            "total_j": self.total,
        }


def schedule_energy_with_layers(
    result: ScheduleResult,
    layers_by_key: dict[tuple[str, int], "object"],
    cfg: SystolicConfig,
    model: EnergyModel,
    baseline_pe: bool,
) -> EnergyBreakdown:
    """Full energy including SRAM/DRAM traffic.

    ``layers_by_key`` maps (tenant, layer_index) -> LayerShape so the access
    counts of each executed layer can be recomputed for its partition.

    Preemption segments stay exact: each :class:`TraceEvent` carries the
    ``fraction`` of the layer's compute it covers, so per-layer access
    counts are scaled per segment (segment fractions sum to 1.0 — a
    preemption-free trace is bit-identical to the pre-segment accounting).
    The preemption *overheads* are added on top: a ``preempted`` segment
    that did compute pays the in-array psum drain (one fp32 accumulator
    per partition PE → two 16-bit DRAM accesses each), and a ``resumed``
    segment pays the weight re-stage (``K×N`` stationary-operand DRAM
    re-reads).
    """
    pj = 1e-12
    mac = fwd = sram = dram = 0.0
    full_pes = cfg.rows * cfg.cols
    for ev in result.trace:
        layer = layers_by_key[(ev.tenant, ev.layer_index)]
        cost = layer_cost(layer, ev.partition)
        # segment scaling; the identity path keeps integer operands intact
        # so preemption-free traces stay bit-identical to the pre-segment
        # accounting
        frac = ev.fraction
        scale = (lambda x: x) if frac == 1.0 else (lambda x: x * frac)
        if baseline_pe:
            # Fig. 7(b): no Mul_En — the multiplier of every clocked PE
            # toggles every compute cycle (stale or real operands alike).
            mac += model.e_mac_pj * scale(cost.cycles) * full_pes * pj
        else:
            # Fig. 7(a): Mul_En=1 only while the partition's own feed data
            # streams through — T multiplier firings per PE per fold;
            # load phases and foreign-tenant pass-through are tri-stated
            # (latch/wire energy only).
            mac += model.e_mac_pj * scale(cost.feed_pe_cycles) * pj
            fwd += model.e_fwd_pj * scale(cost.load_pe_cycles) * pj
            fwd += (model.e_fwd_pj * scale(cost.cycles) * ev.partition.rows
                    * ev.partition.col_start * pj)
        sram += model.e_sram_pj * scale(cost.load_buf_reads
                                        + cost.drain_buf_writes) * pj
        sram += model.e_feed_pj * scale(cost.feed_buf_reads) * pj
        dram += model.e_dram_pj * scale(cost.dram_reads
                                        + cost.dram_writes) * pj
        if ev.preempted and ev.fraction > 0.0:
            # psum drain: fp32 accumulators of the column group, written
            # out as 2 × 16-bit DRAM accesses per PE
            dram += model.e_dram_pj * 2 * ev.partition.n_pes * pj
        if ev.resumed:
            # weight re-stage: the stationary K×N operands re-read from
            # DRAM (their first read was billed to the original segment)
            dram += model.e_dram_pj * layer.gemm_k * layer.gemm_n * pj
    if result.bus_stall_s:
        # memory-contention stalls (MemorySystem.stall_s): the bus was
        # occupied beyond the raw transfer times — bill the stalled DRAM
        # interface + staging-SRAM port cycles into the existing buckets.
        # Unarmed schedules carry bus_stall_s == 0.0 and skip this block,
        # keeping the books byte-identical to the pre-contention model.
        stall_cycles = result.bus_stall_s * cfg.clock_hz
        sram += model.e_stall_sram_pj * stall_cycles * pj
        dram += model.e_stall_dram_pj * stall_cycles * pj
    leak = model.leak_power(cfg.array) * result.makespan
    clk = (model.e_clk_pj * full_pes * result.makespan * cfg.clock_hz) * pj
    return EnergyBreakdown(mac_j=mac, forward_j=fwd, sram_j=sram, dram_j=dram,
                           clock_j=clk, leakage_j=leak)
