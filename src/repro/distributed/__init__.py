"""Distribution layer: sharding rules, mesh-level tenancy, compression."""
