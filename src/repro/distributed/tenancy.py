"""Mesh-level multi-tenancy — Algorithm 1 applied to the TPU device grid.

This is the cluster-scale realisation of the paper's claim: ONE physical
resource pool (the ``model`` axis of a pod's device mesh ≙ the systolic
array's columns) is *vertically partitioned* into contiguous per-tenant
slices, sized dynamically by load and merged when tenants drain.

Mapping (DESIGN.md §2):

    PE columns            →  devices along the "model" mesh axis
    vertical partition    →  contiguous column range [c0, c0+w) of the grid
    Mul_En isolation      →  per-tenant sub-``Mesh`` objects — jit'ing a
                             tenant's step inside its sub-mesh means GSPMD
                             can never emit a collective that crosses a
                             partition edge (isolation is structural)
    Partition_Calculation →  ``TenantMeshManager.rebalance`` — widths come
                             from a pluggable ``repro.api.policy``
                             :class:`PartitionPolicy` (default ``equal``:
                             the paper's ⌊Y/n⌋)
    Task_Assignment       →  policy order (equal: heaviest demand) →
                             widest free slice
    merge on free         →  inherited verbatim from core.partition

Fault tolerance: ``mark_unhealthy(col)`` removes a device column from
service; affected tenants are re-assigned on the next rebalance — the
paper's merge/re-assign machinery *is* the recovery policy (stragglers are
handled the same way: ``shrink`` demotes a slow tenant's width so the
heaviest-first sort hands the freed columns to healthy tenants).
"""

from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh

from repro.core.dnng import LayerShape
from repro.core.partition import ArrayShape, Partition, PartitionSet


@dataclasses.dataclass(frozen=True)
class MeshLatencyModel:
    """Analytic per-layer latency of a GEMM on a mesh column slice.

    The cluster-scale analogue of `repro.sim.systolic`: a layer sharded
    over a ``w``-device slice pays per-device compute, a ring collective
    over its output activations (weights are column-sharded along the
    ``model`` axis, so each step all-gathers/reduce-scatters the OFMap),
    and a fixed dispatch overhead.  Used by the ``mesh`` backend of
    `repro.api` to drive the same event scheduler at cluster scale.
    """

    device_flops: float = 90e12      # bf16 sustained per device
    ici_bw_bytes: float = 45e9       # per-link interconnect bandwidth
    host_bw_bytes: float = 50e9      # host→HBM staging (shared bus)
    launch_overhead_s: float = 5e-6  # per-layer dispatch latency

    def layer_time_s(self, layer: LayerShape, part: Partition) -> float:
        flops = 2.0 * layer.macs
        compute = flops / (self.device_flops * part.n_pes)
        comm = 0.0
        if part.cols > 1:
            out_bytes = 2.0 * layer.gemm_m * layer.gemm_n
            comm = (2.0 * (part.cols - 1) / part.cols
                    * out_bytes / self.ici_bw_bytes)
        return self.launch_overhead_s + compute + comm

    def time_fn(self):
        return self.layer_time_s


@dataclasses.dataclass
class Tenant:
    """One admitted model/service occupying a column slice of the mesh."""

    name: str
    demand: float                  # load estimate (≙ Opr of Algorithm 1)
    min_cols: int = 1              # e.g. memory floor: params must fit
    tier: int = 0                  # SLA class (policy="priority"; 0 = top)
    partition: Partition | None = None


class TenantMeshManager:
    """Dynamic vertical partitioning of a device mesh among tenants.

    ``policy`` (a `repro.api.policy` registry name or instance, default
    ``"equal"``) decides target widths and grant order at every
    :meth:`rebalance`; the free-slice carving, unhealthy-column fencing and
    merge-on-free mechanics are policy-independent.
    """

    def __init__(self, mesh: Mesh, column_axis: str = "model",
                 policy="equal"):
        if column_axis not in mesh.axis_names:
            raise ValueError(f"mesh has no {column_axis!r} axis: "
                             f"{mesh.axis_names}")
        self.mesh = mesh
        self.column_axis = column_axis
        self.axis_index = mesh.axis_names.index(column_axis)
        n_cols = mesh.devices.shape[self.axis_index]
        # "rows" of the paper's array = all other mesh axes, collapsed
        n_rows = int(np.prod(mesh.devices.shape)) // n_cols
        self._pset = PartitionSet(ArrayShape(rows=max(n_rows, 1),
                                             cols=n_cols))
        self._tenants: dict[str, Tenant] = {}
        self._unhealthy: set[int] = set()
        self.policy = policy  # resolved lazily (str | PartitionPolicy)

    # -- queries -----------------------------------------------------------
    @property
    def n_cols(self) -> int:
        return self._pset.array.cols

    def tenant(self, name: str) -> Tenant:
        return self._tenants[name]

    def tenants(self) -> list[Tenant]:
        return list(self._tenants.values())

    def utilization(self) -> float:
        return self._pset.utilization

    def submesh(self, name: str) -> Mesh:
        """Per-tenant Mesh over its column slice (the sub-accelerator)."""
        t = self._tenants[name]
        if t.partition is None:
            raise ValueError(f"tenant {name!r} holds no partition")
        sl = [slice(None)] * self.mesh.devices.ndim
        sl[self.axis_index] = slice(t.partition.col_start, t.partition.col_end)
        return Mesh(self.mesh.devices[tuple(sl)], self.mesh.axis_names)

    # -- admission / release ------------------------------------------------
    def admit(self, name: str, demand: float, min_cols: int = 1,
              tier: int = 0) -> Tenant:
        """Queue a tenant; slices are handed out by :meth:`rebalance`."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already admitted")
        if min_cols > self.n_cols:
            raise ValueError(f"min_cols {min_cols} exceeds mesh width "
                             f"{self.n_cols}")
        t = Tenant(name=name, demand=demand, min_cols=min_cols, tier=tier)
        self._tenants[name] = t
        return t

    def release(self, name: str) -> None:
        """Tenant drains: free its slice and merge (Fig. 5 merge-on-free)."""
        t = self._tenants.pop(name)
        if t.partition is not None:
            self._pset.free(name)
        self._pset.check()

    def mark_unhealthy(self, col: int) -> list[str]:
        """Remove a device column from service; returns evicted tenants."""
        if not (0 <= col < self.n_cols):
            raise ValueError(f"column {col} out of range")
        self._unhealthy.add(col)
        evicted = []
        for name, t in self._tenants.items():
            if t.partition and t.partition.col_start <= col < t.partition.col_end:
                self._pset.free(name)
                t.partition = None
                evicted.append(name)
        return evicted

    def mark_healthy(self, col: int) -> None:
        self._unhealthy.discard(col)

    # -- Algorithm 1, policy-generalised ------------------------------------
    def rebalance(self, policy=None) -> dict[str, Partition]:
        """(Re-)run the policy's Partition_Calculation + Task_Assignment.

        All slices are dropped and re-cut (tenancy rebalance happens at step
        boundaries — tenants re-jit onto their new sub-mesh; checkpointed
        state is resharded by ``training.checkpoint.reshard``).
        Unhealthy columns are fenced off as permanently-busy pseudo-tenants.
        ``policy`` overrides the manager's default for this round.
        """
        # lazy import: repro.api builds on repro.core, not the reverse
        from repro.api.policy import TenantDemand, resolve_policy
        pol = resolve_policy(policy if policy is not None else self.policy)

        # reset: drop every grant, rebuild the interval state from scratch
        for t in self._tenants.values():
            t.partition = None
        self._pset = PartitionSet(self._pset.array)
        # fence unhealthy columns as permanently-busy pseudo-tenants
        for col in sorted(self._unhealthy):
            self._pset.allocate_exact(
                f"__dead{col}",
                Partition(rows=self._pset.array.rows, col_start=col, cols=1))

        if not self._tenants:
            return {}
        avail = self.n_cols - len(self._unhealthy)
        demands = [TenantDemand(name=t.name, demand=t.demand,
                                min_cols=t.min_cols, tier=t.tier)
                   for t in self._tenants.values()]
        widths = pol.widths(avail, demands) if avail >= 1 else {}

        out: dict[str, Partition] = {}
        for d in pol.order(demands):
            width = widths.get(d.name, 0)
            if width < 1:
                continue  # over-subscribed: tenant waits for a free round
            t = self._tenants[d.name]
            width = max(width, t.min_cols)
            # policy order: grant from the largest free slice, verbatim
            # Task_Assignment; clamp to what is actually free.
            free = self._pset.largest_free()
            if free is None:
                continue
            width = min(width, free.cols)
            if width < t.min_cols:
                continue
            got = self._pset.allocate_exact(
                t.name, Partition(rows=free.rows, col_start=free.col_start,
                                  cols=width))
            t.partition = got
            out[t.name] = got
        self._pset.check()
        return out

    def grow_into_free(self) -> dict[str, Partition]:
        """Merge-accelerate (paper §3.3): expand tenants adjacent to free
        slices, heaviest first, without moving anyone (no re-shard storm)."""
        grown: dict[str, Partition] = {}
        for t in sorted(self._tenants.values(), key=lambda t: t.demand,
                        reverse=True):
            if t.partition is None:
                continue
            for f in self._pset.free_partitions:
                if self._unhealthy & set(range(f.col_start, f.col_end)):
                    continue
                if f.col_start == t.partition.col_end or \
                        f.col_end == t.partition.col_start:
                    self._pset.free(t.name)
                    merged = t.partition.merge(f)
                    # re-claim the merged span (consumes the free slice)
                    self._pset.allocate_exact(t.name, merged)
                    t.partition = merged
                    grown[t.name] = merged
                    break
        self._pset.check()
        return grown
