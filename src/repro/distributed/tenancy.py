"""Mesh-level multi-tenancy — Algorithm 1 applied to the TPU device grid.

This is the cluster-scale realisation of the paper's claim: ONE physical
resource pool (the ``model`` axis of a pod's device mesh ≙ the systolic
array's columns) is *vertically partitioned* into contiguous per-tenant
slices, sized dynamically by load and merged when tenants drain.

Mapping (DESIGN.md §2):

    PE columns            →  devices along the "model" mesh axis
    vertical partition    →  contiguous column range [c0, c0+w) of the grid
    Mul_En isolation      →  per-tenant sub-``Mesh`` objects — jit'ing a
                             tenant's step inside its sub-mesh means GSPMD
                             can never emit a collective that crosses a
                             partition edge (isolation is structural)
    Partition_Calculation →  ``TenantMeshManager.rebalance`` (⌊Y/n⌋ widths)
    Task_Assignment       →  heaviest-demand tenant → widest free slice
    merge on free         →  inherited verbatim from core.partition

Fault tolerance: ``mark_unhealthy(col)`` removes a device column from
service; affected tenants are re-assigned on the next rebalance — the
paper's merge/re-assign machinery *is* the recovery policy (stragglers are
handled the same way: ``shrink`` demotes a slow tenant's width so the
heaviest-first sort hands the freed columns to healthy tenants).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.partition import ArrayShape, Partition, PartitionSet


@dataclasses.dataclass
class Tenant:
    """One admitted model/service occupying a column slice of the mesh."""

    name: str
    demand: float                  # load estimate (≙ Opr of Algorithm 1)
    min_cols: int = 1              # e.g. memory floor: params must fit
    partition: Partition | None = None


class TenantMeshManager:
    """Dynamic vertical partitioning of a device mesh among tenants."""

    def __init__(self, mesh: Mesh, column_axis: str = "model"):
        if column_axis not in mesh.axis_names:
            raise ValueError(f"mesh has no {column_axis!r} axis: "
                             f"{mesh.axis_names}")
        self.mesh = mesh
        self.column_axis = column_axis
        self.axis_index = mesh.axis_names.index(column_axis)
        n_cols = mesh.devices.shape[self.axis_index]
        # "rows" of the paper's array = all other mesh axes, collapsed
        n_rows = int(np.prod(mesh.devices.shape)) // n_cols
        self._pset = PartitionSet(ArrayShape(rows=max(n_rows, 1),
                                             cols=n_cols))
        self._tenants: dict[str, Tenant] = {}
        self._unhealthy: set[int] = set()

    # -- queries -----------------------------------------------------------
    @property
    def n_cols(self) -> int:
        return self._pset.array.cols

    def tenant(self, name: str) -> Tenant:
        return self._tenants[name]

    def tenants(self) -> list[Tenant]:
        return list(self._tenants.values())

    def utilization(self) -> float:
        return self._pset.utilization

    def submesh(self, name: str) -> Mesh:
        """Per-tenant Mesh over its column slice (the sub-accelerator)."""
        t = self._tenants[name]
        if t.partition is None:
            raise ValueError(f"tenant {name!r} holds no partition")
        sl = [slice(None)] * self.mesh.devices.ndim
        sl[self.axis_index] = slice(t.partition.col_start, t.partition.col_end)
        return Mesh(self.mesh.devices[tuple(sl)], self.mesh.axis_names)

    # -- admission / release ------------------------------------------------
    def admit(self, name: str, demand: float, min_cols: int = 1) -> Tenant:
        """Queue a tenant; slices are handed out by :meth:`rebalance`."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already admitted")
        if min_cols > self.n_cols:
            raise ValueError(f"min_cols {min_cols} exceeds mesh width "
                             f"{self.n_cols}")
        t = Tenant(name=name, demand=demand, min_cols=min_cols)
        self._tenants[name] = t
        return t

    def release(self, name: str) -> None:
        """Tenant drains: free its slice and merge (Fig. 5 merge-on-free)."""
        t = self._tenants.pop(name)
        if t.partition is not None:
            self._pset.free(name)
        self._pset.check()

    def mark_unhealthy(self, col: int) -> list[str]:
        """Remove a device column from service; returns evicted tenants."""
        if not (0 <= col < self.n_cols):
            raise ValueError(f"column {col} out of range")
        self._unhealthy.add(col)
        evicted = []
        for name, t in self._tenants.items():
            if t.partition and t.partition.col_start <= col < t.partition.col_end:
                self._pset.free(name)
                t.partition = None
                evicted.append(name)
        return evicted

    def mark_healthy(self, col: int) -> None:
        self._unhealthy.discard(col)

    # -- Algorithm 1 --------------------------------------------------------
    def rebalance(self) -> dict[str, Partition]:
        """(Re-)run Partition_Calculation + Task_Assignment over all tenants.

        All slices are dropped and re-cut (tenancy rebalance happens at step
        boundaries — tenants re-jit onto their new sub-mesh; checkpointed
        state is resharded by ``training.checkpoint.reshard``).
        Unhealthy columns are fenced off as permanently-busy pseudo-tenants.
        """
        # reset: drop every grant, rebuild the interval state from scratch
        for t in self._tenants.values():
            t.partition = None
        self._pset = PartitionSet(self._pset.array)
        # fence unhealthy columns as permanently-busy pseudo-tenants
        for col in sorted(self._unhealthy):
            self._pset.allocate_exact(
                f"__dead{col}",
                Partition(rows=self._pset.array.rows, col_start=col, cols=1))

        live = sorted(self._tenants.values(), key=lambda t: t.demand,
                      reverse=True)
        if not live:
            return {}
        avail = self.n_cols - len(self._unhealthy)
        n = min(len(live), avail)
        base = avail // n if n else 0

        out: dict[str, Partition] = {}
        for i, t in enumerate(live):
            if i >= n or base < 1:
                continue  # over-subscribed: tenant waits for a free round
            width = max(base, t.min_cols)
            # heaviest-first: grant from the largest free slice, verbatim
            # Task_Assignment; clamp to what is actually free.
            free = self._pset.largest_free()
            if free is None:
                continue
            width = min(width, free.cols)
            if width < t.min_cols:
                continue
            got = self._pset.allocate_exact(
                t.name, Partition(rows=free.rows, col_start=free.col_start,
                                  cols=width))
            t.partition = got
            out[t.name] = got
        self._pset.check()
        return out

    def grow_into_free(self) -> dict[str, Partition]:
        """Merge-accelerate (paper §3.3): expand tenants adjacent to free
        slices, heaviest first, without moving anyone (no re-shard storm)."""
        grown: dict[str, Partition] = {}
        for t in sorted(self._tenants.values(), key=lambda t: t.demand,
                        reverse=True):
            if t.partition is None:
                continue
            for f in self._pset.free_partitions:
                if self._unhealthy & set(range(f.col_start, f.col_end)):
                    continue
                if f.col_start == t.partition.col_end or \
                        f.col_end == t.partition.col_start:
                    self._pset.free(t.name)
                    merged = t.partition.merge(f)
                    # re-claim the merged span (consumes the free slice)
                    self._pset.allocate_exact(t.name, merged)
                    t.partition = merged
                    grown[t.name] = merged
                    break
        self._pset.check()
        return grown
