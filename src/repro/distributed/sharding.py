"""Logical-axis sharding rules → NamedSharding for every model family.

Scheme (MaxText-style FSDP + TP):

* Every parameter leaf gets a tuple of **logical axes** derived from its
  path in the params pytree (``_logical_axes``).
* ``MeshRules`` maps logical axes → mesh axes:
      embed       → "data"          (FSDP: shard the d_model dim over data)
      heads/ff/…  → "model"         (tensor parallel)
      vocab       → "model"
      layers      → None            (the lax.scan stacking dim)
* A dim is sharded only if it divides evenly by the mesh-axis size —
  otherwise it silently falls back to replication (odd vocab sizes, tiny
  smoke configs).  This keeps ONE rule set valid for every (config × mesh).

Activation/batch specs: batch is sharded over ("pod", "data") — the "pod"
axis is pure data parallelism across pods, so the multi-pod lowering only
adds a second all-reduce stage for gradients (hierarchical DP).

The KV/SSM caches shard their *sequence* (or window) dim over "model": at
decode the per-token attention over a sequence-sharded cache costs two tiny
all-reduces (log-sum-exp terms) — far cheaper than replicating a 32k cache.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.model import ModelConfig

# ---------------------------------------------------------------------------
# logical axes per parameter path
# ---------------------------------------------------------------------------

# leaf-name → logical axes (no leading "layers" axis; that is added for
# stacked block params automatically).
_LEAF_RULES: dict[str, tuple[str | None, ...]] = {
    # embeddings / head
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "enc_pos": (None, "embed"),
    "dec_pos": (None, "embed"),
    # attention
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv"),
    "wv": ("embed", "kv"),
    "wo": ("heads", "embed"),
    "bq": ("heads",),
    "bk": ("kv",),
    "bv": ("kv",),
    # dense MLP
    "gate": ("embed", "ff"),
    "up": ("embed", "ff"),
    "down": ("ff", "embed"),
    # norms
    "scale": ("embed",),
    "bias": ("embed",),
    # moe (expert-leading)
    "router": ("embed", "expert"),
    # ssd
    "in_proj": ("embed", "ssm"),
    "out_proj": ("ssm", "embed"),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
    # rglru
    "in_x": ("embed", "lru"),
    "in_gate": ("embed", "lru"),
    "wa": ("lru", "lru_out"),
    "wx": ("lru", "lru_out"),
    "ba": ("lru",),
    "bx": ("lru",),
    "lambda": ("lru",),
    "out": ("lru", "embed"),
    # conv1d
    "w": (None, "ssm"),
    "b": ("ssm",),
}

# leaves that live under a "moe" subtree get an "expert" axis prepended
_MOE_3D = {"gate", "up", "down"}

# subtrees whose direct arrays are stacked over layers by lax.scan
_STACKED_PREFIXES = ("blocks", "enc_blocks", "dec_blocks")


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """logical axis → mesh axis (None = replicate)."""

    embed: Any = "data"        # FSDP
    heads: Any = "model"
    kv: Any = "model"
    ff: Any = "model"
    vocab: Any = "model"
    expert: Any = "model"
    ssm: Any = "model"
    lru: Any = "model"
    lru_out: Any = None
    layers: Any = None

    def mesh_axis(self, logical: str | None) -> Any:
        if logical is None:
            return None
        return getattr(self, logical, None)


TP_ONLY = MeshRules(embed=None)
FSDP_TP = MeshRules()
REPLICATED = MeshRules(embed=None, heads=None, kv=None, ff=None, vocab=None,
                       expert=None, ssm=None, lru=None)


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def logical_axes_of(path, leaf) -> tuple[str | None, ...]:
    """Logical axes for one parameter leaf, from its pytree path."""
    names = _path_names(path)
    leaf_name = names[-1]
    axes = _LEAF_RULES.get(leaf_name)
    if axes is None:
        axes = (None,) * leaf.ndim
    if "moe" in names and leaf_name in _MOE_3D:
        axes = ("expert",) + axes
    # stacked-block leading layer axis
    if any(names[0].startswith(p) for p in _STACKED_PREFIXES):
        axes = ("layers",) + axes
    # pad/trim to rank (robust to bias-vs-matrix reuse of names)
    if len(axes) < leaf.ndim:
        axes = (None,) * (leaf.ndim - len(axes)) + axes
    elif len(axes) > leaf.ndim:
        axes = axes[-leaf.ndim:]
    return axes


def _divisible(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axis, (tuple, list)):
        total = int(np.prod([sizes[a] for a in axis]))
    else:
        total = sizes[axis]
    return dim % total == 0


def param_pspec(path, leaf, mesh: Mesh, rules: MeshRules) -> P:
    """PartitionSpec for one leaf under ``rules`` on ``mesh``."""
    logical = logical_axes_of(path, leaf)
    spec = []
    used: set = set()
    for dim, ax in zip(leaf.shape, logical):
        mesh_ax = rules.mesh_axis(ax)
        # never map two tensor dims to the same mesh axis
        key = tuple(mesh_ax) if isinstance(mesh_ax, list) else mesh_ax
        if mesh_ax is not None and key not in used \
                and _divisible(dim, mesh, mesh_ax):
            spec.append(mesh_ax)
            used.add(key)
        else:
            spec.append(None)
    return P(*spec)


def params_shardings(params_tree, mesh: Mesh,
                     rules: MeshRules = FSDP_TP):
    """NamedSharding pytree matching ``params_tree`` (arrays or SDS)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(mesh, param_pspec(p, x, mesh, rules)),
        params_tree)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def choose_mesh_shape(cfg: ModelConfig, n_chips: int = 256,
                      tp_candidates: tuple[int, ...] = (16, 8, 4, 2, 1)
                      ) -> tuple[int, int]:
    """(data, model) factorization of ``n_chips`` for this architecture.

    §Perf lesson (EXPERIMENTS.md): if the TP width does not divide the
    attention head counts, GSPMD shards the score einsum over head_dim and
    all-reduces the (B, H, Sq, chunk) score tensor in EVERY chunk step —
    the single largest collective pathology we measured (deepseek train:
    7.7× collective reduction from fixing this).  Rule: the widest TP that
    divides n_heads, n_kv_heads, d_ff and d_model; everything else goes to
    the data (FSDP) axis.
    """
    for tp in tp_candidates:
        if n_chips % tp:
            continue
        dims = [d for d in (cfg.n_heads, cfg.d_ff, cfg.d_model) if d]
        # MQA (kv=1): replicating the single KV head is standard; only
        # grouped KV (>1) must divide the TP width
        if cfg.n_kv_heads > 1:
            dims.append(cfg.n_kv_heads)
        if not dims:      # attention-free (mamba2): d_inner splits instead
            dims = [cfg.ssm_expand * cfg.d_model]
        if all(d % tp == 0 for d in dims):
            return (n_chips // tp, tp)
    return (n_chips, 1)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes carrying data parallelism ("pod" included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_pspec(mesh: Mesh) -> P:
    return P(batch_axes(mesh))


def batch_shardings(batch_tree, mesh: Mesh):
    """Shard every batch leaf's dim-0 over the data axes."""
    ax = batch_axes(mesh)

    def spec(x):
        if x.shape and _divisible(x.shape[0], mesh, list(ax)):
            return NamedSharding(mesh, P(ax))
        return NamedSharding(mesh, P())

    return jax.tree.map(spec, batch_tree)


def cache_pspec(path, leaf, mesh: Mesh) -> P:
    """Decode-cache sharding: batch over data axes, seq/window over model.

    Cache leaves look like (L, B, S, KV, D) for attention KV,
    (L, B, H, P, N) for SSM state, (L, B, W-1, dim) for conv windows.
    Heuristic: dim 1 is batch (data axes); for KV caches (rank 5 with big
    dim-2) the seq dim shards over "model".
    """
    names = _path_names(path)
    ax = batch_axes(mesh)
    spec: list = [None] * leaf.ndim
    if leaf.ndim >= 2 and _divisible(leaf.shape[1], mesh, list(ax)):
        spec[1] = ax
    is_kv = any(n in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v")
                for n in names)
    if is_kv and leaf.ndim == 5 and "model" in mesh.axis_names \
            and _divisible(leaf.shape[2], mesh, "model"):
        spec[2] = "model"
    return P(*spec)


def cache_shardings(cache_tree, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(mesh, cache_pspec(p, x, mesh)),
        cache_tree)
