"""Gradient compression for the data-parallel all-reduce.

Two schemes, both with **error feedback** (the compression residual is
carried to the next step so the compressed SGD direction stays unbiased in
the long run — Karimireddy et al. 2019):

* ``int8``  — blockwise symmetric int8 quantisation.  The cross-replica
  reduction runs as reduce-scatter(all_to_all of int8 chunks) → local f32
  sum → int8 all-gather: 4× fewer bytes on both wire legs than a f32
  all-reduce, at one extra tiny f32 psum for the shared scale.
* ``topk``  — magnitude top-k sparsification (indices + values), reduced by
  dense scatter-add on each replica (k ≪ N so the wire cost is 2k words).

Both are pure-JAX and run inside ``shard_map`` over the "data" axis; see
``training.train_loop.make_train_step(compression=...)``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"            # none | int8 | topk
    block: int = 256              # int8 quantisation block
    topk_frac: float = 0.01      # fraction of entries kept by topk


# ---------------------------------------------------------------------------
# int8 blockwise quantisation
# ---------------------------------------------------------------------------

def _pad_to(x: jax.Array, m: int) -> tuple[jax.Array, int]:
    n = x.size
    pad = (-n) % m
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize_int8(g: jax.Array, block: int) -> tuple[jax.Array, jax.Array]:
    """g -> (int8 codes, f32 per-block scales)."""
    flat, _ = _pad_to(g, block)
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape,
                    size: int) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def int8_psum_mean(g: jax.Array, axis_name: str, block: int = 256) -> jax.Array:
    """Mean-all-reduce of ``g`` over ``axis_name`` with int8 wire format.

    reduce-scatter leg: all_to_all of int8 chunks (each replica becomes the
    reducer of 1/R of the tensor); local dequant + f32 mean; all-gather leg:
    int8 again.  Wire bytes ≈ 2·N·1 B vs 2·N·4 B for f32 — the scales add
    N/block extra f32 words.
    """
    R = jax.lax.axis_size(axis_name)
    flat, size = _pad_to(g, block * R)
    chunks = flat.reshape(R, -1)                       # (R, N/R)
    q, scale = quantize_int8(chunks, block)            # q: (R·nb, block)
    nb = q.shape[0] // R
    q = q.reshape(R, nb, block)
    scale = scale.reshape(R, nb, 1)
    # reduce-scatter: replica r receives chunk r from everyone
    q_rs = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)             # (R, nb, block)
    s_rs = jax.lax.all_to_all(scale, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    local = jnp.mean(q_rs.astype(jnp.float32) * s_rs, axis=0)  # (nb, block)
    # all-gather (int8 again)
    q2, s2 = quantize_int8(local, block)
    qg = jax.lax.all_gather(q2.reshape(nb, block), axis_name)   # (R, nb, bl)
    sg = jax.lax.all_gather(s2, axis_name)
    out = (qg.astype(jnp.float32) * sg).reshape(-1)[:size]
    return out.reshape(g.shape)


# ---------------------------------------------------------------------------
# top-k sparsification
# ---------------------------------------------------------------------------

def topk_psum_mean(g: jax.Array, axis_name: str,
                   frac: float = 0.01) -> jax.Array:
    """Mean-all-reduce keeping only each replica's top-k |g| entries."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    picked = flat[idx]
    dense = jnp.zeros_like(flat).at[idx].set(picked)
    # the dense psum here stands in for an index-union collective; the wire
    # bytes of a real deployment are 2k words (idx+val all-gather).
    return jax.lax.pmean(dense, axis_name).reshape(g.shape)


# ---------------------------------------------------------------------------
# error-feedback wrapper
# ---------------------------------------------------------------------------

def init_error_state(grads: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, grads)


def compressed_mean(grads: Any, err: Any, axis_name: str,
                    cfg: CompressionConfig) -> tuple[Any, Any]:
    """(grads + err) --compress--> mean over axis; returns (mean, new_err).

    new_err is the per-leaf residual (what compression destroyed locally);
    it is added back before the next step's compression.
    """
    if cfg.kind == "none":
        return jax.tree.map(partial(jax.lax.pmean, axis_name=axis_name),
                            grads), err

    def leaf(g, e):
        corrected = g + e
        if cfg.kind == "int8":
            q, s = quantize_int8(corrected, cfg.block)
            local_hat = dequantize_int8(q, s, corrected.shape, corrected.size)
            reduced = int8_psum_mean(corrected, axis_name, cfg.block)
        elif cfg.kind == "topk":
            flat = corrected.reshape(-1)
            k = max(1, int(flat.size * cfg.topk_frac))
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            local_hat = (jnp.zeros_like(flat).at[idx].set(flat[idx])
                         .reshape(corrected.shape))
            reduced = topk_psum_mean(corrected, axis_name, cfg.topk_frac)
        else:
            raise ValueError(cfg.kind)
        return reduced, corrected - local_hat

    flat, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    pairs = [leaf(g, e) for g, e in zip(flat, flat_e)]
    reduced = treedef.unflatten([p[0] for p in pairs])
    new_err = treedef.unflatten([p[1] for p in pairs])
    return reduced, new_err
