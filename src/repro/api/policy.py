"""Pluggable partition policies — Algorithm 1 generalised to a protocol.

The paper's Algorithm 1 is ONE policy: equal ⌊Y/n⌋ vertical splits
(Partition_Calculation, Fig. 5 lines 15–19) plus heaviest-``Opr``-first
assignment (Task_Assignment, lines 20–27).  MoCA (Kim et al., 2023) and the
systolic-vector scheduling study (Kim et al., 2022) both show the *policy*
choice dominates under dynamic multi-tenant load, so this module turns the
two steps into a protocol every consumer (scheduler, serving engine, mesh
tenancy manager) programs against:

* :meth:`PartitionPolicy.split`  — cut a fully-free array into per-tenant
  vertical slices.  Returned slices always **tile** ``[0, cols)``; the
  remainder goes to the highest-priority tenant, as in the paper.
* :meth:`PartitionPolicy.assign` — bind ready layers to offered slices.  A
  policy may *trim* a grant (return a sub-slice anchored at the offered
  ``col_start``) or *decline* one (omit it) — the scheduler re-offers on the
  next completion event.
* :meth:`PartitionPolicy.widths` / :meth:`PartitionPolicy.order` — the
  demand→width core both of the above share; also used directly by
  ``TenantMeshManager.rebalance`` where slices are carved out of a
  partially-fenced free list instead of a whole array.

Registered implementations (``list_policies()``):

==============  ============================================================
``equal``       the paper verbatim: ⌊Y/n⌋ widths, heaviest→largest, whole
                grants (alias: ``paper``)
``proportional``MoCA-style demand-weighted widths (largest-remainder
                apportionment over ``demand``), heaviest→largest
``best_fit``    demand-capped widths + smallest-slice-that-fits assignment,
                grants trimmed to the layer's ``gemm_n`` (fold-waste killer)
``priority``    SLA tiers: reservation floors via ``min_cols`` honoured
                tier-by-tier, leftover split equally, high tiers assigned
                first
``width_aware`` the seed scheduler's beyond-paper refinement: equal splits
                with demand-trimmed grants and hold-for-width declines
``moca``        MoCA-style joint compute + memory partitioning: tier-0
                tenants first (floors + largest slices) with guaranteed
                memory bandwidth, batch tenants throttled via the
                ``bandwidth(ctx)`` hook while a tier-0 tenant is live
==============  ============================================================

Adding a policy is ~30 lines: subclass :class:`PartitionPolicy`, implement
``widths`` (and optionally ``assign``), decorate with
``@register_policy("name")``.
"""

from __future__ import annotations

import abc
import dataclasses
import math
from typing import Callable, Mapping, MutableMapping, Optional, Sequence

from repro.core.dnng import LayerShape
from repro.core.partition import (
    ArrayShape,
    Assignment,
    Partition,
    partition_calculation,
    task_assignment,
)
from repro.core.registry import Registry

ReadyLayer = tuple[str, int, LayerShape]  # (tenant, layer_index, layer)


def _time_batch(time_fn, cost_cache, pairs):
    """Shared body of ``AssignContext.time_batch``/``PreemptContext
    .time_batch``: price many (layer, partition) pairs at once, preferring
    the oracle's vectorized ``time_fn.batch`` (see
    :func:`repro.sim.systolic.layer_time_fn`) and filling the rebalance
    round's shared ``cost_cache`` so later scalar :meth:`time` probes of
    the same pairings are dict hits.  Falls back to the scalar oracle
    pair-by-pair when the backend has no batch surface — values are
    identical either way (the batch oracle is bit-exact by contract)."""
    if time_fn is None:
        raise ValueError("context has no time_fn oracle")
    batch = getattr(time_fn, "batch", None)
    if cost_cache is None:
        if batch is not None:
            return list(batch(pairs))
        return [time_fn(layer, part) for layer, part in pairs]
    missing = [pair for pair in dict.fromkeys(pairs) if pair not in cost_cache]
    if missing:
        if batch is not None:
            vals = batch(missing)
        else:
            vals = [time_fn(layer, part) for layer, part in missing]
        for pair, v in zip(missing, vals):
            cost_cache[pair] = v
    return [cost_cache[pair] for pair in pairs]


@dataclasses.dataclass(frozen=True)
class TenantDemand:
    """Policy-facing view of one tenant competing for columns.

    ``demand`` is the Opr analogue (MACs for a layer, outstanding FLOPs for
    a serving tenant); ``width_demand`` is the number of columns the tenant
    can actually use (``min(gemm_n, cols)`` for a layer; None = unbounded);
    ``min_cols`` is a reservation floor (memory footprint / SLA guarantee);
    ``tier`` is the SLA class — smaller is more important.

    ``layer`` (optional) is the concrete next layer behind the demand, when
    the caller has one — the scheduler's ``_demands`` fills it so
    resource-vector policies (``repro.fairness``'s ``drf``) can derive bus
    and SRAM footprints; width-only callers may leave it None and such
    policies degrade to columns-only fairness.
    """

    name: str
    demand: float = 1.0
    width_demand: Optional[int] = None
    min_cols: int = 1
    tier: int = 0
    layer: Optional[LayerShape] = None


@dataclasses.dataclass(frozen=True)
class InFlightLayer:
    """Policy-facing view of one executing layer (preemption candidate).

    ``remaining_s`` is the compute time left on the current partition at
    ``PreemptContext.now``; ``fraction_done`` is the share of the layer's
    total compute already finished (across all of its segments).
    """

    tenant: str
    layer_index: int
    layer: LayerShape
    partition: Partition
    compute_start: float
    compute_end: float
    remaining_s: float
    fraction_done: float


@dataclasses.dataclass(frozen=True)
class PreemptContext:
    """Runtime context for :meth:`PartitionPolicy.preempt`.

    Built by the scheduler at every rebalance point when a
    :class:`~repro.core.scheduler.PreemptionModel` is armed: ``ready`` is
    the waiting layer set, ``free`` the current free slices, ``inflight``
    the preemptible (mid-compute) layers, and ``deadlines`` the absolute
    SLA deadlines of tenants that carry one.  ``drain_s``/``stage_in_s``
    price a candidate eviction so hooks can weigh the drain + re-stage
    overhead against the columns reclaimed.
    """

    array: ArrayShape
    now: float
    ready: tuple[ReadyLayer, ...]
    free: tuple[Partition, ...]
    inflight: Mapping[str, InFlightLayer]
    deadlines: Mapping[str, float]
    time_fn: Callable[[LayerShape, Partition], float]
    drain_s: Callable[[Partition], float]
    stage_in_s: Callable[[LayerShape], float]
    cost_cache: Optional[MutableMapping] = None
    # latency class per live tenant (0 = latency-critical) and the
    # currently-enforced per-tenant bandwidth caps — same semantics as the
    # AssignContext fields of the same names
    tiers: Mapping[str, int] = dataclasses.field(default_factory=dict)
    bandwidth: Mapping[str, float] = dataclasses.field(default_factory=dict)

    def time(self, layer: LayerShape, part: Partition) -> float:
        """Memoized ``time_fn(layer, part)`` — shares the rebalance round's
        oracle memo with :meth:`AssignContext.time`."""
        if self.cost_cache is None:
            return self.time_fn(layer, part)
        key = (layer, part)
        try:
            return self.cost_cache[key]
        except KeyError:
            self.cost_cache[key] = cost = self.time_fn(layer, part)
            return cost

    def time_batch(self, pairs: Sequence[tuple[LayerShape, Partition]]
                   ) -> list[float]:
        """Batched :meth:`time`: one vectorized oracle pass for all
        ``pairs``, memoized in the shared rebalance-round cost cache."""
        return _time_batch(self.time_fn, self.cost_cache, pairs)

    def preempt_cost_s(self, victim: InFlightLayer) -> float:
        """Drain + weight re-stage time for evicting ``victim`` now."""
        return self.drain_s(victim.partition) + self.stage_in_s(victim.layer)


@dataclasses.dataclass(frozen=True)
class AssignContext:
    """Runtime context the scheduler passes to :meth:`PartitionPolicy.assign`.

    ``busy`` is the current tenant→partition occupancy (empty when the whole
    array is free); ``time_fn`` is the backend's compute oracle, available to
    policies that weigh opportunity cost (e.g. ``width_aware``'s
    hold-for-width rule).

    ``cost_cache`` is an optional shared ``(layer, partition) → seconds``
    memo the scheduler threads through every context of one rebalance
    round: a policy that probes the same pairing the round already priced
    (steady-state assign re-offers after every grant) gets a dict hit
    instead of a fresh oracle call.  Policies should query the oracle via
    :meth:`time` so they participate in the cache transparently.

    ``deadlines`` maps tenant name → absolute SLA deadline for tenants
    that carry one (supplied by ``DynamicScheduler.submit(...,
    deadline=)``); deadline-aware policies (``deadline_preempt``) use it
    for earliest-deadline-first assignment ordering.

    ``tiers`` maps every live (submitted, unfinished) tenant to its
    latency class (0 = latency-critical; supplied by ``submit(...,
    tier=)``).  ``bandwidth`` is a live view of the per-tenant memory
    caps currently enforced by the scheduler's
    :class:`~repro.core.scheduler.MemorySystem` — the output of the
    policy's own ``bandwidth(ctx)`` hook from the previous round.  Both
    are state, not clock: policies may depend on them without breaking
    the scheduler's dirty-round skip.
    """

    array: ArrayShape
    time_fn: Optional[Callable[[LayerShape, Partition], float]] = None
    busy: Mapping[str, Partition] = dataclasses.field(default_factory=dict)
    cost_cache: Optional[MutableMapping] = None
    deadlines: Mapping[str, float] = dataclasses.field(default_factory=dict)
    tiers: Mapping[str, int] = dataclasses.field(default_factory=dict)
    bandwidth: Mapping[str, float] = dataclasses.field(default_factory=dict)

    def time(self, layer: LayerShape, part: Partition) -> float:
        """Memoized ``time_fn(layer, part)`` (falls through when no cache)."""
        if self.time_fn is None:
            raise ValueError("AssignContext has no time_fn oracle")
        if self.cost_cache is None:
            return self.time_fn(layer, part)
        key = (layer, part)
        try:
            return self.cost_cache[key]
        except KeyError:
            self.cost_cache[key] = cost = self.time_fn(layer, part)
            return cost

    def time_batch(self, pairs: Sequence[tuple[LayerShape, Partition]]
                   ) -> list[float]:
        """Batched :meth:`time`: price every pair in one vectorized oracle
        pass (``time_fn.batch`` when the backend provides it), filling the
        shared round cache.  Policies with several probes per candidate
        (``width_aware``, ``deadline_preempt``) consume the batched table
        instead of per-candidate :meth:`time` calls."""
        return _time_batch(self.time_fn, self.cost_cache, pairs)


class PartitionPolicy(abc.ABC):
    """Base class + protocol for partition policies.

    Consumers only rely on ``split``/``assign`` (and the mesh manager on
    ``order``/``widths``), so third-party policies may also duck-type the
    same surface without subclassing.
    """

    name: str = ""

    # -- demand -> width core ----------------------------------------------
    def order(self, tenants: Sequence[TenantDemand]) -> list[TenantDemand]:
        """Tenants in grant-priority order (default: heaviest demand first,
        stable — ties keep arrival order, matching Task_Assignment's sort)."""
        return sorted(tenants, key=lambda t: -t.demand)

    @abc.abstractmethod
    def widths(self, total_cols: int,
               tenants: Sequence[TenantDemand]) -> dict[str, int]:
        """Target column widths per tenant for ``total_cols`` available.

        Only tenants placed this round appear in the result; every returned
        width is >= 1 and the widths sum to <= ``total_cols`` (``split``
        hands any remainder to the first tenant in :meth:`order`).
        """

    def _placements(self, array: ArrayShape,
                    tenants: Sequence[TenantDemand]
                    ) -> list[tuple[TenantDemand, Partition]]:
        """Cut the array per :meth:`widths`, in priority order, remainder
        to the first tenant — the shared body of split() and place()."""
        tenants = list(tenants)
        if not tenants:
            return []
        ws = self.widths(array.cols, tenants)
        placed = [t for t in self.order(tenants) if ws.get(t.name, 0) >= 1]
        if not placed:
            return []
        rem = array.cols - sum(ws[t.name] for t in placed)
        if rem < 0:
            raise ValueError(f"{self.name or type(self).__name__}.widths "
                             f"oversubscribed {array.cols} columns: {ws}")
        out: list[tuple[TenantDemand, Partition]] = []
        col = 0
        for i, t in enumerate(placed):
            w = ws[t.name] + (rem if i == 0 else 0)
            out.append((t, Partition(rows=array.rows, col_start=col,
                                     cols=w)))
            col += w
        return out

    # -- the protocol ------------------------------------------------------
    def split(self, array: ArrayShape,
              tenants: Sequence[TenantDemand]) -> list[Partition]:
        """Cut the (fully free) array into per-tenant slices that tile it."""
        return [p for _, p in self._placements(array, tenants)]

    def assign(self, ready: Sequence[ReadyLayer],
               partitions: Sequence[Partition],
               ctx: AssignContext | None = None) -> list[Assignment]:
        """Bind ready layers to offered slices (default: the paper's
        Task_Assignment — heaviest ``Opr`` → largest slice, whole grants)."""
        return task_assignment(ready, partitions)

    def preempt(self, ctx: PreemptContext) -> Sequence[str]:
        """Name in-flight tenants whose layer should be evicted *now*.

        Called by the scheduler at every rebalance point, but only when a
        :class:`~repro.core.scheduler.PreemptionModel` is armed.  The
        default never preempts, so every stock policy (``equal`` included)
        stays byte-identical to the preemption-free scheduler even with
        the model configured.
        """
        return ()

    def bandwidth(self, ctx: AssignContext) -> "Mapping[str, float] | None":
        """Per-tenant memory-bandwidth caps: tenant name → share in
        ``(0, 1)`` of the node's DRAM bandwidth; tenants absent from the
        mapping are uncapped.

        Called by the scheduler after every policy round; the returned
        caps govern every bus transfer until the next round
        (:meth:`repro.core.scheduler.MemorySystem.set_caps`).  The default
        returns ``None`` — no caps, byte-identical to the cap-free bus —
        so memory throttling is strictly opt-in per policy.  Overrides
        must depend only on context *state* (``busy``/``tiers``/
        ``bandwidth``), never on a clock, to keep the scheduler's
        dirty-round skip exact.

        Composition with brownout (`repro.overload`): the brownout
        controller's ``cap_bandwidth`` stage writes batch-tenant caps
        through the same :meth:`set_caps` surface, but only on
        schedulers whose policy does NOT override this hook — a policy
        with its own bandwidth logic (``moca``) keeps full authority
        over its caps and is expected to fold overload pressure into its
        own decisions.
        """
        return None

    # -- conveniences ------------------------------------------------------
    def place(self, array: ArrayShape,
              tenants: Sequence[TenantDemand]) -> dict[str, Partition]:
        """Tenant-level convenience for whole-array callers: bind each
        placed tenant to its slice of the split (priority order, first
        slice absorbs the remainder).  Note the mesh manager does NOT use
        this — it carves widths()/order() into a free list that may have
        fenced (unhealthy) columns."""
        return {t.name: p for t, p in self._placements(array, tenants)}

    def _demand_cols(self, layer: LayerShape,
                     ctx: AssignContext | None) -> int:
        cap = ctx.array.cols if ctx is not None else layer.gemm_n
        return max(1, min(layer.gemm_n, cap))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# "paper" is the legacy scheduler string for Algorithm 1 verbatim
_REGISTRY = Registry("policy", aliases={"paper": "equal"})
_POLICIES = _REGISTRY.items  # live dict (tests remove throwaway plugins)


def register_policy(name: str):
    """Class decorator: make a policy constructible by name."""
    return _REGISTRY.register(name)


def _load_plugin_policies() -> None:
    """Import the optional policy packages that register on import.

    `repro.fairness` lives outside this module so `repro.api` carries no
    dependency on it; importing it here (idempotent, lazily, only when a
    name lookup needs it) makes ``get_policy("drf")`` /
    ``get_policy("min_cost_flow")`` work everywhere without eager imports.
    """
    import repro.fairness  # noqa: F401  (import registers drf/min_cost_flow)


def list_policies() -> list[str]:
    _load_plugin_policies()
    return _REGISTRY.names()


def get_policy(name: str, **kwargs) -> PartitionPolicy:
    try:
        return _REGISTRY.get(name, **kwargs)
    except ValueError:
        if name in _REGISTRY.items or name in _REGISTRY.aliases:
            raise  # known name, bad kwargs: not a loading problem
        _load_plugin_policies()
        return _REGISTRY.get(name, **kwargs)


def resolve_policy(policy: "str | PartitionPolicy") -> PartitionPolicy:
    """Accept a registry name (or legacy alias) or a policy instance."""
    if isinstance(policy, str):
        return get_policy(policy)
    if callable(getattr(policy, "split", None)) and \
            callable(getattr(policy, "assign", None)):
        return policy
    raise ValueError(f"not a PartitionPolicy: {policy!r}")


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------

@register_policy("equal")
class EqualPolicy(PartitionPolicy):
    """Algorithm 1 verbatim (paper Fig. 5): ⌊Y/n⌋ equal vertical slices,
    remainder to the first slice, heaviest-``Opr`` layer → largest slice,
    grants are whole slices."""

    def widths(self, total_cols: int,
               tenants: Sequence[TenantDemand]) -> dict[str, int]:
        if not tenants or total_cols < 1:
            return {}
        n = min(len(tenants), total_cols)  # no zero-width slices
        base = total_cols // n
        if base < 1:
            return {}
        return {t.name: base for t in self.order(tenants)[:n]}

    def split(self, array: ArrayShape,
              tenants: Sequence[TenantDemand]) -> list[Partition]:
        # defer to the seed implementation so `equal` is provably the paper
        if not tenants:
            return []
        return partition_calculation(array, len(tenants))


def _floor_cols(t: TenantDemand) -> int:
    """Reservation floor of one tenant (at least one column)."""
    return max(1, t.min_cols)


def _admit_by_floor(order: Sequence[TenantDemand], total_cols: int,
                    floor_of) -> list[TenantDemand]:
    """Admit tenants in priority order while reservation floors still fit."""
    placed: list[TenantDemand] = []
    floor_sum = 0
    for t in order:
        f = floor_of(t)
        if floor_sum + f > total_cols:
            continue
        placed.append(t)
        floor_sum += f
    return placed


def _largest_remainder(cols: int,
                       tenants: Sequence[TenantDemand]) -> dict[str, int]:
    """Apportion ``cols`` to tenants ∝ demand (Hamilton's method; equal
    quotas when all demands are zero; ties → earlier tenant)."""
    total_d = sum(max(t.demand, 0.0) for t in tenants)
    if total_d > 0:
        quotas = [cols * max(t.demand, 0.0) / total_d for t in tenants]
    else:
        quotas = [cols / len(tenants)] * len(tenants)
    ws = {t.name: int(q) for t, q in zip(tenants, quotas)}
    left = cols - sum(ws.values())
    frac = sorted(range(len(tenants)),
                  key=lambda i: (-(quotas[i] - int(quotas[i])), i))
    for i in frac[:left]:
        ws[tenants[i].name] += 1
    return ws


@register_policy("proportional")
class ProportionalPolicy(PartitionPolicy):
    """Demand-weighted widths (MoCA-style): columns are apportioned to
    tenants proportionally to ``demand`` by the largest-remainder method;
    any tenant whose proportional share falls under its ``min_cols`` floor
    is pinned at the floor and the rest re-apportioned."""

    def widths(self, total_cols: int,
               tenants: Sequence[TenantDemand]) -> dict[str, int]:
        placed = _admit_by_floor(self.order(tenants), total_cols, _floor_cols)
        if not placed:
            return {}
        ws: dict[str, int] = {}
        free = list(placed)
        cols_left = total_cols
        while free:
            shares = _largest_remainder(cols_left, free)
            short = [t for t in free if shares[t.name] < _floor_cols(t)]
            if not short:
                ws.update(shares)
                break
            for t in short:  # pin under-floor tenants, re-apportion the rest
                ws[t.name] = _floor_cols(t)
                cols_left -= _floor_cols(t)
                free.remove(t)
        return ws


@register_policy("best_fit")
class BestFitPolicy(PartitionPolicy):
    """Width-demand-aware fitting: splits cap each slice near the tenant's
    usable width (``width_demand`` ≈ ``min(gemm_n, cols)``) and assignment
    gives each layer the *smallest* offered slice that fits it, trimmed to
    its demand — narrow layers stop hogging wide slices, wide layers stop
    folding on slivers."""

    def widths(self, total_cols: int,
               tenants: Sequence[TenantDemand]) -> dict[str, int]:
        placed = _admit_by_floor(self.order(tenants), total_cols, _floor_cols)
        if not placed:
            return {}
        base = max(1, total_cols // len(placed))
        ws = {}
        for t in placed:
            wd = t.width_demand if t.width_demand else base
            ws[t.name] = max(_floor_cols(t), min(base, wd))
        # floors can push the fair-share sum over the array: shave the
        # lowest-priority tenants back toward their floors
        over = sum(ws.values()) - total_cols
        for t in reversed(placed):
            if over <= 0:
                break
            cut = min(ws[t.name] - _floor_cols(t), over)
            ws[t.name] -= cut
            over -= cut
        leftover = total_cols - sum(ws.values())
        # grow under-served tenants (demand order) up to their width demand
        changed = True
        while leftover > 0 and changed:
            changed = False
            for t in placed:
                if leftover <= 0:
                    break
                wd = t.width_demand or total_cols
                if ws[t.name] < wd:
                    grow = min(leftover, wd - ws[t.name])
                    ws[t.name] += grow
                    leftover -= grow
                    changed = True
        return ws

    def assign(self, ready: Sequence[ReadyLayer],
               partitions: Sequence[Partition],
               ctx: AssignContext | None = None) -> list[Assignment]:
        layers = sorted(ready, key=lambda t: t[2].opr, reverse=True)
        avail = sorted(partitions, key=lambda p: (p.n_pes, p.col_start))
        out: list[Assignment] = []
        for tenant, idx, layer in layers:
            if not avail:
                break
            demand = self._demand_cols(layer, ctx)
            pick = next((p for p in avail if p.cols >= demand), None)
            if pick is None:
                pick = max(avail, key=lambda p: p.n_pes)
            avail.remove(pick)
            got = Partition(rows=pick.rows, col_start=pick.col_start,
                            cols=min(pick.cols, demand))
            out.append(Assignment(tenant=tenant, layer_index=idx,
                                  layer=layer, partition=got))
        return out


@register_policy("priority")
class PriorityPolicy(PartitionPolicy):
    """SLA tiers with preemption-free reservation floors.

    Tenants are served tier-by-tier (smaller tier = more important, demand
    breaks ties).  Every placed tenant is guaranteed its ``min_cols`` floor
    — admitted in tier order until floors no longer fit — and leftover
    columns are split equally across the placed set, extras to the highest
    tiers.  ``assign`` offers the largest slices to the highest tiers.

    ``tiers``/``floors`` override per-tenant metadata by name, so the same
    policy instance can drive both layer-level scheduling (where DNNGs carry
    no tier) and serving tenancy.
    """

    def __init__(self, tiers: Mapping[str, int] | None = None,
                 floors: Mapping[str, int] | None = None):
        self.tiers = dict(tiers or {})
        self.floors = dict(floors or {})

    def _tier(self, name: str, default: int = 0) -> int:
        return self.tiers.get(name, default)

    def _floor(self, t: TenantDemand) -> int:
        return max(1, self.floors.get(t.name, t.min_cols))

    def order(self, tenants: Sequence[TenantDemand]) -> list[TenantDemand]:
        return sorted(tenants,
                      key=lambda t: (self._tier(t.name, t.tier), -t.demand))

    def widths(self, total_cols: int,
               tenants: Sequence[TenantDemand]) -> dict[str, int]:
        order = self.order(tenants)
        placed: list[TenantDemand] = []
        floor_sum = 0
        for t in order:
            f = self._floor(t)
            if floor_sum + f > total_cols:
                continue  # floor unsatisfiable this round: tenant waits
            placed.append(t)
            floor_sum += f
        if not placed:
            return {}
        spare = total_cols - floor_sum
        per, extra = divmod(spare, len(placed))
        ws = {}
        for i, t in enumerate(placed):
            ws[t.name] = self._floor(t) + per + (1 if i < extra else 0)
        return ws

    def assign(self, ready: Sequence[ReadyLayer],
               partitions: Sequence[Partition],
               ctx: AssignContext | None = None) -> list[Assignment]:
        layers = sorted(ready,
                        key=lambda t: (self._tier(t[0]), -t[2].opr))
        parts = sorted(partitions, key=lambda p: p.n_pes, reverse=True)
        return [Assignment(tenant=tenant, layer_index=idx, layer=layer,
                           partition=part)
                for (tenant, idx, layer), part in zip(layers, parts)]


@register_policy("width_aware")
class WidthAwarePolicy(EqualPolicy):
    """The seed scheduler's beyond-paper refinement, now expressed as a
    policy: equal splits, but (i) a grant is trimmed to the layer's usable
    width ``min(gemm_n, cols)``, and (ii) *hold-for-width* — a layer
    declines a sliver under half its demand whose runtime would exceed 2×
    the demand-width runtime, as long as another tenant is computing (a
    future merge event is then guaranteed, so no deadlock)."""

    def assign(self, ready: Sequence[ReadyLayer],
               partitions: Sequence[Partition],
               ctx: AssignContext | None = None) -> list[Assignment]:
        matched = task_assignment(ready, partitions)
        self._prime_decline_probes(matched, ctx)
        out: list[Assignment] = []
        for a in matched:
            if self._declines(a.layer, a.partition.cols, ctx):
                continue
            w = min(a.partition.cols, self._demand_cols(a.layer, ctx))
            out.append(dataclasses.replace(
                a, partition=Partition(rows=a.partition.rows,
                                       col_start=a.partition.col_start,
                                       cols=w)))
        return out

    def _prime_decline_probes(self, matched: Sequence[Assignment],
                              ctx: AssignContext | None) -> None:
        """Batch-price the round's hold-for-width probes: every sliver
        candidate needs (sliver, demand-width) runtimes — one vectorized
        oracle pass instead of two scalar ``ctx.time`` calls each."""
        if ctx is None or ctx.time_fn is None or not ctx.busy:
            return
        rows = ctx.array.rows
        pairs = []
        for a in matched:
            demand = self._demand_cols(a.layer, ctx)
            if a.partition.cols * 2 < demand:
                pairs.append((a.layer, Partition(rows=rows, col_start=0,
                                                 cols=a.partition.cols)))
                pairs.append((a.layer, Partition(rows=rows, col_start=0,
                                                 cols=demand)))
        if pairs:
            ctx.time_batch(pairs)

    def _declines(self, layer: LayerShape, slice_cols: int,
                  ctx: AssignContext | None) -> bool:
        if ctx is None or ctx.time_fn is None or not ctx.busy:
            return False
        demand = self._demand_cols(layer, ctx)
        if slice_cols * 2 >= demand:
            return False
        rows = ctx.array.rows
        t_here = ctx.time(layer, Partition(rows=rows, col_start=0,
                                           cols=slice_cols))
        t_want = ctx.time(layer, Partition(rows=rows, col_start=0,
                                           cols=demand))
        return t_here > 2.0 * t_want


@register_policy("deadline_preempt")
class DeadlinePreemptPolicy(EqualPolicy):
    """Equal splits + deadline-driven preemption (the MoCA-style runtime
    adaptation the base scheduler lacks: arXiv:2305.05843 §IV).

    Split and assign are Algorithm 1 verbatim, so with no deadline pressure
    this policy schedules exactly like ``equal``.  The :meth:`preempt` hook
    fires when a *ready* layer's tenant is under deadline pressure and the
    array has no free columns: the in-flight layer with the weakest claim
    (latest or no deadline, longest remaining compute) is evicted, provided
    the reclaimed compute time clearly exceeds the drain + re-stage
    overhead.

    A ready tenant is *pressured* when waiting for the earliest in-flight
    completion would bust its deadline (``slack < slack_factor × (wait +
    own runtime)``) while acting now can still meet it (``slack > own
    runtime``) — already-doomed jobs never trigger thrash.
    ``min_gain_factor`` additionally requires a victim's remaining compute
    to exceed ``min_gain_factor ×`` the eviction overhead (drain + weight
    re-stage), so near-done layers are never evicted.
    """

    def __init__(self, slack_factor: float = 1.25,
                 min_gain_factor: float = 1.5):
        self.slack_factor = slack_factor
        self.min_gain_factor = min_gain_factor

    def preempt(self, ctx: PreemptContext) -> Sequence[str]:
        if ctx.free or not ctx.inflight:
            return ()  # free columns exist: let assign() place the layer
        wait_s = min(v.remaining_s for v in ctx.inflight.values())
        fair = Partition(
            rows=ctx.array.rows, col_start=0,
            cols=max(1, ctx.array.cols // (len(ctx.inflight) + 1)))
        # batch-price the fair-share runtime of every deadline holder in one
        # oracle pass (the batched table replaces per-candidate ctx.time)
        holders = [(tenant, layer) for tenant, _idx, layer in ctx.ready
                   if tenant in ctx.deadlines]
        if not holders:
            return ()
        ests = ctx.time_batch([(layer, fair) for _, layer in holders])
        pressured = []
        for (tenant, _layer), est in zip(holders, ests):
            slack = ctx.deadlines[tenant] - ctx.now
            if slack <= est:
                continue  # hopeless even with an instant grant
            if slack < self.slack_factor * (wait_s + est):
                pressured.append((slack, tenant))
        if not pressured:
            return ()
        urgent_slack = min(pressured)[0]
        victims = self._pick_victims(ctx, urgent_slack)
        return victims

    def assign(self, ready: Sequence[ReadyLayer],
               partitions: Sequence[Partition],
               ctx: AssignContext | None = None) -> list[Assignment]:
        """Earliest-deadline-first assignment (deadline-less tenants fall
        back to the paper's heaviest-``Opr`` order, after every deadline
        holder): the tenant a preemption was fired *for* must reach the
        bus ahead of the victim's re-stage, or the eviction bought
        nothing."""
        dls = ctx.deadlines if ctx is not None else {}
        layers = sorted(ready, key=lambda t: (dls.get(t[0], math.inf),
                                              -t[2].opr))
        parts = sorted(partitions, key=lambda p: p.n_pes, reverse=True)
        return [Assignment(tenant=tenant, layer_index=idx, layer=layer,
                           partition=part)
                for (tenant, idx, layer), part in zip(layers, parts)]

    def _pick_victims(self, ctx: PreemptContext,
                      urgent_slack: float) -> Sequence[str]:
        victims = []
        for v in ctx.inflight.values():
            v_dl = ctx.deadlines.get(v.tenant)
            if v_dl is not None and 0.0 < v_dl - ctx.now <= urgent_slack:
                # victim is salvageable and at least as urgent: never
                # invert SLAs.  Victims whose deadline already passed are
                # fair game — they miss either way, so their columns are
                # worth more to a job that can still be saved.
                continue
            if v.remaining_s <= self.min_gain_factor * ctx.preempt_cost_s(v):
                continue  # nearly done / tiny layer: eviction buys nothing
            victims.append((-v.remaining_s, v.tenant))
        if not victims:
            return ()
        return (min(victims)[1],)


@register_policy("moca")
class MocaPolicy(PartitionPolicy):
    """MoCA-style joint compute + memory partitioning per latency class
    (Kim et al., 2023: dynamically throttling co-resident tenants' memory
    access rates to QoS targets beats pure compute partitioning).

    **Compute side** — priority-by-tier: tenants are served tier-by-tier
    (tier 0 = latency-critical, from ``submit(..., tier=)`` via
    ``TenantDemand.tier`` / ``AssignContext.tiers``), every placed tenant
    gets its ``min_cols`` floor, leftover columns split equally with
    extras to the highest tiers; ``assign`` hands the largest slices to
    the most urgent (lowest-tier, then heaviest) layers, so a tier-0
    arrival reaches the bus ahead of co-resident batch work.

    **Memory side** — the :meth:`bandwidth` hook: while at least one
    tier-0 tenant is live alongside batch (tier > 0) tenants, each batch
    tenant is capped at ``max(min_share, (1 - tier0_guarantee) /
    n_batch)`` of the node's DRAM bandwidth.  Throttled transfers spread
    their demand over time instead of adding to it
    (:class:`~repro.core.scheduler.MemorySystem`), which relieves the
    shared per-window pressure exactly when the guaranteed tier needs
    it.  With no tier mix — all tier-0 or all batch — no caps apply and
    the memory system runs cap-free.

    The hook reads only live-tenant state (``ctx.tiers``), never the
    clock, so the scheduler's dirty-round skip stays exact.
    """

    def __init__(self, tier0_guarantee: float = 0.7,
                 min_share: float = 0.1):
        if not 0.0 <= tier0_guarantee < 1.0:
            raise ValueError(
                f"tier0_guarantee must be in [0, 1), got {tier0_guarantee}")
        if not 0.0 < min_share <= 1.0:
            raise ValueError(
                f"min_share must be in (0, 1], got {min_share}")
        self.tier0_guarantee = tier0_guarantee
        self.min_share = min_share

    def order(self, tenants: Sequence[TenantDemand]) -> list[TenantDemand]:
        return sorted(tenants, key=lambda t: (t.tier, -t.demand))

    def widths(self, total_cols: int,
               tenants: Sequence[TenantDemand]) -> dict[str, int]:
        placed = _admit_by_floor(self.order(tenants), total_cols, _floor_cols)
        if not placed:
            return {}
        spare = total_cols - sum(_floor_cols(t) for t in placed)
        per, extra = divmod(spare, len(placed))
        return {t.name: _floor_cols(t) + per + (1 if i < extra else 0)
                for i, t in enumerate(placed)}

    def assign(self, ready: Sequence[ReadyLayer],
               partitions: Sequence[Partition],
               ctx: AssignContext | None = None) -> list[Assignment]:
        tiers = ctx.tiers if ctx is not None else {}
        layers = sorted(ready, key=lambda t: (tiers.get(t[0], 0),
                                              -t[2].opr))
        parts = sorted(partitions, key=lambda p: p.n_pes, reverse=True)
        return [Assignment(tenant=tenant, layer_index=idx, layer=layer,
                           partition=part)
                for (tenant, idx, layer), part in zip(layers, parts)]

    def bandwidth(self, ctx: AssignContext) -> "dict[str, float] | None":
        tiers = ctx.tiers
        if not tiers:
            return None
        batch = [name for name, tier in tiers.items() if tier > 0]
        if not batch or len(batch) == len(tiers):
            return None  # no tier mix: nothing to protect, nothing to cap
        share = max(self.min_share,
                    (1.0 - self.tier0_guarantee) / len(batch))
        if share >= 1.0:
            return None
        return {name: share for name in batch}
