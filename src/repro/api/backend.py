"""Accelerator backend protocol — one execution surface for every frontend.

A backend answers three questions the scheduler and Session need:

* ``array``        — the partitionable geometry (PE rows × columns, or mesh
  rows × device columns);
* ``time_fn``      — the compute oracle ``(layer, partition) -> seconds``;
* ``stage_model``  — the shared-bus staging model (None = staging is free);
* ``energy``       — post-hoc energy accounting for a finished schedule
  (None when the backend has no energy model).

Registered backends (``list_backends()``):

=========  ==============================================================
``sim``    the paper's evaluation rig: Scale-Sim-style analytic cycle
           model (`repro.sim.systolic`) + 45 nm Accelergy-style energy
           (`repro.sim.energy`) on a 128×128 weight-stationary array
``mesh``   cluster-scale analogue: device columns along the ``model``
           mesh axis with the `repro.distributed.tenancy` latency
           estimator (compute + per-layer collective + launch overhead)
=========  ==============================================================
"""

from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

from repro.core.dnng import LayerShape
from repro.core.partition import ArrayShape
from repro.core.registry import Registry
from repro.core.scheduler import ScheduleResult, StageModel, TimeFn


@runtime_checkable
class EnergyReport(Protocol):
    """Structural type of a backend's energy accounting result.

    ``repro.sim.energy.EnergyBreakdown`` is the canonical implementation;
    any object exposing a joule ``total`` and a serializable ``as_dict``
    satisfies the consumers (`SessionResult.energy_saving`, the Fig. 9(e,f)
    benches).  ``dynamic`` (total minus leakage) is optional extra surface.
    """

    @property
    def total(self) -> float: ...

    def as_dict(self) -> dict: ...


@runtime_checkable
class Accelerator(Protocol):
    """Structural protocol — any object with this surface is a backend."""

    name: str

    @property
    def array(self) -> ArrayShape: ...

    def time_fn(self) -> TimeFn: ...

    def stage_model(self) -> Optional[StageModel]: ...

    def energy(self, result: ScheduleResult,
               layers_by_key: dict[tuple[str, int], LayerShape],
               baseline_pe: bool) -> Optional[EnergyReport]: ...


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY = Registry("backend")
_BACKENDS = _REGISTRY.items


def register_backend(name: str):
    return _REGISTRY.register(name)


def list_backends() -> list[str]:
    return _REGISTRY.names()


def get_backend(name: str, **kwargs) -> Accelerator:
    return _REGISTRY.get(name, **kwargs)


def resolve_backend(backend: "str | Accelerator", **kwargs) -> Accelerator:
    return _REGISTRY.resolve(backend, Accelerator, **kwargs)


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------

@register_backend("sim")
class SimBackend:
    """The paper's evaluation toolchain: analytic 128×128 WS systolic array
    (Scale-Sim analogue) + the 45 nm Mul_En energy model."""

    def __init__(self, config=None, energy=None):
        from repro.sim.energy import EnergyModel
        from repro.sim.systolic import SystolicConfig
        self.config = config or SystolicConfig()
        self.energy_model = energy or EnergyModel()

    @property
    def array(self) -> ArrayShape:
        return self.config.array

    def time_fn(self) -> TimeFn:
        from repro.sim.systolic import layer_time_fn
        return layer_time_fn(self.config)

    def stage_model(self) -> Optional[StageModel]:
        return StageModel(dram_bw_bytes=self.config.dram_bw_bytes)

    def energy(self, result, layers_by_key, baseline_pe):
        from repro.sim.energy import schedule_energy_with_layers
        return schedule_energy_with_layers(result, layers_by_key,
                                           self.config, self.energy_model,
                                           baseline_pe=baseline_pe)


@register_backend("mesh")
class MeshBackend:
    """Cluster-scale backend: ``n_cols`` device columns along the ``model``
    mesh axis, timed by the `repro.distributed.tenancy` latency estimator
    (per-slice compute + output collective + dispatch overhead).  No energy
    model — mesh runs report time/utilization only."""

    def __init__(self, n_cols: int = 8, rows: int = 1, latency=None):
        # lazy: distributed.tenancy imports jax, which sim-only users may
        # not want on the import path of `repro.api`
        from repro.distributed.tenancy import MeshLatencyModel
        self.latency = latency or MeshLatencyModel()
        self._array = ArrayShape(rows=rows, cols=n_cols)

    @property
    def array(self) -> ArrayShape:
        return self._array

    def time_fn(self) -> TimeFn:
        return self.latency.time_fn()

    def stage_model(self) -> Optional[StageModel]:
        return StageModel(dram_bw_bytes=self.latency.host_bw_bytes)

    def energy(self, result, layers_by_key, baseline_pe):
        return None
