"""`ServeConfig` — the consolidated serving front door.

The ``serve()`` surface grew one keyword at a time until it reached 17
knobs spread over five subsystems.  This module groups them into small
frozen per-subsystem dataclasses under one :class:`ServeConfig`, so a
serving experiment is a *value* that can be stored, diffed and re-used:

    from repro.api import ServeConfig, SchedulingConfig, MemoryConfig

    cfg = ServeConfig(
        scheduling=SchedulingConfig(n_arrays=4, max_concurrent=3),
        memory=MemoryConfig(contention=True),
    )
    res = Session(policy="moca").serve("mmpp", config=cfg,
                                       rate=40.0, horizon=1.0)

Bare keywords keep working — ``serve(arrivals, n_arrays=4, memory=True)``
is coerced into a :class:`ServeConfig` right here, in one place
(:func:`resolve_serve_config`), so :class:`~repro.traffic.simulator
.TrafficSimulator` validates a single canonical object either way and its
error messages are identical for both spellings.  Mixing the two spellings
for the *same* run is rejected rather than merged: a config is supposed to
be the complete record of the serving setup.

Two fields are **sentinel-valued** (``None`` = "caller said nothing"):

* ``RebalanceConfig.rebalancer`` — the rebalancer only runs under
  ``interval=``; naming one without an interval is a configuration error,
  and the sentinel makes that error fire even for the default strategy's
  own name (previously ``rebalancer="migrate_on_pressure"`` slipped
  through validation while every other name raised);
* ``MemoryConfig.contention`` — memory contention is strictly opt-in;
  the unarmed path must stay byte-identical to pre-contention records.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SchedulingConfig:
    """Fleet shape + per-node scheduler knobs (always active)."""

    n_arrays: int = 1
    dispatch: str = "jsq"
    max_concurrent: int = 4
    queue_cap: int = 16
    seed: int = 0
    keep_trace: bool = False
    # True (default PreemptionModel) or a model instance; None/False = off
    preemption: object = None
    check_invariants: bool = False


@dataclasses.dataclass(frozen=True)
class RebalanceConfig:
    """Cross-node migration: armed by ``interval`` (seconds per tick)."""

    interval: Optional[float] = None
    # sentinel: None = default strategy ("migrate_on_pressure") — an
    # explicit name (even the default's) without an interval is an error
    rebalancer: object = None
    migration: object = None        # MigrationModel, registry-built only


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Fault injection: armed by ``faults`` (FaultPlan/event/sequence)."""

    faults: object = None
    recovery: object = "retry_restart"
    monitor: object = None


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    """Shared memory-bandwidth contention (`repro.core.scheduler`).

    ``contention`` arms the fleet-shared DRAM bandwidth ledger: ``True``
    for the default :class:`~repro.core.scheduler.ContentionModel`, or a
    model instance to set window/capacity/interference-curve parameters.
    ``None`` (default) keeps every serialized record byte-identical to
    pre-contention runs.
    """

    contention: object = None


@dataclasses.dataclass(frozen=True)
class OverloadConfig:
    """Closed-loop overload control (`repro.overload`).

    ``admission`` arms per-arrival admission control in front of the
    dispatcher: a registry name (``"static"``, ``"codel"``,
    ``"token_bucket"``) or an :class:`~repro.overload.AdmissionPolicy`
    instance.  ``brownout`` arms the degrade-before-drop ladder:
    ``True`` for a default :class:`~repro.overload.BrownoutController`
    or a controller instance.  Both default to ``None`` (off) — the
    unarmed path stays byte-identical to pre-overload records.
    """

    admission: object = None
    brownout: object = None


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Everything :func:`repro.traffic.serve` accepts beyond the arrival
    stream and the policy × backend pair, grouped by subsystem."""

    scheduling: SchedulingConfig = dataclasses.field(
        default_factory=SchedulingConfig)
    rebalance: RebalanceConfig = dataclasses.field(
        default_factory=RebalanceConfig)
    # fairness accounting: True or a repro.fairness.drf.ResourceModel
    fairness: object = False
    # observability: True or a repro.obs.Observability
    obs: object = None
    chaos: ChaosConfig = dataclasses.field(default_factory=ChaosConfig)
    memory: MemoryConfig = dataclasses.field(default_factory=MemoryConfig)
    overload: OverloadConfig = dataclasses.field(
        default_factory=OverloadConfig)

    @classmethod
    def of(cls, **knobs) -> "ServeConfig":
        """Build a config from the historical flat keyword spelling —
        the one place bare ``serve()`` kwargs become a config."""
        unknown = set(knobs) - _SERVE_KNOBS
        if unknown:
            raise TypeError(f"unknown serve knobs: {sorted(unknown)}")
        return cls(
            scheduling=SchedulingConfig(
                n_arrays=knobs.get("n_arrays", 1),
                dispatch=knobs.get("dispatch", "jsq"),
                max_concurrent=knobs.get("max_concurrent", 4),
                queue_cap=knobs.get("queue_cap", 16),
                seed=knobs.get("seed", 0),
                keep_trace=knobs.get("keep_trace", False),
                preemption=knobs.get("preemption"),
                check_invariants=knobs.get("check_invariants", False)),
            rebalance=RebalanceConfig(
                interval=knobs.get("rebalance_interval"),
                rebalancer=knobs.get("rebalancer"),
                migration=knobs.get("migration")),
            fairness=knobs.get("fairness", False),
            obs=knobs.get("obs"),
            chaos=ChaosConfig(
                faults=knobs.get("faults"),
                recovery=knobs.get("recovery", "retry_restart"),
                monitor=knobs.get("monitor")),
            memory=MemoryConfig(contention=knobs.get("memory")),
            overload=OverloadConfig(
                admission=knobs.get("admission"),
                brownout=knobs.get("brownout")))


#: the flat keyword surface ServeConfig.of consolidates — anything else
#: passed to serve()/TrafficSimulator is an arrival-process constructor
#: kwarg (forwarded to the arrivals registry)
_SERVE_KNOBS = frozenset({
    "n_arrays", "dispatch", "max_concurrent", "queue_cap", "seed",
    "keep_trace", "preemption", "check_invariants",
    "rebalance_interval", "rebalancer", "migration",
    "fairness", "obs",
    "faults", "recovery", "monitor",
    "memory",
    "admission", "brownout",
})


def resolve_serve_config(config, kwargs: dict
                         ) -> tuple[ServeConfig, dict]:
    """Split ``serve()``'s ``**kwargs`` into (config, arrival kwargs).

    ``kwargs`` is consumed: serve knobs are folded into a
    :class:`ServeConfig` (when ``config`` is None) and the remainder is
    returned for the arrivals registry.  Passing a knob both ways —
    ``config=`` alongside a flat serve keyword — raises, so one object
    always describes the run.
    """
    serve_kw = {k: kwargs.pop(k) for k in list(kwargs)
                if k in _SERVE_KNOBS}
    if config is not None:
        if not isinstance(config, ServeConfig):
            raise TypeError(f"config must be a ServeConfig, got "
                            f"{type(config).__name__}")
        if serve_kw:
            raise ValueError(
                f"pass serve knobs via config= or as keywords, not both: "
                f"{sorted(serve_kw)}")
        return config, kwargs
    return ServeConfig.of(**serve_kw), kwargs
