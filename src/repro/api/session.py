"""`Session` — the single front door to the reproduction.

    from repro.api import Session

    res = Session(policy="proportional", backend="sim").run("heavy")
    print(res.time_saving, res.energy_saving, res.partition_histogram())

A Session binds one :class:`~repro.api.policy.PartitionPolicy` to one
:class:`~repro.api.backend.Accelerator` backend, runs a workload (a name
from ``repro.sim.workloads.WORKLOADS`` or an explicit ``Sequence[DNNG]``)
under dynamic partitioning, and — unless ``compare_baseline=False`` — also
runs the sequential single-tenancy baseline so savings can be reported.

Benchmarks, examples and the serving engine all select policy and backend
by registry name, so a new policy plugin is immediately runnable everywhere.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Optional, Sequence

from repro.api.backend import Accelerator, EnergyReport, resolve_backend
from repro.api.policy import PartitionPolicy, resolve_policy
from repro.core.dnng import DNNG, LayerShape
from repro.core.scheduler import (
    ScheduleResult,
    schedule_dynamic,
    schedule_sequential,
)


@dataclasses.dataclass(frozen=True)
class BaselineRun:
    """A sequential single-tenancy run of one workload on one backend.

    Policy-independent (the baseline never partitions), so one instance can
    be shared across every policy's :meth:`Session.run` on the same
    workload — see ``benchmarks/run.py``.  Sharing is validated by workload
    name, DNNG set, array geometry and backend name; two backends with the
    same name but different model constants (e.g. custom ``SystolicConfig``
    clocks) are indistinguishable here — reusing across those is on the
    caller.
    """

    workload: str
    schedule: ScheduleResult
    energy: Optional[EnergyReport] = None
    backend: str = ""


@dataclasses.dataclass(frozen=True)
class SessionResult:
    """One workload run: dynamic-partitioned schedule vs (optionally) the
    sequential baseline, with backend energy accounting when available."""

    workload: str
    policy: str
    backend: str
    partitioned: ScheduleResult
    baseline: Optional[ScheduleResult] = None
    partitioned_energy: Optional[EnergyReport] = None
    baseline_energy: Optional[EnergyReport] = None

    # -- headline metrics (Fig. 9) ----------------------------------------
    @property
    def time_saving(self) -> float:
        """Fractional makespan reduction vs the sequential baseline."""
        if self.baseline is None or self.baseline.makespan == 0:
            return 0.0
        return 1.0 - self.partitioned.makespan / self.baseline.makespan

    @property
    def turnaround_saving(self) -> float:
        """Fractional mean per-DNN completion-time reduction."""
        if self.baseline is None:
            return 0.0
        bsum = sum(self.baseline.completion.values())
        psum = sum(self.partitioned.completion.values())
        return 1.0 - psum / bsum if bsum else 0.0

    @property
    def energy_saving(self) -> float:
        if self.baseline_energy is None or self.partitioned_energy is None:
            return 0.0
        return 1.0 - self.partitioned_energy.total / self.baseline_energy.total

    @property
    def utilization(self) -> float:
        return self.partitioned.utilization

    def partition_histogram(self) -> dict[str, int]:
        """How many layers ran on each partition width (Fig. 9 c,d)."""
        c = Counter(f"{e.partition.rows}x{e.partition.cols}"
                    for e in self.partitioned.trace)
        return dict(sorted(c.items()))

    def as_dict(self) -> dict:
        """Machine-readable summary (the BENCH_fig9.json row format)."""
        return {
            "workload": self.workload,
            "policy": self.policy,
            "backend": self.backend,
            "makespan_s": self.partitioned.makespan,
            "baseline_makespan_s":
                self.baseline.makespan if self.baseline else None,
            "time_saving": self.time_saving,
            "turnaround_saving": self.turnaround_saving,
            "energy_saving": self.energy_saving,
            "utilization": self.utilization,
            "partition_histogram": self.partition_histogram(),
        }


class Session:
    """Bind a policy to a backend; run workloads by name or as DNNG lists."""

    def __init__(self, policy: "str | PartitionPolicy" = "equal",
                 backend: "str | Accelerator" = "sim", **backend_kwargs):
        self.policy = resolve_policy(policy)
        self.backend = resolve_backend(backend, **backend_kwargs)

    # -- workload resolution ------------------------------------------------
    @staticmethod
    def _resolve_workload(workload) -> tuple[str, list[DNNG]]:
        if isinstance(workload, str):
            from repro.sim import workloads as _w  # read at call time so
            if workload not in _w.WORKLOADS:       # ablations may patch it
                raise ValueError(f"unknown workload {workload!r}; known: "
                                 f"{sorted(_w.WORKLOADS)}")
            return workload, list(_w.WORKLOADS[workload]())
        dnngs = list(workload)
        if not all(isinstance(g, DNNG) for g in dnngs):
            raise ValueError("workload must be a name or a sequence of DNNGs")
        return "custom", dnngs

    @staticmethod
    def _layers_by_key(dnngs: Sequence[DNNG]
                       ) -> dict[tuple[str, int], LayerShape]:
        return {(g.name, i): layer
                for g in dnngs for i, layer in enumerate(g.layers)}

    # -- execution ----------------------------------------------------------
    def run_baseline(self, workload) -> BaselineRun:
        """Sequential single-tenancy run only — policy-independent, so the
        result can be passed as ``baseline=`` to several :meth:`run` calls
        on the same workload (the benchmark matrix computes it once)."""
        name, dnngs = self._resolve_workload(workload)
        base = schedule_sequential(dnngs, self.backend.array,
                                   self.backend.time_fn(),
                                   stage=self.backend.stage_model())
        e_base = self.backend.energy(base, self._layers_by_key(dnngs),
                                     baseline_pe=True)
        return BaselineRun(workload=name, schedule=base, energy=e_base,
                           backend=getattr(self.backend, "name",
                                           type(self.backend).__name__))

    def run(self, workload, *, compare_baseline: bool = True,
            baseline: Optional[BaselineRun] = None) -> SessionResult:
        name, dnngs = self._resolve_workload(workload)
        time_fn = self.backend.time_fn()
        stage = self.backend.stage_model()
        layers = self._layers_by_key(dnngs)

        part = schedule_dynamic(dnngs, self.backend.array, time_fn,
                                stage=stage, policy=self.policy)
        e_part = self.backend.energy(part, layers, baseline_pe=False)
        base = e_base = None
        if baseline is not None:
            if baseline.workload != name:
                raise ValueError(f"baseline is for workload "
                                 f"{baseline.workload!r}, not {name!r}")
            # name equality is not enough: every explicit DNNG sequence is
            # "custom", and a baseline from another backend geometry would
            # silently corrupt the savings numbers
            if set(baseline.schedule.completion) != {g.name for g in dnngs}:
                raise ValueError(
                    f"baseline covers DNNGs "
                    f"{sorted(baseline.schedule.completion)}, workload has "
                    f"{sorted(g.name for g in dnngs)}")
            if baseline.schedule.array != self.backend.array:
                raise ValueError(
                    f"baseline ran on array {baseline.schedule.array}, "
                    f"backend has {self.backend.array}")
            mine = getattr(self.backend, "name", type(self.backend).__name__)
            if baseline.backend and baseline.backend != mine:
                raise ValueError(f"baseline ran on backend "
                                 f"{baseline.backend!r}, not {mine!r}")
            base, e_base = baseline.schedule, baseline.energy
        elif compare_baseline:
            base = schedule_sequential(dnngs, self.backend.array, time_fn,
                                       stage=stage)
            e_base = self.backend.energy(base, layers, baseline_pe=True)
        return SessionResult(
            workload=name,
            policy=getattr(self.policy, "name", type(self.policy).__name__),
            backend=getattr(self.backend, "name", type(self.backend).__name__),
            partitioned=part, baseline=base,
            partitioned_energy=e_part, baseline_energy=e_base)

    def serve(self, arrivals, *, config=None, **kwargs):
        """Open-loop serving: drive an arrival process through this
        session's policy × backend and return a
        :class:`repro.traffic.ServeResult` (latency percentiles,
        deadline-miss rate, goodput — the serving-side complement of
        :meth:`run`'s makespan numbers).

        ``arrivals`` is a `repro.traffic.arrivals` process instance, a
        registry name (``"poisson"``, ``"mmpp"``, ``"diurnal"``,
        ``"trace"`` — constructor kwargs such as ``rate=``/``horizon=``
        forwarded), or any time-ordered iterable of
        :class:`~repro.traffic.arrivals.Job`.

        Serving knobs go in a :class:`~repro.api.config.ServeConfig`
        (``config=``, grouped by subsystem) **or** as the historical flat
        keywords below — one spelling per call, never both; the keywords
        are coerced into a config in one place
        (:func:`repro.api.config.resolve_serve_config`), so validation
        and behavior are identical either way.  Any remaining keyword
        arguments (``rate=``/``horizon=``/...) are forwarded to the
        arrivals registry when ``arrivals`` is a name.

        ``preemption`` arms layer-granular preemption: ``True`` for the
        default :class:`~repro.core.scheduler.PreemptionModel`, or a model
        instance (policies without a ``preempt`` hook — everything except
        ``deadline_preempt`` — still never preempt).
        ``rebalance_interval`` (seconds) enables cross-node tenant
        migration on a fleet (``n_arrays > 1``): the ``rebalancer``
        strategy (name or :class:`~repro.traffic.rebalance.Rebalancer`)
        runs every interval and on deadline pressure at each arrival,
        moving queued/pristine tenants under the ``migration``
        (:class:`~repro.traffic.rebalance.MigrationModel`) checkpoint
        cost.

        ``check_invariants`` re-arms the per-event partition tiling check
        on every node's scheduler — a debug net the serving hot path
        leaves off by default (the PR-5 incremental engine made every
        event O(live state delta); the check is O(tenants log tenants)).

        ``fairness`` (``True`` or a
        :class:`~repro.fairness.drf.ResourceModel`) arms per-tenant
        fairness accounting — Jain index, per-model slowdown vs isolated
        baselines, dominant-share series (`repro.fairness.accounting`).

        ``obs`` (``True`` or a :class:`~repro.obs.Observability`) arms
        structured tracing + the time-series metrics registry
        (`repro.obs`): scheduler lifecycle spans, preemption/migration
        markers, per-node/per-tenant series.  The collected state comes
        back as ``ServeResult.timeline`` with terminal-render /
        Perfetto-trace / CSV exporters.  Per-layer spans derive from the
        scheduler's ``keep_trace=True`` records — pass both flags for a
        span-level Perfetto timeline.  Pure observation: disabled adds
        no work, armed never changes any serialized result byte.

        ``faults`` (a :class:`~repro.chaos.FaultPlan`, a single
        :class:`~repro.chaos.FaultEvent`, or a sequence of events) arms
        seeded fault injection (`repro.chaos`): node crashes, transient
        blackouts, column-loss degradation, bus stalls and stragglers.
        ``monitor`` (default :class:`~repro.chaos.HealthMonitor`) detects
        failures at dispatch boundaries; ``recovery`` (registry name or
        :class:`~repro.chaos.RecoveryPolicy`, default ``retry_restart``)
        re-dispatches lost jobs with backoff + checkpoint warm restarts.
        The fault/recovery accounting comes back on
        ``ServeResult.chaos``; ``faults=None`` (default) keeps every
        serialized record byte-identical to fault-free runs.

        ``memory`` (``True`` or a
        :class:`~repro.core.scheduler.ContentionModel`) arms fleet-shared
        DRAM bandwidth contention: concurrent partitions' stage traffic
        draws from one per-window pool and demand beyond capacity
        stretches transfers superlinearly; policies with a ``bandwidth``
        hook (``moca``) throttle per-tenant memory rates on top.
        ``memory=None`` (default) keeps every serialized record
        byte-identical to pre-contention runs.
        """
        # local import: repro.api must stay importable without repro.traffic
        from repro.traffic.simulator import TrafficSimulator
        return TrafficSimulator(
            arrivals, policy=self.policy, backend=self.backend,
            config=config, **kwargs).run()

    def run_all(self, workloads: Sequence[str] | None = None
                ) -> dict[str, SessionResult]:
        """Run every named workload (default: all of ``WORKLOADS``)."""
        if workloads is None:
            from repro.sim import workloads as _w
            workloads = sorted(_w.WORKLOADS)
        return {wl: self.run(wl) for wl in workloads}
