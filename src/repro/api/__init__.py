"""`repro.api` — the public front door: pluggable policies × backends.

    from repro.api import Session
    Session(policy="proportional", backend="sim").run("heavy")

Three pieces:

* :mod:`repro.api.policy`  — the :class:`PartitionPolicy` protocol
  (``split`` / ``assign``) and the string-keyed registry with the
  ``equal`` (paper Algorithm 1), ``proportional``, ``best_fit``,
  ``priority`` and ``width_aware`` implementations;
* :mod:`repro.api.backend` — the :class:`Accelerator` protocol
  (``time_fn`` / ``stage_model`` / ``energy``) with the ``sim``
  (Scale-Sim/Accelergy analogue) and ``mesh`` (device-grid latency)
  backends;
* :mod:`repro.api.session` — the :class:`Session` facade binding one
  policy to one backend and running workloads by name;
* :mod:`repro.api.config`  — the :class:`ServeConfig` value object
  grouping every ``serve()`` knob by subsystem.
"""

from repro.api.config import (
    ChaosConfig,
    MemoryConfig,
    OverloadConfig,
    RebalanceConfig,
    SchedulingConfig,
    ServeConfig,
    resolve_serve_config,
)
from repro.api.policy import (
    AssignContext,
    BestFitPolicy,
    DeadlinePreemptPolicy,
    EqualPolicy,
    InFlightLayer,
    MocaPolicy,
    PartitionPolicy,
    PreemptContext,
    PriorityPolicy,
    ProportionalPolicy,
    TenantDemand,
    WidthAwarePolicy,
    get_policy,
    list_policies,
    register_policy,
    resolve_policy,
)
from repro.api.backend import (
    Accelerator,
    EnergyReport,
    MeshBackend,
    SimBackend,
    get_backend,
    list_backends,
    register_backend,
    resolve_backend,
)
from repro.api.session import BaselineRun, Session, SessionResult

__all__ = [
    # policies
    "PartitionPolicy", "TenantDemand", "AssignContext",
    "PreemptContext", "InFlightLayer",
    "EqualPolicy", "ProportionalPolicy", "BestFitPolicy", "PriorityPolicy",
    "WidthAwarePolicy", "DeadlinePreemptPolicy", "MocaPolicy",
    "register_policy", "get_policy", "list_policies", "resolve_policy",
    # backends
    "Accelerator", "EnergyReport", "SimBackend", "MeshBackend",
    "register_backend", "get_backend", "list_backends", "resolve_backend",
    # session
    "Session", "SessionResult", "BaselineRun",
    # serve config
    "ServeConfig", "SchedulingConfig", "RebalanceConfig",
    "ChaosConfig", "MemoryConfig", "OverloadConfig",
    "resolve_serve_config",
]
