"""Sharded fleet simulation: per-pod event loops, epoch-synced dispatch.

:class:`~repro.traffic.simulator.TrafficSimulator` is a single event loop
over the whole fleet — every arrival touches every node's scheduler, so a
100k-job run over 256+ arrays plateaus around ~71k events/s of straight
Python (BENCH_scale.json).  :class:`ShardedTrafficSimulator` splits the
fleet into ``n_shards`` *pods* of disjoint :class:`ArrayNode` groups and
runs each pod's event loop in its own process (``fork`` + pipes), synced
only at **epoch** boundaries (every ``sync_every`` arrivals).

The design is bulk-synchronous with *replicated routing*:

* every pod holds the full global load vector, refreshed from node truth
  at each epoch boundary, and **replays the routing decision for every
  arrival itself** — dispatcher state (rr counter, p2c rng) and the
  in-epoch load increments are identical in every pod, so all pods agree
  on each job's target with zero per-arrival communication;
* within an epoch the load vector only *increments* (each routed job
  bumps its target); completions on other pods become visible at the next
  boundary.  That staleness is the defined semantics of sharded dispatch
  — and it is the same for every value of ``n_shards``;
* each pod advances **its own** nodes to every global arrival instant and
  records its local queued count, so the per-arrival queue-depth samples
  sum element-wise to the exact fleet series.

**Determinism contract** (exercised by ``tests/test_fairness.py``):

1. results are invariant to ``n_shards`` and to ``parallel=True/False``
   for *every* dispatcher — by induction, identical routing ⇒ identical
   per-node event streams ⇒ identical boundary snapshots;
2. with ``dispatch="rr"`` (load-oblivious round robin) the routing does
   not read loads at all, so a sharded run is **byte-identical** to the
   plain single-process :class:`TrafficSimulator` on the same stream —
   records, metrics, depth samples, everything.  jsq/p2c read loads,
   whose staleness differs from the single loop, so for those the
   contract is (1) only.

Not supported here: cross-node migration (``rebalance_interval``) — a
rebalancer reads global node state mid-epoch, which is exactly what
sharding removes — and ``keep_trace`` (per-node schedules stay in the
worker processes).

**Failure surface.**  Every epoch message a pod sends carries the jobs it
*finalized* (completed or rejected) during that epoch, its depth-sample
slice and its boundary busy vector; the coordinator retains them in an
:class:`_EpochLedger`.  When a pod dies, the raised
:class:`PodFailureError` therefore carries a partial-result payload —
jobs completed so far, per-pod status, the finalized records — instead
of leaving the operator with nothing.  With ``respawn=True`` (and a
``pod_kill`` plan) the coordinator goes further: it builds a fresh
replacement pod, **fast-forwards** its routing replica over the dead
pod's completed epochs (reconstructing the dispatcher state and rng
stream exactly, with no execution), re-admits the lost in-flight jobs
through the `repro.chaos` retry path (seeded first-attempt backoff,
pod-local least-loaded placement), and resumes the epoch protocol.  The
serial path mirrors the identical recovery, so serial == forked
byte-identity holds through a kill.  Lost with the pod, by design: its
in-flight partial work (the jobs re-run from scratch) and its private
observability replica.
"""

from __future__ import annotations

import dataclasses
import heapq
import multiprocessing
import os
import random
import time
from typing import Sequence

from repro.traffic.arrivals import ArrivalProcess, Job, resolve_arrivals
from repro.traffic.cluster import ArrayNode, resolve_dispatcher
from repro.traffic.metrics import summarize
from repro.traffic.simulator import ServeResult, _RecordBuilder


class PodFailureError(RuntimeError):
    """A sharded pod died and the epoch sync could not complete.

    Subclasses RuntimeError (the historical failure surface) and attaches
    what the coordinator's epoch ledger knows survived:

    * ``pod`` / ``epoch`` — which pod died, at which sync epoch;
    * ``jobs_completed`` — fleet-wide completions durably reported before
      the failure;
    * ``pod_status`` — per-pod dict ``{"state": "ok"|"dead",
      "epochs_done": k}``;
    * ``partial_records`` — the finalized
      :class:`~repro.traffic.metrics.JobRecord` set, arrival-ordered.
    """

    def __init__(self, message: str, *, pod: int, epoch: int,
                 jobs_completed: int, pod_status: dict,
                 partial_records: tuple = ()):
        super().__init__(message)
        self.pod = pod
        self.epoch = epoch
        self.jobs_completed = jobs_completed
        self.pod_status = pod_status
        self.partial_records = tuple(partial_records)


class _RoutedLoads:
    """The replicated global load view one pod routes against.

    Duck-types the :class:`~repro.traffic.cluster.FleetLoads` surface
    dispatchers read (``loads`` + ``min_index()``), with the same lazy
    min-heap so jsq stays O(log N) per decision at 256+ arrays.  Within an
    epoch loads only move via :meth:`bump`; :meth:`reset` installs the
    boundary snapshot.
    """

    __slots__ = ("loads", "_heap")

    def __init__(self, n: int):
        self.loads = [0] * n
        self._heap = [(0, i) for i in range(n)]

    def reset(self, snapshot: Sequence[int]) -> None:
        self.loads[:] = snapshot
        self._heap[:] = [(load, i) for i, load in enumerate(self.loads)]
        heapq.heapify(self._heap)

    def bump(self, i: int) -> None:
        self.loads[i] += 1
        heapq.heappush(self._heap, (self.loads[i], i))

    @property
    def routing_loads(self) -> Sequence[int]:
        # FleetLoads surface parity (Dispatcher.choose_tracked routes on
        # it); pods have no health exclusion, so it is the plain view
        return self.loads

    def min_index(self) -> int:
        heap = self._heap
        loads = self.loads
        while True:
            load, i = heap[0]
            if loads[i] == load:
                return i
            heapq.heappop(heap)


class _Pod:
    """One shard: a contiguous node group + a full replica of the routing
    state.  ``run_epoch`` processes a global arrival slice — routing every
    job, executing only the owned ones — and returns the group's
    in-system vector for the next boundary snapshot."""

    def __init__(self, base: int, count: int, n_arrays: int, jobs, *,
                 policy: str, backend: str, dispatch: str,
                 max_concurrent: int, queue_cap: int, seed: int,
                 preemption, check_invariants: bool, obs_cfg=None,
                 kill_at_epoch: "int | None" = None):
        from repro.api.backend import resolve_backend
        from repro.api.policy import resolve_policy
        self.base = base
        self.count = count
        self.jobs = jobs
        self.kill_at_epoch = kill_at_epoch  # pod_kill fault (repro.chaos)
        bk = resolve_backend(backend)
        pol = resolve_policy(policy)
        time_fn = bk.time_fn()
        stage = bk.stage_model()
        # pod-local observability: each pod owns a private bundle (built
        # from the coordinator's arm flags — an object could not cross
        # fork + pipe), folded back picklably via finish()["obs"] and
        # merged by the coordinator
        self.obs = None
        self._tracer = None
        self._registry = None
        self._node_series = None
        if obs_cfg is not None:
            from repro.obs import Observability
            self.obs = Observability(**obs_cfg)
            self._tracer = self.obs.tracer
            self._registry = self.obs.registry
        self.nodes = [
            ArrayNode(base + i, bk.array, time_fn, stage, pol,
                      max_concurrent=max_concurrent, queue_cap=queue_cap,
                      on_complete=self._on_complete,
                      on_submit=self._on_submit,
                      preemption=preemption,
                      on_load_change=self._on_load_change,
                      check_invariants=check_invariants, obs=self.obs)
            for i in range(count)]
        if self._registry is not None:
            reg = self._registry
            self._node_series = [
                (reg.series(f"node{base + i}.in_system"),
                 reg.series(f"node{base + i}.queue_depth"))
                for i in range(count)]
        self.dispatcher = resolve_dispatcher(dispatch)
        self.rng = random.Random(seed)
        self.view = _RoutedLoads(n_arrays)
        self._queued = [0] * count
        self._queued_total = 0
        self._builders: list = []          # (global job idx, builder)
        self._by_name: dict = {}
        self.depth_samples: list[int] = []
        # epoch-ledger shipping state: builders not yet reported as
        # finalized, and how many depth samples have crossed the pipe
        self._pending: list = []           # (global job idx, builder)
        self._depth_sent = 0

    # -- node callbacks (same wiring as TrafficSimulator) -------------------
    def _on_complete(self, node, tenant: str, t: float) -> None:
        self._by_name[tenant].completed = t

    def _on_submit(self, node, job: Job, t: float) -> None:
        b = self._by_name[job.dnng.name]
        b.submitted = t
        b.array = node.index

    def _on_load_change(self, node) -> None:
        i = node.index - self.base
        q = len(node.queue)
        self._queued_total += q - self._queued[i]
        self._queued[i] = q

    # -- event loop ---------------------------------------------------------
    def _advance(self, t: float) -> None:
        for node in self.nodes:
            sched = node.scheduler
            events = sched._events
            if events and events[0][0] <= t:
                sched.run_until(t)

    def run_epoch(self, lo: int, hi: int,
                  snapshot: Sequence[int]) -> dict:
        """Process global arrivals ``jobs[lo:hi]`` against ``snapshot``
        boundary loads; return the epoch message: this group's in-system
        vector plus the ledger payload (newly finalized records, the
        depth-sample slice, boundary busy/preemption state)."""
        self.view.reset(snapshot)
        view = self.view
        dispatcher = self.dispatcher
        rng = self.rng
        base, count = self.base, self.count
        for idx in range(lo, hi):
            job = self.jobs[idx]
            target = dispatcher.choose_tracked(view, rng)
            view.bump(target)
            self._advance(job.arrival)
            if base <= target < base + count:
                b = _RecordBuilder(job)
                self._builders.append((idx, b))
                self._pending.append((idx, b))
                self._by_name[job.dnng.name] = b
                status = self.nodes[target - base].offer(job)
                if status != "rejected":
                    b.array = target
                # owned arrivals only: each dispatch is emitted by exactly
                # one pod, so merged counters/traces match a global view
                if self._tracer is not None:
                    self._tracer.instant(
                        "dispatch", job.arrival, target, job.dnng.name,
                        (("status", status), ("tier", job.tier)))
                if self._registry is not None:
                    self._registry.counter("serve.arrivals").inc()
                    self._registry.counter(
                        f"serve.dispatch.{status}").inc()
                    for node, (s_in, s_q) in zip(self.nodes,
                                                 self._node_series):
                        s_in.sample(job.arrival, node.in_system)
                        s_q.sample(job.arrival, len(node.queue))
            self.depth_samples.append(self._queued_total)
        return self._epoch_msg()

    def _epoch_msg(self) -> dict:
        """The boundary message: loads for the next snapshot + the ledger
        payload the coordinator retains for the failure surface.  A
        builder is *finalized* once its outcome can no longer change —
        completed, or rejected at admission (``array`` never set)."""
        done, still = [], []
        for item in self._pending:
            b = item[1]
            if b.completed is not None or b.array is None:
                done.append((item[0], b.build()))
            else:
                still.append(item)
        self._pending = still
        depth = self.depth_samples[self._depth_sent:]
        self._depth_sent = len(self.depth_samples)
        return {
            "loads": [n.in_system for n in self.nodes],
            "busy": [n.pe_seconds_busy for n in self.nodes],
            "final": done,
            "depth": depth,
            "preemptions": sum(n.scheduler.n_preemptions
                               for n in self.nodes),
        }

    # -- respawn surface (driven by the coordinator) ------------------------
    def fast_forward(self, history: Sequence[tuple]) -> list[int]:
        """Replay the routing decisions of completed epochs — no
        execution, no builders, no depth samples — so this fresh pod's
        dispatcher state and rng stream end up exactly where the dead
        pod's were at the failure boundary.  ``history`` is the
        coordinator's ``(lo, hi, snapshot)`` list; returns the global job
        indices this pod owned over those epochs (the lost-job candidate
        set, pending the ledger's finalized filter)."""
        owned = []
        base, count = self.base, self.count
        for lo, hi, snapshot in history:
            self.view.reset(snapshot)
            for idx in range(lo, hi):
                target = self.dispatcher.choose_tracked(self.view, self.rng)
                self.view.bump(target)
                if base <= target < base + count:
                    owned.append(idx)
        return owned

    def inject_lost(self, lost: Sequence[int], floor: float,
                    seed_key: str) -> None:
        """Re-admit the dead pod's in-flight jobs through the retry path.

        Each lost job gets one fresh attempt with a seeded first-attempt
        backoff (:func:`repro.chaos.respawn_backoffs`), released no
        earlier than the failure boundary (``floor``), placed on the
        least-loaded owned node (ties to the lowest index).  The record
        builder keeps the job's ORIGINAL arrival and deadline, so its
        latency includes the downtime + backoff — recovery is not free.
        Index order + the dedicated rng stream keep the injection
        byte-stable across serial/forked and repeated runs."""
        from repro.chaos import respawn_backoffs
        delays = respawn_backoffs(len(lost), seed_key)
        for idx, delay in zip(lost, delays):
            job = self.jobs[idx]
            t = max(job.arrival, floor) + delay
            retry = dataclasses.replace(
                job, arrival=t, dnng=job.dnng.clone(arrival_time=t))
            b = _RecordBuilder(job)
            self._builders.append((idx, b))
            self._pending.append((idx, b))
            self._by_name[job.dnng.name] = b
            node = min(self.nodes, key=lambda n: (n.in_system, n.index))
            status = node.offer(retry)
            if status != "rejected":
                b.array = node.index

    def finish(self) -> dict:
        """Drain all owned queues and fold the pod's results."""
        for node in self.nodes:
            node.scheduler.run()
        if self._registry is not None:
            reg = self._registry
            reg.counter("sched.events").inc(
                sum(n.scheduler.n_events for n in self.nodes))
            reg.counter("sched.preemptions").inc(
                sum(n.scheduler.n_preemptions for n in self.nodes))
            reg.counter("sched.completions").inc(
                sum(1 for _idx, b in self._builders
                    if b.completed is not None))
        return {
            "obs": self.obs.state() if self.obs is not None else None,
            "records": [(idx, b.build()) for idx, b in self._builders],
            "depth_samples": self.depth_samples,
            # per-node, not pre-summed: the coordinator adds them flat in
            # global node order so the float total is byte-identical to
            # the single-process left-to-right sum
            "pe_busy": [n.pe_seconds_busy for n in self.nodes],
            "preemptions": sum(n.scheduler.n_preemptions
                               for n in self.nodes),
            "max_now": max(n.scheduler.now for n in self.nodes),
        }


def _pod_worker(pod: _Pod, epochs, conn) -> None:
    """Child-process loop: one pod, driven over a pipe.  The pod and the
    materialized job list arrive via ``fork`` (copy-on-write), so only the
    small per-epoch snapshots and the final fold cross the pipe."""
    try:
        for ei, (lo, hi) in enumerate(epochs):
            snapshot = conn.recv()
            if ei == pod.kill_at_epoch:
                # pod_kill fault: hard process death mid-epoch — no error
                # message crosses the pipe, the coordinator must detect
                # the dead worker itself (ShardedTrafficSimulator._recv)
                os._exit(13)
            conn.send(pod.run_epoch(lo, hi, snapshot))
        conn.send(pod.finish())
    except BaseException as exc:   # surface the failure, don't hang the sync
        conn.send(("__error__", repr(exc)))
        raise
    finally:
        conn.close()


class _EpochLedger:
    """What the coordinator durably knows per pod, epoch by epoch.

    Fed from the pods' boundary messages; read in two places: the
    :class:`PodFailureError` partial payload, and the respawn path (the
    finalized-index filter, the routing-replay history, the boundary
    busy/preemption carry for the replacement's fold)."""

    def __init__(self, n_pods: int):
        self.records = [[] for _ in range(n_pods)]   # finalized (idx, rec)
        self.final_idx = [set() for _ in range(n_pods)]
        self.depth = [[] for _ in range(n_pods)]     # shipped depth samples
        self.busy = [None] * n_pods                  # last boundary busy
        self.preemptions = [0] * n_pods              # last boundary count
        self.epochs_done = [0] * n_pods
        self.history: list[tuple] = []               # (lo, hi, snapshot)

    def note(self, pi: int, msg: dict) -> None:
        self.records[pi].extend(msg["final"])
        self.final_idx[pi].update(idx for idx, _r in msg["final"])
        self.depth[pi].extend(msg["depth"])
        self.busy[pi] = msg["busy"]
        self.preemptions[pi] = msg["preemptions"]
        self.epochs_done[pi] += 1


class ShardedTrafficSimulator:
    """Drive one arrival stream through a pod-sharded fleet.

    Same surface as :class:`~repro.traffic.simulator.TrafficSimulator`
    where the semantics overlap; ``policy``/``backend``/``dispatch`` must
    be **registry names** (each pod constructs private instances — an
    object could not be replicated identically), and
    rebalancing/keep_trace are unsupported (see module docstring).

    ``sync_every`` sets the epoch length in arrivals: smaller tracks
    cross-pod load more tightly (jsq quality), larger syncs less.
    ``parallel=False`` runs the identical epoch protocol in-process —
    bit-identical results, useful for tests and when fork is unavailable.

    ``obs`` (``True`` or a :class:`~repro.obs.Observability`) arms
    observability per pod: each pod runs a private tracer/registry replica
    (same arm flags and caps), returns its picklable state with the final
    fold, and the coordinator merges everything into one
    ``ServeResult.timeline`` — counters add, series interleave, trace
    rings merge by timestamp.  Owned arrivals only are counted per pod, so
    merged totals match a global view.

    ``faults`` accepts a `repro.chaos` plan of **pod_kill** events only
    (``node`` = pod index, ``epoch`` = sync epoch): the targeted worker
    process dies hard mid-epoch (``os._exit``), and the coordinator —
    rather than hanging on the pipe — raises a :class:`PodFailureError`
    (a RuntimeError carrying the partial-result payload) naming the dead
    pod within ``pod_timeout_s``.  The serial path raises the same error
    at the same epoch.  In-fleet fault kinds (crash/degrade/...) need
    the single-process :class:`TrafficSimulator`.

    ``respawn=True`` (requires ``faults=``) turns the abort into
    recovery: the coordinator detects the dead pod, rebuilds it from the
    last epoch-boundary state (routing replica fast-forwarded, lost
    in-flight jobs re-admitted through the seeded retry path) and the
    run completes deterministically — serial and forked byte-identical.
    Default off: an armed-but-unfired plan stays byte-identical to a
    fault-free run, and a fired plan without respawn aborts exactly as
    before.
    """

    def __init__(self, arrivals, policy: str = "equal",
                 backend: str = "sim", n_arrays: int = 2,
                 n_shards: int = 2, dispatch: str = "rr",
                 max_concurrent: int = 4, queue_cap: int = 16,
                 seed: int = 0, sync_every: int = 64,
                 parallel: bool = True, preemption=None,
                 check_invariants: bool = False, fairness=False,
                 obs=None, faults=None, pod_timeout_s: float = 120.0,
                 respawn: bool = False, **arrival_kwargs):
        from repro.core.scheduler import PreemptionModel
        for label, v in (("policy", policy), ("backend", backend),
                         ("dispatch", dispatch)):
            if not isinstance(v, str):
                raise ValueError(f"sharded runs need a registry name for "
                                 f"{label}, got {type(v).__name__} (each "
                                 f"pod builds its own instance)")
        if not 1 <= n_shards <= n_arrays:
            raise ValueError(f"need 1 <= n_shards <= n_arrays, got "
                             f"n_shards={n_shards}, n_arrays={n_arrays}")
        if sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got {sync_every}")
        if preemption is True:
            preemption = PreemptionModel()
        elif preemption is False:
            preemption = None
        self.preemption = preemption
        if isinstance(arrivals, str):
            arrival_kwargs.setdefault("seed", seed)
        if isinstance(arrivals, (str, ArrivalProcess)):
            self.arrivals = resolve_arrivals(arrivals, **arrival_kwargs)
        else:
            if arrival_kwargs:
                raise ValueError("arrival kwargs need a registry name")
            self.arrivals = arrivals
        self.policy_name = policy
        self.backend_name = backend
        self.dispatch_name = dispatch
        self.n_arrays = n_arrays
        self.n_shards = n_shards
        self.max_concurrent = max_concurrent
        self.queue_cap = queue_cap
        self.seed = seed
        self.sync_every = sync_every
        self.parallel = parallel
        self.check_invariants = check_invariants
        self.fairness = fairness
        if pod_timeout_s <= 0:
            raise ValueError(f"pod_timeout_s must be positive, got "
                             f"{pod_timeout_s}")
        self.pod_timeout_s = pod_timeout_s
        # pod_kill fault injection: e.node indexes the POD (shard), e.epoch
        # the sync epoch the worker dies in.  The only chaos kind that
        # makes sense here — in-fleet faults need the single-process
        # simulator's global view (TrafficSimulator faults=).
        self._kill_epochs: dict[int, int] = {}
        self._plan_name = None
        if faults is not None:
            from repro.chaos import resolve_faults
            plan = resolve_faults(faults)
            self._plan_name = plan.name
            for e in plan.events:
                if e.kind != "pod_kill":
                    raise ValueError(
                        f"sharded runs only support pod_kill faults, got "
                        f"{e.kind!r}; use TrafficSimulator faults= for "
                        f"in-fleet fault injection")
                if not 0 <= e.node < n_shards:
                    raise ValueError(f"pod_kill targets pod {e.node}, run "
                                     f"has {n_shards} shards")
                cur = self._kill_epochs.get(e.node)
                if cur is None or e.epoch < cur:
                    self._kill_epochs[e.node] = e.epoch
        self.respawn = bool(respawn)
        if respawn and faults is None:
            raise ValueError(
                "respawn=True has no effect without faults=; pass a "
                "pod_kill FaultPlan to arm pod respawn")
        # coordinator-side bundle: pods run private replicas (same arm
        # flags), whose picklable states fold into this one at _fold time
        self._obs = None
        if obs:
            from repro.obs import resolve_obs
            self._obs = resolve_obs(obs)

    # -- pod/epoch layout ---------------------------------------------------
    def _pod_spans(self) -> list[tuple[int, int]]:
        n, s = self.n_arrays, self.n_shards
        bounds = [p * n // s for p in range(s + 1)]
        return [(bounds[p], bounds[p + 1] - bounds[p]) for p in range(s)]

    def _epochs(self, n_jobs: int) -> list[tuple[int, int]]:
        e = self.sync_every
        return [(lo, min(lo + e, n_jobs)) for lo in range(0, n_jobs, e)]

    def _make_pod(self, pod_index: int, base: int, count: int,
                  jobs) -> _Pod:
        obs_cfg = None
        if self._obs is not None:
            o = self._obs
            obs_cfg = {
                "tracer": o.tracer is not None,
                "metrics": o.registry is not None,
                "audit": bool(o.audit),
                "max_events": (o.tracer.max_events
                               if o.tracer is not None else 65536),
                "max_samples": (o.registry.max_samples
                                if o.registry is not None else 4096),
                "sample_every": o.sample_every,
            }
        return _Pod(base, count, self.n_arrays, jobs,
                    policy=self.policy_name, backend=self.backend_name,
                    dispatch=self.dispatch_name,
                    max_concurrent=self.max_concurrent,
                    queue_cap=self.queue_cap, seed=self.seed,
                    preemption=self.preemption,
                    check_invariants=self.check_invariants,
                    obs_cfg=obs_cfg,
                    kill_at_epoch=self._kill_epochs.get(pod_index))

    # -- execution ----------------------------------------------------------
    def run(self) -> ServeResult:
        jobs = list(self.arrivals)
        epochs = self._epochs(len(jobs))
        pods = [self._make_pod(pi, base, count, jobs)
                for pi, (base, count) in enumerate(self._pod_spans())]
        self._ledger = _EpochLedger(self.n_shards)
        # pod index -> pre-death carry spliced into the fold (set only
        # when a respawn actually fired; empty = unchanged result shape)
        self._respawned: dict[int, dict] = {}
        use_fork = self.parallel and self.n_shards > 1 and \
            "fork" in multiprocessing.get_all_start_methods()
        if use_fork:
            folds = self._run_forked(pods, epochs, jobs)
        else:
            folds = self._run_serial(pods, epochs, jobs)
        return self._fold(jobs, folds)

    def _run_serial(self, pods, epochs, jobs) -> list[dict]:
        ledger = self._ledger
        snapshot = [0] * self.n_arrays
        for ei, (lo, hi) in enumerate(epochs):
            ledger.history.append((lo, hi, list(snapshot)))
            nxt: list[int] = []
            for pi, pod in enumerate(pods):
                if ei == pod.kill_at_epoch:
                    if not self.respawn:
                        # same failure surface as the forked path: the
                        # epoch sync cannot complete once a pod is gone
                        raise self._pod_failure(
                            f"sharded pod {pi} died at epoch {ei} "
                            f"(pod_kill fault)", pi, ei)
                    pods[pi] = pod = self._respawn_pod(
                        pi, ei, jobs, floor=jobs[lo].arrival)
                msg = pod.run_epoch(lo, hi, snapshot)
                ledger.note(pi, msg)
                nxt.extend(msg["loads"])
            snapshot = nxt
        return [pod.finish() for pod in pods]

    def _run_forked(self, pods, epochs, jobs) -> list[dict]:
        ctx = multiprocessing.get_context("fork")
        ledger = self._ledger
        conns, procs = [], []

        def spawn(pod, eps):
            parent, child = ctx.Pipe()
            p = ctx.Process(target=_pod_worker,
                            args=(pod, eps, child), daemon=True)
            p.start()
            child.close()   # parent keeps its end only
            return parent, p

        try:
            for pod in pods:
                conn, p = spawn(pod, epochs)
                conns.append(conn)
                procs.append(p)
            snapshot = [0] * self.n_arrays
            for ei, (lo, hi) in enumerate(epochs):
                ledger.history.append((lo, hi, list(snapshot)))
                for pi, conn in enumerate(conns):
                    try:
                        conn.send(snapshot)
                    except BrokenPipeError:
                        raise self._pod_failure(
                            f"sharded pod {pi} (pid {procs[pi].pid}) died "
                            f"mid-epoch: snapshot pipe is broken",
                            pi, ei) from None
                nxt: list[int] = []
                for pi in range(len(conns)):
                    try:
                        msg = self._recv(conns[pi], procs[pi], pi, ei)
                    except PodFailureError:
                        if not self.respawn:
                            raise
                        # the pod died at (or timed out across) this
                        # epoch boundary: discard the corpse, rebuild the
                        # pod from ledger state in-process, and hand the
                        # replacement to a fresh worker that replays this
                        # epoch on the same snapshot
                        if procs[pi].is_alive():
                            procs[pi].terminate()
                        procs[pi].join(timeout=30.0)
                        conns[pi].close()
                        pod = self._respawn_pod(
                            pi, ei, jobs, floor=jobs[lo].arrival)
                        conns[pi], procs[pi] = spawn(pod, epochs[ei:])
                        conns[pi].send(snapshot)
                        msg = self._recv(conns[pi], procs[pi], pi, ei)
                    ledger.note(pi, msg)
                    nxt.extend(msg["loads"])
                snapshot = nxt
            # the final fold: a death here (during finish) is past the
            # last boundary — nothing left to respawn for, so it raises
            return [self._recv(conn, procs[pi], pi, len(epochs))
                    for pi, conn in enumerate(conns)]
        finally:
            for conn in conns:
                conn.close()
            for p in procs:
                p.join(timeout=30.0)
                if p.is_alive():
                    p.terminate()

    def _recv(self, conn, proc, pod_index: int, epoch: int):
        """Receive one pod message without hanging the sync: poll with a
        deadline, and turn a dead worker (EOF / exited process with no
        buffered reply) into a :class:`PodFailureError` naming the pod.
        A worker that *reported* an exception (``__error__``) stays a
        plain RuntimeError — its pod state is not a clean boundary, so
        it is never respawned."""
        deadline = time.monotonic() + self.pod_timeout_s
        while not conn.poll(0.05):
            if not proc.is_alive() and not conn.poll(0):
                raise self._pod_failure(
                    f"sharded pod {pod_index} (pid {proc.pid}) died "
                    f"mid-epoch with exit code {proc.exitcode}",
                    pod_index, epoch)
            if time.monotonic() >= deadline:
                raise self._pod_failure(
                    f"sharded pod {pod_index} (pid {proc.pid}) sent no "
                    f"reply within {self.pod_timeout_s:g}s; aborting the "
                    f"epoch sync", pod_index, epoch)
        try:
            msg = conn.recv()
        except EOFError:
            raise self._pod_failure(
                f"sharded pod {pod_index} (pid {proc.pid}) died "
                f"mid-epoch with exit code {proc.exitcode}",
                pod_index, epoch) from None
        if isinstance(msg, tuple) and len(msg) == 2 \
                and msg[0] == "__error__":
            raise RuntimeError(
                f"sharded pod {pod_index} failed: {msg[1]}")
        return msg

    # -- failure surface ----------------------------------------------------
    def _pod_failure(self, message: str, pod: int,
                     epoch: int) -> PodFailureError:
        """Build the partial-payload error from the epoch ledger."""
        led = self._ledger
        indexed = sorted((pair for recs in led.records for pair in recs),
                         key=lambda p: p[0])
        records = tuple(r for _idx, r in indexed)
        status = {
            pi: {"state": "dead" if pi == pod else "ok",
                 "epochs_done": led.epochs_done[pi]}
            for pi in range(self.n_shards)}
        return PodFailureError(
            message, pod=pod, epoch=epoch,
            jobs_completed=sum(1 for r in records
                               if r.completed is not None),
            pod_status=status, partial_records=records)

    def _respawn_pod(self, pi: int, ei: int, jobs, *, floor: float):
        """Rebuild pod ``pi`` from the last epoch-boundary state.

        The replacement is constructed exactly like the original (so its
        schedulers, dispatcher replica and rng start from the same
        seeds), fast-forwarded over the dead pod's completed epochs, and
        handed the lost in-flight jobs through the retry path.  The
        pre-death finalized records / depth slices / boundary busy are
        frozen here and spliced back in at fold time — work the dead pod
        durably reported is never re-run."""
        led = self._ledger
        base, count = self._pod_spans()[pi]
        pod = self._make_pod(pi, base, count, jobs)
        pod.kill_at_epoch = None   # the plan fires once per pod
        owned = pod.fast_forward(led.history[:ei])
        done = led.final_idx[pi]
        lost = [idx for idx in owned if idx not in done]
        pod.inject_lost(lost, floor,
                        f"respawn:{self.seed}:{pi}:{ei}")
        self._respawned[pi] = {
            "records": list(led.records[pi]),
            "depth": list(led.depth[pi]),
            "busy": list(led.busy[pi] or [0.0] * count),
            "preemptions": led.preemptions[pi],
            "epoch": ei,
            "lost": len(lost),
        }
        return pod

    def _fold(self, jobs, folds: list[dict]) -> ServeResult:
        # splice each respawned pod's pre-death carry (the ledger's
        # durable view) in front of the replacement's fresh fold so the
        # merged result covers every owned job exactly once: finalized
        # pre-death via the carry, lost in-flight via the retry
        # injection, post-respawn via the replacement's own loop
        for pi, carry in self._respawned.items():
            f = folds[pi]
            f["records"] = carry["records"] + f["records"]
            f["depth_samples"] = carry["depth"] + f["depth_samples"]
            f["pe_busy"] = [c + b for c, b in
                            zip(carry["busy"], f["pe_busy"])]
            f["preemptions"] += carry["preemptions"]
        indexed = sorted((pair for f in folds for pair in f["records"]),
                         key=lambda p: p[0])
        records = tuple(r for _idx, r in indexed)
        # element-wise sum of the per-pod queued series == the fleet series
        depth = [0] * (len(folds[0]["depth_samples"]) if folds else 0)
        for f in folds:
            for i, d in enumerate(f["depth_samples"]):
                depth[i] += d
        last_arrival = jobs[-1].arrival if jobs else 0.0
        end = max([f["max_now"] for f in folds]
                  + [last_arrival, getattr(self.arrivals, "horizon", 0.0)])
        fairness = None
        if self.fairness:
            fairness = self._fairness_report(jobs, records)
        from repro.api.backend import resolve_backend
        bk = resolve_backend(self.backend_name)
        pes = bk.array.rows * bk.array.cols
        metrics = summarize(
            records, duration_s=end,
            pe_seconds_busy=sum(busy for f in folds
                                for busy in f["pe_busy"]),
            total_pes=pes * self.n_arrays,
            queue_depth_samples=depth,
            preemptions=sum(f["preemptions"] for f in folds),
            fairness=fairness)
        timeline = None
        if self._obs is not None:
            for f in folds:
                if f.get("obs") is not None:
                    self._obs.absorb(f["obs"])
            from repro.obs import Timeline
            timeline = Timeline(self._obs)
        return ServeResult(
            policy=self.policy_name, backend=self.backend_name,
            arrivals=getattr(self.arrivals, "name",
                             type(self.arrivals).__name__),
            dispatch=self.dispatch_name, n_arrays=self.n_arrays,
            records=records, metrics=metrics,
            preemption=(type(self.preemption).__name__
                        if self.preemption is not None else None),
            fairness=fairness, timeline=timeline,
            # set ONLY when a respawn actually fired: an armed-but-
            # unfired plan must stay byte-identical to a fault-free run
            faults=self._plan_name if self._respawned else None,
            recovery="pod_respawn" if self._respawned else None)

    def _fairness_report(self, jobs, records):
        """Coordinator-side fairness fold: per-tenant slowdowns from the
        merged records.  Dominant-share sampling needs a global in-flight
        snapshot at every arrival — exactly the cross-pod state sharding
        removes — so those report fields stay None here (the gated
        ``jain_dominant_share`` keys never appear; see TrafficMetrics)."""
        from repro.fairness.accounting import FairnessAccounting
        from repro.fairness.drf import ResourceModel
        from repro.api.backend import resolve_backend
        bk = resolve_backend(self.backend_name)
        resources = self.fairness \
            if isinstance(self.fairness, ResourceModel) else None
        acct = FairnessAccounting(
            bk.array, bk.time_fn(), stage=bk.stage_model(),
            n_arrays=self.n_arrays, resources=resources,
            backend_name=getattr(bk, "name", type(bk).__name__))
        for job in jobs:
            acct.observe(job)
        return acct.report(records)


def serve_sharded(arrivals, policy: str = "equal", backend: str = "sim",
                  **kwargs) -> ServeResult:
    """Functional one-shot, mirroring :func:`repro.traffic.simulator.serve`."""
    return ShardedTrafficSimulator(arrivals, policy=policy,
                                   backend=backend, **kwargs).run()
