"""Cross-node tenant migration — fleet-level load rebalancing.

Dispatch (`repro.traffic.cluster`) is a one-shot decision at arrival time:
once a job lands on an array it stays there, even when service-time
variance leaves one array drowning while a neighbour idles.  The
systolic-vector scheduling study (arXiv:2206.03060) shows moving whole
tenants between arrays under dynamic load is where fleet-level SLA wins
come from; this module adds that capability as a pluggable
:class:`Rebalancer` the :class:`~repro.traffic.simulator.TrafficSimulator`
invokes periodically (``rebalance_interval=``) and on deadline pressure at
every arrival.

Only *queued or pristine* tenants move — jobs waiting in a node's FIFO, or
submitted ones that have not touched the array yet
(:meth:`~repro.core.scheduler.DynamicScheduler.withdraw`).  A moved job
pays a :class:`MigrationModel` transit delay (checkpoint over the
inter-node link) before it can start on the target, so thrash is
self-limiting: migration only wins when the queueing it skips exceeds the
checkpoint time.

The stock strategy is ``migrate_on_pressure``:

* **pressure moves** (every invocation): a queued job whose deadline would
  be busted where it sits is moved to the least-loaded node with a free
  run slot, as long as the transit delay does not itself bust the
  deadline;
* **balance moves** (periodic ticks only): while the fleet is imbalanced
  (``max - min in-system > imbalance``), tail jobs of the longest queue
  move to nodes with spare run slots.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Sequence

from repro.core.dnng import DNNG
from repro.core.registry import Registry


@dataclasses.dataclass(frozen=True)
class MigrationModel:
    """Checkpoint-transfer cost of moving one tenant between arrays.

    Only unstarted tenants migrate, so the checkpoint is the job's *input*
    (entry-layer IFMap) plus control state — model weights are assumed
    resident on every node of the serving fleet, as in any real
    multi-replica deployment.  ``include_weights=True`` models the cold
    fleet where the target must also receive the weights.

    ``fixed_overhead_s`` covers the control round-trip (drain decision,
    route update, admission at the target).
    """

    link_bw_bytes: float = 16e9
    fixed_overhead_s: float = 20e-6
    bytes_per_elem: int = 2
    include_weights: bool = False

    def checkpoint_bytes(self, dnng: DNNG) -> int:
        entry = dnng.layers[0]
        total = entry.ifmap_elems * self.bytes_per_elem
        if self.include_weights:
            total += sum(layer.weight_bytes for layer in dnng.layers)
        return total

    def migrate_s(self, dnng: DNNG) -> float:
        return self.fixed_overhead_s + self.checkpoint_bytes(dnng) / self.link_bw_bytes


class Rebalancer(abc.ABC):
    """Move queued/pristine tenants between :class:`ArrayNode`s."""

    name: str = ""

    def __init__(self, migration: MigrationModel | None = None):
        self.migration = migration or MigrationModel()
        self.n_migrations = 0
        # optional repro.obs.Observability, set by the traffic simulator;
        # strategies emit a "migrate" instant marker per move through it
        self.obs = None

    @abc.abstractmethod
    def rebalance(self, nodes: Sequence, now: float, periodic: bool = False) -> int:
        """Perform migrations at time ``now``; return how many moved.

        ``periodic`` distinguishes the simulator's interval ticks (full
        rebalancing allowed) from arrival-time pressure checks (only
        deadline-driven moves).
        """


_REGISTRY = Registry("rebalancer")


def register_rebalancer(name: str):
    return _REGISTRY.register(name)


def list_rebalancers() -> list[str]:
    return _REGISTRY.names()


def resolve_rebalancer(rebalancer, **kwargs) -> Rebalancer:
    return _REGISTRY.resolve(rebalancer, Rebalancer, **kwargs)


@register_rebalancer("migrate_on_pressure")
class MigrateOnPressure(Rebalancer):
    """Deadline-pressure migration + periodic queue balancing.

    ``pressure_factor`` scales the miss prediction (``slack <
    pressure_factor × (local wait estimate + service estimate)`` marks a
    queued job as pressured); ``imbalance`` is the minimum in-system gap
    between the most- and least-loaded nodes before a periodic balance
    move fires.
    """

    def __init__(
        self,
        migration: MigrationModel | None = None,
        pressure_factor: float = 1.0,
        imbalance: int = 2,
    ):
        super().__init__(migration)
        self.pressure_factor = pressure_factor
        self.imbalance = imbalance

    # -- helpers ------------------------------------------------------------
    def _best_target(self, nodes, src):
        """Least-loaded node (ties → lowest index) with a free run slot.

        Queue-to-queue moves are never worth the checkpoint transit, so a
        target must be able to run the job promptly."""
        best = None
        for node in nodes:
            if node is src:
                continue
            if not node.alive or node.health != "healthy":
                continue  # never migrate onto a failed/suspect node
            if node.scheduler.n_active >= node.max_concurrent:
                continue
            key = (node.in_system, node.index)
            if best is None or key < (best.in_system, best.index):
                best = node
        return best

    def _move(self, src, target, name: str, now: float) -> bool:
        job = src.take_for_migration(name)
        if job is None:
            return False
        delay = self.migration.migrate_s(job.dnng)
        target.admit_migrated(job, now, ready_at=now + delay)
        self.n_migrations += 1
        tracer = getattr(self.obs, "tracer", None)
        if tracer is not None:
            tracer.instant(
                "migrate",
                now,
                target.index,
                name,
                (("src", src.index), ("dst", target.index), ("delay_s", delay)),
            )
        return True

    # -- the strategy -------------------------------------------------------
    def rebalance(self, nodes: Sequence, now: float, periodic: bool = False) -> int:
        if len(nodes) < 2:
            return 0
        if not any(n.queue for n in nodes):
            # only queued jobs ever move (pressure AND balance paths), so
            # an all-drained fleet needs no sort/wait-estimate work — the
            # common case at every sub-saturation arrival
            return 0
        moves = 0
        # pressure moves: queued jobs predicted to miss where they sit
        for src in sorted(nodes, key=lambda n: (-n.in_system, n.index)):
            wait = src.wait_estimate()  # loop-invariant until a move
            for job in list(src.queue):
                slack = job.deadline - now
                if slack <= 0:
                    continue  # already doomed: moving it cannot help
                est = src.service_estimate(job.dnng)
                if slack >= self.pressure_factor * (wait + est):
                    continue
                target = self._best_target(nodes, src)
                if target is None or target.in_system >= src.in_system:
                    continue
                if self.migration.migrate_s(job.dnng) + est >= slack:
                    continue  # transit would bust the deadline anyway
                if self._move(src, target, job.dnng.name, now):
                    moves += 1
                    wait = src.wait_estimate()
        if not periodic:
            return moves
        # balance moves: drain the longest queues into idle capacity
        while True:
            src = max(nodes, key=lambda n: (n.in_system, -n.index))
            target = self._best_target(nodes, src)
            if (
                target is None
                or not src.queue
                or src.in_system - target.in_system < self.imbalance
            ):
                break
            if not self._move(src, target, src.queue[-1].dnng.name, now):
                break
            moves += 1
        return moves
