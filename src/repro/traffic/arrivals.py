"""Open-loop arrival processes — the load side of the serving problem.

The paper's evaluation (and everything in `repro.sim`) is *closed*: all
DNNGs are present at t≈0 and the metric is makespan.  Real multi-tenant
accelerators ("No DNN Left Behind", arXiv 1901.06887) are judged open-loop:
jobs arrive on their own clock, each with a deadline, and the system is
measured on latency percentiles and SLO attainment.  This module generates
those arrivals as timestamped :class:`Job` streams.

Five processes, all seeded and fully deterministic (``random.Random``):

==================  =======================================================
``poisson``         memoryless arrivals at a constant ``rate``
``mmpp``            2-state Markov-modulated Poisson (bursty: calm ↔ burst
                    states with different rates and exponential dwell
                    times)
``diurnal``         sinusoid-modulated rate (day/night load swing) via
                    Lewis-Shedler thinning
``trace``           replay of a recorded JSON trace (list of
                    ``{"t", "model", "slo_s", "tier"}`` rows or a file
                    path)
``batch_instance``  replay of an Alibaba cluster-trace
                    ``batch_instance``-style CSV (production arrival
                    pattern + per-row sizes mapped onto Table-1 DNNGs)
==================  =======================================================

Each job samples ONE Table-1 DNNG from a ``pool`` (see
``repro.sim.workloads.MODEL_POOLS``) and carries an absolute ``deadline``
(= arrival + per-job SLO) plus an SLA ``tier`` so priority policies have
something to act on.
"""

from __future__ import annotations

import abc
import csv
import dataclasses
import io
import json
import math
import random
from typing import Iterator, Sequence

from repro.core.dnng import DNNG
from repro.core.registry import Registry
from repro.sim.workloads import MODEL_POOLS, MODELS, sample_dnng


@dataclasses.dataclass(frozen=True)
class Job:
    """One arriving inference request: a DNNG with a deadline and a tier."""

    job_id: int
    arrival: float        # absolute arrival time (s)
    dnng: DNNG            # arrival_time == arrival; name unique per job
    deadline: float       # absolute completion deadline (s)
    tier: int = 0         # SLA class (smaller = more important)

    @property
    def model(self) -> str:
        """Base model name (the DNNG name minus the per-job suffix)."""
        return self.dnng.name.split("#", 1)[0]

    @property
    def slo_s(self) -> float:
        return self.deadline - self.arrival


class ArrivalProcess(abc.ABC):
    """Seeded generator of a finite, time-ordered :class:`Job` stream.

    Subclasses implement :meth:`_arrival_times`; job composition (model
    sampling, deadline, tier) is shared so processes differ *only* in their
    point process.  Iterating a process always replays the same stream —
    the rng is re-seeded per iteration.
    """

    name: str = ""

    def __init__(self, rate: float, horizon: float, seed: int = 0,
                 pool: str = "light", slo_s: float = 0.05,
                 tiers: Sequence[int] = (0,)):
        if rate <= 0 or horizon <= 0:
            raise ValueError(f"rate and horizon must be positive "
                             f"(rate={rate}, horizon={horizon})")
        if pool not in MODEL_POOLS:
            raise ValueError(f"unknown pool {pool!r}; known: "
                             f"{sorted(MODEL_POOLS)}")
        if not tiers:
            raise ValueError("tiers must be non-empty")
        self.rate = rate
        self.horizon = horizon
        self.seed = seed
        self.pool = pool
        self.slo_s = slo_s
        self.tiers = tuple(tiers)

    @abc.abstractmethod
    def _arrival_times(self, rng: random.Random) -> Iterator[float]:
        """Yield strictly increasing arrival instants < ``horizon``."""

    def __iter__(self) -> Iterator[Job]:
        # Iterating always replays the same stream (the rng is re-seeded),
        # so the stream is materialized once and replayed from cache: the
        # benchmark matrices drive the SAME arrivals through several
        # policies, and Job/DNNG are frozen — sharing is safe.
        cache = getattr(self, "_job_cache", None)
        if cache is None:
            cache = self._job_cache = list(self._generate())
        return iter(cache)

    def _generate(self) -> Iterator[Job]:
        rng = random.Random(self.seed)
        for jid, t in enumerate(self._arrival_times(rng)):
            g = sample_dnng(rng, pool=self.pool, arrival_time=t)
            g = g.clone(name=f"{g.name}#{jid}")
            yield Job(job_id=jid, arrival=t, dnng=g,
                      deadline=t + self.slo_s,
                      tier=rng.choice(self.tiers))

    def jobs(self) -> list[Job]:
        return list(self)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY = Registry("arrival process")


def register_arrivals(name: str):
    return _REGISTRY.register(name)


def list_arrival_processes() -> list[str]:
    return _REGISTRY.names()


def get_arrival_process(name: str, **kwargs) -> ArrivalProcess:
    return _REGISTRY.get(name, **kwargs)


def resolve_arrivals(arrivals, **kwargs) -> ArrivalProcess:
    """Accept a registry name or an :class:`ArrivalProcess` instance."""
    return _REGISTRY.resolve(arrivals, ArrivalProcess, **kwargs)


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------

@register_arrivals("poisson")
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson process: exponential inter-arrival times."""

    def _arrival_times(self, rng: random.Random) -> Iterator[float]:
        t = rng.expovariate(self.rate)
        while t < self.horizon:
            yield t
            t += rng.expovariate(self.rate)


@register_arrivals("mmpp")
class MMPPArrivals(ArrivalProcess):
    """2-state Markov-modulated Poisson process (bursty traffic).

    The process alternates between a *calm* and a *burst* state (rates in
    ratio ``burst_factor``), each with exponentially distributed dwell time
    of mean ``dwell_s``.  ``rate`` is the **long-run mean** arrival rate —
    equal expected dwell in both states means the calm rate is
    ``2·rate/(1+burst_factor)`` — so a given ``rate`` offers the same load
    as the other processes.  Memorylessness lets us redraw the
    inter-arrival after each state switch.
    """

    def __init__(self, rate: float, horizon: float, seed: int = 0,
                 burst_factor: float = 4.0, dwell_s: float | None = None,
                 **kwargs):
        super().__init__(rate, horizon, seed, **kwargs)
        if burst_factor <= 0:
            raise ValueError("burst_factor must be positive")
        if dwell_s is not None and dwell_s <= 0:
            raise ValueError("dwell_s must be positive")
        self.burst_factor = burst_factor
        self.calm_rate = 2.0 * rate / (1.0 + burst_factor)
        self.dwell_s = dwell_s if dwell_s is not None else horizon / 8.0

    def _arrival_times(self, rng: random.Random) -> Iterator[float]:
        t = 0.0
        burst = False
        switch_at = rng.expovariate(1.0 / self.dwell_s)
        while t < self.horizon:
            lam = self.calm_rate * (self.burst_factor if burst else 1.0)
            dt = rng.expovariate(lam)
            if t + dt >= switch_at:
                # state flips before the tentative arrival: jump to the
                # switch instant and redraw (exponential = memoryless)
                t = switch_at
                burst = not burst
                switch_at = t + rng.expovariate(1.0 / self.dwell_s)
                continue
            t += dt
            if t < self.horizon:
                yield t


@register_arrivals("diurnal")
class DiurnalArrivals(ArrivalProcess):
    """Sinusoid-modulated Poisson: λ(t) = rate·(1 + amp·sin(2πt/period)).

    Generated by Lewis-Shedler thinning against λ_max = rate·(1+amp), so the
    mean rate over a whole period is exactly ``rate``.
    """

    def __init__(self, rate: float, horizon: float, seed: int = 0,
                 amplitude: float = 0.8, period_s: float | None = None,
                 **kwargs):
        super().__init__(rate, horizon, seed, **kwargs)
        if not 0.0 <= amplitude <= 1.0:
            raise ValueError("amplitude must be in [0, 1]")
        if period_s is not None and period_s <= 0:
            raise ValueError("period_s must be positive")
        self.amplitude = amplitude
        self.period_s = period_s if period_s is not None else horizon

    def _arrival_times(self, rng: random.Random) -> Iterator[float]:
        lam_max = self.rate * (1.0 + self.amplitude)
        t = 0.0
        while True:
            t += rng.expovariate(lam_max)
            if t >= self.horizon:
                return
            lam_t = self.rate * (1.0 + self.amplitude
                                 * math.sin(2.0 * math.pi * t / self.period_s))
            if rng.random() * lam_max <= lam_t:
                yield t


@register_arrivals("trace")
class TraceArrivals(ArrivalProcess):
    """Replay a recorded trace: a JSON file path or a list of row dicts.

    Each row: ``{"t": float, "model": str, "slo_s": float?, "tier": int?}``.
    ``model`` must be a ``repro.sim.workloads.MODELS`` key.  Rows are sorted
    by ``t``; ``rate``/``horizon`` are derived from the trace itself.
    """

    def __init__(self, trace, slo_s: float = 0.05, seed: int = 0, **kwargs):
        if isinstance(trace, str):
            with open(trace) as f:
                rows = json.load(f)
        else:
            rows = list(trace)
        if not rows:
            raise ValueError("empty arrival trace")
        for r in rows:
            if r.get("model") not in MODELS:
                raise ValueError(f"trace row has unknown model "
                                 f"{r.get('model')!r}; known: {sorted(MODELS)}")
        self._rows = sorted(rows, key=lambda r: float(r["t"]))
        horizon = float(self._rows[-1]["t"]) + 1e-9
        rate = len(rows) / horizon
        kwargs.setdefault("pool", "all")
        super().__init__(rate=rate, horizon=horizon, seed=seed,
                         slo_s=slo_s, **kwargs)

    def _arrival_times(self, rng: random.Random) -> Iterator[float]:
        for r in self._rows:  # pragma: no cover — __iter__ is overridden
            yield float(r["t"])

    def __iter__(self) -> Iterator[Job]:
        for jid, r in enumerate(self._rows):
            t = float(r["t"])
            g = MODELS[r["model"]]()
            g = dataclasses.replace(g, name=f"{g.name}#{jid}",
                                    arrival_time=t)
            yield Job(job_id=jid, arrival=t, dnng=g,
                      deadline=t + float(r.get("slo_s", self.slo_s)),
                      tier=int(r.get("tier", 0)))


# Alibaba cluster-trace v2018 batch_instance column layout (the subset the
# loader consumes, by header name with positional fallback)
_BI_COLUMNS = ("instance_name", "job_name", "task_type", "status",
               "start_time", "end_time", "plan_cpu", "plan_mem")


@register_arrivals("batch_instance")
class BatchInstanceArrivals(ArrivalProcess):
    """Replay an Alibaba ``batch_instance``-style CSV as a DNN job stream.

    ``source`` is a CSV file path or an iterable of CSV lines with columns
    ``instance_name,job_name,task_type,status,start_time,end_time,
    plan_cpu,plan_mem`` (a header row is detected and skipped; extra
    columns are ignored).  That is the production-trace shape the
    SNIPPETS.md exemplar repo feeds its Firmament / DRF / SLO scheduler
    comparisons, mapped onto this repo's serving model:

    * rows whose ``status`` is not in ``keep_status`` (default
      ``Terminated``) or whose times are unusable are dropped;
    * **arrival** = ``(start_time − t₀ + jitter) × time_scale``.  The
      trace clock has 1 s resolution, so many rows share a second;
      ``jitter=True`` (default) spreads each row uniformly inside its
      source second with the seeded rng — this is the only randomness in
      the replay, and the whole stream is reproducible from (CSV, seed);
    * **model**: each row's requested work ``(end−start) × plan_cpu``
      (CPU-seconds) is rank-mapped onto the ``pool``'s DNNGs sorted by
      total Opr — heavier trace tasks become heavier networks, preserving
      the trace's size mix without inventing sizes;
    * **tier** 0 (latency-critical) when ``plan_cpu ≥ cpu_hi`` (default
      100 = one full core in trace units), else tier 1; the deadline is
      ``arrival + slo_s × (1 + tier)`` — best-effort rows get double
      slack, mirroring the exemplar's SLO classes.
    """

    def __init__(self, source, time_scale: float = 1e-3,
                 slo_s: float = 0.05, seed: int = 0, pool: str = "heavy",
                 keep_status: Sequence[str] = ("Terminated",),
                 jitter: bool = True, cpu_hi: float = 100.0, **kwargs):
        if time_scale <= 0:
            raise ValueError(f"time_scale must be positive, got "
                             f"{time_scale}")
        rows = self._parse(source, set(keep_status))
        if not rows:
            raise ValueError("no usable batch_instance rows "
                             "(all filtered by status/time?)")
        self._trace_rows = rows
        self.time_scale = time_scale
        self.jitter = jitter
        self.cpu_hi = cpu_hi
        self._t0 = min(r[0] for r in rows)
        last = max(r[0] for r in rows)
        # +1 source second: jittered arrivals stay strictly under horizon
        horizon = (last - self._t0 + 1.0) * time_scale
        super().__init__(rate=len(rows) / horizon, horizon=horizon,
                         seed=seed, pool=pool, slo_s=slo_s, **kwargs)

    @staticmethod
    def _parse(source, keep_status):
        if isinstance(source, str):
            with open(source, newline="") as f:
                return BatchInstanceArrivals._parse_file(f, keep_status)
        return BatchInstanceArrivals._parse_file(
            io.StringIO("\n".join(str(line) for line in source)),
            keep_status)

    @staticmethod
    def _parse_file(f, keep_status):
        rows = []
        header = None
        for rec in csv.reader(f):
            if not rec:
                continue
            if header is None and rec[0].strip() == _BI_COLUMNS[0]:
                header = {name.strip(): i for i, name in enumerate(rec)}
                continue
            if header is None:
                header = {name: i for i, name in enumerate(_BI_COLUMNS)}
            try:
                status = rec[header["status"]].strip()
                start = float(rec[header["start_time"]])
                end = float(rec[header["end_time"]])
                cpu = float(rec[header["plan_cpu"]] or 0.0)
            except (KeyError, IndexError, ValueError):
                continue  # malformed row: production traces have them
            if status not in keep_status or end <= start or start <= 0:
                continue
            task_type = rec[header["task_type"]].strip() \
                if header["task_type"] < len(rec) else ""
            rows.append((start, end, cpu, task_type))
        return rows

    def _pool_by_opr(self) -> list[str]:
        names = MODEL_POOLS[self.pool]
        return sorted(names,
                      key=lambda n: (sum(layer.opr
                                         for layer in MODELS[n]().layers), n))

    def _arrival_times(self, rng: random.Random) -> Iterator[float]:
        # pragma-free: __iter__ is overridden, but keep the base surface
        # usable (e.g. for rate/horizon sanity probes)
        for t, _e, _c, _tt in sorted(self._trace_rows):
            yield (t - self._t0) * self.time_scale

    def __iter__(self) -> Iterator[Job]:
        cache = getattr(self, "_job_cache", None)
        if cache is None:
            cache = self._job_cache = list(self._generate_jobs())
        return iter(cache)

    def _generate_jobs(self) -> Iterator[Job]:
        rng = random.Random(self.seed)
        rows = self._trace_rows
        arrivals = []
        for start, _end, _cpu, _tt in rows:
            j = rng.random() if self.jitter else 0.0
            arrivals.append((start - self._t0 + j) * self.time_scale)
        # rank-map work quantiles onto the pool sorted by total Opr
        by_opr = self._pool_by_opr()
        work_order = sorted(range(len(rows)),
                            key=lambda i: ((rows[i][1] - rows[i][0])
                                           * rows[i][2], i))
        model_of = [""] * len(rows)
        for rank, i in enumerate(work_order):
            model_of[i] = by_opr[rank * len(by_opr) // len(rows)]
        order = sorted(range(len(rows)), key=lambda i: (arrivals[i], i))
        for jid, i in enumerate(order):
            t = arrivals[i]
            g = MODELS[model_of[i]]()
            g = dataclasses.replace(g, name=f"{g.name}#{jid}",
                                    arrival_time=t)
            tier = 0 if rows[i][2] >= self.cpu_hi else 1
            yield Job(job_id=jid, arrival=t, dnng=g,
                      deadline=t + self.slo_s * (1 + tier), tier=tier)


def synth_batch_instance_rows(n: int, seed: int = 0,
                              span_s: float = 600.0,
                              burstiness: float = 0.3) -> list[str]:
    """Generate an in-memory Alibaba-style ``batch_instance`` CSV.

    Bench and test helper: header + ``n`` data rows shaped like the real
    trace (epoch-offset integer seconds, bursty arrivals, lognormal-ish
    durations, ``plan_cpu`` in trace centi-core units, a sprinkling of
    non-``Terminated`` rows the loader must drop) without committing a
    multi-MB CSV.  Fully deterministic from (``n``, ``seed``).
    """
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    rng = random.Random(seed)
    lines = [",".join(_BI_COLUMNS)]
    t = 86400.0  # arbitrary epoch offset: exercises t0 normalization
    mean_gap = span_s / n
    for i in range(n):
        if rng.random() < burstiness:
            t += rng.expovariate(8.0 / mean_gap)   # burst: 8x rate
        else:
            t += rng.expovariate(1.0 / mean_gap)
        dur = max(1.0, rng.lognormvariate(3.0, 1.0))
        cpu = rng.choice((50, 50, 100, 100, 100, 200, 400, 800))
        mem = round(rng.uniform(0.1, 4.0), 2)
        status = "Terminated" if rng.random() >= 0.05 else \
            rng.choice(("Failed", "Running"))
        lines.append(f"instance_{i},j_{i // 4},{1 + i % 12},{status},"
                     f"{int(t)},{int(t + dur)},{cpu},{mem}")
    return lines
