"""Fleet-of-arrays dispatch: many systolic arrays, one arrival stream.

One 128×128 array saturates quickly under open-loop load; a serving fleet
runs N of them behind a dispatcher.  This module provides the two classic
randomized-load-balancing dispatchers plus the per-array bookkeeping the
traffic simulator drives:

* :class:`JoinShortestQueue` (``"jsq"``) — route to the array with the
  fewest in-system jobs (queued + executing); optimal information, O(N)
  per decision;
* :class:`PowerOfTwoChoices` (``"p2c"``) — sample two arrays uniformly,
  route to the less loaded (Mitzenmacher's exponential-improvement
  result); O(1) information per decision, the practical choice at fleet
  scale.

:class:`ArrayNode` wraps one :class:`repro.core.scheduler.DynamicScheduler`
with admission control (``max_concurrent`` jobs co-resident on the array)
and a bounded FIFO wait queue (``queue_cap``); overflow is rejected — shed
load is an SLA miss, not a silent drop.
"""

from __future__ import annotations

import abc
import dataclasses
import random
from typing import Callable, Sequence

from repro.core.partition import ArrayShape
from repro.core.registry import Registry
from repro.core.scheduler import DynamicScheduler, StageModel, TimeFn
from repro.traffic.arrivals import Job


class ArrayNode:
    """One systolic array in the fleet: scheduler + admission + wait queue."""

    def __init__(self, index: int, array: ArrayShape, time_fn: TimeFn,
                 stage: StageModel | None, policy,
                 max_concurrent: int, queue_cap: int,
                 on_complete: Callable[["ArrayNode", str, float], None],
                 on_submit: Callable[[Job, float], None] | None = None,
                 keep_trace: bool = False):
        if max_concurrent < 1 or queue_cap < 0:
            raise ValueError(f"need max_concurrent >= 1 (got {max_concurrent})"
                             f" and queue_cap >= 0 (got {queue_cap})")
        self.index = index
        self.max_concurrent = max_concurrent
        self.queue_cap = queue_cap
        self.queue: list[Job] = []
        self._notify_done = on_complete
        self._notify_submit = on_submit or (lambda job, t: None)
        self.scheduler = DynamicScheduler(
            array, time_fn, stage=stage, policy=policy,
            on_complete=self._job_done, keep_trace=keep_trace)

    @property
    def in_system(self) -> int:
        """Jobs on this array: executing + waiting (the dispatch load key)."""
        return self.scheduler.n_active + len(self.queue)

    def offer(self, job: Job) -> str:
        """Admission control at ``job.arrival``.

        Returns ``"run"`` (submitted to the array now), ``"queued"``
        (parked in the bounded FIFO), or ``"rejected"`` (queue full —
        load shed, counted as a deadline miss)."""
        if self.scheduler.n_active < self.max_concurrent:
            self.scheduler.submit(job.dnng)
            self._notify_submit(job, job.arrival)
            return "run"
        if len(self.queue) < self.queue_cap:
            self.queue.append(job)
            return "queued"
        return "rejected"

    def _job_done(self, tenant: str, t: float) -> None:
        self._notify_done(self, tenant, t)
        # completion freed a co-residency slot: promote the head-of-line job
        while self.queue and self.scheduler.n_active < self.max_concurrent:
            job = self.queue.pop(0)
            g = dataclasses.replace(job.dnng, arrival_time=t)
            self.scheduler.submit(g)
            self._notify_submit(job, t)


# ---------------------------------------------------------------------------
# dispatchers
# ---------------------------------------------------------------------------

class Dispatcher(abc.ABC):
    """Pick a target array for an arriving job from in-system loads."""

    name: str = ""

    @abc.abstractmethod
    def choose(self, loads: Sequence[int], rng: random.Random) -> int:
        """Index of the array to route to (``loads[i]`` = jobs in system)."""


_REGISTRY = Registry("dispatcher")


def register_dispatcher(name: str):
    return _REGISTRY.register(name)


def list_dispatchers() -> list[str]:
    return _REGISTRY.names()


def resolve_dispatcher(dispatch) -> Dispatcher:
    return _REGISTRY.resolve(dispatch, Dispatcher)


@register_dispatcher("jsq")
class JoinShortestQueue(Dispatcher):
    """Full-information balancing: fewest in-system jobs, ties → lowest
    index (deterministic)."""

    def choose(self, loads: Sequence[int], rng: random.Random) -> int:
        return min(range(len(loads)), key=lambda i: (loads[i], i))


@register_dispatcher("p2c")
class PowerOfTwoChoices(Dispatcher):
    """Sample two distinct arrays, keep the shorter queue (Mitzenmacher
    1996); collapses to the single array when the fleet has one."""

    def choose(self, loads: Sequence[int], rng: random.Random) -> int:
        if len(loads) == 1:
            return 0
        i, j = rng.sample(range(len(loads)), 2)
        if loads[j] < loads[i] or (loads[j] == loads[i] and j < i):
            return j
        return i
